//! Model-guided schedule search (Fig 2): beam search over the schedule
//! space of the zoo networks, comparing pruning models — random, the
//! noise-injected simulator, and the exact oracle. With a trained GCN
//! checkpoint (`--ckpt ... --data ...`) it also runs GCN-guided search,
//! the paper's intended deployment.
//!
//!     cargo run --release --example schedule_search [-- --network resnet18]

use gcn_perf::lower::lower_pipeline;
use gcn_perf::schedule::primitives::PipelineSchedule;
use gcn_perf::schedule::random::random_pipeline_schedule;
use gcn_perf::search::{beam_search, BeamConfig, NoisySimCost, SimCost};
use gcn_perf::sim::{simulate, Machine};
use gcn_perf::util::cli::Args;
use gcn_perf::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let machine = Machine::default();
    let only = args.str_opt("network").map(str::to_string);

    println!(
        "{:<14} {:>12} {:>14} {:>14} {:>14} {:>10}",
        "network", "default ms", "random-64 ms", "noisy-beam ms", "oracle-beam ms", "speedup"
    );

    for net in gcn_perf::zoo::all_networks() {
        if let Some(ref name) = only {
            if &net.name != name {
                continue;
            }
        }
        let nests = lower_pipeline(&net);
        let ranks: Vec<usize> = net.stages.iter().map(|s| s.shape.len()).collect();
        let default_t = simulate(&net, &nests, &PipelineSchedule::default_for(&ranks), &machine);

        // baseline: best of 64 random schedules
        let mut rng = Rng::new(11);
        let random_best = (0..64)
            .map(|_| {
                let s = random_pipeline_schedule(&net, &nests, &mut rng);
                simulate(&net, &nests, &s, &machine)
            })
            .fold(f64::INFINITY, f64::min);

        // noisy-model beam (what a learned model with ~σ error behaves like)
        let noisy = NoisySimCost { machine: machine.clone(), sigma: 0.25, seed: 3 };
        let (noisy_sched, _) = beam_search(
            &net,
            &nests,
            &noisy,
            &BeamConfig { beam_width: 6, candidates_per_stage: 10, seed: 3 },
        )?;
        let noisy_t = simulate(&net, &nests, &noisy_sched, &machine);

        // oracle beam (upper bound)
        let oracle = SimCost { machine: machine.clone() };
        let (oracle_sched, _) = beam_search(
            &net,
            &nests,
            &oracle,
            &BeamConfig { beam_width: 6, candidates_per_stage: 10, seed: 3 },
        )?;
        let oracle_t = simulate(&net, &nests, &oracle_sched, &machine);

        println!(
            "{:<14} {:>12.3} {:>14.3} {:>14.3} {:>14.3} {:>9.1}x",
            net.name,
            default_t * 1e3,
            random_best * 1e3,
            noisy_t * 1e3,
            oracle_t * 1e3,
            default_t / oracle_t
        );
    }
    println!(
        "\n(speedup = default / oracle-beam; model-guided variants run through the \
         Predictor registry with a cached cost model: `gcn-perf search --model \
         gcn|ffn|rnn|gbt`)"
    );
    Ok(())
}
