//! Fig 9 standalone driver: pairwise ranking of schedules on the nine
//! real-world networks with a trained GCN checkpoint.
//!
//!     cargo run --release --example rank_networks -- \
//!         --data data/dataset.bin --ckpt data/gcn.ckpt [--schedules 100]
//!
//! Without --ckpt it falls back to untrained parameters, which documents
//! the null baseline (≈50% ranking accuracy = coin flip).

use gcn_perf::eval::harness;
use gcn_perf::eval::ranking::{rank_networks, RankResult};
use gcn_perf::runtime::{load_backend, Backend, Params};
use gcn_perf::sim::Machine;
use gcn_perf::util::cli::Args;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let rt = load_backend(Path::new("artifacts"), false)?;

    let (params, stats) = match (args.str_opt("ckpt"), args.str_opt("data")) {
        (Some(ckpt), Some(data)) => {
            let params = Params::load(Path::new(ckpt), rt.manifest())?;
            let ds = gcn_perf::dataset::store::load(Path::new(data))?;
            let (train_ds, _) = ds.split(0.1, 1234);
            (params, train_ds.stats.clone().unwrap())
        }
        _ => {
            eprintln!("no --ckpt/--data given: using UNTRAINED params (expect ~50%)");
            // identity-ish stats from a tiny generated set
            let ds = gcn_perf::dataset::builder::build_dataset(
                &gcn_perf::dataset::builder::DataGenConfig {
                    n_pipelines: 10,
                    schedules_per_pipeline: 4,
                    seed: 2,
                    ..Default::default()
                },
            );
            (rt.init_params(42), ds.stats.clone().unwrap())
        }
    };

    let rows = harness::run_fig9(
        rt.as_ref(),
        &params,
        &stats,
        &Machine::default(),
        args.usize_or("schedules", 100),
        args.u64_or("seed", 5),
    )?;
    let (rows, avg) = rank_networks(rows);
    println!("{}", RankResult::header());
    for r in &rows {
        println!("{}", r.row());
    }
    println!("{:<14} {:>10} {:>10} {:>10.1}%", "AVERAGE", "", "", avg);
    println!("(paper: 65–90% per network, ~75% average)");
    Ok(())
}
