//! Fig 9 standalone driver: pairwise ranking of schedules on the zoo
//! networks (the paper's nine + the >48-stage resnet50) with a trained
//! GCN bundle.
//!
//!     cargo run --release --example rank_networks -- \
//!         --bundle data/gcn.bundle [--schedules 100]
//!
//! Without --bundle it falls back to an untrained session, which documents
//! the null baseline (≈50% ranking accuracy = coin flip).

use gcn_perf::eval::harness;
use gcn_perf::eval::ranking::{rank_networks, RankResult};
use gcn_perf::predictor::{GcnPredictor, PredictService};
use gcn_perf::runtime::{load_backend, Backend};
use gcn_perf::sim::Machine;
use gcn_perf::util::cli::Args;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();

    let gcn = match args.str_opt("bundle").or_else(|| args.str_opt("ckpt")) {
        Some(bundle) => GcnPredictor::load(Path::new(bundle))?,
        None => {
            eprintln!("no --bundle given: using an UNTRAINED session (expect ~50%)");
            let rt = load_backend(Path::new("artifacts"), false)?.warn_to_stderr();
            // identity-ish stats from a tiny generated set
            let ds = gcn_perf::dataset::builder::build_dataset(
                &gcn_perf::dataset::builder::DataGenConfig {
                    n_pipelines: 10,
                    schedules_per_pipeline: 4,
                    seed: 2,
                    ..Default::default()
                },
            );
            let params = rt.init_params(42);
            GcnPredictor::new(rt, params, ds.stats.clone().unwrap())
        }
    };
    // ranking traffic rides the serving layer, like every other consumer
    let gcn = PredictService::with_defaults(Arc::new(gcn));

    let rows = harness::run_fig9(
        &gcn,
        &Machine::default(),
        args.usize_or("schedules", 100),
        args.u64_or("seed", 5),
    )?;
    let (rows, avg) = rank_networks(rows);
    println!("{}", RankResult::header());
    for r in &rows {
        println!("{}", r.row());
    }
    println!("{:<14} {:>10} {:>10} {:>10.1}%", "AVERAGE", "", "", avg);
    println!("(paper: 65–90% per network, ~75% average)");
    Ok(())
}
