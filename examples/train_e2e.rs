//! End-to-end driver (DESIGN.md "End-to-end validation"): the full Fig 4
//! data pipeline + training + the paper's headline comparison, on a real
//! (scaled-down) workload:
//!
//!   1. generate random ONNX-style pipelines, lower, sample schedules,
//!      benchmark them on the simulated 18-core Xeon;
//!   2. train the GCN through the Backend trait (native engine by
//!      default), logging the loss curve;
//!   3. fit the Halide-FFN and TVM-GBT baselines on the same data;
//!   4. report Fig 8 (avg/max error, R²) and Fig 9 (ranking) numbers.
//!
//!     cargo run --release --example train_e2e [-- --pipelines 300 --schedules 24 --epochs 30]
//!
//! Results from a full run are recorded in EXPERIMENTS.md.

use gcn_perf::dataset::builder::{build_dataset, DataGenConfig};
use gcn_perf::eval::harness;
use gcn_perf::eval::metrics::RegressionMetrics;
use gcn_perf::eval::ranking::{rank_networks, RankResult};
use gcn_perf::predictor::{GcnPredictor, PredictService, Predictor};
use gcn_perf::runtime::{load_backend, Backend};
use gcn_perf::sim::Machine;
use gcn_perf::train::{train, TrainConfig};
use gcn_perf::util::cli::Args;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).unwrap_or_default();
    let n_pipelines = args.usize_or("pipelines", 300);
    let n_schedules = args.usize_or("schedules", 24);
    let epochs = args.usize_or("epochs", 30);
    let fig9_schedules = args.usize_or("fig9-schedules", 80);
    // paper lr is 0.0075; 0.03 converges ~1.4x better on our (smaller)
    // dataset within the epoch budget — see EXPERIMENTS.md §Perf notes
    let lr = args.f64_or("lr", 0.03) as f32;

    // ---- 1. dataset (Fig 4)
    let t0 = Instant::now();
    let cfg = DataGenConfig {
        n_pipelines,
        schedules_per_pipeline: n_schedules,
        seed: 42,
        ..Default::default()
    };
    eprintln!("[1/4] generating {} x {} schedules...", n_pipelines, n_schedules);
    let ds = build_dataset(&cfg);
    let gen_secs = t0.elapsed().as_secs_f64();
    let (train_ds, test_ds) = ds.split(0.1, 1234);
    println!(
        "dataset: {} samples ({} pipelines) in {:.1}s — train {}, test {}",
        ds.len(),
        ds.num_pipelines(),
        gen_secs,
        train_ds.len(),
        test_ds.len()
    );

    // ---- 2. train the GCN through the Backend trait
    let rt = load_backend(Path::new("artifacts"), true)?.warn_to_stderr();
    eprintln!("[2/4] training GCN ({epochs} epochs, batch 32, Adagrad, {} backend)...", rt.name());
    let t1 = Instant::now();
    let result = train(
        rt.as_ref(),
        &train_ds,
        &test_ds,
        &TrainConfig { epochs, seed: 7, patience: 10, lr, ..Default::default() },
    )?;
    println!(
        "trained in {:.1}s; loss curve (first→last): {}",
        t1.elapsed().as_secs_f64(),
        result
            .history
            .iter()
            .step_by((result.history.len() / 8).max(1))
            .map(|h| format!("{:.3}", h.train_loss))
            .collect::<Vec<_>>()
            .join(" → ")
    );

    // wrap the trained model in a Predictor session served through the
    // coalescing PredictService; everything downstream (Fig 8, Fig 9, the
    // saved bundle) is a client of this one serving seam — exactly what
    // `gcn-perf serve` runs long-lived
    let session = GcnPredictor::new(rt, result.params.clone(), train_ds.stats.clone().unwrap());
    let gcn = PredictService::with_defaults(Arc::new(session));

    // ---- 3 + 4. baselines + Fig 8
    eprintln!("[3/4] fitting baselines + Fig 8 comparison...");
    let rows = harness::run_fig8(&gcn, &train_ds, &test_ds, 25, true)?;
    println!("\nFig 8 — prediction quality on the unseen test split");
    println!("{}", RegressionMetrics::header());
    for r in &rows {
        println!("{}", r.row());
    }
    println!(
        "error reduction: {:.2}x vs halide-ffn, {:.2}x vs tvm-gbt (paper: 7.75x / 12x)",
        rows[1].avg_error_pct / rows[0].avg_error_pct,
        rows[2].avg_error_pct / rows[0].avg_error_pct
    );

    // ---- Fig 9 on the zoo networks
    eprintln!("[4/4] Fig 9 ranking on the 9 real-world networks...");
    let fig9 = harness::run_fig9(&gcn, &Machine::default(), fig9_schedules, 5)?;
    let (fig9, avg) = rank_networks(fig9);
    println!("\nFig 9 — pairwise ranking accuracy");
    println!("{}", RankResult::header());
    for r in &fig9 {
        println!("{}", r.row());
    }
    println!("{:<14} {:>10} {:>10} {:>10.1}%  (paper avg ≈75%)", "AVERAGE", "", "", avg);

    harness::write_report(Path::new("results/train_e2e.json"), &rows, &fig9, avg)?;
    gcn.save(Path::new("results/gcn.bundle"))?;
    println!("\nreport: results/train_e2e.json   bundle: results/gcn.bundle");
    Ok(())
}
