//! Quickstart: the paper's §II linear-layer example end to end —
//! build a pipeline, apply Halide-style schedules, simulate-benchmark them,
//! featurize, and run the GCN performance model (the native backend needs
//! no artifacts and no external runtime).
//!
//!     cargo run --release --example quickstart

use gcn_perf::dataset::builder::sample_from_schedule;
use gcn_perf::ir::op::{Op, OpAttrs, OpKind};
use gcn_perf::ir::pipeline::Pipeline;
use gcn_perf::lower::lower_pipeline;
use gcn_perf::predictor::{GcnPredictor, Predictor};
use gcn_perf::runtime::{load_backend, Backend};
use gcn_perf::schedule::primitives::{ComputeLoc, PipelineSchedule};
use gcn_perf::schedule::random::random_pipeline_schedule;
use gcn_perf::sim::{simulate, Machine};
use gcn_perf::util::rng::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // --- the paper's linear layer: Y = XW + B as two Halide stages
    let mut p = Pipeline::new("linear_layer");
    let x = p.add_input(vec![64, 1024]); // batch x inputs
    let bias = p.add_input(vec![64, 16]);
    let mut gemm = OpAttrs::default();
    gemm.out_channels = 16;
    let mm = p
        .add_stage("matrix_mul", Op::with_attrs(OpKind::Gemm, gemm), vec![x])
        .unwrap();
    p.add_stage("add_bias", Op::new(OpKind::Add), vec![mm, bias]).unwrap();
    p.validate().expect("valid pipeline");
    println!("pipeline '{}': {} stages, depth {}", p.name, p.num_stages(), p.depth());

    let nests = lower_pipeline(&p);
    let machine = Machine::default();

    // --- schedule it three ways (§II-A)
    let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
    let default = PipelineSchedule::default_for(&ranks);

    let mut vectorized = default.clone();
    vectorized.stages[0].vector_width = 8; // vectorize matrix_mul inner loop
    vectorized.stages[0].parallel_depth = 1; // parallel over rows
    vectorized.stages[1].vector_width = 8;

    let mut tiled = vectorized.clone();
    tiled.stages[0].tile = vec![8, 8]; // blocked matmul
    tiled.stages[0].compute = ComputeLoc::At { consumer: 1, level: 1 };

    println!("\nschedule                 simulated runtime");
    for (name, sched) in [
        ("compute_root scalar", &default),
        ("vectorize + parallel", &vectorized),
        ("+ tiling + compute_at", &tiled),
    ] {
        let t = simulate(&p, &nests, sched, &machine);
        println!("{:<24} {:>10.1} µs", name, t * 1e6);
    }

    // --- featurize + benchmark like the dataset pipeline does
    let mut rng = Rng::new(0);
    let sample = sample_from_schedule(&p, &nests, &vectorized, &machine, 0, 0, &mut rng);
    println!(
        "\nfeaturized: {} stages x ({} invariant + {} dependent features)",
        sample.n_stages,
        gcn_perf::constants::INV_DIM,
        gcn_perf::constants::DEP_DIM
    );
    println!(
        "benchmark runs (10x, noisy): mean {:.1} µs, std {:.2} µs",
        sample.mean_runtime() * 1e6,
        sample.std_runtime() * 1e6
    );

    // --- GCN inference through a Predictor session (native backend by
    // default; PJRT if built with `--features pjrt` and artifacts exist).
    // The session owns backend + params + stats and is what `gcn-perf
    // train` saves as a single-file bundle.
    let rt = load_backend(Path::new("artifacts"), false)?.warn_to_stderr();
    let params = rt.init_params(42); // untrained — see examples/train_e2e.rs
    let mut samples = vec![sample];
    for i in 1..6 {
        let s = random_pipeline_schedule(&p, &nests, &mut rng);
        samples.push(sample_from_schedule(&p, &nests, &s, &machine, 0, i, &mut rng));
    }
    let mut ds = gcn_perf::dataset::sample::Dataset { samples, stats: None };
    ds.fit_stats();
    let stats = ds.stats.clone().unwrap();
    let session = GcnPredictor::new(rt, params, stats);
    let refs: Vec<&gcn_perf::dataset::sample::GraphSample> = ds.samples.iter().collect();
    let preds = session.predict(&refs)?;
    println!("\nGCN (untrained, {} backend):", session.backend().name());
    for (s, pred) in ds.samples.iter().zip(&preds) {
        println!(
            "  schedule {}: measured {:>9.1} µs   predicted {:>9.1} µs",
            s.schedule_id,
            s.mean_runtime() * 1e6,
            pred * 1e6
        );
    }

    // the session round-trips through a single-file model bundle; bundles
    // always reload onto the native backend, so compare at the documented
    // pjrt/native parity tolerance (bit-exact in the default build)
    let bundle = std::env::temp_dir().join("quickstart_gcn.bundle");
    session.save(&bundle)?;
    let reloaded = GcnPredictor::load(&bundle)?;
    for (a, b) in session.predict(&refs)?.iter().zip(&reloaded.predict(&refs)?) {
        assert!((a - b).abs() <= 1e-3 * a.abs().max(1e-12), "round trip drift: {a} vs {b}");
    }
    println!("bundle round trip OK: {}", bundle.display());
    std::fs::remove_file(&bundle).ok();
    println!("(train with `gcn-perf train` or examples/train_e2e for real predictions)");
    Ok(())
}
