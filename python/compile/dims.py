"""Model dimensions — MUST match rust/src/constants.rs.

`aot.py` writes these into artifacts/manifest.json; the rust runtime
cross-checks them at load time so a drift fails fast.
"""

INV_DIM = 48       # schedule-invariant features per stage
DEP_DIM = 88       # schedule-dependent (+compound) features per stage
EMB_INV = 32       # invariant embedding width (Fig 5)
EMB_DEP = 48       # dependent embedding width (Fig 5)
NODE_DIM = EMB_INV + EMB_DEP   # node embedding width (80)
HIDDEN = NODE_DIM  # conv layer width
N_CONV = 2         # graph conv layers (paper sweeps 0..8, picks 2)
READOUT = NODE_DIM * (N_CONV + 1)  # sum-pool readout width (Fig 7)
MAX_NODES = 48     # graphs padded to this many stages
BATCH = 32         # AOT batch size

# Adagrad (§III-C)
LEARNING_RATE = 0.0075
WEIGHT_DECAY = 0.0001
ADAGRAD_EPS = 1e-10
