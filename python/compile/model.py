"""L2: the GCN performance model (§III), in JAX, calling the L1 Pallas
kernels. Lowered once by `aot.py`; never imported at runtime by the rust
coordinator.

Architecture (Fig 7):
  features --(Fig 5 embed)--> E0 --conv--> E1 --conv--> E2
  F = [sumpool(E0) ; sumpool(E1) ; sumpool(E2)]   (masked sum over stages)
  z = F @ w_out + b_out            (predicted *log* runtime)

The model predicts log-runtime; ŷ = exp(z). The paper's loss is built on
the ratio ŷ/ȳ, so working in log space is the identical objective with
better conditioning (DESIGN.md §Paper-faithfulness).

Loss (§III-C):  ℓ = mean over batch of  α·β̂·ξ  with
  ξ = |ŷ/ȳ − 1| = |exp(z − log ȳ) − 1|   (Property 1, typo-corrected)
  α = min_runtime(pipeline)/ȳ            (Property 2 — computed by rust)
  β̂ = normalized 1/std of the runs       (Property 3 — computed by rust)
rust passes w = α·β̂ per sample; the HLO computes ξ and the weighted mean.
"""

from collections import OrderedDict

import jax
import jax.numpy as jnp

from . import dims
from .kernels import gcn_conv as kernels
from .kernels import ref


# --------------------------------------------------------------- parameters
def param_specs(n_conv: int = dims.N_CONV):
    """Ordered (name, shape) list — the flat calling convention shared with
    the rust runtime (manifest.json)."""
    specs = [
        ("w_inv", (dims.INV_DIM, dims.EMB_INV)),
        ("b_inv", (dims.EMB_INV,)),
        ("w_dep", (dims.DEP_DIM, dims.EMB_DEP)),
        ("b_dep", (dims.EMB_DEP,)),
    ]
    for k in range(n_conv):
        specs += [
            (f"conv{k}_w", (dims.HIDDEN, dims.HIDDEN)),
            (f"conv{k}_b", (dims.HIDDEN,)),
            (f"conv{k}_scale", (dims.HIDDEN,)),
            (f"conv{k}_shift", (dims.HIDDEN,)),
        ]
    readout = dims.NODE_DIM * (n_conv + 1)
    specs += [("w_out", (readout, 1)), ("b_out", (1,))]
    return specs


def init_params(key, n_conv: int = dims.N_CONV):
    """He init for weights, zeros/ones for biases/scales; order matches
    param_specs."""
    params = OrderedDict()
    for name, shape in param_specs(n_conv):
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif len(shape) == 1:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(
                2.0 / fan_in
            )
    return params


# ------------------------------------------------------------------ forward
def graph_batch_norm(h, mask, scale, shift, eps=1e-5):
    """Normalization inside the conv block (Fig 6 "batch-normalization").

    True batch-norm needs running statistics, which a stateless AOT artifact
    cannot carry — and computing the stats per batch makes every prediction
    depend on which samples share its batch (large train/eval skew, measured
    in EXPERIMENTS.md §Perf notes). We therefore normalize per *node* over
    the channel dim (LayerNorm-style) with the same learnable scale/shift:
    batch-independent, stateless, deterministic. `mask` is unused but kept
    in the signature for drop-in compatibility. See DESIGN.md
    §Paper-faithfulness.
    """
    del mask
    mean = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mean) ** 2, axis=-1, keepdims=True)
    return ((h - mean) * jax.lax.rsqrt(var + eps)) * scale + shift


def forward(params, inv, dep, adj, mask, n_conv: int = dims.N_CONV,
            use_pallas: bool = True):
    """Predict log-runtime for a batch of graphs.

    inv  [B, N, INV_DIM]  normalized schedule-invariant features
    dep  [B, N, DEP_DIM]  normalized schedule-dependent features
    adj  [B, N, N]        row-normalized adjacency with self loops (A')
    mask [B, N]           1.0 for real stages, 0.0 for padding
    returns z [B] (log seconds)
    """
    k_embed = kernels.embed if use_pallas else ref.embed_ref
    k_conv = kernels.gcn_conv if use_pallas else ref.gcn_conv_ref

    m = mask[:, :, None]
    e = k_embed(inv, dep, params["w_inv"], params["b_inv"],
                params["w_dep"], params["b_dep"]) * m
    pooled = [jnp.sum(e, axis=1)]  # F(0)
    for k in range(n_conv):
        h = k_conv(adj, e, params[f"conv{k}_w"], params[f"conv{k}_b"])
        h = graph_batch_norm(h, m, params[f"conv{k}_scale"], params[f"conv{k}_shift"])
        e = jnp.maximum(h, 0.0) * m
        pooled.append(jnp.sum(e, axis=1))  # F(k)
    feat = jnp.concatenate(pooled, axis=-1)  # [B, READOUT]
    z = feat @ params["w_out"] + params["b_out"]
    return z[:, 0]


# --------------------------------------------------------------------- loss
def loss_fn(params, inv, dep, adj, mask, log_y, weight, sample_mask,
            n_conv: int = dims.N_CONV, use_pallas: bool = True):
    """Weighted relative-error loss (§III-C). `weight` = α·β̂ from rust;
    `sample_mask` zeroes padded batch rows."""
    z = forward(params, inv, dep, adj, mask, n_conv, use_pallas)
    d = z - log_y
    # ξ = |exp(d) − 1|, linearized beyond |d| = 3 so a badly-off prediction
    # cannot explode the step yet still receives gradient (slope e³ ≈ 20)
    dc = jnp.clip(d, -3.0, 3.0)
    xi = jnp.abs(jnp.expm1(dc)) + jnp.abs(d - dc) * jnp.exp(3.0)
    w = weight * sample_mask
    return jnp.sum(w * xi) / jnp.maximum(jnp.sum(w), 1e-6)


# --------------------------------------------------------------- train step
def train_step(params, accum, inv, dep, adj, mask, log_y, weight, sample_mask,
               n_conv: int = dims.N_CONV, use_pallas: bool = True,
               lr: float = dims.LEARNING_RATE,
               weight_decay: float = dims.WEIGHT_DECAY):
    """One Adagrad step (§III-C: Adagrad, lr 0.0075, weight decay 1e-4).

    Functional: (params, accum, batch) -> (params', accum', loss).
    """
    loss, grads = jax.value_and_grad(loss_fn)(
        params, inv, dep, adj, mask, log_y, weight, sample_mask,
        n_conv, use_pallas)
    new_params = OrderedDict()
    new_accum = OrderedDict()
    for name in params:
        g = grads[name] + weight_decay * params[name]
        a = accum[name] + g * g
        new_params[name] = params[name] - lr * g / (jnp.sqrt(a) + dims.ADAGRAD_EPS)
        new_accum[name] = a
    return new_params, new_accum, loss


# ------------------------------------------------- flat AOT entry points
def infer_flat(n_conv: int = dims.N_CONV, use_pallas: bool = True):
    """Returns fn(*params, inv, dep, adj, mask) -> (z,) with flat args in
    param_specs order — the artifact signature."""
    names = [n for n, _ in param_specs(n_conv)]

    def fn(*args):
        params = OrderedDict(zip(names, args[: len(names)]))
        inv, dep, adj, mask = args[len(names):]
        return (forward(params, inv, dep, adj, mask, n_conv, use_pallas),)

    return fn


def train_flat(n_conv: int = dims.N_CONV, use_pallas: bool = True):
    """Returns fn(*params, *accum, inv, dep, adj, mask, log_y, weight,
    sample_mask, lr) -> (*params', *accum', loss). `lr` is a runtime scalar
    input so the rust coordinator can tune/schedule it without re-AOT."""
    names = [n for n, _ in param_specs(n_conv)]
    np_ = len(names)

    def fn(*args):
        params = OrderedDict(zip(names, args[:np_]))
        accum = OrderedDict(zip(names, args[np_: 2 * np_]))
        inv, dep, adj, mask, log_y, weight, sample_mask, lr = args[2 * np_:]
        new_p, new_a, loss = train_step(
            params, accum, inv, dep, adj, mask, log_y, weight, sample_mask,
            n_conv, use_pallas, lr=lr)
        return tuple(new_p.values()) + tuple(new_a.values()) + (loss,)

    return fn
