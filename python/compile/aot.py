"""AOT compile path: lower the L2 model to HLO *text* artifacts the rust
runtime loads via PJRT.

HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits:
  gcn_infer.hlo.txt   forward pass, batch=BATCH
  gcn_train.hlo.txt   Adagrad train step, batch=BATCH
  gcn_infer_l{K}.hlo.txt / gcn_train_l{K}.hlo.txt for the §III-C conv-depth
                      ablation sweep (when --ablation is passed)
  manifest.json       dims + parameter shapes/order for the rust side
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dims, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def batch_specs(batch, n):
    return [
        spec((batch, n, dims.INV_DIM)),   # inv
        spec((batch, n, dims.DEP_DIM)),   # dep
        spec((batch, n, n)),              # adj (A')
        spec((batch, n)),                 # mask
    ]


def target_specs(batch):
    return [
        spec((batch,)),  # log_y
        spec((batch,)),  # weight = alpha * beta_norm
        spec((batch,)),  # sample_mask
        spec(()),        # lr (runtime-tunable)
    ]


def lower_infer(n_conv, batch, n, use_pallas=True):
    p_specs = [spec(s) for _, s in model.param_specs(n_conv)]
    args = p_specs + batch_specs(batch, n)
    return jax.jit(model.infer_flat(n_conv, use_pallas), keep_unused=True).lower(*args)


def lower_train(n_conv, batch, n, use_pallas=True):
    p_specs = [spec(s) for _, s in model.param_specs(n_conv)]
    args = p_specs + p_specs + batch_specs(batch, n) + target_specs(batch)
    return jax.jit(model.train_flat(n_conv, use_pallas), keep_unused=True).lower(*args)


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>10} chars  {path}")


def manifest(n_conv, batch, n):
    return {
        "inv_dim": dims.INV_DIM,
        "dep_dim": dims.DEP_DIM,
        "node_dim": dims.NODE_DIM,
        "hidden": dims.HIDDEN,
        "n_conv": n_conv,
        "readout": dims.NODE_DIM * (n_conv + 1),
        "max_nodes": n,
        "batch": batch,
        "learning_rate": dims.LEARNING_RATE,
        "weight_decay": dims.WEIGHT_DECAY,
        "params": [
            {"name": name, "shape": list(shape)}
            for name, shape in model.param_specs(n_conv)
        ],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=dims.BATCH)
    ap.add_argument("--nodes", type=int, default=dims.MAX_NODES)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference instead of the "
                    "Pallas kernels (perf A/B)")
    ap.add_argument("--ablation", action="store_true",
                    help="also emit conv-depth ablation artifacts (0/1/4)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    use_pallas = not args.no_pallas
    b, n = args.batch, args.nodes

    write(os.path.join(args.out_dir, "gcn_infer.hlo.txt"),
          to_hlo_text(lower_infer(dims.N_CONV, b, n, use_pallas)))
    write(os.path.join(args.out_dir, "gcn_train.hlo.txt"),
          to_hlo_text(lower_train(dims.N_CONV, b, n, use_pallas)))

    man = manifest(dims.N_CONV, b, n)
    if args.ablation:
        layers = [0, 1, 4]
        man["ablation_layers"] = layers
        for k in layers:
            write(os.path.join(args.out_dir, f"gcn_infer_l{k}.hlo.txt"),
                  to_hlo_text(lower_infer(k, b, n, use_pallas)))
            write(os.path.join(args.out_dir, f"gcn_train_l{k}.hlo.txt"),
                  to_hlo_text(lower_train(k, b, n, use_pallas)))
            man[f"params_l{k}"] = [
                {"name": name, "shape": list(shape)}
                for name, shape in model.param_specs(k)
            ]

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    print(f"wrote manifest  {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
