"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: `pytest python/tests` checks the
Pallas kernels (interpret mode) against these for random inputs, and the
L2 model is free to call either implementation (`use_pallas` flag).
"""

import jax.numpy as jnp


def embed_ref(inv, dep, w_inv, b_inv, w_dep, b_dep):
    """Initial node embeddings (Fig 5).

    inv: [B, N, INV_DIM], dep: [B, N, DEP_DIM]
    returns [B, N, EMB_INV + EMB_DEP] = relu(inv@w_inv+b_inv) ++ relu(dep@w_dep+b_dep)
    """
    e_inv = jnp.maximum(inv @ w_inv + b_inv, 0.0)
    e_dep = jnp.maximum(dep @ w_dep + b_dep, 0.0)
    return jnp.concatenate([e_inv, e_dep], axis=-1)


def gcn_conv_ref(adj, e, w, b):
    """One graph-convolution aggregate-update (§III-B, Kipf-Welling form):

        out = A' . (E . W) + b

    adj: [B, N, N] row-normalized adjacency with self loops (A')
    e:   [B, N, F] current node embeddings
    w:   [F, G], b: [G]
    returns [B, N, G]
    """
    return adj @ (e @ w) + b
