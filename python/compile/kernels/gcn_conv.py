"""L1 Pallas kernels: the GCN hot spots.

Two kernels, each gridded over the batch dimension (one graph per grid
step — BlockSpec keeps that graph's adjacency + embeddings resident in
VMEM while both matmuls run on the MXU):

* ``gcn_conv``: fused aggregate-update  ``out = A' @ (E @ W) + b``
  (two chained matmuls + bias; the intermediate [N, F] tile never leaves
  VMEM — on a GPU the paper-era equivalent would round-trip shared mem /
  HBM between the dense layer and the SpMM aggregation).
* ``embed``: fused dual feature embedding
  ``out = relu(INV @ Wi + bi) ++ relu(DEP @ Wd + bd)``
  (both projections + activation + concat in one VMEM-resident tile).

``interpret=True`` everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls, and correctness is what the AOT path needs (DESIGN.md
§Hardware-Adaptation has the TPU tiling/VMEM analysis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------- gcn_conv
def _gcn_conv_kernel(adj_ref, e_ref, w_ref, b_ref, out_ref):
    # One graph per grid step: adj [N, N], e [N, F] live in VMEM.
    # E @ W then A' @ (.) — both hit the MXU; fp32 accumulation.
    h = jnp.dot(e_ref[0], w_ref[...], preferred_element_type=jnp.float32)
    out_ref[0] = (
        jnp.dot(adj_ref[0], h, preferred_element_type=jnp.float32) + b_ref[...]
    )


def _gcn_conv_call(adj, e, w, b):
    batch, n, _ = adj.shape
    g = w.shape[1]
    return pl.pallas_call(
        _gcn_conv_kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, e.shape[2]), lambda i: (i, 0, 0)),
            pl.BlockSpec((w.shape[0], g), lambda i: (0, 0)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n, g), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n, g), jnp.float32),
        interpret=True,
    )(adj, e, w, b)


# interpret-mode pallas_call has no reverse-mode rule in this jax version;
# the VJP of out = A'(EW) + b is closed-form, so supply it analytically.
@jax.custom_vjp
def gcn_conv(adj, e, w, b):
    """Pallas fused graph convolution. Shapes: adj [B,N,N], e [B,N,F],
    w [F,G], b [G] -> [B,N,G]."""
    return _gcn_conv_call(adj, e, w, b)


def _gcn_conv_fwd(adj, e, w, b):
    return _gcn_conv_call(adj, e, w, b), (adj, e, w)


def _gcn_conv_bwd(res, g_out):
    adj, e, w = res
    ew = e @ w                                   # [B,N,G]
    d_adj = g_out @ jnp.swapaxes(ew, -1, -2)     # [B,N,N]
    at_g = jnp.swapaxes(adj, -1, -2) @ g_out     # [B,N,G]
    d_e = at_g @ w.T                             # [B,N,F]
    d_w = jnp.einsum("bnf,bng->fg", e, at_g)     # [F,G]
    d_b = jnp.sum(g_out, axis=(0, 1))            # [G]
    return d_adj, d_e, d_w, d_b


gcn_conv.defvjp(_gcn_conv_fwd, _gcn_conv_bwd)


# ------------------------------------------------------------------- embed
def _embed_kernel(inv_ref, dep_ref, wi_ref, bi_ref, wd_ref, bd_ref, out_ref):
    ei = jnp.maximum(
        jnp.dot(inv_ref[0], wi_ref[...], preferred_element_type=jnp.float32)
        + bi_ref[...],
        0.0,
    )
    ed = jnp.maximum(
        jnp.dot(dep_ref[0], wd_ref[...], preferred_element_type=jnp.float32)
        + bd_ref[...],
        0.0,
    )
    out_ref[0] = jnp.concatenate([ei, ed], axis=-1)


def _embed_call(inv, dep, w_inv, b_inv, w_dep, b_dep):
    batch, n, i_dim = inv.shape
    d_dim = dep.shape[2]
    ei = w_inv.shape[1]
    ed = w_dep.shape[1]
    return pl.pallas_call(
        _embed_kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, n, i_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d_dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((i_dim, ei), lambda i: (0, 0)),
            pl.BlockSpec((ei,), lambda i: (0,)),
            pl.BlockSpec((d_dim, ed), lambda i: (0, 0)),
            pl.BlockSpec((ed,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n, ei + ed), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n, ei + ed), jnp.float32),
        interpret=True,
    )(inv, dep, w_inv, b_inv, w_dep, b_dep)


@jax.custom_vjp
def embed(inv, dep, w_inv, b_inv, w_dep, b_dep):
    """Pallas fused feature embedding. inv [B,N,I], dep [B,N,D] ->
    [B,N,EI+ED]."""
    return _embed_call(inv, dep, w_inv, b_inv, w_dep, b_dep)


def _embed_fwd(inv, dep, w_inv, b_inv, w_dep, b_dep):
    out = _embed_call(inv, dep, w_inv, b_inv, w_dep, b_dep)
    return out, (inv, dep, w_inv, w_dep, out)


def _embed_bwd(res, g_out):
    inv, dep, w_inv, w_dep, out = res
    ei = w_inv.shape[1]
    # ReLU mask from the saved activations
    gi = g_out[..., :ei] * (out[..., :ei] > 0)
    gd = g_out[..., ei:] * (out[..., ei:] > 0)
    d_inv = gi @ w_inv.T
    d_dep = gd @ w_dep.T
    d_wi = jnp.einsum("bni,bne->ie", inv, gi)
    d_bi = jnp.sum(gi, axis=(0, 1))
    d_wd = jnp.einsum("bnd,bne->de", dep, gd)
    d_bd = jnp.sum(gd, axis=(0, 1))
    return d_inv, d_dep, d_wi, d_bi, d_wd, d_bd


embed.defvjp(_embed_fwd, _embed_bwd)
