"""L2 correctness: forward invariances, loss properties, Adagrad math."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import dims, model


B, N = 4, 10  # small instances for speed (model is shape-generic)


def make_batch(seed=0, b=B, n=N):
    rng = np.random.default_rng(seed)
    inv = rng.standard_normal((b, n, dims.INV_DIM)).astype(np.float32)
    dep = rng.standard_normal((b, n, dims.DEP_DIM)).astype(np.float32)
    a = np.triu((rng.random((b, n, n)) < 0.3).astype(np.float32), 1)
    a = a + np.transpose(a, (0, 2, 1)) + np.eye(n, dtype=np.float32)
    adj = np.minimum(a, 1.0)
    adj = adj / adj.sum(-1, keepdims=True)
    mask = np.ones((b, n), np.float32)
    return inv, dep, adj, mask


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def test_forward_shape_and_finite(params):
    inv, dep, adj, mask = make_batch()
    z = model.forward(params, inv, dep, adj, mask, use_pallas=False)
    assert z.shape == (B,)
    assert np.isfinite(np.asarray(z)).all()


def test_pallas_and_ref_paths_agree(params):
    inv, dep, adj, mask = make_batch(3)
    z_ref = model.forward(params, inv, dep, adj, mask, use_pallas=False)
    z_pal = model.forward(params, inv, dep, adj, mask, use_pallas=True)
    np.testing.assert_allclose(np.asarray(z_ref), np.asarray(z_pal),
                               rtol=1e-4, atol=1e-4)


def test_padding_nodes_do_not_affect_output(params):
    """Masked (padding) stages must be invisible: growing N with zero-mask
    padding keeps z identical."""
    inv, dep, adj, mask = make_batch(1, n=6)
    pad = 4
    inv2 = np.pad(inv, ((0, 0), (0, pad), (0, 0)))
    dep2 = np.pad(dep, ((0, 0), (0, pad), (0, 0)))
    adj2 = np.zeros((B, 6 + pad, 6 + pad), np.float32)
    adj2[:, :6, :6] = adj
    # padding rows get self-loops (as the rust batcher emits)
    for i in range(6, 6 + pad):
        adj2[:, i, i] = 1.0
    mask2 = np.pad(mask, ((0, 0), (0, pad)))
    z1 = model.forward(params, inv, dep, adj, mask, use_pallas=False)
    z2 = model.forward(params, inv2, dep2, adj2, mask2, use_pallas=False)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=2e-4,
                               atol=2e-4)


def test_param_specs_order_and_count():
    specs = model.param_specs()
    names = [n for n, _ in specs]
    assert names[0] == "w_inv" and names[-1] == "b_out"
    assert len(specs) == 4 + 4 * dims.N_CONV + 2
    p = model.init_params(jax.random.PRNGKey(1))
    assert list(p.keys()) == names
    for (name, shape) in specs:
        assert p[name].shape == shape


def test_loss_zero_when_prediction_exact(params):
    inv, dep, adj, mask = make_batch(2)
    z = model.forward(params, inv, dep, adj, mask, use_pallas=False)
    log_y = np.asarray(z)  # targets equal predictions
    w = np.ones(B, np.float32)
    sm = np.ones(B, np.float32)
    loss = model.loss_fn(params, inv, dep, adj, mask, log_y, w, sm,
                         use_pallas=False)
    assert float(loss) < 1e-5


def test_loss_respects_sample_mask(params):
    inv, dep, adj, mask = make_batch(2)
    log_y = np.zeros(B, np.float32)
    w = np.ones(B, np.float32)
    sm_all = np.ones(B, np.float32)
    sm_first = np.array([1, 0, 0, 0], np.float32)
    l_all = float(model.loss_fn(params, inv, dep, adj, mask, log_y, w,
                                sm_all, use_pallas=False))
    l_first = float(model.loss_fn(params, inv, dep, adj, mask, log_y, w,
                                  sm_first, use_pallas=False))
    # masking changes the loss (unless by freak chance all ξ equal)
    assert l_all != pytest.approx(l_first, rel=1e-6) or l_all == 0


def test_train_step_decreases_loss(params):
    inv, dep, adj, mask = make_batch(4)
    log_y = np.full(B, -1.0, np.float32)
    w = np.ones(B, np.float32)
    sm = np.ones(B, np.float32)
    accum = {k: jnp.zeros_like(v) for k, v in params.items()}
    p = params
    losses = []
    for _ in range(50):
        p, accum, loss = model.train_step(p, accum, inv, dep, adj, mask,
                                          log_y, w, sm, use_pallas=False,
                                          lr=0.05)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_adagrad_matches_manual_formula(params):
    """One step on a single weight matches p - lr*g/(sqrt(g²)+eps)."""
    inv, dep, adj, mask = make_batch(5)
    log_y = np.zeros(B, np.float32)
    w = np.ones(B, np.float32)
    sm = np.ones(B, np.float32)
    grads = jax.grad(model.loss_fn)(params, inv, dep, adj, mask, log_y, w,
                                    sm, use_pallas=False)
    accum = {k: jnp.zeros_like(v) for k, v in params.items()}
    new_p, new_a, _ = model.train_step(params, accum, inv, dep, adj, mask,
                                       log_y, w, sm, use_pallas=False)
    g = grads["w_out"] + dims.WEIGHT_DECAY * params["w_out"]
    expect = params["w_out"] - dims.LEARNING_RATE * g / (
        jnp.sqrt(g * g) + dims.ADAGRAD_EPS)
    np.testing.assert_allclose(np.asarray(new_p["w_out"]),
                               np.asarray(expect), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_a["w_out"]),
                               np.asarray(g * g), rtol=1e-6)


def test_flat_entry_points_roundtrip(params):
    inv, dep, adj, mask = make_batch(6)
    flat = list(params.values())
    z_flat = model.infer_flat(use_pallas=False)(*flat, inv, dep, adj, mask)[0]
    z = model.forward(params, inv, dep, adj, mask, use_pallas=False)
    np.testing.assert_allclose(np.asarray(z_flat), np.asarray(z), rtol=1e-6)

    accum = [jnp.zeros_like(v) for v in flat]
    log_y = np.zeros(B, np.float32)
    w = np.ones(B, np.float32)
    sm = np.ones(B, np.float32)
    out = model.train_flat(use_pallas=False)(
        *flat, *accum, inv, dep, adj, mask, log_y, w, sm,
        jnp.float32(dims.LEARNING_RATE))
    assert len(out) == 2 * len(flat) + 1
    # shapes preserved
    for o, pv in zip(out[: len(flat)], flat):
        assert o.shape == pv.shape


def test_graph_norm_handles_all_masked():
    h = jnp.ones((2, 3, 4))
    mask = jnp.zeros((2, 3, 1))
    out = model.graph_batch_norm(h, mask, jnp.ones(4), jnp.zeros(4))
    assert np.isfinite(np.asarray(out)).all()


def test_ablation_depths_forward():
    """n_conv = 0 (pure FFN readout) .. 4 all produce finite outputs."""
    inv, dep, adj, mask = make_batch(7)
    for k in [0, 1, 4]:
        p = model.init_params(jax.random.PRNGKey(k), n_conv=k)
        z = model.forward(p, inv, dep, adj, mask, n_conv=k, use_pallas=False)
        assert z.shape == (B,)
        assert np.isfinite(np.asarray(z)).all()
