"""AOT lowering sanity: HLO text emission and manifest consistency.

Kept light (one lowering) — the full artifact build is `make artifacts`.
"""

import json
import os

import pytest

from compile import aot, dims, model


def entry_input_arity(text):
    """Number of inputs in the HLO entry computation layout."""
    header = text.split("entry_computation_layout={(", 1)[1]
    header = header.split(")->", 1)[0]
    # each input is one fNN[...]{...} spec at depth 0
    return header.count("f32[")


def test_lower_infer_produces_hlo_text():
    lowered = aot.lower_infer(n_conv=1, batch=2, n=6, use_pallas=False)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # one tensor input per model param + 4 batch inputs
    n_params = len(model.param_specs(1))
    assert entry_input_arity(text) == n_params + 4


def test_lower_train_returns_params_accum_loss():
    lowered = aot.lower_train(n_conv=0, batch=2, n=4, use_pallas=False)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    n_params = len(model.param_specs(0))
    # inputs: params + accum + 4 batch + 3 targets + lr
    assert entry_input_arity(text) == 2 * n_params + 8


def test_manifest_matches_param_specs():
    man = aot.manifest(dims.N_CONV, dims.BATCH, dims.MAX_NODES)
    specs = model.param_specs(dims.N_CONV)
    assert len(man["params"]) == len(specs)
    for entry, (name, shape) in zip(man["params"], specs):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == shape
    assert man["inv_dim"] == dims.INV_DIM
    assert man["dep_dim"] == dims.DEP_DIM
    assert man["batch"] == dims.BATCH
    assert man["max_nodes"] == dims.MAX_NODES


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_consistent():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    assert man["inv_dim"] == dims.INV_DIM
    assert man["n_conv"] == dims.N_CONV
    for fname in ("gcn_infer.hlo.txt", "gcn_train.hlo.txt"):
        with open(os.path.join(root, fname)) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), fname
