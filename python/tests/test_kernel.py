"""L1 correctness: Pallas kernels (interpret mode) vs the pure-jnp oracle.

Hypothesis sweeps shapes; fixed-seed numpy draws the values. This is the
core numerical signal for the whole stack: the AOT artifacts embed these
kernels.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import dims
from compile.kernels import gcn_conv as kernels
from compile.kernels import ref


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


def make_adj(rng, b, n):
    """Random row-normalized DAG adjacency with self loops, like the rust
    side produces."""
    a = (rng.random((b, n, n)) < 0.15).astype(np.float32)
    a = np.triu(a, 1)  # DAG: edges i->j only for i<j
    a = a + np.transpose(a, (0, 2, 1)) + np.eye(n, dtype=np.float32)
    a = np.minimum(a, 1.0)
    return a / a.sum(-1, keepdims=True)


# ------------------------------------------------------------------ embed
@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    n=st.integers(1, 24),
    i_dim=st.sampled_from([4, 16, dims.INV_DIM]),
    d_dim=st.sampled_from([8, 24, dims.DEP_DIM]),
    ei=st.sampled_from([8, dims.EMB_INV]),
    ed=st.sampled_from([8, dims.EMB_DEP]),
    seed=st.integers(0, 2**31 - 1),
)
def test_embed_matches_ref(b, n, i_dim, d_dim, ei, ed, seed):
    rng = np.random.default_rng(seed)
    inv, dep = rand(rng, b, n, i_dim), rand(rng, b, n, d_dim)
    wi, bi = rand(rng, i_dim, ei), rand(rng, ei)
    wd, bd = rand(rng, d_dim, ed), rand(rng, ed)
    got = np.asarray(kernels.embed(inv, dep, wi, bi, wd, bd))
    want = np.asarray(ref.embed_ref(inv, dep, wi, bi, wd, bd))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- gcn_conv
@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    n=st.integers(1, 24),
    f=st.sampled_from([4, 16, dims.NODE_DIM]),
    g=st.sampled_from([4, 16, dims.HIDDEN]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gcn_conv_matches_ref(b, n, f, g, seed):
    rng = np.random.default_rng(seed)
    adj = make_adj(rng, b, n)
    e = rand(rng, b, n, f)
    w, bias = rand(rng, f, g), rand(rng, g)
    got = np.asarray(kernels.gcn_conv(adj, e, w, bias))
    want = np.asarray(ref.gcn_conv_ref(adj, e, w, bias))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gcn_conv_artifact_shape():
    """Exact artifact configuration (B=32, N=48, F=80)."""
    rng = np.random.default_rng(0)
    b, n, f = dims.BATCH, dims.MAX_NODES, dims.NODE_DIM
    adj = make_adj(rng, b, n)
    e = rand(rng, b, n, f)
    w, bias = rand(rng, f, f), rand(rng, f)
    got = np.asarray(kernels.gcn_conv(adj, e, w, bias))
    want = np.asarray(ref.gcn_conv_ref(adj, e, w, bias))
    assert got.shape == (b, n, f)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_aggregates_neighbors_only():
    """A node with no in-edges (beyond self loop) must only see itself."""
    b, n, f = 1, 4, 8
    adj = np.zeros((b, n, n), np.float32)
    adj[0] = np.eye(n)  # self loops only
    rng = np.random.default_rng(1)
    e = rand(rng, b, n, f)
    w = np.eye(f, dtype=np.float32)
    bias = np.zeros(f, np.float32)
    out = np.asarray(kernels.gcn_conv(adj, e, w, bias))
    np.testing.assert_allclose(out, e, rtol=1e-6)


def test_embed_relu_clamps():
    """Large negative weights must produce exact zeros (ReLU)."""
    b, n = 2, 3
    inv = np.ones((b, n, 4), np.float32)
    dep = np.ones((b, n, 4), np.float32)
    wi = -np.ones((4, 8), np.float32)
    wd = -np.ones((4, 8), np.float32)
    bi = np.zeros(8, np.float32)
    bd = np.zeros(8, np.float32)
    out = np.asarray(kernels.embed(inv, dep, wi, bi, wd, bd))
    assert (out == 0.0).all()
