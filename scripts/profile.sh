#!/usr/bin/env bash
# Profile the native engine's hot loops so perf PRs start from a measured
# baseline instead of a guess.
#
# Wraps `gcn-perf bench --engine` (the engine micro-suite only — no
# serving threads muddying the profile) under `perf record`, then emits a
# flamegraph if a flamegraph tool is on PATH, falling back to a plain
# `perf report` summary otherwise.
#
# Usage:
#   scripts/profile.sh            # full measurement windows
#   scripts/profile.sh --fast     # short windows (quick look)
#
# Outputs land in ./profile/ at the repository root:
#   profile/perf.data       raw samples
#   profile/flamegraph.svg  (if inferno-flamegraph or flamegraph.pl exist)
#   profile/report.txt      perf report --stdio summary
set -euo pipefail
cd "$(dirname "$0")/.."
ROOT="$(pwd)"
OUT="$ROOT/profile"
mkdir -p "$OUT"

FAST_FLAG=""
if [[ "${1:-}" == "--fast" ]]; then
    FAST_FLAG="--fast"
fi

echo "==> building release with debug symbols"
( cd rust && CARGO_PROFILE_RELEASE_DEBUG=true cargo build --release )

BIN="$ROOT/rust/target/release/gcn-perf"
BENCH_CMD=("$BIN" bench --engine ${FAST_FLAG} --engine-out "$OUT/BENCH_5.json")

if ! command -v perf >/dev/null 2>&1; then
    echo "perf(1) not found — running the engine bench unprofiled." >&2
    echo "Install linux-tools (or run on a machine with perf) for flamegraphs." >&2
    exec "${BENCH_CMD[@]}"
fi

echo "==> perf record: gcn-perf bench --engine ${FAST_FLAG}"
# -g: call graphs; dwarf unwinding gives readable Rust stacks
perf record -g --call-graph dwarf,16384 -o "$OUT/perf.data" -- "${BENCH_CMD[@]}"

echo "==> perf report summary -> $OUT/report.txt"
perf report --stdio -i "$OUT/perf.data" > "$OUT/report.txt" 2>/dev/null || true
head -n 40 "$OUT/report.txt" || true

# flamegraph, with whichever tool is available
if command -v inferno-collapse-perf >/dev/null 2>&1 && command -v inferno-flamegraph >/dev/null 2>&1; then
    echo "==> flamegraph (inferno) -> $OUT/flamegraph.svg"
    perf script -i "$OUT/perf.data" | inferno-collapse-perf | inferno-flamegraph \
        > "$OUT/flamegraph.svg"
elif command -v stackcollapse-perf.pl >/dev/null 2>&1 && command -v flamegraph.pl >/dev/null 2>&1; then
    echo "==> flamegraph (FlameGraph scripts) -> $OUT/flamegraph.svg"
    perf script -i "$OUT/perf.data" | stackcollapse-perf.pl | flamegraph.pl \
        > "$OUT/flamegraph.svg"
else
    echo "(no flamegraph tool found — install 'inferno' (cargo install inferno)"
    echo " or Brendan Gregg's FlameGraph scripts for $OUT/flamegraph.svg)"
fi

echo "profile: done — artifacts in $OUT/"
