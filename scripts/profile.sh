#!/usr/bin/env bash
# Profile the native engine's hot loops so perf PRs start from a measured
# baseline instead of a guess.
#
# Wraps `gcn-perf bench --engine` (the engine micro-suite only — no
# serving threads muddying the profile) under `perf record`, then emits a
# flamegraph if a flamegraph tool is on PATH, falling back to a plain
# `perf report` summary otherwise.
#
# Usage:
#   scripts/profile.sh                          # full measurement windows
#   scripts/profile.sh --fast                   # short windows (quick look)
#   scripts/profile.sh --engine-precision int8 --bundle data/gcn-int8.bundle
#                                               # profile the int8 lane
#
# --engine-precision {f32,int8} passes --precision through to the bench
# binary (int8 needs a quantized bundle — mint one with `gcn-perf
# quantize` and hand it over with --bundle, or the bench exits 2).
#
# Kernel-lane A/B flamegraphs: build with --features simd (the script
# does when GCN_PERF_PROFILE_SIMD=1), record once per lane and diff the
# graphs —
#   GCN_PERF_PROFILE_SIMD=1 scripts/profile.sh            # detected tier
#   GCN_PERF_PROFILE_SIMD=1 GCN_PERF_KERNELS=scalar \
#       scripts/profile.sh                                # forced scalar
# GCN_PERF_KERNELS clamps runtime dispatch downward (scalar/sse2/avx2),
# so the two runs differ only in the microkernels — any delta in the
# flamegraph is the vector win, on identical workloads.
#
# Outputs land in ./profile/ at the repository root:
#   profile/perf.data       raw samples
#   profile/flamegraph.svg  (if inferno-flamegraph or flamegraph.pl exist)
#   profile/report.txt      perf report --stdio summary
set -euo pipefail
cd "$(dirname "$0")/.."
ROOT="$(pwd)"
OUT="$ROOT/profile"
mkdir -p "$OUT"

FAST_FLAG=""
EXTRA_ARGS=()
while [[ $# -gt 0 ]]; do
    case "$1" in
        --fast)
            FAST_FLAG="--fast"
            shift
            ;;
        --engine-precision)
            [[ $# -ge 2 ]] || { echo "--engine-precision needs a value (f32|int8)" >&2; exit 2; }
            EXTRA_ARGS+=(--precision "$2")
            shift 2
            ;;
        --bundle)
            [[ $# -ge 2 ]] || { echo "--bundle needs a path" >&2; exit 2; }
            EXTRA_ARGS+=(--bundle "$2")
            shift 2
            ;;
        *)
            echo "unknown argument '$1' (valid: --fast, --engine-precision V, --bundle P)" >&2
            exit 2
            ;;
    esac
done

FEATURES=()
if [[ "${GCN_PERF_PROFILE_SIMD:-}" == "1" ]]; then
    FEATURES=(--features simd)
    export RUSTFLAGS="${RUSTFLAGS:-} -C target-cpu=native"
fi

echo "==> building release with debug symbols"
( cd rust && CARGO_PROFILE_RELEASE_DEBUG=true cargo build --release \
    ${FEATURES[@]+"${FEATURES[@]}"} )

BIN="$ROOT/rust/target/release/gcn-perf"
BENCH_CMD=("$BIN" bench --engine ${FAST_FLAG} --engine-out "$OUT/BENCH_5.json"
    --simd-out "$OUT/BENCH_8.json")
BENCH_CMD+=(${EXTRA_ARGS[@]+"${EXTRA_ARGS[@]}"})

if ! command -v perf >/dev/null 2>&1; then
    echo "perf(1) not found — running the engine bench unprofiled." >&2
    echo "Install linux-tools (or run on a machine with perf) for flamegraphs." >&2
    exec "${BENCH_CMD[@]}"
fi

echo "==> perf record: gcn-perf bench --engine ${FAST_FLAG}"
# -g: call graphs; dwarf unwinding gives readable Rust stacks
perf record -g --call-graph dwarf,16384 -o "$OUT/perf.data" -- "${BENCH_CMD[@]}"

echo "==> perf report summary -> $OUT/report.txt"
perf report --stdio -i "$OUT/perf.data" > "$OUT/report.txt" 2>/dev/null || true
head -n 40 "$OUT/report.txt" || true

# flamegraph, with whichever tool is available
if command -v inferno-collapse-perf >/dev/null 2>&1 && command -v inferno-flamegraph >/dev/null 2>&1; then
    echo "==> flamegraph (inferno) -> $OUT/flamegraph.svg"
    perf script -i "$OUT/perf.data" | inferno-collapse-perf | inferno-flamegraph \
        > "$OUT/flamegraph.svg"
elif command -v stackcollapse-perf.pl >/dev/null 2>&1 && command -v flamegraph.pl >/dev/null 2>&1; then
    echo "==> flamegraph (FlameGraph scripts) -> $OUT/flamegraph.svg"
    perf script -i "$OUT/perf.data" | stackcollapse-perf.pl | flamegraph.pl \
        > "$OUT/flamegraph.svg"
else
    echo "(no flamegraph tool found — install 'inferno' (cargo install inferno)"
    echo " or Brendan Gregg's FlameGraph scripts for $OUT/flamegraph.svg)"
fi

echo "profile: done — artifacts in $OUT/"
