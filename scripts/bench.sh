#!/usr/bin/env bash
# Perf trajectory: builds the release binary and writes BENCH_3.json
# (dense-vs-sparse engines) and BENCH_4.json (naive-vs-coalesced serving)
# at the repository root. Pass --fast for the short smoke variant CI runs.
set -euo pipefail
cd "$(dirname "$0")/../rust"

FAST_FLAG=""
if [[ "${1:-}" == "--fast" ]]; then
    FAST_FLAG="--fast"
fi

cargo run --release -- bench ${FAST_FLAG} --out ../BENCH_3.json --serve-out ../BENCH_4.json
echo "wrote $(cd .. && pwd)/BENCH_3.json and BENCH_4.json"
