#!/usr/bin/env bash
# Perf trajectory: builds the release binary and writes BENCH_3.json
# (dense-vs-sparse engines), BENCH_4.json (naive-vs-coalesced serving),
# BENCH_5.json (PR-5 engine core vs the frozen PR-4 core), BENCH_6.json
# (the TCP front-end under the loadgen client fleet), BENCH_7.json
# (concurrent autotune fleet vs sequential tuning through one shared
# service), BENCH_8.json (scalar vs SIMD vs int8 inference lanes) and
# BENCH_10.json (in-RAM vs streamed out-of-core training plus full vs
# partitioned steps over the synthetic 1k/10k/100k-stage tiers) at
# the repository root. Pass --fast for the short smoke variant CI runs.
# Build with `cargo build --release --features simd` (ideally under
# RUSTFLAGS="-C target-cpu=native") for BENCH_8 to exercise real
# vector kernels; a default build records the scalar-only baseline.
set -euo pipefail
cd "$(dirname "$0")/../rust"

FAST_FLAG=""
if [[ "${1:-}" == "--fast" ]]; then
    FAST_FLAG="--fast"
fi

cargo run --release -- bench ${FAST_FLAG} \
    --out ../BENCH_3.json --serve-out ../BENCH_4.json --engine-out ../BENCH_5.json \
    --autotune-out ../BENCH_7.json --simd-out ../BENCH_8.json --scale-out ../BENCH_10.json
cargo run --release -- loadgen ${FAST_FLAG} --out ../BENCH_6.json
echo "wrote $(cd .. && pwd)/BENCH_3.json, BENCH_4.json, BENCH_5.json, BENCH_6.json, BENCH_7.json, BENCH_8.json and BENCH_10.json"
