#!/usr/bin/env bash
# Tier-1 verification, exactly what CI runs. Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "==> cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "(rustfmt not installed — skipping; CI runs it)"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo clippy --all-targets -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets --quiet -- -D warnings
else
    echo "(clippy not installed — skipping; CI runs it)"
fi

echo "==> cargo doc --no-deps (deny rustdoc warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> cargo check --features pjrt --all-targets"
cargo check --features pjrt --all-targets --quiet

echo "==> cargo test -q --features simd (SIMD lane: scalar parity + envelopes)"
cargo test -q --features simd

echo "==> analyze gate (zoo must be clean under --strict; corrupt fixtures must exit 1 with a D0xx code)"
cargo run --release --quiet -- analyze --zoo --strict --schedules 10
set +e
ANALYZE_OUT="$(cargo run --release --quiet -- analyze --samples tests/fixtures/bad_runtime.json 2>&1)"
ANALYZE_RC=$?
set -e
if [ "$ANALYZE_RC" -ne 1 ]; then
    echo "expected exit 1 analyzing a corrupt fixture, got $ANALYZE_RC" >&2
    exit 1
fi
echo "$ANALYZE_OUT" | grep -q "D0" || { echo "analyzer output lacks a D0xx code: $ANALYZE_OUT" >&2; exit 1; }

echo "==> serve smoke (tiny bundle, JSON requests + STATS through the stdin daemon)"
SMOKE="$(mktemp -d)"
trap 'rm -rf "$SMOKE"' EXIT
cargo run --release --quiet -- gen-data --pipelines 8 --schedules 4 --seed 1 --out "$SMOKE/ds.bin"
cargo run --release --quiet -- train --data "$SMOKE/ds.bin" --bundle "$SMOKE/gcn.bundle" --epochs 1 --test-frac 0.25
cargo run --release --quiet -- export-samples --data "$SMOKE/ds.bin" --limit 2 --out "$SMOKE/req.json"
{ cat "$SMOKE/req.json"; echo; echo STATS; } > "$SMOKE/req_stats.json"
timeout 120 bash -c "cargo run --release --quiet -- serve --bundle '$SMOKE/gcn.bundle' < '$SMOKE/req_stats.json' > '$SMOKE/resp.json'"
grep -q predicted_runtime_s "$SMOKE/resp.json"
grep -q '"stats"' "$SMOKE/resp.json"

echo "==> TCP serve smoke (daemon + loadgen fleet, throughput floor, SIGTERM drain)"
./target/release/gcn-perf serve --bundle "$SMOKE/gcn.bundle" --listen 127.0.0.1:0 --port-file "$SMOKE/port" &
SERVE_PID=$!
for _ in $(seq 1 100); do [ -s "$SMOKE/port" ] && break; sleep 0.1; done
ADDR="$(cat "$SMOKE/port")"
timeout 120 ./target/release/gcn-perf loadgen --addr "$ADDR" --samples "$SMOKE/req.json" \
    --bundle "$SMOKE/gcn.bundle" --fast --min-rps 25 --out "$SMOKE/bench6_smoke.json"
grep -q requests_per_s "$SMOKE/bench6_smoke.json"
kill -TERM "$SERVE_PID"
wait "$SERVE_PID"

echo "==> autotune smoke (tiny fleet through one shared service; tuned must not regress the default)"
cargo run --release --quiet -- autotune --networks alexnet,squeezenet --bundle "$SMOKE/gcn.bundle" \
    --population 3 --offspring 4 --immigrants 1 --generations 3 --seed 5 \
    --require-improvement --report-out "$SMOKE/fleet.json" --trace-out "$SMOKE/trace.json"
grep -q tuned_cost "$SMOKE/fleet.json"
grep -q pipeline_id "$SMOKE/trace.json"

echo "==> quantize smoke (mint an int8 bundle; precision mismatches must exit 2)"
cargo run --release --quiet -- quantize --bundle "$SMOKE/gcn.bundle" --out "$SMOKE/gcn-int8.bundle"
{ cat "$SMOKE/req.json"; echo; echo STATS; } > "$SMOKE/req_stats8.json"
timeout 120 bash -c "cargo run --release --quiet -- serve --bundle '$SMOKE/gcn-int8.bundle' --precision int8 < '$SMOKE/req_stats8.json' > '$SMOKE/resp8.json'"
grep -q predicted_runtime_s "$SMOKE/resp8.json"
grep -q '"precision":"int8"' "$SMOKE/resp8.json"
if cargo run --release --quiet -- predict --bundle "$SMOKE/gcn.bundle" --precision int8 --samples "$SMOKE/req.json" >/dev/null 2>&1; then
    echo "expected exit 2 for --precision int8 on an f32 bundle" >&2
    exit 1
fi

echo "==> large-graph smoke (1k-stage sharded corpus -> stream-train one epoch -> streamed predict, MaxRSS ceiling)"
cargo run --release --quiet -- gen-data --scale 1000 --style transformer \
    --pipelines 2 --schedules 3 --seed 11 --out "$SMOKE/corpus"
if /usr/bin/time -v true >/dev/null 2>&1; then
    /usr/bin/time -v -o "$SMOKE/train.time" ./target/release/gcn-perf train --stream "$SMOKE/corpus" \
        --epochs 1 --node-budget 2048 --test-frac 0.34 --bundle "$SMOKE/large.bundle"
    /usr/bin/time -v -o "$SMOKE/predict.time" ./target/release/gcn-perf predict --stream "$SMOKE/corpus" \
        --node-budget 2048 --bundle "$SMOKE/large.bundle" --out "$SMOKE/large_pred.json"
    for f in "$SMOKE/train.time" "$SMOKE/predict.time"; do
        KB="$(awk '/Maximum resident set size/ {print $NF}' "$f")"
        echo "    $f: MaxRSS ${KB} kB"
        if [ "$KB" -ge 786432 ]; then
            echo "peak RSS ${KB} kB exceeds the 768 MiB streaming ceiling" >&2
            exit 1
        fi
    done
else
    echo "(GNU time not installed — running without the MaxRSS ceiling; CI enforces it)"
    ./target/release/gcn-perf train --stream "$SMOKE/corpus" \
        --epochs 1 --node-budget 2048 --test-frac 0.34 --bundle "$SMOKE/large.bundle"
    ./target/release/gcn-perf predict --stream "$SMOKE/corpus" \
        --node-budget 2048 --bundle "$SMOKE/large.bundle" --out "$SMOKE/large_pred.json"
fi
grep -q predicted_runtime_s "$SMOKE/large_pred.json"

echo "==> autotune checkpoint smoke (interrupted run, then --resume finishes the search)"
cargo run --release --quiet -- autotune --networks alexnet --population 3 --offspring 4 \
    --immigrants 1 --generations 3 --seed 5 \
    --checkpoint-dir "$SMOKE/ckpt" --checkpoint-every 1 --step-limit 1
cargo run --release --quiet -- autotune --networks alexnet --population 3 --offspring 4 \
    --immigrants 1 --generations 3 --seed 5 \
    --checkpoint-dir "$SMOKE/ckpt" --resume --require-improvement

echo "verify: OK"
