#!/usr/bin/env bash
# Tier-1 verification, exactly what CI runs. Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "==> cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "(rustfmt not installed — skipping; CI runs it)"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo clippy --all-targets -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets --quiet -- -D warnings
else
    echo "(clippy not installed — skipping; CI runs it)"
fi

echo "==> cargo doc --no-deps (deny rustdoc warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> cargo check --features pjrt --all-targets"
cargo check --features pjrt --all-targets --quiet

echo "verify: OK"
