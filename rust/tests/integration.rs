//! Cross-module integration tests: the full Fig 4 pipeline, the native
//! GCN backend (always available — no artifacts needed), training
//! convergence, and the eval harnesses. PJRT-artifact round trips live in
//! the `pjrt` module at the bottom and only build with `--features pjrt`.

use gcn_perf::constants::*;
use gcn_perf::dataset::builder::{build_dataset, sample_from_schedule, DataGenConfig};
use gcn_perf::dataset::store;
use gcn_perf::eval::harness;
use gcn_perf::model::PackedBatch;
use gcn_perf::predictor::{GcnPredictor, GcnView, Predictor};
use gcn_perf::runtime::{load_backend, Backend, DenseRefBackend, NativeBackend};
use gcn_perf::sim::Machine;
use gcn_perf::train::{train, TrainConfig};
use std::path::Path;

fn small_dataset(pipelines: usize, schedules: usize, seed: u64) -> gcn_perf::dataset::Dataset {
    build_dataset(&DataGenConfig {
        n_pipelines: pipelines,
        schedules_per_pipeline: schedules,
        seed,
        ..Default::default()
    })
}

#[test]
fn fig4_pipeline_end_to_end() {
    // random models -> lower -> schedules -> features -> bench -> store
    let ds = small_dataset(10, 6, 101);
    assert_eq!(ds.len(), 60);
    let path = std::env::temp_dir().join("gcn_perf_it_ds.bin");
    store::save(&ds, &path).unwrap();
    let rt = store::load(&path).unwrap();
    assert_eq!(rt.len(), 60);
    std::fs::remove_file(&path).ok();

    // schedules of the same pipeline share invariant features but differ in
    // runtime — the core structure of the learning problem
    let p0: Vec<_> = ds.samples.iter().filter(|s| s.pipeline_id == 0).collect();
    assert!(p0.len() >= 2);
    assert_eq!(p0[0].inv, p0[1].inv);
    let runtimes: Vec<f64> = p0.iter().map(|s| s.mean_runtime()).collect();
    let min = runtimes.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = runtimes.iter().cloned().fold(0.0f64, f64::max);
    assert!(max > min, "schedules must differentiate runtimes");
}

#[test]
fn default_backend_loads_without_artifacts() {
    // the whole point of the native backend: step zero works everywhere;
    // the loader reports problems as structured warnings, not stderr spam
    let loaded = load_backend(Path::new("artifacts_that_do_not_exist"), true).unwrap();
    assert!(loaded.warnings.is_empty());
    let be = loaded.backend;
    assert_eq!(be.name(), "native");
    assert_eq!(be.manifest().n_conv, N_CONV);
}

#[test]
fn native_infer_shape_and_determinism() {
    let rt = NativeBackend::new();
    let ds = small_dataset(4, 8, 5);
    let stats = ds.stats.clone().unwrap();
    let best = ds.best_per_pipeline();
    let refs: Vec<_> = ds.samples.iter().take(BATCH).collect();
    let bests: Vec<f64> = refs.iter().map(|s| best[&s.pipeline_id]).collect();
    let batch = PackedBatch::build(&refs, &stats, &bests).unwrap();
    let params = rt.init_params(3);
    let z1 = rt.infer(&params, &batch).unwrap();
    let z2 = rt.infer(&params, &batch).unwrap();
    assert_eq!(z1.len(), refs.len());
    assert_eq!(z1, z2, "inference must be deterministic");
    assert!(z1.iter().all(|v| v.is_finite()));
}

#[test]
fn sparse_and_dense_reference_agree_on_real_pipelines() {
    // the two engines share params and batches; on generator output they
    // must agree within the parity budget (the in-crate property test
    // covers random graphs — this covers the real featurization path)
    let sparse = NativeBackend::new();
    let dense = DenseRefBackend::new();
    let ds = small_dataset(4, 8, 6);
    let stats = ds.stats.clone().unwrap();
    let best = ds.best_per_pipeline();
    let refs: Vec<_> = ds.samples.iter().take(BATCH).collect();
    let bests: Vec<f64> = refs.iter().map(|s| best[&s.pipeline_id]).collect();
    let batch = PackedBatch::build(&refs, &stats, &bests).unwrap();
    let params = sparse.init_params(4);
    let zs = sparse.infer(&params, &batch).unwrap();
    let zd = dense.infer(&params, &batch).unwrap();
    assert_eq!(zs.len(), zd.len());
    for (i, (a, b)) in zs.iter().zip(&zd).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5,
            "engines diverge at graph {i}: sparse {a} vs dense {b}"
        );
    }
}

#[test]
fn native_training_reduces_loss_and_mape() {
    let rt = NativeBackend::new();
    let ds = small_dataset(24, 10, 7);
    let (train_ds, test_ds) = ds.split(0.15, 99);
    let result = train(
        &rt,
        &train_ds,
        &test_ds,
        &TrainConfig {
            epochs: 6,
            seed: 7,
            patience: 10,
            verbose: false,
            eval_every: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let first = result.history.first().unwrap().train_loss;
    let last = result.history.last().unwrap().train_loss;
    assert!(
        last < first * 0.8,
        "training should reduce loss: {first} -> {last}"
    );
    assert!(result.best_test_mape.is_finite());
}

#[test]
fn native_ablation_variants_run() {
    let ds = small_dataset(4, 8, 11);
    let stats = ds.stats.clone().unwrap();
    let best = ds.best_per_pipeline();
    let refs: Vec<_> = ds.samples.iter().take(BATCH).collect();
    let bests: Vec<f64> = refs.iter().map(|s| best[&s.pipeline_id]).collect();
    let batch = PackedBatch::build(&refs, &stats, &bests).unwrap();
    for layers in [0usize, 1, 4] {
        let rt = NativeBackend::with_layers(layers);
        assert_eq!(rt.manifest().batch, BATCH);
        assert_eq!(rt.manifest().params.len(), 6 + 4 * layers);
        let params = rt.init_params(layers as u64 + 1);
        let z = rt.infer(&params, &batch).unwrap();
        assert!(z.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn fig8_harness_produces_three_rows() {
    let rt = NativeBackend::new();
    let ds = small_dataset(16, 8, 8);
    let (train_ds, test_ds) = ds.split(0.2, 77);
    let result = train(
        &rt,
        &train_ds,
        &test_ds,
        &TrainConfig { epochs: 3, verbose: false, ..Default::default() },
    )
    .unwrap();
    let stats = train_ds.stats.clone().unwrap();
    let view = GcnView { backend: &rt, params: &result.params, stats: &stats };
    let rows = harness::run_fig8(&view, &train_ds, &test_ds, 3, false).unwrap();
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[0].model, "gcn (ours)");
    assert_eq!(rows[1].model, "halide-ffn");
    assert_eq!(rows[2].model, "tvm-gbt");
    for r in &rows {
        assert!(r.avg_error_pct.is_finite() && r.avg_error_pct >= 0.0);
        assert!(r.max_error_pct >= r.avg_error_pct);
    }
}

#[test]
fn fig9_harness_covers_all_zoo_networks() {
    let rt = NativeBackend::new();
    let ds = small_dataset(6, 6, 9);
    let stats = ds.stats.clone().unwrap();
    let params = rt.init_params(5);
    let gcn = GcnPredictor::new(Box::new(rt), params, stats);
    let rows = harness::run_fig9(&gcn, &Machine::default(), 8, 3).unwrap();
    // the nine paper networks plus the >48-stage resnet50
    assert_eq!(rows.len(), 10);
    assert!(rows.iter().any(|r| r.network == "resnet50"));
    for r in &rows {
        assert_eq!(r.n_schedules, 8);
        assert!(r.n_pairs > 0);
        assert!(r.accuracy_pct() >= 0.0 && r.accuracy_pct() <= 100.0);
    }
}

#[test]
fn big_network_trains_and_predicts_end_to_end() {
    // the >48-stage zoo network through the full stack: featurize →
    // packed batches → train → bundle round trip → predict. None of this
    // was representable in the old padded layout.
    let net = gcn_perf::zoo::resnet50();
    assert!(net.num_stages() > MAX_NODES);
    let nests = gcn_perf::lower::lower_pipeline(&net);
    let machine = Machine::default();
    let mut rng = gcn_perf::util::rng::Rng::new(31);

    let mut ds = gcn_perf::dataset::Dataset::default();
    for sid in 0..8u32 {
        let sched = gcn_perf::schedule::random::random_pipeline_schedule(&net, &nests, &mut rng);
        ds.samples
            .push(sample_from_schedule(&net, &nests, &sched, &machine, 100, sid, &mut rng));
    }
    // mix in small pipelines so the batch spans graph sizes
    let small = small_dataset(3, 4, 17);
    ds.samples.extend(small.samples);
    ds.fit_stats();

    let rt = NativeBackend::new();
    let result = train(
        &rt,
        &ds,
        &ds,
        &TrainConfig { epochs: 2, verbose: false, ..Default::default() },
    )
    .unwrap();
    assert!(result.history.iter().all(|e| e.train_loss.is_finite()));

    let stats = ds.stats.clone().unwrap();
    let view = GcnView { backend: &rt, params: &result.params, stats: &stats };
    let refs: Vec<_> = ds.samples.iter().collect();
    let preds = view.predict(&refs).unwrap();
    assert_eq!(preds.len(), ds.len());
    assert!(preds.iter().all(|p| p.is_finite() && *p > 0.0));

    // bundle round trip serves the big graphs identically
    let path = std::env::temp_dir().join("gcn_perf_it_bignet.bundle");
    view.save(&path).unwrap();
    let served = gcn_perf::predictor::registry::load_bundle(&path).unwrap();
    let again = served.predict(&refs).unwrap();
    assert_eq!(preds, again, "bundle round trip must preserve big-graph predictions");
    std::fs::remove_file(&path).ok();
}

#[test]
fn beam_search_with_gcn_shaped_cost_runs() {
    // search loop with a model in the loop (oracle stands in for the GCN to
    // keep this test fast)
    use gcn_perf::search::{beam_search, BeamConfig, SimCost};
    let net = gcn_perf::zoo::squeezenet();
    let nests = gcn_perf::lower::lower_pipeline(&net);
    let model = SimCost { machine: Machine::default() };
    let (sched, score) = beam_search(
        &net,
        &nests,
        &model,
        &BeamConfig { beam_width: 3, candidates_per_stage: 5, seed: 2 },
    )
    .unwrap();
    gcn_perf::schedule::legality::check_pipeline(&net, &nests, &sched).unwrap();
    assert!(score > 0.0 && score.is_finite());
}

#[test]
fn native_predict_runtimes_spans_chunks() {
    // 3 chunks (2 full + 1 partial) through the parallel inference path
    let rt = NativeBackend::new();
    let ds = small_dataset(10, 7, 12);
    let stats = ds.stats.clone().unwrap();
    let params = rt.init_params(6);
    let refs: Vec<_> = ds.samples.iter().collect();
    let preds = rt.predict_runtimes(&params, &refs, &stats).unwrap();
    assert_eq!(preds.len(), ds.len());
    assert!(preds.iter().all(|p| p.is_finite() && *p > 0.0));
}

#[test]
fn dataset_scales_runtime_spread() {
    // sanity on the learning signal: across pipelines runtimes span decades,
    // within a pipeline schedules move runtime by >2x typically
    let ds = small_dataset(12, 12, 10);
    let all: Vec<f64> = ds.samples.iter().map(|s| s.mean_runtime()).collect();
    let gmin = all.iter().cloned().fold(f64::INFINITY, f64::min);
    let gmax = all.iter().cloned().fold(0.0f64, f64::max);
    assert!(gmax / gmin > 10.0, "cross-pipeline spread {gmin}..{gmax}");
    let mut per_pipeline_ratios = Vec::new();
    for pid in 0..12u32 {
        let rts: Vec<f64> = ds
            .samples
            .iter()
            .filter(|s| s.pipeline_id == pid)
            .map(|s| s.mean_runtime())
            .collect();
        let min = rts.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = rts.iter().cloned().fold(0.0f64, f64::max);
        per_pipeline_ratios.push(max / min);
    }
    let median = {
        per_pipeline_ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        per_pipeline_ratios[per_pipeline_ratios.len() / 2]
    };
    assert!(median > 1.5, "median within-pipeline spread {median}");
}

#[test]
fn trained_bundle_roundtrips_through_predict_path() {
    // the acceptance loop of the predictor API: train → save bundle →
    // reload via the registry (as `gcn-perf predict` does) → serve the
    // same samples through the JSON interchange — predictions must match
    // in-process inference bit-exactly
    let rt = NativeBackend::new();
    let ds = small_dataset(12, 8, 21);
    let (train_ds, test_ds) = ds.split(0.2, 55);
    let result = train(
        &rt,
        &train_ds,
        &test_ds,
        &TrainConfig { epochs: 2, verbose: false, ..Default::default() },
    )
    .unwrap();
    let stats = train_ds.stats.clone().unwrap();
    let view = GcnView { backend: &rt, params: &result.params, stats: &stats };
    let refs: Vec<_> = test_ds.samples.iter().collect();
    let in_process = view.predict(&refs).unwrap();

    let path = std::env::temp_dir().join("gcn_perf_it_trained.bundle");
    view.save(&path).unwrap();
    let served = gcn_perf::predictor::registry::load_bundle(&path).unwrap();
    assert_eq!(served.name(), "gcn");

    // through the JSON sample interchange (what `predict --samples` reads)
    let json = gcn_perf::dataset::json::samples_to_json(&test_ds.samples);
    let parsed = gcn_perf::dataset::json::samples_from_json(&json).unwrap();
    let parsed_refs: Vec<_> = parsed.iter().collect();
    let from_bundle = served.predict(&parsed_refs).unwrap();
    assert_eq!(in_process, from_bundle, "bundle + JSON round trip must be bit-exact");
    std::fs::remove_file(&path).ok();
}

#[test]
fn large_sample_roundtrips_json_and_store() {
    // satellite: a 1k-stage TpuGraphs-scale sample must survive both
    // persistence formats unchanged — the JSON interchange (`predict
    // --samples`) and the binary store (`train --data`) — now that stage
    // ids are u32
    use gcn_perf::zoo::large::{large_sample, LargeConfig, LargeStyle};
    let cfg = LargeConfig { style: LargeStyle::Inception, n_stages: 1_000, ..Default::default() };
    let s = large_sample(&cfg, 3, 5);
    assert_eq!(s.n_stages, 1_000);

    // JSON: the text interchange keeps ids, topology and payload intact
    let json = gcn_perf::dataset::json::samples_to_json(std::slice::from_ref(&s));
    let parsed = gcn_perf::dataset::json::samples_from_json(&json).unwrap();
    assert_eq!(parsed.len(), 1);
    assert_eq!(parsed[0].pipeline_id, s.pipeline_id);
    assert_eq!(parsed[0].schedule_id, s.schedule_id);
    assert_eq!(parsed[0].n_stages, s.n_stages);
    assert_eq!(parsed[0].edges, s.edges);
    assert_eq!(parsed[0].inv, s.inv);
    assert_eq!(parsed[0].dep, s.dep);
    assert_eq!(parsed[0].runs, s.runs);

    // binary store: the JSON-parsed sample saves and loads bit-exactly
    let ds = gcn_perf::dataset::Dataset { samples: parsed, stats: None };
    let path = std::env::temp_dir().join("gcn_perf_it_large_roundtrip.bin");
    store::save(&ds, &path).unwrap();
    let loaded = store::load(&path).unwrap();
    assert_eq!(loaded.samples.len(), 1);
    assert_eq!(loaded.samples[0].edges, s.edges);
    assert_eq!(loaded.samples[0].inv, s.inv);
    assert_eq!(loaded.samples[0].dep, s.dep);
    assert_eq!(loaded.samples[0].runs, s.runs);
    std::fs::remove_file(&path).ok();
}

#[test]
fn search_accepts_every_registered_model() {
    // `gcn-perf search --model <name>` resolution: baselines fit from a
    // training split, the gcn arrives as a bundle; all drive beam search
    // through the cached PredictorCost bridge
    use gcn_perf::predictor::registry::{fit_model, load_bundle, FitConfig, REGISTERED};
    use gcn_perf::search::{beam_search, BeamConfig, CostModel, PredictorCost, SimCost};

    let ds = small_dataset(5, 6, 23);
    let net = gcn_perf::zoo::unet();
    let nests = gcn_perf::lower::lower_pipeline(&net);
    let machine = Machine::default();
    let cfg = FitConfig { ffn_epochs: 1, rnn_epochs: 1, gbt_trees: 8, ..Default::default() };

    let bundle = std::env::temp_dir().join("gcn_perf_it_search_gcn.bundle");
    let backend = NativeBackend::new();
    let params = backend.init_params(11);
    GcnPredictor::new(Box::new(backend), params, ds.stats.clone().unwrap())
        .save(&bundle)
        .unwrap();

    let mut rng = gcn_perf::util::rng::Rng::new(6);
    let probe: Vec<_> = (0..4)
        .map(|_| gcn_perf::schedule::random::random_pipeline_schedule(&net, &nests, &mut rng))
        .collect();

    for &name in REGISTERED {
        let predictor = if name == "gcn" {
            load_bundle(&bundle).unwrap()
        } else {
            fit_model(name, &ds, &cfg).unwrap()
        };
        let cost = PredictorCost::new(predictor, machine.clone());
        let scores = cost.score(&net, &nests, &probe).unwrap();
        assert!(
            scores.iter().all(|s| s.is_finite() && *s > 0.0),
            "model '{name}' produced bad scores: {scores:?}"
        );
    }
    std::fs::remove_file(&bundle).ok();

    // the oracle path still works and beam search runs on a learned cost
    let oracle = SimCost { machine: machine.clone() };
    let (sched, _) = beam_search(
        &net,
        &nests,
        &oracle,
        &BeamConfig { beam_width: 2, candidates_per_stage: 3, seed: 1 },
    )
    .unwrap();
    gcn_perf::schedule::legality::check_pipeline(&net, &nests, &sched).unwrap();
}

/// The serving layer against the real GCN — the PR 4 acceptance tests:
/// coalesced results bitwise-equal to direct single-caller predictions
/// under concurrent mixed-size traffic, plus backpressure and clean
/// shutdown semantics end to end.
mod service {
    use super::*;
    use gcn_perf::dataset::builder::sample_from_schedule;
    use gcn_perf::dataset::sample::GraphSample;
    use gcn_perf::predictor::{PredictHandle, PredictRequest, PredictService, ServiceConfig};
    use std::sync::Arc;

    /// Mixed-size workload: generator pipelines (~5–10 stages) plus
    /// >48-stage resnet50 schedules.
    fn mixed_samples(
        seed: u64,
    ) -> (Vec<GraphSample>, gcn_perf::features::normalize::FeatureStats) {
        let ds = small_dataset(6, 4, seed);
        let stats = ds.stats.clone().unwrap();
        let mut samples = ds.samples;
        let net = gcn_perf::zoo::resnet50();
        let nests = gcn_perf::lower::lower_pipeline(&net);
        let machine = Machine::default();
        let mut rng = gcn_perf::util::rng::Rng::new(seed ^ 0xA5);
        for sid in 0..6u32 {
            let sched =
                gcn_perf::schedule::random::random_pipeline_schedule(&net, &nests, &mut rng);
            samples.push(sample_from_schedule(
                &net, &nests, &sched, &machine, 500, sid, &mut rng,
            ));
        }
        (samples, stats)
    }

    fn gcn_session(
        stats: gcn_perf::features::normalize::FeatureStats,
        seed: u64,
    ) -> Arc<GcnPredictor> {
        let backend = NativeBackend::new();
        let params = backend.init_params(seed);
        Arc::new(GcnPredictor::new(Box::new(backend), params, stats))
    }

    #[test]
    fn stress_coalesced_equals_direct_bitwise() {
        let (samples, stats) = mixed_samples(41);
        let predictor = gcn_session(stats, 9);
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let direct = predictor.predict(&refs).unwrap();

        let service = PredictService::spawn(
            predictor.clone(),
            ServiceConfig { workers: 2, queue_cap: 8, max_coalesce: 16, ..Default::default() },
        );
        // 8 concurrent clients; each interleaves whole-list requests with
        // per-candidate (size-1) requests over a rotated view of the
        // samples, so drains coalesce heterogeneous graph sizes
        std::thread::scope(|scope| {
            for c in 0..8usize {
                let service = &service;
                let samples = &samples;
                let direct = &direct;
                scope.spawn(move || {
                    for round in 0..3usize {
                        let rot = (c * 5 + round) % samples.len();
                        if round % 2 == 0 {
                            // whole rotated list in one request
                            let list: Vec<GraphSample> = samples[rot..]
                                .iter()
                                .chain(&samples[..rot])
                                .cloned()
                                .collect();
                            let want: Vec<f64> = direct[rot..]
                                .iter()
                                .chain(&direct[..rot])
                                .copied()
                                .collect();
                            let resp = service
                                .predict_blocking(PredictRequest::new(list))
                                .unwrap();
                            assert_eq!(
                                resp.predictions, want,
                                "client {c} round {round}: coalesced != direct"
                            );
                        } else {
                            // per-candidate singles
                            for (i, s) in samples.iter().enumerate().skip(rot).take(4) {
                                let resp = service
                                    .predict_blocking(PredictRequest::new(vec![s.clone()]))
                                    .unwrap();
                                assert_eq!(resp.predictions, vec![direct[i]]);
                            }
                        }
                    }
                });
            }
        });
        let stats = service.stats();
        assert!(stats.requests >= 8, "stress traffic not recorded: {stats:?}");
        assert!(stats.samples_evaluated > 0);
    }

    #[test]
    fn shutdown_drains_in_flight_gcn_requests() {
        let (samples, stats) = mixed_samples(43);
        let predictor = gcn_session(stats, 11);
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let direct = predictor.predict(&refs).unwrap();

        let service = PredictService::spawn(
            predictor,
            ServiceConfig { queue_cap: 64, ..Default::default() },
        );
        let handles: Vec<(usize, PredictHandle)> = (0..samples.len())
            .map(|i| (i, service.submit(PredictRequest::new(vec![samples[i].clone()])).unwrap()))
            .collect();
        drop(service); // drain-on-drop: every accepted request completes
        for (i, h) in handles {
            assert_eq!(h.wait().unwrap().predictions, vec![direct[i]]);
        }
    }
}

/// PJRT-artifact round trips — only meaningful with a real xla binding and
/// built artifacts; gated behind the `pjrt` feature. Tests skip gracefully
/// when `artifacts/` is missing (run `make artifacts`).
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use gcn_perf::runtime::GcnRuntime;

    fn artifacts() -> Option<&'static Path> {
        let p = Path::new("artifacts");
        if p.join("manifest.json").exists() {
            Some(p)
        } else {
            eprintln!("skipping: artifacts/ not built");
            None
        }
    }

    #[test]
    fn pjrt_infer_matches_native_forward() {
        let Some(dir) = artifacts() else { return };
        let rt = match GcnRuntime::load(dir, false) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: pjrt unavailable ({e:#})");
                return;
            }
        };
        let ds = small_dataset(4, 8, 5);
        let stats = ds.stats.clone().unwrap();
        let best = ds.best_per_pipeline();
        let refs: Vec<_> = ds.samples.iter().take(BATCH).collect();
        let bests: Vec<f64> = refs.iter().map(|s| best[&s.pipeline_id]).collect();
        let batch = PackedBatch::build(&refs, &stats, &bests).unwrap();
        let params = rt.init_params(3);
        let z = rt.infer(&params, &batch).unwrap();
        assert_eq!(z.len(), refs.len());
        assert_eq!(z, rt.infer(&params, &batch).unwrap(), "pjrt inference must be deterministic");
        assert!(z.iter().all(|v| v.is_finite()));

        // the two engines run the same model on the same params: the AOT
        // artifact (f32 XLA graph) and the native engine (f64-accumulated)
        // must agree closely
        let native = NativeBackend::new();
        let zn = native.infer(&params, &batch).unwrap();
        for (i, (a, b)) in z.iter().zip(&zn).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3,
                "pjrt/native divergence at sample {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn pjrt_training_reduces_loss() {
        let Some(dir) = artifacts() else { return };
        let rt = match GcnRuntime::load(dir, true) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: pjrt unavailable ({e:#})");
                return;
            }
        };
        let ds = small_dataset(24, 10, 7);
        let (train_ds, test_ds) = ds.split(0.15, 99);
        let result = train(
            &rt,
            &train_ds,
            &test_ds,
            &TrainConfig {
                epochs: 6,
                seed: 7,
                patience: 10,
                verbose: false,
                eval_every: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let first = result.history.first().unwrap().train_loss;
        let last = result.history.last().unwrap().train_loss;
        assert!(last < first * 0.8, "training should reduce loss: {first} -> {last}");
    }

    #[test]
    fn ablation_variants_load_and_run() {
        let Some(dir) = artifacts() else { return };
        for suffix in ["_l0", "_l1", "_l4"] {
            let rt = match GcnRuntime::load_variant(dir, suffix, false) {
                Ok(rt) => rt,
                Err(e) => {
                    eprintln!("skipping {suffix}: {e}");
                    return;
                }
            };
            assert_eq!(rt.manifest.batch, BATCH);
        }
    }
}

/// Process-level CLI tests of the quantized serving path: the `quantize`
/// subcommand mints a registry bundle the binary serves at int8, and a
/// `--precision` request that contradicts the bundle is a *usage* error
/// (exit 2 with a pointed message), never a runtime crash.
mod cli {
    use super::{small_dataset, Backend, NativeBackend};
    use std::process::Command;

    fn bin() -> Command {
        Command::new(env!("CARGO_BIN_EXE_gcn-perf"))
    }

    fn mint_f32_bundle(path: &std::path::Path, seed: u64) -> gcn_perf::dataset::Dataset {
        let ds = small_dataset(3, 4, seed);
        let be = NativeBackend::new();
        gcn_perf::predictor::save_gcn_bundle(
            path,
            be.manifest().n_conv,
            &be.init_params(seed),
            ds.stats.as_ref().unwrap(),
        )
        .unwrap();
        ds
    }

    #[test]
    fn bench_precision_int8_without_a_quantized_bundle_exits_2() {
        // no bundle at all: nothing quantized to run against
        let out = bin().args(["bench", "--fast", "--precision", "int8"]).output().unwrap();
        assert_eq!(out.status.code(), Some(2), "{out:?}");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("gcn-perf quantize"), "stderr: {err}");

        // an explicit f32 bundle on hand: still a usage error, caught
        // before any benchmark timing starts
        let f32_path = std::env::temp_dir().join("gcn_perf_cli_bench_f32.bundle");
        mint_f32_bundle(&f32_path, 33);
        let out = bin()
            .args(["bench", "--fast", "--precision", "int8", "--bundle"])
            .arg(&f32_path)
            .output()
            .unwrap();
        assert_eq!(out.status.code(), Some(2), "{out:?}");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("quantized bundle"), "stderr: {err}");
        std::fs::remove_file(&f32_path).ok();
    }

    #[test]
    fn quantize_then_predict_int8_through_the_binary() {
        let dir = std::env::temp_dir();
        let f32_path = dir.join("gcn_perf_cli_q_src.bundle");
        let int8_path = dir.join("gcn_perf_cli_q_int8.bundle");
        let samples_path = dir.join("gcn_perf_cli_q_samples.json");
        let ds = mint_f32_bundle(&f32_path, 77);
        std::fs::write(
            &samples_path,
            gcn_perf::dataset::json::samples_to_json(&ds.samples[..4]),
        )
        .unwrap();

        let out = bin()
            .args(["quantize", "--bundle"])
            .arg(&f32_path)
            .arg("--out")
            .arg(&int8_path)
            .output()
            .unwrap();
        assert!(out.status.success(), "{out:?}");
        assert!(String::from_utf8_lossy(&out.stdout).contains("gcn-int8"), "{out:?}");

        // full precision is the original bundle's job, not the int8 one's
        let out = bin()
            .args(["predict", "--precision", "f32", "--samples"])
            .arg(&samples_path)
            .arg("--bundle")
            .arg(&int8_path)
            .output()
            .unwrap();
        assert_eq!(out.status.code(), Some(2), "{out:?}");
        assert!(String::from_utf8_lossy(&out.stderr).contains("f32 bundle"), "{out:?}");

        // the int8 bundle answers predictions through the stock CLI path
        let out = bin()
            .args(["predict", "--precision", "int8", "--samples"])
            .arg(&samples_path)
            .arg("--bundle")
            .arg(&int8_path)
            .output()
            .unwrap();
        assert!(out.status.success(), "{out:?}");
        assert!(String::from_utf8_lossy(&out.stderr).contains("int8 precision"), "{out:?}");
        let report = String::from_utf8_lossy(&out.stdout).to_string();
        assert!(report.contains("gcn-int8"), "stdout: {report}");
        gcn_perf::util::json::Json::parse(&report).unwrap();

        for p in [&f32_path, &int8_path, &samples_path] {
            std::fs::remove_file(p).ok();
        }
    }
}
