//! Fleet autotuner integration tests: determinism through a shared
//! service, bitwise checkpoint-resume, legality of every emitted
//! schedule, and trace harvesting into the training format.

use anyhow::Result;
use gcn_perf::autotune::{
    run_fleet, BeamStrategy, EvolutionConfig, EvolutionStrategy, FleetConfig, FleetCost,
    SearchStrategy, StrategyKind,
};
use gcn_perf::dataset::sample::GraphSample;
use gcn_perf::predictor::{PredictService, Predictor, ServiceConfig};
use gcn_perf::search::{BeamConfig, SimCost};
use gcn_perf::sim::Machine;
use std::path::Path;
use std::sync::Arc;

/// Deterministic toy model: a fixed linear read of each sample's
/// schedule-dependent features. Per-sample and order-independent, so
/// served predictions cannot depend on how the coalescer batches them —
/// which is what lets the fleet tests assert bitwise determinism.
struct FeatureSum;

impl Predictor for FeatureSum {
    fn name(&self) -> String {
        "feature-sum".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        Ok(samples
            .iter()
            .map(|s| {
                let mut acc = s.n_stages as f64 * 1e-3;
                for row in &s.dep {
                    for (j, v) in row.iter().enumerate() {
                        acc += (*v as f64) * (1.0 + (j % 7) as f64) * 1e-6;
                    }
                }
                acc
            })
            .collect())
    }
    fn save(&self, _: &Path) -> Result<()> {
        anyhow::bail!("toy test model; not saveable")
    }
}

fn fleet_cfg(nets: &[&str], seed: u64) -> FleetConfig {
    FleetConfig {
        networks: nets.iter().map(|s| s.to_string()).collect(),
        strategy: StrategyKind::Evolution,
        evolution: EvolutionConfig {
            population: 3,
            offspring: 5,
            immigrants: 2,
            generations: 5,
            seed: 0,
        },
        seed,
        ..Default::default()
    }
}

#[test]
fn concurrent_fleet_through_one_shared_service_is_deterministic() {
    // acceptance: >= 4 pipelines tuned concurrently through ONE shared
    // PredictService, bitwise repeatable for a fixed seed
    let nets = ["alexnet", "squeezenet", "unet", "resnet18"];
    let run = |sequential: bool| {
        let service = Arc::new(PredictService::spawn(
            Arc::new(FeatureSum),
            ServiceConfig { workers: 2, queue_cap: 8, ..Default::default() },
        ));
        let cfg = FleetConfig { sequential, ..fleet_cfg(&nets, 11) };
        run_fleet(&cfg, &FleetCost::Service(service)).unwrap()
    };
    let a = run(false);
    let b = run(false);
    for (x, y) in a.results.iter().zip(&b.results) {
        assert_eq!(x.network, y.network);
        assert_eq!(x.best_schedule, y.best_schedule, "{} diverged across runs", x.network);
        assert_eq!(x.tuned_cost.to_bits(), y.tuned_cost.to_bits());
        assert_eq!(
            x.model_best_cost.map(f64::to_bits),
            y.model_best_cost.map(f64::to_bits)
        );
    }
    // ...and the interleaving doesn't matter: sequential mode agrees too
    let s = run(true);
    for (x, y) in a.results.iter().zip(&s.results) {
        assert_eq!(x.best_schedule, y.best_schedule, "{}: concurrent != sequential", x.network);
        assert_eq!(x.tuned_cost.to_bits(), y.tuned_cost.to_bits());
    }
    let stats = a.service_stats.expect("shared service counters");
    assert!(stats.requests >= nets.len(), "every fleet member scored through the service");
    assert!(stats.samples_evaluated > 0 && stats.batches > 0);
    for r in &a.results {
        assert!(r.completed);
        assert!(r.tuned_cost <= r.default_cost, "{}: incumbent rule violated", r.network);
    }
}

#[test]
fn interrupted_fleet_resumes_bitwise() {
    let dir = std::env::temp_dir().join("gcn_perf_autotune_resume_test");
    let _ = std::fs::remove_dir_all(&dir);
    let nets = ["alexnet", "squeezenet"];
    let base = fleet_cfg(&nets, 23);

    // reference: one uninterrupted run, no checkpoints
    let full = run_fleet(&base, &FleetCost::Oracle).unwrap();

    // "kill" after 2 generations, checkpointing every generation
    let interrupted = FleetConfig {
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 1,
        step_limit: 2,
        ..base.clone()
    };
    let partial = run_fleet(&interrupted, &FleetCost::Oracle).unwrap();
    for r in &partial.results {
        assert!(!r.completed, "{} should have been interrupted", r.network);
        assert_eq!(r.generations, 2);
        // the incumbent rule keeps the default until the search finishes
        assert!(r.adopted_default);
        assert_eq!(r.tuned_cost.to_bits(), r.default_cost.to_bits());
    }

    // resume to completion: bitwise identical to the uninterrupted run
    let resumed_cfg = FleetConfig { resume: true, step_limit: 0, ..interrupted };
    let resumed = run_fleet(&resumed_cfg, &FleetCost::Oracle).unwrap();
    for (a, b) in full.results.iter().zip(&resumed.results) {
        assert!(b.completed);
        assert_eq!(b.resumed_from, Some(2));
        assert_eq!(a.best_schedule, b.best_schedule, "{}: resume diverged", a.network);
        assert_eq!(a.tuned_cost.to_bits(), b.tuned_cost.to_bits());
        assert_eq!(a.generations, b.generations);
    }

    // resuming a finished fleet is a no-op reporting the same outcome
    let again = run_fleet(&resumed_cfg, &FleetCost::Oracle).unwrap();
    for (a, b) in resumed.results.iter().zip(&again.results) {
        assert_eq!(a.best_schedule, b.best_schedule);
        assert_eq!(a.tuned_cost.to_bits(), b.tuned_cost.to_bits());
        assert_eq!(b.candidates_scored, 0, "finished search must not rescore");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_random_beam_and_evolution_schedules_are_all_legal() {
    use gcn_perf::schedule::legality::check_pipeline;
    use gcn_perf::schedule::random::random_pipeline_schedule;
    use gcn_perf::util::propcheck;

    let p = gcn_perf::zoo::squeezenet();
    let nests = gcn_perf::lower::lower_pipeline(&p);
    let model = SimCost { machine: Machine::default() };
    let cases = propcheck::default_cases().min(8);
    propcheck::check_rng("autotune schedule legality", 0xA07, cases, |rng| {
        let seed = rng.next_u64();
        for _ in 0..4 {
            let s = random_pipeline_schedule(&p, &nests, rng);
            check_pipeline(&p, &nests, &s)?;
        }
        let mut beam =
            BeamStrategy::new(BeamConfig { beam_width: 2, candidates_per_stage: 2, seed });
        let mut evo = EvolutionStrategy::new(EvolutionConfig {
            population: 3,
            offspring: 4,
            immigrants: 1,
            generations: 2,
            seed,
        });
        for strat in [&mut beam as &mut dyn SearchStrategy, &mut evo] {
            while !strat.done() {
                let scored = strat.step(&p, &nests, &model).map_err(|e| e.to_string())?;
                for (sched, _) in scored {
                    check_pipeline(&p, &nests, &sched)
                        .map_err(|e| format!("{}: {e}", strat.name()))?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fleet_traces_round_trip_into_the_training_format() {
    let nets = ["alexnet", "squeezenet"];
    let cfg = fleet_cfg(&nets, 31);
    let report = run_fleet(&cfg, &FleetCost::Oracle).unwrap();
    assert!(!report.samples.is_empty());
    for s in &report.samples {
        s.validate().unwrap();
    }
    // pipeline ids tag fleet membership
    let pids: std::collections::BTreeSet<u32> =
        report.samples.iter().map(|s| s.pipeline_id).collect();
    assert_eq!(pids.len(), nets.len());

    // the wire format `train --data` reads: serialize, parse, fit stats
    let text = gcn_perf::dataset::json::samples_to_json(&report.samples);
    let back = gcn_perf::dataset::json::samples_from_json(&text).unwrap();
    assert_eq!(back.len(), report.samples.len());
    for (a, b) in report.samples.iter().zip(&back) {
        assert_eq!(a.runs, b.runs, "cost-to-go labels must survive the round trip");
    }
    let mut ds = gcn_perf::dataset::Dataset { samples: back, stats: None };
    ds.fit_stats();
    assert!(ds.stats.is_some());
}
