//! Static-analyzer integration tests: analyzer cleanliness of everything
//! the generators and search strategies produce, loader rejection of the
//! corrupt fixtures with stable `D0xx` codes, checkpoint-restore bitwise
//! equivalence through the precomputed-analysis strategies, and the
//! `gcn-perf analyze` subcommand's exit-code contract.

use gcn_perf::analysis::{analyze_pipeline_schedule, AnalyzedPipeline, Report, Severity};
use gcn_perf::lower::lower_pipeline;
use gcn_perf::onnx_gen::{generate_model, GenConfig};
use gcn_perf::schedule::primitives::PipelineSchedule;
use gcn_perf::schedule::random::random_pipeline_schedule;
use gcn_perf::util::propcheck::{check_rng, default_cases};
use gcn_perf::util::rng::Rng;

fn fixture(name: &str) -> String {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// Every finding the full pass stack produces for `(p, sched)`.
fn full_analysis(p: &gcn_perf::ir::pipeline::Pipeline, sched: &PipelineSchedule) -> Report {
    let mut report = Report::new(&p.name);
    analyze_pipeline_schedule(p, sched, &mut report);
    report
}

#[test]
fn zoo_default_schedules_are_analyzer_clean_strict() {
    for net in gcn_perf::zoo::all_networks() {
        let ranks: Vec<usize> = net.stages.iter().map(|s| s.shape.len()).collect();
        let report = full_analysis(&net, &PipelineSchedule::default_for(&ranks));
        assert!(report.is_clean(true), "{}: {}", net.name, report.to_text());
    }
}

#[test]
fn prop_random_schedules_are_analyzer_error_free() {
    // whatever the generator emits for whatever pipeline the model
    // generator builds must carry zero Error-severity findings (warnings
    // like W003/W004 are legitimate fusion-hazard notes, not bugs)
    check_rng("random_schedules_analyzer_clean", 0x9A7, default_cases() / 4, |rng| {
        let p = generate_model(&GenConfig::default(), rng, 0);
        let nests = lower_pipeline(&p);
        let sched = random_pipeline_schedule(&p, &nests, rng);
        let report = full_analysis(&p, &sched);
        let errors: Vec<_> = report
            .diags
            .iter()
            .filter(|d| d.severity() == Severity::Error)
            .collect();
        if errors.is_empty() {
            Ok(())
        } else {
            Err(format!("{} analyzer errors on generator output: {errors:?}", errors.len()))
        }
    });
}

#[test]
fn beam_and_evolution_outputs_are_analyzer_error_free() {
    use gcn_perf::autotune::{BeamStrategy, EvolutionConfig, EvolutionStrategy, SearchStrategy};
    use gcn_perf::search::{BeamConfig, SimCost};
    use gcn_perf::sim::Machine;

    let net = gcn_perf::zoo::squeezenet();
    let nests = lower_pipeline(&net);
    let model = SimCost { machine: Machine::default() };

    let mut strategies: Vec<Box<dyn SearchStrategy>> = vec![
        Box::new(BeamStrategy::new(BeamConfig {
            beam_width: 3,
            candidates_per_stage: 4,
            seed: 5,
        })),
        Box::new(EvolutionStrategy::new(EvolutionConfig {
            population: 4,
            offspring: 6,
            immigrants: 2,
            generations: 4,
            seed: 5,
        })),
    ];
    for strat in &mut strategies {
        while !strat.done() {
            strat.step(&net, &nests, &model).unwrap();
        }
        let (best, cost) = strat.best().expect("strategy found no schedule");
        assert!(cost.is_finite() && cost > 0.0);
        let report = full_analysis(&net, best);
        assert_eq!(
            report.errors(),
            0,
            "{} best schedule has analyzer errors: {}",
            strat.name(),
            report.to_text()
        );
    }
}

#[test]
fn checkpoint_restore_stays_bitwise_with_precomputed_analysis() {
    // the strategies rebuild their AnalyzedPipeline lazily after a
    // restore; a resumed run must still replay bit-for-bit (schedule and
    // cost) against the uninterrupted one
    use gcn_perf::autotune::{BeamStrategy, EvolutionConfig, EvolutionStrategy, SearchStrategy};
    use gcn_perf::search::{BeamConfig, SimCost};
    use gcn_perf::sim::Machine;

    let net = gcn_perf::zoo::unet();
    let nests = lower_pipeline(&net);
    let model = SimCost { machine: Machine::default() };

    let make: Vec<fn() -> Box<dyn SearchStrategy>> = vec![
        || {
            Box::new(BeamStrategy::new(BeamConfig {
                beam_width: 2,
                candidates_per_stage: 3,
                seed: 9,
            }))
        },
        || {
            Box::new(EvolutionStrategy::new(EvolutionConfig {
                population: 3,
                offspring: 4,
                immigrants: 1,
                generations: 5,
                seed: 9,
            }))
        },
    ];
    for mk in make {
        let mut uninterrupted = mk();
        let mut a = mk();
        a.step(&net, &nests, &model).unwrap();
        a.step(&net, &nests, &model).unwrap();
        let state = a.save_state();

        let mut resumed = mk();
        resumed.restore_state(&state).unwrap();
        while !resumed.done() {
            resumed.step(&net, &nests, &model).unwrap();
        }
        while !uninterrupted.done() {
            uninterrupted.step(&net, &nests, &model).unwrap();
        }
        let (su, cu) = uninterrupted.best().unwrap();
        let (sr, cr) = resumed.best().unwrap();
        assert_eq!(su, sr, "{}: resumed schedule diverged", resumed.name());
        assert_eq!(
            cu.to_bits(),
            cr.to_bits(),
            "{}: resumed cost diverged",
            resumed.name()
        );
    }
}

mod loader_rejection {
    use super::fixture;
    use gcn_perf::dataset::json::samples_from_json;

    fn rejects_with(name: &str, code: &str) {
        let err = samples_from_json(&fixture(name))
            .expect_err(&format!("{name} must be rejected"));
        let rendered = format!("{err:#}");
        assert!(rendered.contains(code), "{name}: expected {code} in: {rendered}");
    }

    #[test]
    fn out_of_range_edge_is_d002() {
        rejects_with("bad_edge_range.json", "D002");
    }

    #[test]
    fn forward_edge_is_d008() {
        rejects_with("bad_edge_forward.json", "D008");
    }

    #[test]
    fn cycle_is_d008() {
        rejects_with("bad_edge_cycle.json", "D008");
    }

    #[test]
    fn negative_runtime_is_d004() {
        rejects_with("bad_runtime.json", "D004");
    }

    #[test]
    fn binary_store_rejects_the_same_graphs() {
        // the two loaders share validate(): a graph the JSON path rejects
        // must not slip through the binary one
        use gcn_perf::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
        use gcn_perf::dataset::sample::{Dataset, GraphSample};
        let bad = GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: 2,
            edges: vec![(1, 0)],
            inv: vec![[0.5; INV_DIM]; 2],
            dep: vec![[1.0; DEP_DIM]; 2],
            runs: [1e-3; BENCH_RUNS],
        };
        let ds = Dataset { samples: vec![bad], stats: None };
        let path = std::env::temp_dir().join("gcn_perf_analysis_it_forward.bin");
        gcn_perf::dataset::store::save(&ds, &path).unwrap();
        let err = gcn_perf::dataset::store::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("D008"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn shim_and_analyzed_pipeline_agree_on_the_fixture_networks() {
    // accept/reject parity of the legacy entry point and the precomputed
    // tables on real zoo networks (random legal + hand-broken schedules)
    let mut rng = Rng::new(77);
    for net in [gcn_perf::zoo::unet(), gcn_perf::zoo::squeezenet()] {
        let nests = lower_pipeline(&net);
        let ap = AnalyzedPipeline::build(&net, &nests);
        for i in 0..24 {
            let mut sched = random_pipeline_schedule(&net, &nests, &mut rng);
            if i % 3 == 0 {
                let sid = rng.gen_range(sched.stages.len());
                sched.stages[sid].vector_width = 7;
            }
            let legacy = gcn_perf::schedule::legality::check_pipeline(&net, &nests, &sched);
            assert_eq!(
                legacy.is_ok(),
                ap.check_schedule(&sched).is_ok(),
                "verdict divergence on {} schedule {i}",
                net.name
            );
            // the collect-all verifier must agree with the fast path too
            assert_eq!(legacy.is_ok(), ap.verify_schedule(&sched).is_empty());
        }
    }
}

/// Process-level tests of the `analyze` subcommand's exit-code contract:
/// 0 clean, 1 with findings, 2 on usage errors.
mod cli {
    use std::process::Command;

    fn bin() -> Command {
        Command::new(env!("CARGO_BIN_EXE_gcn-perf"))
    }

    fn fixture_path(name: &str) -> String {
        format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn analyze_zoo_is_clean_and_exits_0() {
        let out = bin().args(["analyze", "--zoo", "--schedules", "3"]).output().unwrap();
        assert_eq!(out.status.code(), Some(0), "{out:?}");
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("0 error(s)"), "stdout: {text}");
    }

    #[test]
    fn analyze_one_network_emits_parseable_json() {
        let out = bin()
            .args(["analyze", "--network", "unet", "--format", "json"])
            .output()
            .unwrap();
        assert_eq!(out.status.code(), Some(0), "{out:?}");
        let text = String::from_utf8_lossy(&out.stdout);
        let j = gcn_perf::util::json::Json::parse(&text).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("errors").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn analyze_corrupt_samples_exits_1_with_the_code() {
        let out = bin()
            .args(["analyze", "--samples", &fixture_path("bad_runtime.json")])
            .output()
            .unwrap();
        assert_eq!(out.status.code(), Some(1), "{out:?}");
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("D004"), "stdout: {text}");
    }

    #[test]
    fn analyze_bad_format_exits_2() {
        let out = bin().args(["analyze", "--format", "yaml"]).output().unwrap();
        assert_eq!(out.status.code(), Some(2), "{out:?}");
    }

    #[test]
    fn analyze_unknown_flag_exits_2() {
        let out = bin().args(["analyze", "--no-such-flag"]).output().unwrap();
        assert_eq!(out.status.code(), Some(2), "{out:?}");
    }
}
