//! Integration tests for the TCP serving front-end: fault injection
//! (torn lines, disconnects, slow-loris peers, oversized payloads),
//! pipelining and ordering, admission control, backpressure under
//! networked load, drain-during-load, and the stdin/TCP `STATS` parity
//! contract.
//!
//! Determinism policy: no sleeps as synchronization. Every trigger is an
//! observed event (a response arriving, a counter crossing a threshold,
//! EOF, a join); the only timeouts are bounds that turn a hang into a
//! failing test.

use anyhow::{bail, Result};
use gcn_perf::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
use gcn_perf::dataset::json::samples_to_json;
use gcn_perf::dataset::sample::GraphSample;
use gcn_perf::net::{
    fetch_stats, run_loadgen, serve_session, write_frame, FrameReader, LoadgenConfig, ServeShared,
    SessionOpts, TcpServer, TcpServerConfig, DEFAULT_MAX_FRAME_BYTES,
};
use gcn_perf::predictor::{PredictRequest, PredictService, Predictor, ServiceConfig};
use gcn_perf::util::json::Json;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A linear-chain sample; `n` stages, all features `tag` (invariant) and
/// `tag * 0.5` (dependent) — distinct `(n, tag)` pairs never collide in
/// the memo cache.
fn chain_sample(n: u32, tag: f32) -> GraphSample {
    GraphSample {
        pipeline_id: tag as u32,
        schedule_id: n,
        n_stages: n,
        edges: (1..n).map(|i| (i - 1, i)).collect(),
        inv: vec![[tag; INV_DIM]; n as usize],
        dep: vec![[tag * 0.5; DEP_DIM]; n as usize],
        runs: [1e-3; BENCH_RUNS],
    }
}

/// Deterministic stand-in model whose output depends on the payload
/// (stage count *and* a feature value), so a served prediction proves
/// the request round-tripped the wire intact.
struct StagesPredictor {
    scale: f64,
}

impl Predictor for StagesPredictor {
    fn name(&self) -> String {
        "stages".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        Ok(samples.iter().map(|s| s.n_stages as f64 * self.scale + s.inv[0][0] as f64).collect())
    }
    fn save(&self, _: &Path) -> Result<()> {
        bail!("test predictor cannot be saved")
    }
}

/// Blocks inside `predict` until released; signals entry so tests can
/// park the worker deterministically.
struct GatedPredictor {
    entered: Arc<(Mutex<usize>, Condvar)>,
    release: Arc<(Mutex<bool>, Condvar)>,
}

impl Predictor for GatedPredictor {
    fn name(&self) -> String {
        "gated".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        {
            let (m, c) = &*self.entered;
            *lock(m) += 1;
            c.notify_all();
        }
        let (m, c) = &*self.release;
        let mut open = lock(m);
        while !*open {
            open = c.wait(open).unwrap_or_else(|e| e.into_inner());
        }
        Ok(vec![0.5; samples.len()])
    }
    fn save(&self, _: &Path) -> Result<()> {
        bail!("gated predictor cannot be saved")
    }
}

fn stages_shared(workers: usize, queue_cap: usize) -> (ServeShared, Arc<dyn Predictor>) {
    let predictor: Arc<dyn Predictor> = Arc::new(StagesPredictor { scale: 1e-3 });
    let service = Arc::new(PredictService::spawn(
        Arc::clone(&predictor),
        ServiceConfig { workers, queue_cap, ..Default::default() },
    ));
    (ServeShared::new(service), predictor)
}

fn start_server(shared: ServeShared, cfg: TcpServerConfig) -> (TcpServer, String) {
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = TcpServer::bind("127.0.0.1:0", shared, cfg, shutdown).unwrap();
    let addr = server.local_addr().to_string();
    (server, addr)
}

/// Bounded poll: the *condition* is the synchronization; the deadline
/// only turns a hang into a failing test.
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn read_frames_until_eof(stream: TcpStream) -> Vec<String> {
    let mut frames = FrameReader::new(stream, DEFAULT_MAX_FRAME_BYTES);
    let mut out = Vec::new();
    while let Ok(Some(line)) = frames.next_frame() {
        out.push(line);
    }
    out
}

fn expect_preds(predictor: &dyn Predictor, samples: &[GraphSample]) -> Vec<f64> {
    let refs: Vec<&GraphSample> = samples.iter().collect();
    predictor.predict(&refs).unwrap()
}

/// Assert one response line reports `samples` in order, with predictions
/// bitwise equal to direct `Predictor::predict` output.
fn check_response_bitwise(line: &str, model: &str, samples: &[GraphSample], expected: &[f64]) {
    let j = Json::parse(line).unwrap();
    assert_eq!(j.get("model").and_then(|m| m.as_str()), Some(model), "in {line}");
    let rows = j.get("predictions").and_then(|p| p.as_arr()).expect("predictions array");
    assert_eq!(rows.len(), samples.len());
    for ((row, s), want) in rows.iter().zip(samples).zip(expected) {
        let pid = row.get("pipeline_id").and_then(|v| v.as_usize());
        let sid = row.get("schedule_id").and_then(|v| v.as_usize());
        assert_eq!(pid, Some(s.pipeline_id as usize));
        assert_eq!(sid, Some(s.schedule_id as usize));
        let got = row.get("predicted_runtime_s").and_then(|v| v.as_f64()).expect("runtime");
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "served prediction {got} diverges bitwise from direct {want}"
        );
    }
}

// ---------------------------------------------------- pipelining + order

#[test]
fn tcp_pipelining_preserves_order_and_matches_direct_predict_bitwise() {
    let (shared, predictor) = stages_shared(1, 8);
    let (server, addr) = start_server(shared, TcpServerConfig::default());

    // six requests written back-to-back before any response is read
    let requests: Vec<Vec<GraphSample>> =
        (1..=6u32).map(|n| vec![chain_sample(n, 0.5), chain_sample(n + 6, 0.25)]).collect();
    let mut stream = TcpStream::connect(&addr).unwrap();
    for req in &requests {
        write_frame(&mut stream, &samples_to_json(req)).unwrap();
    }
    stream.shutdown(Shutdown::Write).unwrap();

    let lines = read_frames_until_eof(stream);
    assert_eq!(lines.len(), requests.len(), "one response per request line");
    for (line, req) in lines.iter().zip(&requests) {
        check_response_bitwise(line, "stages", req, &expect_preds(predictor.as_ref(), req));
    }

    server.shutdown_now();
    let report = server.join().unwrap();
    assert_eq!(report.connections, 1);
    assert_eq!(report.rejected, 0);
}

#[test]
fn requests_split_across_many_socket_writes_still_frame() {
    let (shared, predictor) = stages_shared(1, 8);
    let (server, addr) = start_server(shared, TcpServerConfig::default());

    // a half-written line is not an error, just an incomplete frame: the
    // server must reassemble it however the bytes trickle in
    let req = vec![chain_sample(4, 0.125)];
    let mut line = samples_to_json(&req).into_bytes();
    line.push(b'\n');
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    for chunk in line.chunks(7) {
        stream.write_all(chunk).unwrap();
        stream.flush().unwrap();
    }
    stream.shutdown(Shutdown::Write).unwrap();

    let lines = read_frames_until_eof(stream);
    assert_eq!(lines.len(), 1);
    check_response_bitwise(&lines[0], "stages", &req, &expect_preds(predictor.as_ref(), &req));
    server.shutdown_now();
    server.join().unwrap();
}

// ------------------------------------------------------- fault injection

#[test]
fn mid_request_disconnect_is_contained_to_its_connection() {
    let (shared, predictor) = stages_shared(1, 8);
    let shared_view = shared.clone();
    let (server, addr) = start_server(shared, TcpServerConfig::default());

    // client 1: half a request line, then a hard disconnect
    let mut c1 = TcpStream::connect(&addr).unwrap();
    c1.write_all(b"[{\"pipeline_id\": 7, \"n_st").unwrap();
    drop(c1);

    // the torn line surfaces as exactly one protocol error on that
    // connection; the service itself never sees a request
    wait_until("the torn request to be counted", || {
        shared_view.counters.protocol_errors.load(Ordering::Relaxed) >= 1
    });

    // client 2 is served normally by the same shared service
    let req = vec![chain_sample(3, 0.5)];
    let mut c2 = TcpStream::connect(&addr).unwrap();
    write_frame(&mut c2, &samples_to_json(&req)).unwrap();
    c2.shutdown(Shutdown::Write).unwrap();
    let lines = read_frames_until_eof(c2);
    assert_eq!(lines.len(), 1);
    check_response_bitwise(&lines[0], "stages", &req, &expect_preds(predictor.as_ref(), &req));

    assert_eq!(server.service().stats().requests, 1, "torn line must not reach the service");
    server.shutdown_now();
    let report = server.join().unwrap();
    assert_eq!(report.connections, 2);
}

#[test]
fn oversized_request_line_gets_one_error_then_close() {
    let (shared, predictor) = stages_shared(1, 8);
    let cfg = TcpServerConfig { max_frame_bytes: 1024, ..Default::default() };
    let (server, addr) = start_server(shared, cfg);

    // 8 KiB without a newline: the framer must reject without buffering
    // the line to completion, answer once, and close
    let mut big = TcpStream::connect(&addr).unwrap();
    big.write_all(&[b'x'; 8 * 1024]).unwrap();
    let lines = read_frames_until_eof(big);
    assert_eq!(lines.len(), 1, "exactly one error line, then close");
    let j = Json::parse(&lines[0]).unwrap();
    let msg = j.get("error").and_then(|e| e.as_str()).expect("an error response");
    assert!(msg.contains("1024"), "error should name the limit: {msg}");

    // per-connection containment: the same server keeps serving
    let req = vec![chain_sample(2, 0.25)];
    let mut ok = TcpStream::connect(&addr).unwrap();
    write_frame(&mut ok, &samples_to_json(&req)).unwrap();
    ok.shutdown(Shutdown::Write).unwrap();
    let lines = read_frames_until_eof(ok);
    assert_eq!(lines.len(), 1);
    check_response_bitwise(&lines[0], "stages", &req, &expect_preds(predictor.as_ref(), &req));
    server.shutdown_now();
    server.join().unwrap();
}

#[test]
fn slow_loris_peer_is_evicted_by_the_read_timeout() {
    let (shared, predictor) = stages_shared(1, 8);
    let cfg = TcpServerConfig {
        read_timeout: Some(Duration::from_millis(100)),
        ..Default::default()
    };
    let (server, addr) = start_server(shared, cfg);

    // hold a connection open with a line that never completes
    let mut loris = TcpStream::connect(&addr).unwrap();
    loris.write_all(b"[").unwrap();
    // the server times the connection out and closes it without a
    // response; the bound below only turns a missed eviction into a
    // failing test instead of a hang
    loris.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut buf = Vec::new();
    let n = loris.read_to_end(&mut buf).unwrap();
    assert_eq!(n, 0, "evicted peer must get no bytes: {:?}", String::from_utf8_lossy(&buf));

    // eviction is per-connection: a prompt client is unaffected
    let req = vec![chain_sample(5, 0.75)];
    let mut ok = TcpStream::connect(&addr).unwrap();
    write_frame(&mut ok, &samples_to_json(&req)).unwrap();
    ok.shutdown(Shutdown::Write).unwrap();
    let lines = read_frames_until_eof(ok);
    assert_eq!(lines.len(), 1);
    check_response_bitwise(&lines[0], "stages", &req, &expect_preds(predictor.as_ref(), &req));
    server.shutdown_now();
    server.join().unwrap();
}

#[test]
fn admission_control_rejects_excess_connections_with_an_error_line() {
    let (shared, predictor) = stages_shared(1, 8);
    let cfg = TcpServerConfig { max_conns: 1, ..Default::default() };
    let (server, addr) = start_server(shared, cfg);

    // first client occupies the only slot; its served response proves
    // the slot was taken before the second connect below
    let req = vec![chain_sample(2, 0.5)];
    let expected = expect_preds(predictor.as_ref(), &req);
    let mut c1 = TcpStream::connect(&addr).unwrap();
    let mut frames1 = FrameReader::new(c1.try_clone().unwrap(), DEFAULT_MAX_FRAME_BYTES);
    write_frame(&mut c1, &samples_to_json(&req)).unwrap();
    let line = frames1.next_frame().unwrap().expect("first response");
    check_response_bitwise(&line, "stages", &req, &expected);

    // second client is turned away: one error line, then close
    let c2 = TcpStream::connect(&addr).unwrap();
    let lines = read_frames_until_eof(c2);
    assert_eq!(lines.len(), 1);
    let j = Json::parse(&lines[0]).unwrap();
    let msg = j.get("error").and_then(|e| e.as_str()).expect("rejection error line");
    assert!(msg.contains("capacity"), "{msg}");

    // the occupant is still fully served after the rejection
    write_frame(&mut c1, &samples_to_json(&req)).unwrap();
    let line = frames1.next_frame().unwrap().expect("second response");
    check_response_bitwise(&line, "stages", &req, &expected);

    drop(frames1);
    drop(c1);
    server.shutdown_now();
    let report = server.join().unwrap();
    assert_eq!(report.connections, 1);
    assert_eq!(report.rejected, 1);
}

// --------------------------------------------- backpressure under load

#[test]
fn backpressure_engages_under_networked_load_and_drains_on_release() {
    let entered = Arc::new((Mutex::new(0usize), Condvar::new()));
    let release = Arc::new((Mutex::new(false), Condvar::new()));
    let predictor: Arc<dyn Predictor> = Arc::new(GatedPredictor {
        entered: Arc::clone(&entered),
        release: Arc::clone(&release),
    });
    let service = Arc::new(PredictService::spawn(
        Arc::clone(&predictor),
        ServiceConfig { workers: 1, queue_cap: 2, ..Default::default() },
    ));
    let shared = ServeShared::new(Arc::clone(&service));
    let (server, addr) = start_server(shared, TcpServerConfig::default());

    let mut c = TcpStream::connect(&addr).unwrap();
    let mut frames = FrameReader::new(c.try_clone().unwrap(), DEFAULT_MAX_FRAME_BYTES);

    // request 1 parks the sole worker inside predict...
    write_frame(&mut c, &samples_to_json(&[chain_sample(1, 0.0)])).unwrap();
    {
        let (m, cv) = &*entered;
        let mut n = lock(m);
        while *n == 0 {
            n = cv.wait(n).unwrap_or_else(|e| e.into_inner());
        }
    }
    // ...then requests 2 and 3 fill the bounded queue to its cap of 2
    write_frame(&mut c, &samples_to_json(&[chain_sample(2, 0.0)])).unwrap();
    write_frame(&mut c, &samples_to_json(&[chain_sample(3, 0.0)])).unwrap();
    wait_until("both pipelined requests to be accepted", || service.stats().requests == 3);

    // the queue is exactly full: a non-blocking submit must fail fast
    let err = service
        .try_submit(PredictRequest::new(vec![chain_sample(4, 0.0)]))
        .unwrap_err()
        .to_string();
    assert!(err.contains("full"), "{err}");

    // release the model: everything accepted resolves, exactly once each
    {
        let (m, cv) = &*release;
        *lock(m) = true;
        cv.notify_all();
    }
    for _ in 0..3 {
        let line = frames.next_frame().unwrap().expect("a drained response");
        let j = Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "unexpected error line: {line}");
        let rows = j.get("predictions").and_then(|p| p.as_arr()).unwrap();
        assert_eq!(rows[0].get("predicted_runtime_s").and_then(|v| v.as_f64()), Some(0.5));
    }

    // recovery: the connection keeps serving after the pressure spike
    write_frame(&mut c, &samples_to_json(&[chain_sample(5, 0.0)])).unwrap();
    let line = frames.next_frame().unwrap().expect("post-release response");
    assert!(Json::parse(&line).unwrap().get("predictions").is_some());

    assert!(service.stats().peak_queue <= 2, "queue exceeded its bound");
    drop(frames);
    drop(c);
    server.shutdown_now();
    server.join().unwrap();
}

#[test]
fn stress_pipelined_fleet_against_a_small_queue_answers_exactly_once() {
    // 8 clients x 16 pipelined requests against a 2-deep queue: constant
    // backpressure, zero losses, zero duplicates, all bits intact
    let pool: Vec<GraphSample> = (1..=6u16).map(|n| chain_sample(n, 0.0625 * n as f32)).collect();
    let (shared, predictor) = stages_shared(1, 2);
    let service = Arc::clone(&shared.service);
    let cfg = TcpServerConfig { max_inflight_per_conn: 4, ..Default::default() };
    let (server, addr) = start_server(shared, cfg);

    let refs: Vec<&GraphSample> = pool.iter().collect();
    let expected = predictor.predict(&refs).unwrap();
    let workload = LoadgenConfig {
        clients: 8,
        requests_per_client: 16,
        samples_per_request: 2,
        rate_per_client: 0.0,
        pipeline_depth: 4,
    };
    let report = run_loadgen(&addr, &pool, Some(&expected), &workload).unwrap();

    let total = workload.clients * workload.requests_per_client;
    assert_eq!(report.requests_sent, total);
    assert_eq!(report.responses_ok, total);
    assert_eq!(report.responses_err, 0);
    assert_eq!(report.bitwise_verified, total);
    assert_eq!(report.samples_scored, total * workload.samples_per_request);

    let stats = service.stats();
    assert_eq!(stats.requests, total, "exactly one service submission per request line");
    assert!(stats.peak_queue <= 2, "queue grew past its bound: {}", stats.peak_queue);
    server.shutdown_now();
    let srv = server.join().unwrap();
    assert_eq!(srv.connections, workload.clients);
    assert_eq!(srv.rejected, 0);
}

#[test]
fn shutdown_during_load_drains_accepted_requests_exactly_once() {
    let pool: Vec<GraphSample> = (1..=5u16).map(|n| chain_sample(n, 0.5)).collect();
    let (shared, predictor) = stages_shared(1, 4);
    let service = Arc::clone(&shared.service);
    let (server, addr) = start_server(shared, TcpServerConfig::default());
    let refs: Vec<&GraphSample> = pool.iter().collect();
    let expected = predictor.predict(&refs).unwrap();

    let n_clients = 3usize;
    let per_client = 30usize;
    let responses_seen = AtomicUsize::new(0);

    let received: Vec<usize> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                let addr = &addr;
                let pool = &pool;
                let expected = &expected;
                let responses_seen = &responses_seen;
                scope.spawn(move || -> usize {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let reader = stream.try_clone().unwrap();
                    for i in 0..per_client {
                        let k = (c * 7 + i) % pool.len();
                        let line = samples_to_json(std::slice::from_ref(&pool[k]));
                        if write_frame(&mut stream, &line).is_err() {
                            break; // the drain closed this socket mid-send
                        }
                    }
                    let mut frames = FrameReader::new(reader, DEFAULT_MAX_FRAME_BYTES);
                    let mut got = 0usize;
                    while let Ok(Some(line)) = frames.next_frame() {
                        if Json::parse(&line).unwrap().get("error").is_some() {
                            // a line torn by the drain race parses server-side
                            // as garbage; it was never submitted, so it is not
                            // a response to count
                            break;
                        }
                        // responses are the exact in-order prefix of what was
                        // sent — none lost, none duplicated, none reordered
                        let k = (c * 7 + got) % pool.len();
                        check_response_bitwise(
                            &line,
                            "stages",
                            std::slice::from_ref(&pool[k]),
                            std::slice::from_ref(&expected[k]),
                        );
                        got += 1;
                        responses_seen.fetch_add(1, Ordering::SeqCst);
                    }
                    got
                })
            })
            .collect();

        // trigger the drain as soon as load is demonstrably in flight
        wait_until("a first response under load", || responses_seen.load(Ordering::SeqCst) >= 1);
        server.shutdown_now();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let total: usize = received.iter().sum();
    assert!(total >= 1, "the drain trigger saw a response");
    // every request the service accepted produced exactly one response
    // line that a client received before its clean EOF
    assert_eq!(service.stats().requests, total);
    let report = server.join().unwrap();
    assert_eq!(report.connections, n_clients);
}

// ------------------------------------------------- stdin / TCP parity

#[test]
fn stats_counters_agree_between_stdin_and_tcp_modes() {
    // identical traffic through both front-ends over identical services;
    // `STATS` must then report identical schemas and identical values
    // for every deterministic field
    let reqs: Vec<Vec<GraphSample>> = vec![
        vec![chain_sample(2, 0.5)],
        vec![chain_sample(3, 0.25), chain_sample(4, 0.75)],
        vec![chain_sample(5, 0.125)],
    ];
    let mut input = String::new();
    for r in &reqs {
        input.push_str(&samples_to_json(r));
        input.push('\n');
    }

    // stdin mode: in-memory byte streams through the same serve_session
    let (shared_a, _) = stages_shared(1, 8);
    let opts = SessionOpts::default();
    let summary = serve_session(input.as_bytes(), Vec::new(), &shared_a, &opts).unwrap();
    assert_eq!(summary.requests, reqs.len());
    assert_eq!(summary.responses, reqs.len());
    let mut stats_out = Vec::new();
    serve_session(&b"STATS\n"[..], &mut stats_out, &shared_a, &opts).unwrap();
    let stdin_stats = Json::parse(std::str::from_utf8(&stats_out).unwrap().trim()).unwrap();

    // TCP mode: the same three lines over one pipelined connection
    let (shared_b, _) = stages_shared(1, 8);
    let shared_view = shared_b.clone();
    let (server, addr) = start_server(shared_b, TcpServerConfig::default());
    let mut c = TcpStream::connect(&addr).unwrap();
    for r in &reqs {
        write_frame(&mut c, &samples_to_json(r)).unwrap();
    }
    c.shutdown(Shutdown::Write).unwrap();
    let lines = read_frames_until_eof(c);
    assert_eq!(lines.len(), reqs.len());
    // the traffic connection retires fully (its writer joined, counters
    // settled) before STATS reads them — same quiesce point the stdin
    // session reached when serve_session returned
    wait_until("the traffic connection to retire", || {
        shared_view.counters.connections_active.load(Ordering::Relaxed) == 0
    });
    let tcp_stats = fetch_stats(&addr).unwrap();
    server.shutdown_now();
    server.join().unwrap();

    let a = stdin_stats.get("stats").expect("stdin stats object");
    let b = tcp_stats.get("stats").expect("tcp stats object");
    let (am, bm) = match (a, b) {
        (Json::Obj(am), Json::Obj(bm)) => (am, bm),
        _ => panic!("stats must be objects"),
    };
    let keys_a: Vec<&String> = am.keys().collect();
    let keys_b: Vec<&String> = bm.keys().collect();
    assert_eq!(keys_a, keys_b, "the two modes must expose the same stats schema");
    // connection and latency fields legitimately differ (stdin has no
    // sockets; timings are wall-clock); everything else must agree
    for key in [
        "model", "requests", "samples_evaluated", "cache_hits", "cache_misses", "request_lines",
        "responses", "protocol_errors", "queue_cap",
    ] {
        assert_eq!(
            am.get(key).map(|v| v.to_string()),
            bm.get(key).map(|v| v.to_string()),
            "stats field {key} diverges between stdin and TCP modes"
        );
    }
}

#[test]
fn stats_response_embeds_the_service_snapshot_verbatim() {
    // the serve `STATS` object and `ServiceStats::to_json` are the same
    // source by construction; this pins every snapshot field (name and
    // value) inside the served response, so autotune reports — which
    // embed the snapshot directly — can never drift from serve output
    let (shared, _) = stages_shared(1, 8);
    let reqs: Vec<Vec<GraphSample>> =
        vec![vec![chain_sample(2, 0.5)], vec![chain_sample(3, 0.25), chain_sample(4, 0.75)]];
    let mut input = String::new();
    for r in &reqs {
        input.push_str(&samples_to_json(r));
        input.push('\n');
    }
    let opts = SessionOpts::default();
    serve_session(input.as_bytes(), Vec::new(), &shared, &opts).unwrap();

    let mut out = Vec::new();
    serve_session(&b"STATS\n"[..], &mut out, &shared, &opts).unwrap();
    let served = Json::parse(std::str::from_utf8(&out).unwrap().trim()).unwrap();
    let served_stats = served.get("stats").expect("stats object");

    let snap = shared.service.stats();
    assert!(snap.requests >= reqs.len(), "traffic must be visible in the snapshot");
    let snap_fields = match snap.to_json() {
        Json::Obj(m) => m,
        other => panic!("snapshot must be an object, got {other:?}"),
    };
    assert!(!snap_fields.is_empty());
    for (key, want) in &snap_fields {
        assert_eq!(
            served_stats.get(key).map(|v| v.to_string()),
            Some(want.to_string()),
            "served STATS field {key} diverges from ServiceStats::to_json"
        );
    }
    // the human rendering quotes the same numbers
    let line = snap.summary_line();
    for v in [snap.requests, snap.samples_evaluated, snap.batches] {
        assert!(line.contains(&v.to_string()), "{line} missing {v}");
    }
}
