//! Benchmark suite (`cargo bench`) — criterion-like harness from
//! `util::bench` (criterion itself is not in the offline vendor set).
//!
//! Groups map to the paper's experiment pipeline:
//!   sim       — the benchmarking substrate (Fig 4 "benchmark on hardware")
//!   features  — §II-C featurization
//!   dataset   — end-to-end sample generation rate
//!   baselines — Halide-FFN fwd, TVM-GBT fit/predict (Fig 8 comparators)
//!   gcn       — native-backend inference / train-step latency (the served
//!               model); PJRT variants when built with `--features pjrt`
//!   search    — beam-search step (Fig 2 deployment loop)
//!
//! Set GCN_PERF_BENCH_FAST=1 for quick runs.

use gcn_perf::baselines::gbt::{Gbt, GbtConfig};
use gcn_perf::baselines::halide_ffn::{FfnTrainConfig, HalideFfn};
use gcn_perf::constants::{BATCH, LEARNING_RATE};
use gcn_perf::dataset::builder::{build_dataset, sample_from_schedule, DataGenConfig};
use gcn_perf::features::featurize;
use gcn_perf::lower::lower_pipeline;
use gcn_perf::model::PackedBatch;
use gcn_perf::predictor::GcnPredictor;
use gcn_perf::runtime::{Backend, DenseRefBackend, NativeBackend};
use gcn_perf::schedule::random::random_pipeline_schedule;
use gcn_perf::search::{beam_search, BeamConfig, CostModel, PredictorCost, SimCost};
use gcn_perf::sim::{simulate, Machine};
use gcn_perf::util::bench::{bench_default, black_box, header, BenchResult};
use gcn_perf::util::rng::Rng;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    println!("{}", header());
    let mut run = |r: BenchResult| {
        println!("{}", r.report());
        results.push(r);
    };

    // ---------------------------------------------------------------- sim
    let machine = Machine::default();
    let net = gcn_perf::zoo::resnet18();
    let nests = lower_pipeline(&net);
    let mut rng = Rng::new(1);
    let scheds: Vec<_> = (0..64)
        .map(|_| random_pipeline_schedule(&net, &nests, &mut rng))
        .collect();
    let mut i = 0;
    run(bench_default("sim/simulate resnet18 (35 stages)", || {
        i = (i + 1) % scheds.len();
        black_box(simulate(&net, &nests, &scheds[i], &machine));
    }));

    let mut rng2 = Rng::new(2);
    run(bench_default("sim/bench_schedule (10 noisy runs)", || {
        i = (i + 1) % scheds.len();
        black_box(gcn_perf::sim::bench_schedule(
            &net, &nests, &scheds[i], &machine, &mut rng2,
        ));
    }));

    // ----------------------------------------------------------- features
    run(bench_default("features/featurize resnet18", || {
        i = (i + 1) % scheds.len();
        black_box(featurize(&net, &nests, &scheds[i], &machine));
    }));

    run(bench_default("schedule/random sample resnet18", || {
        black_box(random_pipeline_schedule(&net, &nests, &mut rng2));
    }));

    // ------------------------------------------------------------ dataset
    let mut rng3 = Rng::new(3);
    run(bench_default("dataset/sample (featurize+bench)", || {
        i = (i + 1) % scheds.len();
        black_box(sample_from_schedule(
            &net, &nests, &scheds[i], &machine, 0, 0, &mut rng3,
        ));
    }));

    // one small dataset for model benches
    let ds = build_dataset(&DataGenConfig {
        n_pipelines: 24,
        schedules_per_pipeline: 8,
        seed: 9,
        ..Default::default()
    });
    let stats = ds.stats.clone().unwrap();
    let best = ds.best_per_pipeline();

    let refs: Vec<&gcn_perf::dataset::sample::GraphSample> =
        ds.samples.iter().take(BATCH).collect();
    let bests: Vec<f64> = refs.iter().map(|s| best[&s.pipeline_id]).collect();
    run(bench_default("model/packed batch build (32 graphs)", || {
        black_box(PackedBatch::build(&refs, &stats, &bests).unwrap());
    }));

    // ---------------------------------------------------------- baselines
    let mut ffn = HalideFfn::new(stats.clone(), 5);
    ffn.fit(&ds, &FfnTrainConfig { epochs: 1, ..Default::default() });
    run(bench_default("baselines/ffn predict (1 sample)", || {
        black_box(ffn.predict_sample(&ds.samples[i % ds.samples.len()]));
    }));

    run(bench_default("baselines/gbt fit (192 samples)", || {
        black_box(Gbt::fit(&ds, GbtConfig { n_trees: 20, ..Default::default() }));
    }));
    let gbt = Gbt::fit(&ds, GbtConfig::default());
    run(bench_default("baselines/gbt predict (1 sample)", || {
        black_box(gbt.predict_sample(&ds.samples[i % ds.samples.len()]));
    }));

    // ---------------------------------------------------------------- gcn
    let rt = NativeBackend::new();
    let params = rt.init_params(1);
    let batch = PackedBatch::build(&refs, &stats, &bests).unwrap();
    run(bench_default("gcn/native sparse infer (batch 32)", || {
        black_box(rt.infer(&params, &batch).unwrap());
    }));
    let mut p = params.clone();
    let mut a = p.zeros_like();
    run(bench_default("gcn/native sparse train step (batch 32)", || {
        black_box(rt.train_step(&mut p, &mut a, &batch).unwrap());
    }));

    // the dense padded reference on the identical batch — the layout the
    // sparse engine replaced (see `gcn-perf bench` / BENCH_3.json for the
    // full dense-vs-sparse report). Converted once, outside the timed
    // loops: the old engine consumed ready-built dense batches, so a fair
    // comparison must not time the converter.
    let dense = DenseRefBackend::new();
    let dense_batch = dense.to_dense(&batch).unwrap();
    run(bench_default("gcn/dense-ref infer (batch 32)", || {
        black_box(dense.infer_dense(&params, &dense_batch).unwrap());
    }));
    let mut dp = params.clone();
    let mut da = dp.zeros_like();
    run(bench_default("gcn/dense-ref train step (batch 32)", || {
        black_box(
            dense
                .train_step_dense(&mut dp, &mut da, &dense_batch, LEARNING_RATE as f32)
                .unwrap(),
        );
    }));
    let many_refs: Vec<&gcn_perf::dataset::sample::GraphSample> = ds.samples.iter().collect();
    run(bench_default("gcn/native predict_runtimes (192 samples, parallel)", || {
        black_box(rt.predict_runtimes(&params, &many_refs, &stats).unwrap());
    }));

    // PJRT benches (require `--features pjrt`, a real xla binding and
    // built artifacts — see DESIGN.md §Backends). The `artifacts_nopallas`
    // directory, when built with `aot.py --no-pallas`, gives the
    // Pallas-vs-jnp lowering A/B for the same model.
    #[cfg(feature = "pjrt")]
    for (dir, tag) in [("artifacts", ""), ("artifacts_nopallas", " no-pallas")] {
        use gcn_perf::runtime::GcnRuntime;
        let artifacts = std::path::Path::new(dir);
        if !artifacts.join("manifest.json").exists() {
            eprintln!("({dir}/ missing — skipping gcn PJRT{tag} benches)");
            continue;
        }
        match GcnRuntime::load(artifacts, true) {
            Ok(prt) => {
                let pparams = prt.init_params(1);
                run(bench_default(&format!("gcn/pjrt infer{tag} (batch 32)"), || {
                    black_box(prt.infer(&pparams, &batch).unwrap());
                }));
                let mut pp = pparams.clone();
                let mut pa = pp.zeros_like();
                run(bench_default(&format!("gcn/pjrt train step{tag} (batch 32)"), || {
                    black_box(prt.train_step(&mut pp, &mut pa, &batch).unwrap());
                }));
            }
            Err(e) => eprintln!("(pjrt unavailable — {e:#})"),
        }
    }

    // -------------------------------------------------------------- search
    let unet = gcn_perf::zoo::unet();
    let unests = lower_pipeline(&unet);
    let oracle = SimCost { machine: machine.clone() };
    run(bench_default("search/beam unet (w=2, c=4)", || {
        black_box(
            beam_search(
                &unet,
                &unests,
                &oracle,
                &BeamConfig { beam_width: 2, candidates_per_stage: 4, seed: 1 },
            )
            .unwrap(),
        );
    }));

    // cached vs uncached predictor-cost scoring: the same 16 schedules
    // re-scored every call models beam re-scoring surviving states
    let mut srng = Rng::new(4);
    let scheds16: Vec<_> = (0..16)
        .map(|_| random_pipeline_schedule(&unet, &unests, &mut srng))
        .collect();
    let mk_gcn = || {
        let be = NativeBackend::new();
        let p = be.init_params(1);
        GcnPredictor::new(Box::new(be), p, stats.clone())
    };
    let uncached = PredictorCost::uncached(Box::new(mk_gcn()), machine.clone());
    run(bench_default("search/predictor-cost uncached (16 scheds)", || {
        black_box(uncached.score(&unet, &unests, &scheds16).unwrap());
    }));
    let cached = PredictorCost::new(Box::new(mk_gcn()), machine.clone());
    black_box(cached.score(&unet, &unests, &scheds16).unwrap()); // warm the cache
    run(bench_default("search/predictor-cost cached (16 scheds)", || {
        black_box(cached.score(&unet, &unests, &scheds16).unwrap());
    }));

    // summary for EXPERIMENTS.md §Perf
    println!("\n--- summary (mean) ---");
    for r in &results {
        println!("{:<42} {}", r.name, gcn_perf::util::bench::fmt_ns(r.mean_ns()));
    }
}
