//! Streaming sample access: the abstraction that lets `train`/`eval`
//! consume a corpus without holding it in RAM.
//!
//! [`SampleSource`] is random-access sample storage with cheap metadata
//! (`n_stages`, `pipeline_id`) separated from the expensive decode
//! (`fetch`). Batch planning, train/test splitting, and shuffling run on
//! metadata alone; samples are decoded one batch at a time and dropped.
//! Implementations: [`MemorySource`] (a borrowed in-RAM [`Dataset`]) and
//! [`crate::dataset::shard::ShardedDataset`] (the out-of-core corpus) —
//! so the in-RAM and streamed training paths are the *same code* and the
//! streamed run reproduces the in-RAM run bitwise whenever the corpus
//! fits in memory (pinned by a test in `train`).
//!
//! [`SourceView`] is a subset of a source (a train or test split) that
//! carries the normalization stats fitted on the training view;
//! [`split_source`] reproduces [`Dataset::split`]'s pipeline-granular
//! split and Welford stats bitwise by reusing
//! [`crate::dataset::sample::split_pipeline_ids`] and
//! [`crate::features::normalize::StatsAccumulator`] in storage order.
//! [`SampleStream`] and [`BudgetChunks`] are the iterator forms eval and
//! prediction consume.

use crate::constants::BATCH;
use crate::dataset::sample::{split_pipeline_ids, Dataset, GraphSample};
use crate::dataset::shard::ShardedDataset;
use crate::features::normalize::{FeatureStats, StatsAccumulator};
use anyhow::{ensure, Context, Result};

/// Random-access sample storage with metadata/payload separation.
///
/// `n_stages` and `pipeline_id` must be O(1) and allocation-free (they
/// drive per-epoch planning); `fetch` may do I/O and returns an owned,
/// validated sample the caller is expected to drop after use.
pub trait SampleSource {
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stage (node) count of sample `i`, without decoding it.
    fn n_stages(&self, i: usize) -> u32;

    /// Pipeline id of sample `i`, without decoding it.
    fn pipeline_id(&self, i: usize) -> u32;

    /// Decode sample `i`.
    fn fetch(&self, i: usize) -> Result<GraphSample>;
}

/// An in-RAM [`Dataset`] viewed as a [`SampleSource`]. `fetch` clones —
/// the training loop consumes owned samples so the two paths stay
/// identical, and a sample clone is noise next to its train step.
pub struct MemorySource<'a>(pub &'a Dataset);

impl SampleSource for MemorySource<'_> {
    fn len(&self) -> usize {
        self.0.samples.len()
    }

    fn n_stages(&self, i: usize) -> u32 {
        self.0.samples[i].n_stages
    }

    fn pipeline_id(&self, i: usize) -> u32 {
        self.0.samples[i].pipeline_id
    }

    fn fetch(&self, i: usize) -> Result<GraphSample> {
        Ok(self.0.samples[i].clone())
    }
}

impl SampleSource for ShardedDataset {
    fn len(&self) -> usize {
        ShardedDataset::len(self)
    }

    fn n_stages(&self, i: usize) -> u32 {
        self.entry(i).n_stages
    }

    fn pipeline_id(&self, i: usize) -> u32 {
        self.entry(i).pipeline_id
    }

    fn fetch(&self, i: usize) -> Result<GraphSample> {
        ShardedDataset::fetch(self, i)
    }
}

/// A storage-order subset of a source plus the feature stats the view's
/// consumers normalize with (fitted on the *train* view by
/// [`split_source`]; a test view carries a copy of its train stats, the
/// same sharing [`Dataset::split`] does).
pub struct SourceView<'a> {
    src: &'a dyn SampleSource,
    idx: Vec<usize>,
    pub stats: FeatureStats,
}

impl<'a> SourceView<'a> {
    /// View an entire source through pre-fitted stats.
    pub fn whole(src: &'a dyn SampleSource, stats: FeatureStats) -> SourceView<'a> {
        SourceView { src, idx: (0..src.len()).collect(), stats }
    }

    /// Stage count summed over the view (planning metadata only).
    pub fn total_nodes(&self) -> u64 {
        self.idx.iter().map(|&i| self.src.n_stages(i) as u64).sum()
    }

    /// Best (minimum) mean runtime per pipeline over this view — the α
    /// denominator. One streaming pass; holds one decoded sample at a
    /// time. Identical fold order to [`Dataset::best_per_pipeline`].
    pub fn best_per_pipeline(&self) -> Result<std::collections::BTreeMap<u32, f64>> {
        let mut best = std::collections::BTreeMap::new();
        for s in self.iter() {
            let s = s?;
            let m = s.mean_runtime();
            best.entry(s.pipeline_id).and_modify(|b: &mut f64| *b = b.min(m)).or_insert(m);
        }
        Ok(best)
    }

    /// Storage-order stream over the view.
    pub fn iter(&self) -> SampleStream<'_> {
        SampleStream { src: self.src, idx: &self.idx, pos: 0 }
    }
}

impl SampleSource for SourceView<'_> {
    fn len(&self) -> usize {
        self.idx.len()
    }

    fn n_stages(&self, i: usize) -> u32 {
        self.src.n_stages(self.idx[i])
    }

    fn pipeline_id(&self, i: usize) -> u32 {
        self.src.pipeline_id(self.idx[i])
    }

    fn fetch(&self, i: usize) -> Result<GraphSample> {
        self.src.fetch(self.idx[i])
    }
}

/// Pipeline-granular train/test split over any source — the out-of-core
/// counterpart of [`Dataset::split`], bitwise-compatible with it:
/// identical test-pipeline selection ([`split_pipeline_ids`], same seed),
/// identical storage-order index partition, and train-view stats folded
/// through [`StatsAccumulator`] in exactly `fit_stats`' op order. Peak
/// memory is one decoded sample, not the corpus.
pub fn split_source(
    src: &dyn SampleSource,
    test_frac: f64,
    seed: u64,
) -> Result<(SourceView<'_>, SourceView<'_>)> {
    let ids: Vec<u32> = {
        let mut v: Vec<u32> = (0..src.len()).map(|i| src.pipeline_id(i)).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    ensure!(ids.len() >= 2, "need at least 2 pipelines to split, got {}", ids.len());
    let test_ids = split_pipeline_ids(&ids, test_frac, seed);
    let (mut train_idx, mut test_idx) = (Vec::new(), Vec::new());
    for i in 0..src.len() {
        if test_ids.contains(&src.pipeline_id(i)) {
            test_idx.push(i);
        } else {
            train_idx.push(i);
        }
    }
    let mut acc = StatsAccumulator::new();
    for &i in &train_idx {
        let s = src.fetch(i).with_context(|| format!("fitting stats over sample {i}"))?;
        for (iv, dv) in s.inv.iter().zip(&s.dep) {
            acc.push(iv, dv);
        }
    }
    let stats = acc.finish();
    Ok((
        SourceView { src, idx: train_idx, stats: stats.clone() },
        SourceView { src, idx: test_idx, stats },
    ))
}

/// Storage-order iterator over a view's samples: one decoded sample in
/// flight at a time. This is the `Vec<GraphSample>` replacement the
/// ISSUE's out-of-core format feeds to eval/predict.
pub struct SampleStream<'a> {
    src: &'a dyn SampleSource,
    idx: &'a [usize],
    pos: usize,
}

impl Iterator for SampleStream<'_> {
    type Item = Result<GraphSample>;

    fn next(&mut self) -> Option<Result<GraphSample>> {
        let &i = self.idx.get(self.pos)?;
        self.pos += 1;
        Some(self.src.fetch(i))
    }
}

impl<'a> SampleStream<'a> {
    /// Group the stream into prediction-sized chunks: at most [`BATCH`]
    /// graphs or `node_budget` packed nodes per chunk, whichever binds
    /// first. A single graph above the budget is yielded alone (the
    /// caller routes it through `model::partition`).
    pub fn budget_chunks(self, node_budget: usize) -> BudgetChunks<'a> {
        BudgetChunks { stream: self, node_budget: node_budget.max(1), carry: None }
    }
}

/// See [`SampleStream::budget_chunks`].
pub struct BudgetChunks<'a> {
    stream: SampleStream<'a>,
    node_budget: usize,
    carry: Option<GraphSample>,
}

impl Iterator for BudgetChunks<'_> {
    type Item = Result<Vec<GraphSample>>;

    fn next(&mut self) -> Option<Result<Vec<GraphSample>>> {
        let mut chunk: Vec<GraphSample> = Vec::new();
        let mut nodes = 0usize;
        if let Some(s) = self.carry.take() {
            nodes = s.n_stages as usize;
            chunk.push(s);
        }
        loop {
            if chunk.len() >= BATCH {
                return Some(Ok(chunk));
            }
            let s = match self.stream.next() {
                Some(Ok(s)) => s,
                Some(Err(e)) => return Some(Err(e)),
                None => return if chunk.is_empty() { None } else { Some(Ok(chunk)) },
            };
            let n = s.n_stages as usize;
            if !chunk.is_empty() && nodes + n > self.node_budget {
                self.carry = Some(s);
                return Some(Ok(chunk));
            }
            nodes += n;
            chunk.push(s);
            if nodes >= self.node_budget {
                return Some(Ok(chunk));
            }
        }
    }
}

/// Plan an epoch's batches from shuffled view-relative indices using
/// metadata only: cut at `max_graphs` graphs or `node_budget` packed
/// nodes, whichever binds first; a single over-budget graph rides alone
/// (the train loop partitions it). With a budget no batch can reach
/// (zoo-scale corpora under the default budget) this degenerates to
/// `order.chunks(max_graphs)` — the historical policy — exactly.
pub fn plan_batches(
    src: &dyn SampleSource,
    order: &[usize],
    max_graphs: usize,
    node_budget: usize,
) -> Vec<Vec<usize>> {
    let max_graphs = max_graphs.max(1);
    let node_budget = node_budget.max(1);
    let mut batches: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut nodes = 0usize;
    for &i in order {
        let n = src.n_stages(i) as usize;
        if !cur.is_empty() && (nodes + n > node_budget || cur.len() >= max_graphs) {
            batches.push(std::mem::take(&mut cur));
            nodes = 0;
        }
        cur.push(i);
        nodes += n;
    }
    if !cur.is_empty() {
        batches.push(cur);
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    fn small_ds() -> Dataset {
        build_dataset(&DataGenConfig {
            n_pipelines: 6,
            schedules_per_pipeline: 5,
            seed: 5,
            ..Default::default()
        })
    }

    #[test]
    fn split_source_matches_dataset_split_bitwise() {
        let ds = small_ds();
        let (train, test) = ds.split(0.25, 7);
        let mem = MemorySource(&ds);
        let (tv, ev) = split_source(&mem, 0.25, 7).unwrap();
        assert_eq!(tv.len(), train.len());
        assert_eq!(ev.len(), test.len());
        // same pipelines on each side, same storage order, same stats bits
        for (i, want) in train.samples.iter().enumerate() {
            let got = tv.fetch(i).unwrap();
            assert_eq!((got.pipeline_id, got.schedule_id), (want.pipeline_id, want.schedule_id));
        }
        for (i, want) in test.samples.iter().enumerate() {
            assert_eq!(ev.pipeline_id(i), want.pipeline_id);
        }
        assert_eq!(tv.stats.to_flat(), train.stats.as_ref().unwrap().to_flat());
        assert_eq!(
            tv.best_per_pipeline().unwrap(),
            train.best_per_pipeline()
        );
    }

    #[test]
    fn plan_batches_covers_everything_within_limits() {
        let ds = small_ds();
        let mem = MemorySource(&ds);
        let order: Vec<usize> = (0..ds.len()).collect();
        // a tight budget that forces node-bound cuts on zoo-scale graphs
        let budget = 64;
        let batches = plan_batches(&mem, &order, BATCH, budget);
        let covered: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(covered, ds.len());
        let mut seen: Vec<usize> = batches.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, order);
        for b in &batches {
            assert!(b.len() <= BATCH);
            let nodes: usize = b.iter().map(|&i| mem.n_stages(i) as usize).sum();
            // multi-graph batches respect the budget; only a single
            // over-budget graph may exceed it (and then rides alone)
            if b.len() > 1 {
                assert!(nodes <= budget, "{nodes} nodes in a {}-graph batch", b.len());
            }
        }
        // a budget nothing reaches degenerates to the historical policy
        let loose = plan_batches(&mem, &order, BATCH, usize::MAX);
        let historical: Vec<Vec<usize>> = order.chunks(BATCH).map(|c| c.to_vec()).collect();
        assert_eq!(loose, historical);
    }

    #[test]
    fn budget_chunks_respect_budget_and_order() {
        let ds = small_ds();
        let mem = MemorySource(&ds);
        let view = SourceView::whole(&mem, ds.stats.clone().unwrap());
        let budget = 48;
        let mut seen = 0usize;
        for chunk in view.iter().budget_chunks(budget) {
            let chunk = chunk.unwrap();
            assert!(!chunk.is_empty() && chunk.len() <= BATCH);
            let nodes: usize = chunk.iter().map(|s| s.n_stages as usize).sum();
            if chunk.len() > 1 {
                assert!(nodes <= budget);
            }
            for s in &chunk {
                assert_eq!(s.schedule_id, ds.samples[seen].schedule_id);
                seen += 1;
            }
        }
        assert_eq!(seen, ds.len());
    }
}
