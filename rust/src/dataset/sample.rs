//! Dataset record types.

use crate::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
use crate::features::normalize::FeatureStats;
use anyhow::Result;
use std::collections::BTreeMap;

/// One (pipeline, schedule) pair with its measured runtimes — one training
/// sample for every model (GCN, Halide FFN, TVM GBT).
#[derive(Debug, Clone)]
pub struct GraphSample {
    pub pipeline_id: u32,
    pub schedule_id: u32,
    /// Stage count. `u32` so TpuGraphs-scale graphs (100k+ stages) are
    /// representable; the on-disk v1 format capped this at `u16`, and
    /// [`crate::dataset::store`] still reads those files.
    pub n_stages: u32,
    /// Directed producer→consumer stage edges.
    pub edges: Vec<(u32, u32)>,
    /// Raw (unnormalized) schedule-invariant features per stage.
    pub inv: Vec<[f32; INV_DIM]>,
    /// Raw schedule-dependent (+compound) features per stage.
    pub dep: Vec<[f32; DEP_DIM]>,
    /// The N = 10 noisy benchmark measurements, seconds.
    pub runs: [f32; BENCH_RUNS],
}

impl GraphSample {
    /// Structural + numeric validation, delegated to the analyzer's data
    /// audit pass ([`crate::analysis::audit_sample`]): stage/feature-row
    /// agreement (`D001`), edge ranges (`D002`), topological edge order
    /// (`D008` — catches cycles, self loops, forward refs in hand-built
    /// files), feature finiteness (`D003`), and runtime labels (`D004`).
    /// Dataset loaders run this on every sample so malformed graphs fail
    /// at load time with a coded diagnostic instead of corrupting batches
    /// downstream.
    pub fn validate(&self) -> Result<()> {
        match crate::analysis::audit_sample(self).into_iter().next() {
            None => Ok(()),
            Some(diag) => Err(anyhow::Error::new(diag)),
        }
    }

    /// ȳ — mean of the measurements (the regression target).
    pub fn mean_runtime(&self) -> f64 {
        self.runs.iter().map(|&r| r as f64).sum::<f64>() / BENCH_RUNS as f64
    }

    /// Std-dev of the measurements (Property 3 of the loss).
    pub fn std_runtime(&self) -> f64 {
        let m = self.mean_runtime();
        (self
            .runs
            .iter()
            .map(|&r| (r as f64 - m) * (r as f64 - m))
            .sum::<f64>()
            / BENCH_RUNS as f64)
            .sqrt()
    }
}

/// A dataset plus the feature statistics fitted on its training portion.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub samples: Vec<GraphSample>,
    /// Fitted on the train split; `None` until `fit_stats` runs.
    pub stats: Option<FeatureStats>,
}

impl Dataset {
    /// Best (minimum) mean runtime per pipeline — the α term denominator.
    pub fn best_per_pipeline(&self) -> BTreeMap<u32, f64> {
        let mut best = BTreeMap::new();
        for s in &self.samples {
            let m = s.mean_runtime();
            best.entry(s.pipeline_id)
                .and_modify(|b: &mut f64| *b = b.min(m))
                .or_insert(m);
        }
        best
    }

    /// Pipeline-granular train/test split (no pipeline appears in both —
    /// the paper evaluates on unseen schedules; splitting by pipeline is
    /// the stricter, leak-free variant).
    pub fn split(&self, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let ids: Vec<u32> = {
            let mut v: Vec<u32> = self.samples.iter().map(|s| s.pipeline_id).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let test_ids = split_pipeline_ids(&ids, test_frac, seed);
        let (mut train, mut test) = (Dataset::default(), Dataset::default());
        for s in &self.samples {
            if test_ids.contains(&s.pipeline_id) {
                test.samples.push(s.clone());
            } else {
                train.samples.push(s.clone());
            }
        }
        train.fit_stats();
        test.stats = train.stats.clone();
        (train, test)
    }

    /// Fit feature normalization stats over all stages of all samples.
    pub fn fit_stats(&mut self) {
        let feats: Vec<crate::features::StageFeatures> = self
            .samples
            .iter()
            .flat_map(|s| {
                s.inv.iter().zip(&s.dep).map(|(iv, dv)| crate::features::StageFeatures {
                    invariant: *iv,
                    dependent: *dv,
                })
            })
            .collect();
        self.stats = Some(FeatureStats::fit(feats.iter()));
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn num_pipelines(&self) -> usize {
        let mut v: Vec<u32> = self.samples.iter().map(|s| s.pipeline_id).collect();
        v.sort_unstable();
        v.dedup();
        v.len()
    }
}

/// Choose the test-side pipeline ids for a pipeline-granular split.
///
/// `ids` must be the sorted, deduplicated pipeline-id universe. This is
/// the exact id-selection step [`Dataset::split`] performs; the streaming
/// loaders ([`crate::dataset::stream`]) call it directly so an out-of-core
/// split lands on bitwise the same pipelines as the in-RAM one.
pub fn split_pipeline_ids(
    ids: &[u32],
    test_frac: f64,
    seed: u64,
) -> std::collections::BTreeSet<u32> {
    let mut ids = ids.to_vec();
    let mut rng = crate::util::rng::Rng::new(seed);
    rng.shuffle(&mut ids);
    let n_test = ((ids.len() as f64 * test_frac).round() as usize).clamp(1, ids.len() - 1);
    ids[..n_test].iter().copied().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(pid: u32, sid: u32, rt: f32) -> GraphSample {
        GraphSample {
            pipeline_id: pid,
            schedule_id: sid,
            n_stages: 2,
            edges: vec![(0, 1)],
            inv: vec![[0.0; INV_DIM]; 2],
            dep: vec![[0.0; DEP_DIM]; 2],
            runs: [rt; BENCH_RUNS],
        }
    }

    #[test]
    fn mean_and_std() {
        let mut s = mk(0, 0, 2.0);
        s.runs[0] = 4.0;
        let m = s.mean_runtime();
        assert!((m - 2.2).abs() < 1e-9);
        assert!(s.std_runtime() > 0.0);
    }

    #[test]
    fn best_per_pipeline_takes_min() {
        let ds = Dataset {
            samples: vec![mk(1, 0, 3.0), mk(1, 1, 1.0), mk(2, 0, 5.0)],
            stats: None,
        };
        let best = ds.best_per_pipeline();
        assert_eq!(best[&1], 1.0);
        assert_eq!(best[&2], 5.0);
    }

    #[test]
    fn split_is_pipeline_granular() {
        let samples: Vec<GraphSample> = (0..20u32)
            .flat_map(|pid| (0..5u32).map(move |sid| mk(pid, sid, 1.0)))
            .collect();
        let ds = Dataset { samples, stats: None };
        let (train, test) = ds.split(0.2, 7);
        assert_eq!(train.len() + test.len(), 100);
        let train_ids: std::collections::BTreeSet<u32> =
            train.samples.iter().map(|s| s.pipeline_id).collect();
        let test_ids: std::collections::BTreeSet<u32> =
            test.samples.iter().map(|s| s.pipeline_id).collect();
        assert!(train_ids.is_disjoint(&test_ids));
        assert_eq!(test_ids.len(), 4);
        assert!(train.stats.is_some() && test.stats.is_some());
    }
}
