//! Chunked on-disk dataset: binary shards plus a lightweight index — the
//! out-of-core counterpart of [`crate::dataset::store`].
//!
//! A sharded corpus is a directory:
//!
//! ```text
//! corpus/
//!   index.bin          magic "GCNPERFX", per-sample locator records
//!   shard-00000.bin    magic "GCNPERFS", version-2 sample records
//!   shard-00001.bin    ...
//! ```
//!
//! Each shard holds consecutive sample records in exactly the encoding
//! [`crate::dataset::store`] writes (shared `write_sample`/`read_sample`
//! helpers), rolled over at [`DEFAULT_SHARD_BYTES`]. The index stores,
//! per sample, its shard number, byte offset, and the cheap metadata the
//! batch planners need (`pipeline_id`, `schedule_id`, `n_stages`) — so
//! split/shuffle/batch decisions never touch the shards, and peak RSS of
//! a training run is bounded by the node budget, not the corpus size.
//!
//! [`ShardWriter`] streams samples out (validating each — a malformed
//! sample is rejected at *write* time); [`ShardedDataset`] is the
//! random-access reader behind [`crate::dataset::stream::SampleSource`].
//! Reads re-validate, so a shard corrupted on disk surfaces the same
//! `D0xx` diagnostics as the monolithic loader.

use crate::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
use crate::dataset::sample::GraphSample;
use crate::dataset::store::{read_sample, write_sample, Reader, Writer, VERSION};
use crate::features::normalize::FeatureStats;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

const INDEX_MAGIC: &[u8; 8] = b"GCNPERFX";
const SHARD_MAGIC: &[u8; 8] = b"GCNPERFS";
const INDEX_VERSION: u32 = 1;

/// Shard rollover threshold. Small enough that a corpus streams in
/// pieces, big enough that a 1k-stage sample (~0.5 MB) never dominates
/// its shard.
pub const DEFAULT_SHARD_BYTES: u64 = 64 * 1024 * 1024;

/// Per-sample locator + the metadata batch planning needs.
#[derive(Debug, Clone, Copy)]
pub struct IndexEntry {
    pub shard: u32,
    /// Byte offset of the record inside its shard file.
    pub offset: u64,
    pub pipeline_id: u32,
    pub schedule_id: u32,
    pub n_stages: u32,
}

fn shard_path(dir: &Path, shard: u32) -> PathBuf {
    dir.join(format!("shard-{shard:05}.bin"))
}

/// Exact encoded size of one version-2 sample record, so the writer can
/// index offsets without flushing or re-measuring the file.
fn record_bytes(s: &GraphSample) -> u64 {
    let ns = s.n_stages as u64;
    16 + 8 * s.edges.len() as u64 + 4 * ns * (INV_DIM + DEP_DIM) as u64 + 4 * BENCH_RUNS as u64
}

/// Streaming corpus writer: push samples one at a time, never holding
/// more than the current sample in memory.
pub struct ShardWriter {
    dir: PathBuf,
    max_shard_bytes: u64,
    cur: Option<Writer<BufWriter<std::fs::File>>>,
    cur_shard: u32,
    cur_offset: u64,
    entries: Vec<IndexEntry>,
}

impl ShardWriter {
    /// Create (or truncate into) a corpus directory.
    pub fn create(dir: &Path) -> Result<ShardWriter> {
        ShardWriter::with_shard_bytes(dir, DEFAULT_SHARD_BYTES)
    }

    /// [`ShardWriter::create`] with an explicit rollover threshold
    /// (tests use tiny shards to exercise multi-shard corpora cheaply).
    pub fn with_shard_bytes(dir: &Path, max_shard_bytes: u64) -> Result<ShardWriter> {
        std::fs::create_dir_all(dir).with_context(|| format!("create corpus dir {dir:?}"))?;
        Ok(ShardWriter {
            dir: dir.to_path_buf(),
            max_shard_bytes: max_shard_bytes.max(1),
            cur: None,
            cur_shard: 0,
            cur_offset: 0,
            entries: Vec::new(),
        })
    }

    fn open_shard(&mut self) -> Result<()> {
        let path = shard_path(&self.dir, self.cur_shard);
        let f = std::fs::File::create(&path).with_context(|| format!("create {path:?}"))?;
        let mut w = Writer { w: BufWriter::new(f) };
        w.w.write_all(SHARD_MAGIC)?;
        w.u32(VERSION)?;
        self.cur_offset = 12;
        self.cur = Some(w);
        Ok(())
    }

    /// Validate + append one sample, rolling to a new shard when the
    /// current one is full.
    pub fn push(&mut self, s: &GraphSample) -> Result<()> {
        s.validate().with_context(|| {
            format!("sample {} rejected by the shard writer", self.entries.len())
        })?;
        if self.cur.is_none() {
            self.open_shard()?;
        } else if self.cur_offset >= self.max_shard_bytes {
            let mut w = self.cur.take().context("shard writer state")?;
            w.w.flush()?;
            self.cur_shard += 1;
            self.open_shard()?;
        }
        self.entries.push(IndexEntry {
            shard: self.cur_shard,
            offset: self.cur_offset,
            pipeline_id: s.pipeline_id,
            schedule_id: s.schedule_id,
            n_stages: s.n_stages,
        });
        let w = self.cur.as_mut().context("shard writer state")?;
        write_sample(w, s)?;
        self.cur_offset += record_bytes(s);
        Ok(())
    }

    /// Samples pushed so far.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Flush the open shard and write `index.bin`. `stats` (if given)
    /// ride in the index the way [`crate::dataset::store::save`] embeds
    /// them in the monolithic file.
    pub fn finish(mut self, stats: Option<&FeatureStats>) -> Result<()> {
        if let Some(mut w) = self.cur.take() {
            w.w.flush()?;
        }
        let path = self.dir.join("index.bin");
        let f = std::fs::File::create(&path).with_context(|| format!("create {path:?}"))?;
        let mut w = Writer { w: BufWriter::new(f) };
        w.w.write_all(INDEX_MAGIC)?;
        w.u32(INDEX_VERSION)?;
        w.u32(self.cur_shard + u32::from(!self.entries.is_empty()))?;
        w.u32(self.entries.len() as u32)?;
        w.u8(stats.is_some() as u8)?;
        if let Some(stats) = stats {
            w.f64s(&stats.to_flat())?;
        }
        for e in &self.entries {
            w.u32(e.shard)?;
            w.u64(e.offset)?;
            w.u32(e.pipeline_id)?;
            w.u32(e.schedule_id)?;
            w.u32(e.n_stages)?;
        }
        w.w.flush()?;
        Ok(())
    }
}

/// Random-access reader over a sharded corpus. Holds the index (a few
/// dozen bytes per sample) plus at most one open shard handle — never a
/// decoded sample, so memory stays flat no matter the corpus size.
pub struct ShardedDataset {
    dir: PathBuf,
    entries: Vec<IndexEntry>,
    stats: Option<FeatureStats>,
    /// One cached open shard (number, handle): epoch iteration visits
    /// samples in storage order, so consecutive fetches overwhelmingly
    /// hit the same shard.
    open: Mutex<Option<(u32, BufReader<std::fs::File>)>>,
}

impl ShardedDataset {
    /// Open a corpus directory written by [`ShardWriter`].
    pub fn open(dir: &Path) -> Result<ShardedDataset> {
        let path = dir.join("index.bin");
        let f = std::fs::File::open(&path).with_context(|| format!("open {path:?}"))?;
        let mut r = Reader { r: BufReader::new(f) };
        let mut magic = [0u8; 8];
        r.r.read_exact(&mut magic)?;
        if &magic != INDEX_MAGIC {
            bail!("not a gcn-perf corpus index: bad magic {magic:?}");
        }
        let version = r.u32()?;
        if version != INDEX_VERSION {
            bail!("unsupported corpus index version {version}");
        }
        let n_shards = r.u32()?;
        let n = r.u32()? as usize;
        let has_stats = r.u8()? != 0;
        let stats = if has_stats {
            Some(FeatureStats::from_flat(&r.f64s(2 * (INV_DIM + DEP_DIM))?))
        } else {
            None
        };
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let e = IndexEntry {
                shard: r.u32()?,
                offset: r.u64()?,
                pipeline_id: r.u32()?,
                schedule_id: r.u32()?,
                n_stages: r.u32()?,
            };
            if e.shard >= n_shards {
                bail!("index entry references shard {} of {n_shards}", e.shard);
            }
            entries.push(e);
        }
        Ok(ShardedDataset { dir: dir.to_path_buf(), entries, stats, open: Mutex::new(None) })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Corpus-level feature stats, if the writer embedded them.
    pub fn stats(&self) -> Option<&FeatureStats> {
        self.stats.as_ref()
    }

    pub fn entry(&self, i: usize) -> &IndexEntry {
        &self.entries[i]
    }

    /// Total packed nodes across the corpus (index metadata only).
    pub fn total_nodes(&self) -> u64 {
        self.entries.iter().map(|e| e.n_stages as u64).sum()
    }

    /// Sorted, deduplicated pipeline ids (index metadata only).
    pub fn pipeline_ids(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.entries.iter().map(|e| e.pipeline_id).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Read + validate sample `i` from its shard (buffered seek-read).
    pub fn fetch(&self, i: usize) -> Result<GraphSample> {
        let e = *self.entries.get(i).with_context(|| format!("sample index {i} out of range"))?;
        let mut guard = self.open.lock().unwrap_or_else(|p| p.into_inner());
        let needs_open = !matches!(&*guard, Some((s, _)) if *s == e.shard);
        if needs_open {
            let path = shard_path(&self.dir, e.shard);
            let f = std::fs::File::open(&path).with_context(|| format!("open {path:?}"))?;
            let mut br = BufReader::new(f);
            let mut magic = [0u8; 8];
            br.read_exact(&mut magic)?;
            if &magic != SHARD_MAGIC {
                bail!("shard {path:?} has bad magic {magic:?}");
            }
            let mut vb = [0u8; 4];
            br.read_exact(&mut vb)?;
            let version = u32::from_le_bytes(vb);
            if version != VERSION {
                bail!("shard {path:?} has unsupported record version {version}");
            }
            *guard = Some((e.shard, br));
        }
        let (_, br) = guard.as_mut().context("shard handle")?;
        br.seek(SeekFrom::Start(e.offset))?;
        let sample = {
            let mut r = Reader { r: br };
            read_sample(&mut r, VERSION)
        }
        .with_context(|| format!("sample {i} of shard {} is unreadable", e.shard))?;
        drop(guard);
        // the same coded D0xx audit the monolithic loader runs — a shard
        // corrupted on disk fails here, not deep inside a train step
        sample
            .validate()
            .with_context(|| format!("sample {i} of shard {} is malformed", e.shard))?;
        if sample.pipeline_id != e.pipeline_id
            || sample.schedule_id != e.schedule_id
            || sample.n_stages != e.n_stages
        {
            bail!("sample {i} disagrees with its index entry (corrupt shard or stale index)");
        }
        Ok(sample)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    fn corpus_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gcn_perf_shard_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn multi_shard_roundtrip_preserves_samples() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 4,
            seed: 11,
            ..Default::default()
        });
        let dir = corpus_dir("roundtrip");
        // tiny rollover so even this small corpus spans several shards
        let mut w = ShardWriter::with_shard_bytes(&dir, 64 * 1024).unwrap();
        for s in &ds.samples {
            w.push(s).unwrap();
        }
        w.finish(ds.stats.as_ref()).unwrap();
        let n_shards = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with("shard-")
            })
            .count();
        assert!(n_shards > 1, "rollover produced only {n_shards} shard(s)");

        let sd = ShardedDataset::open(&dir).unwrap();
        assert_eq!(sd.len(), ds.samples.len());
        assert_eq!(
            sd.stats().unwrap().to_flat(),
            ds.stats.as_ref().unwrap().to_flat()
        );
        // storage order and random access both reproduce the samples
        for (i, want) in ds.samples.iter().enumerate() {
            let got = sd.fetch(i).unwrap();
            assert_eq!(got.pipeline_id, want.pipeline_id);
            assert_eq!(got.schedule_id, want.schedule_id);
            assert_eq!(got.edges, want.edges);
            assert_eq!(got.inv, want.inv);
            assert_eq!(got.dep, want.dep);
            assert_eq!(got.runs, want.runs);
        }
        let last = sd.fetch(sd.len() - 1).unwrap();
        let first = sd.fetch(0).unwrap();
        assert_eq!(first.pipeline_id, ds.samples[0].pipeline_id);
        assert_eq!(last.schedule_id, ds.samples.last().unwrap().schedule_id);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_rejects_malformed_samples() {
        let dir = corpus_dir("reject");
        let mut w = ShardWriter::create(&dir).unwrap();
        let bad = GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: 2,
            edges: vec![(0, 5)],
            inv: vec![[0.0; INV_DIM]; 2],
            dep: vec![[0.0; DEP_DIM]; 2],
            runs: [1e-3; BENCH_RUNS],
        };
        let err = w.push(&bad).unwrap_err();
        assert!(
            crate::analysis::diag_code_in_chain(&err).is_some(),
            "expected a D0xx diagnostic in: {err:#}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_shard_is_rejected_with_a_coded_diagnostic() {
        // write a valid single-sample corpus, then flip the sample's edge
        // bytes so it references a stage that does not exist — the reader
        // must reject it through the same D002 audit path as store::load
        let dir = corpus_dir("corrupt");
        let good = crate::testfix::chain_sample(3, 1e-3);
        let mut w = ShardWriter::create(&dir).unwrap();
        w.push(&good).unwrap();
        w.finish(None).unwrap();

        let shard = shard_path(&dir, 0);
        let mut bytes = std::fs::read(&shard).unwrap();
        // record layout after the 12-byte shard header: pid u32, sid u32,
        // n_stages u32, n_edges u32, then edge pairs — corrupt the first
        // edge's dst (bytes 12+16+4..12+16+8)
        let dst_at = 12 + 16 + 4;
        bytes[dst_at..dst_at + 4].copy_from_slice(&900u32.to_le_bytes());
        std::fs::write(&shard, bytes).unwrap();

        let sd = ShardedDataset::open(&dir).unwrap();
        let err = sd.fetch(0).unwrap_err();
        let code = crate::analysis::diag_code_in_chain(&err);
        assert_eq!(code.as_deref(), Some("D002"), "got: {err:#}");
        assert!(format!("{err:#}").contains("malformed"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_index_errors() {
        let dir = corpus_dir("missing");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ShardedDataset::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
