//! Dataset generation (Fig 4): parallel over pipelines; per pipeline,
//! sample schedules (the paper's noise-injected auto-scheduler stand-in),
//! featurize, and "benchmark" each on the simulated machine.

use crate::constants::BENCH_RUNS;
use crate::dataset::sample::{Dataset, GraphSample};
use crate::features;
use crate::ir::pipeline::{Pipeline, SourceRef};
use crate::lower::lower_pipeline;
use crate::onnx_gen::{generate_model, GenConfig};
use crate::schedule::primitives::PipelineSchedule;
use crate::schedule::random::random_pipeline_schedule;
use crate::sim::{bench_schedule, Machine};
use crate::util::progress::Progress;
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map_indexed;

/// Dataset generation configuration.
#[derive(Debug, Clone)]
pub struct DataGenConfig {
    pub n_pipelines: usize,
    pub schedules_per_pipeline: usize,
    pub seed: u64,
    pub gen: GenConfig,
    pub machine: Machine,
}

impl Default for DataGenConfig {
    fn default() -> Self {
        DataGenConfig {
            n_pipelines: 200,
            schedules_per_pipeline: 16,
            seed: 42,
            gen: GenConfig::default(),
            machine: Machine::default(),
        }
    }
}

/// Featurize one (pipeline, schedule) pair into a sample with zeroed
/// measurements — for model *input* (e.g. search cost scoring), where the
/// 10 simulated benchmark runs of [`sample_from_schedule`] would be pure
/// waste: predictors read features, never `runs`.
pub fn featurize_schedule(
    p: &Pipeline,
    nests: &[crate::lower::LoopNest],
    sched: &PipelineSchedule,
    machine: &Machine,
    pipeline_id: u32,
    schedule_id: u32,
) -> GraphSample {
    let feats = features::featurize(p, nests, sched, machine);
    let mut edges = Vec::new();
    for s in &p.stages {
        for &inp in &s.inputs {
            if let SourceRef::Stage(src) = inp {
                edges.push((src as u32, s.id as u32));
            }
        }
    }
    GraphSample {
        pipeline_id,
        schedule_id,
        n_stages: p.num_stages() as u32,
        edges,
        inv: feats.iter().map(|f| f.invariant).collect(),
        dep: feats.iter().map(|f| f.dependent).collect(),
        runs: [0f32; BENCH_RUNS],
    }
}

/// Featurize + benchmark one (pipeline, schedule) pair into a training
/// sample (features plus the noisy measured runtimes).
pub fn sample_from_schedule(
    p: &Pipeline,
    nests: &[crate::lower::LoopNest],
    sched: &PipelineSchedule,
    machine: &Machine,
    pipeline_id: u32,
    schedule_id: u32,
    rng: &mut Rng,
) -> GraphSample {
    let mut sample = featurize_schedule(p, nests, sched, machine, pipeline_id, schedule_id);
    let runs_v = bench_schedule(p, nests, sched, machine, rng);
    for (i, r) in runs_v.iter().enumerate() {
        sample.runs[i] = *r as f32;
    }
    sample
}

/// Generate all samples for one pipeline id.
fn build_pipeline_samples(cfg: &DataGenConfig, pid: usize) -> Vec<GraphSample> {
    let mut rng = Rng::new(cfg.seed ^ (pid as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let p = generate_model(&cfg.gen, &mut rng, pid);
    let nests = lower_pipeline(&p);
    let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();

    let mut out = Vec::with_capacity(cfg.schedules_per_pipeline);
    for sid in 0..cfg.schedules_per_pipeline {
        // schedule 0 is always the Halide default (compute_root, scalar) so
        // every pipeline has a common reference point; the rest are sampled
        let sched = if sid == 0 {
            PipelineSchedule::default_for(&ranks)
        } else {
            random_pipeline_schedule(&p, &nests, &mut rng)
        };
        out.push(sample_from_schedule(
            &p,
            &nests,
            &sched,
            &cfg.machine,
            pid as u32,
            sid as u32,
            &mut rng,
        ));
    }
    out
}

/// Generate the full dataset in parallel (deterministic per seed regardless
/// of thread count — each pipeline derives its own RNG stream).
pub fn build_dataset(cfg: &DataGenConfig) -> Dataset {
    let progress = Progress::new("dataset", cfg.n_pipelines);
    let per_pipeline = parallel_map_indexed(cfg.n_pipelines, |pid| {
        let s = build_pipeline_samples(cfg, pid);
        progress.tick();
        s
    });
    progress.finish();
    let mut ds = Dataset {
        samples: per_pipeline.into_iter().flatten().collect(),
        stats: None,
    };
    ds.fit_stats();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> DataGenConfig {
        DataGenConfig {
            n_pipelines: 6,
            schedules_per_pipeline: 4,
            seed: 11,
            ..Default::default()
        }
    }

    #[test]
    fn builds_expected_counts() {
        let ds = build_dataset(&tiny_cfg());
        assert_eq!(ds.len(), 6 * 4);
        assert_eq!(ds.num_pipelines(), 6);
        assert!(ds.stats.is_some());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let a = build_dataset(&tiny_cfg());
        std::env::set_var("GCN_PERF_THREADS", "1");
        let b = build_dataset(&tiny_cfg());
        std::env::remove_var("GCN_PERF_THREADS");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.pipeline_id, y.pipeline_id);
            assert_eq!(x.runs, y.runs);
            assert_eq!(x.inv, y.inv);
        }
    }

    #[test]
    fn samples_have_positive_runtimes_and_edges() {
        let ds = build_dataset(&tiny_cfg());
        for s in &ds.samples {
            assert!(s.runs.iter().all(|&r| r > 0.0 && r.is_finite()));
            assert_eq!(s.inv.len(), s.n_stages as usize);
            assert_eq!(s.dep.len(), s.n_stages as usize);
            // depth>=5 filter implies at least one edge
            assert!(!s.edges.is_empty());
        }
    }

    #[test]
    fn schedule_zero_is_shared_baseline() {
        let ds = build_dataset(&tiny_cfg());
        // schedule 0 of each pipeline exists and no schedule ids repeat
        for pid in 0..6u32 {
            let scheds: Vec<u32> = ds
                .samples
                .iter()
                .filter(|s| s.pipeline_id == pid)
                .map(|s| s.schedule_id)
                .collect();
            assert_eq!(scheds.len(), 4);
            assert!(scheds.contains(&0));
        }
    }
}
