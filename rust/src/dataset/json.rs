//! JSON interchange for [`GraphSample`]s — the input format of the
//! `gcn-perf predict` subcommand, so external tooling can request
//! predictions from a saved bundle without speaking the binary dataset
//! format.
//!
//! A sample file is a JSON array of objects:
//!
//! ```json
//! [{"pipeline_id": 0, "schedule_id": 0,
//!   "edges": [[0, 1]],
//!   "inv": [[...INV_DIM floats...], ...one row per stage...],
//!   "dep": [[...DEP_DIM floats...], ...],
//!   "runs": [...BENCH_RUNS floats, optional...]}]
//! ```
//!
//! `n_stages` is implied by the row count; `runs` may be omitted (zeros)
//! since predictors never read measurements.

use crate::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
use crate::dataset::sample::GraphSample;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Serialize samples to the JSON interchange format.
pub fn samples_to_json(samples: &[GraphSample]) -> String {
    let arr: Vec<Json> = samples
        .iter()
        .map(|s| {
            let edges: Vec<Json> = s
                .edges
                .iter()
                .map(|&(a, b)| Json::Arr(vec![Json::Num(a as f64), Json::Num(b as f64)]))
                .collect();
            let rows = |m: &[Vec<f64>]| -> Vec<Json> {
                m.iter()
                    .map(|row| Json::Arr(row.iter().map(|&v| Json::Num(v)).collect()))
                    .collect()
            };
            let inv: Vec<Vec<f64>> =
                s.inv.iter().map(|r| r.iter().map(|&v| v as f64).collect()).collect();
            let dep: Vec<Vec<f64>> =
                s.dep.iter().map(|r| r.iter().map(|&v| v as f64).collect()).collect();
            Json::obj(vec![
                ("pipeline_id", Json::Num(s.pipeline_id as f64)),
                ("schedule_id", Json::Num(s.schedule_id as f64)),
                ("edges", Json::Arr(edges)),
                ("inv", Json::Arr(rows(&inv))),
                ("dep", Json::Arr(rows(&dep))),
                (
                    "runs",
                    Json::Arr(s.runs.iter().map(|&v| Json::Num(v as f64)).collect()),
                ),
            ])
        })
        .collect();
    Json::Arr(arr).to_string()
}

fn feature_rows<const D: usize>(j: &Json, key: &str, idx: usize) -> Result<Vec<[f32; D]>> {
    let rows = j
        .get(key)
        .and_then(|v| v.as_arr())
        .with_context(|| format!("sample {idx}: missing '{key}' array"))?;
    let mut out = Vec::with_capacity(rows.len());
    for (ri, row) in rows.iter().enumerate() {
        let vals = row
            .as_arr()
            .with_context(|| format!("sample {idx}: '{key}'[{ri}] is not an array"))?;
        if vals.len() != D {
            bail!(
                "sample {idx}: '{key}'[{ri}] has {} values, this build expects {D}",
                vals.len()
            );
        }
        let mut arr = [0f32; D];
        for (ci, v) in vals.iter().enumerate() {
            arr[ci] = v
                .as_f64()
                .with_context(|| format!("sample {idx}: '{key}'[{ri}][{ci}] is not a number"))?
                as f32;
        }
        out.push(arr);
    }
    Ok(out)
}

/// Parse samples from the JSON interchange format.
pub fn samples_from_json(text: &str) -> Result<Vec<GraphSample>> {
    let root = Json::parse(text).map_err(|e| anyhow::anyhow!("sample json: {e}"))?;
    let arr = root.as_arr().context("sample file must be a JSON array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (idx, j) in arr.iter().enumerate() {
        let num_or = |key: &str, default: f64| -> f64 {
            j.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
        };
        let inv = feature_rows::<INV_DIM>(j, "inv", idx)?;
        let dep = feature_rows::<DEP_DIM>(j, "dep", idx)?;
        // no model-side stage cap: the packed sparse layout handles any
        // graph size (only the pjrt dense artifacts are limited, and they
        // reject oversize batches themselves). The record format stores
        // stage ids as u32, so that is the one remaining hard bound.
        let n_stages = inv.len();
        if n_stages > u32::MAX as usize {
            bail!("sample {idx}: {n_stages} stages exceeds the u32 stage-id range");
        }
        let mut edges = Vec::new();
        if let Some(es) = j.get("edges").and_then(|v| v.as_arr()) {
            for (ei, e) in es.iter().enumerate() {
                let pair = e
                    .as_arr()
                    .with_context(|| format!("sample {idx}: edges[{ei}] is not a pair"))?;
                if pair.len() != 2 {
                    bail!("sample {idx}: edges[{ei}] must be [src, dst]");
                }
                // cast-safety only — range-vs-n_stages is validate()'s job
                let a = u32::try_from(pair[0].as_usize().context("edge src")?)
                    .map_err(|_| anyhow::anyhow!("sample {idx}: edges[{ei}] src exceeds u32"))?;
                let b = u32::try_from(pair[1].as_usize().context("edge dst")?)
                    .map_err(|_| anyhow::anyhow!("sample {idx}: edges[{ei}] dst exceeds u32"))?;
                edges.push((a, b));
            }
        }
        let mut runs = [0f32; BENCH_RUNS];
        if let Some(rs) = j.get("runs").and_then(|v| v.as_arr()) {
            if rs.len() != BENCH_RUNS {
                bail!("sample {idx}: 'runs' has {} values, expected {BENCH_RUNS}", rs.len());
            }
            for (ri, v) in rs.iter().enumerate() {
                runs[ri] = v.as_f64().context("runs value")? as f32;
            }
        }
        let sample = GraphSample {
            pipeline_id: num_or("pipeline_id", 0.0) as u32,
            schedule_id: num_or("schedule_id", 0.0) as u32,
            n_stages: n_stages as u32,
            edges,
            inv,
            dep,
            runs,
        };
        // the canonical structural check, shared with dataset::store::load
        sample
            .validate()
            .with_context(|| format!("sample {idx} is malformed"))?;
        out.push(sample);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    #[test]
    fn json_roundtrip_preserves_samples() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 3,
            schedules_per_pipeline: 3,
            seed: 81,
            ..Default::default()
        });
        let text = samples_to_json(&ds.samples);
        let back = samples_from_json(&text).unwrap();
        assert_eq!(back.len(), ds.samples.len());
        for (a, b) in ds.samples.iter().zip(&back) {
            assert_eq!(a.pipeline_id, b.pipeline_id);
            assert_eq!(a.schedule_id, b.schedule_id);
            assert_eq!(a.n_stages, b.n_stages);
            assert_eq!(a.edges, b.edges);
            assert_eq!(a.inv, b.inv);
            assert_eq!(a.dep, b.dep);
            assert_eq!(a.runs, b.runs);
        }
    }

    #[test]
    fn samples_beyond_the_old_cap_parse() {
        // 60 stages — rejected by the old MAX_NODES = 48 gate, fine now
        let s = GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: 60,
            edges: (0..59).map(|i| (i as u32, (i + 1) as u32)).collect(),
            inv: vec![[0.25; INV_DIM]; 60],
            dep: vec![[0.75; DEP_DIM]; 60],
            runs: [1e-3; BENCH_RUNS],
        };
        let text = samples_to_json(&[s]);
        let back = samples_from_json(&text).unwrap();
        assert_eq!(back[0].n_stages, 60);
        assert_eq!(back[0].edges.len(), 59);
    }

    #[test]
    fn runs_are_optional_and_dims_are_checked() {
        let text = format!(
            r#"[{{"edges": [[0, 1]], "inv": [{inv}, {inv}], "dep": [{dep}, {dep}]}}]"#,
            inv = Json::Arr(vec![Json::Num(1.0); INV_DIM]).to_string(),
            dep = Json::Arr(vec![Json::Num(2.0); DEP_DIM]).to_string(),
        );
        let samples = samples_from_json(&text).unwrap();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].n_stages, 2);
        assert!(samples[0].runs.iter().all(|&r| r == 0.0));

        let bad = r#"[{"inv": [[1.0]], "dep": [[2.0]]}]"#;
        assert!(samples_from_json(bad).is_err(), "short feature rows must be rejected");
        assert!(samples_from_json("{}").is_err());
    }
}
