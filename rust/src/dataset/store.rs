//! Binary dataset persistence (little-endian, versioned magic header).
//!
//! Layout:
//!   magic "GCNPERFD" + u32 version + u32 n_samples + u8 has_stats
//!   [stats: 2*(INV_DIM+DEP_DIM) f64]           (if has_stats)
//!   per sample:
//!     u32 pipeline_id, u32 schedule_id, u16 n_stages, u32 n_edges
//!     edges (u16, u16)*, inv f32*, dep f32*, runs f32[BENCH_RUNS]

use crate::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
use crate::dataset::sample::{Dataset, GraphSample};
use crate::features::normalize::FeatureStats;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GCNPERFD";
const VERSION: u32 = 1;

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u16(&mut self, v: u16) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u8(&mut self, v: u8) -> Result<()> {
        self.w.write_all(&[v])?;
        Ok(())
    }
    fn f32s(&mut self, vs: &[f32]) -> Result<()> {
        for v in vs {
            self.w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }
    fn f64s(&mut self, vs: &[f64]) -> Result<()> {
        for v in vs {
            self.w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        self.r.read_exact(&mut b)?;
        Ok(u16::from_le_bytes(b))
    }
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.r.read_exact(&mut b)?;
        Ok(b[0])
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut buf = vec![0u8; n * 4];
        self.r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let mut buf = vec![0u8; n * 8];
        self.r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Save a dataset (creates parent directories).
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = Writer { w: BufWriter::new(f) };
    w.w.write_all(MAGIC)?;
    w.u32(VERSION)?;
    w.u32(ds.samples.len() as u32)?;
    w.u8(ds.stats.is_some() as u8)?;
    if let Some(stats) = &ds.stats {
        w.f64s(&stats.to_flat())?;
    }
    for s in &ds.samples {
        w.u32(s.pipeline_id)?;
        w.u32(s.schedule_id)?;
        w.u16(s.n_stages)?;
        w.u32(s.edges.len() as u32)?;
        for &(a, b) in &s.edges {
            w.u16(a)?;
            w.u16(b)?;
        }
        for iv in &s.inv {
            w.f32s(iv)?;
        }
        for dv in &s.dep {
            w.f32s(dv)?;
        }
        w.f32s(&s.runs)?;
    }
    w.w.flush()?;
    Ok(())
}

/// Load a dataset saved by [`save`].
pub fn load(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = Reader { r: BufReader::new(f) };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a gcn-perf dataset: bad magic {magic:?}");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported dataset version {version}");
    }
    let n = r.u32()? as usize;
    let has_stats = r.u8()? != 0;
    let stats = if has_stats {
        Some(FeatureStats::from_flat(&r.f64s(2 * (INV_DIM + DEP_DIM))?))
    } else {
        None
    };
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let pipeline_id = r.u32()?;
        let schedule_id = r.u32()?;
        let n_stages = r.u16()?;
        let n_edges = r.u32()? as usize;
        let mut edges = Vec::with_capacity(n_edges);
        for _ in 0..n_edges {
            edges.push((r.u16()?, r.u16()?));
        }
        let ns = n_stages as usize;
        let mut inv = Vec::with_capacity(ns);
        for _ in 0..ns {
            let v = r.f32s(INV_DIM)?;
            let mut arr = [0f32; INV_DIM];
            arr.copy_from_slice(&v);
            inv.push(arr);
        }
        let mut dep = Vec::with_capacity(ns);
        for _ in 0..ns {
            let v = r.f32s(DEP_DIM)?;
            let mut arr = [0f32; DEP_DIM];
            arr.copy_from_slice(&v);
            dep.push(arr);
        }
        let rv = r.f32s(BENCH_RUNS)?;
        let mut runs = [0f32; BENCH_RUNS];
        runs.copy_from_slice(&rv);
        let sample = GraphSample {
            pipeline_id,
            schedule_id,
            n_stages,
            edges,
            inv,
            dep,
            runs,
        };
        // fail at load time on malformed graphs (e.g. edges referencing
        // stages that do not exist) instead of corrupting batches later
        sample
            .validate()
            .with_context(|| format!("sample {} of {path:?} is malformed", samples.len()))?;
        samples.push(sample);
    }
    Ok(Dataset { samples, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    #[test]
    fn roundtrip_preserves_everything() {
        let cfg = DataGenConfig {
            n_pipelines: 3,
            schedules_per_pipeline: 3,
            seed: 5,
            ..Default::default()
        };
        let ds = build_dataset(&cfg);
        let dir = std::env::temp_dir().join("gcn_perf_test_store");
        let path = dir.join("ds.bin");
        save(&ds, &path).unwrap();
        let rt = load(&path).unwrap();
        assert_eq!(rt.samples.len(), ds.samples.len());
        for (a, b) in ds.samples.iter().zip(&rt.samples) {
            assert_eq!(a.pipeline_id, b.pipeline_id);
            assert_eq!(a.schedule_id, b.schedule_id);
            assert_eq!(a.edges, b.edges);
            assert_eq!(a.inv, b.inv);
            assert_eq!(a.dep, b.dep);
            assert_eq!(a.runs, b.runs);
        }
        let s1 = ds.stats.unwrap().to_flat();
        let s2 = rt.stats.unwrap().to_flat();
        assert_eq!(s1, s2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed_edges_at_load() {
        // save() is a dumb serializer; load() must catch a sample whose
        // edge references a stage that does not exist
        let bad = GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: 2,
            edges: vec![(0, 5)], // stage 5 of a 2-stage graph
            inv: vec![[0.0; INV_DIM]; 2],
            dep: vec![[0.0; DEP_DIM]; 2],
            runs: [1e-3; BENCH_RUNS],
        };
        let ds = Dataset { samples: vec![bad], stats: None };
        let dir = std::env::temp_dir().join("gcn_perf_test_store");
        let path = dir.join("malformed.bin");
        save(&ds, &path).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("malformed"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("gcn_perf_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a dataset at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load(Path::new("/nonexistent/nope.bin")).is_err());
    }
}
