//! Binary dataset persistence (little-endian, versioned magic header).
//!
//! Layout (version 2):
//!   magic "GCNPERFD" + u32 version + u32 n_samples + u8 has_stats
//!   [stats: 2*(INV_DIM+DEP_DIM) f64]           (if has_stats)
//!   per sample:
//!     u32 pipeline_id, u32 schedule_id, u32 n_stages, u32 n_edges
//!     edges (u32, u32)*, inv f32*, dep f32*, runs f32[BENCH_RUNS]
//!
//! Version 1 (the pre-large-graph format) stored `n_stages` and the edge
//! endpoints as `u16`; [`load`] still reads those files. [`save`] always
//! writes version 2. The per-sample encode/decode is shared with the
//! chunked shard format in [`crate::dataset::shard`], so one sample has
//! exactly one binary encoding regardless of which container holds it.

use crate::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
use crate::dataset::sample::{Dataset, GraphSample};
use crate::features::normalize::FeatureStats;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GCNPERFD";
/// Current write version: u32 stage ids (TpuGraphs-scale graphs).
pub(crate) const VERSION: u32 = 2;
/// The legacy u16-stage-id version, still accepted by [`load`].
pub(crate) const VERSION_U16: u32 = 1;

pub(crate) struct Writer<W: Write> {
    pub(crate) w: W,
}

impl<W: Write> Writer<W> {
    pub(crate) fn u32(&mut self, v: u32) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn u64(&mut self, v: u64) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn u8(&mut self, v: u8) -> Result<()> {
        self.w.write_all(&[v])?;
        Ok(())
    }
    pub(crate) fn f32s(&mut self, vs: &[f32]) -> Result<()> {
        for v in vs {
            self.w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }
    pub(crate) fn f64s(&mut self, vs: &[f64]) -> Result<()> {
        for v in vs {
            self.w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }
}

pub(crate) struct Reader<R: Read> {
    pub(crate) r: R,
}

impl<R: Read> Reader<R> {
    pub(crate) fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        self.r.read_exact(&mut b)?;
        Ok(u16::from_le_bytes(b))
    }
    pub(crate) fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.r.read_exact(&mut b)?;
        Ok(b[0])
    }
    pub(crate) fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut buf = vec![0u8; n * 4];
        self.r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    pub(crate) fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let mut buf = vec![0u8; n * 8];
        self.r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Encode one sample in the version-2 record layout.
pub(crate) fn write_sample<W: Write>(w: &mut Writer<W>, s: &GraphSample) -> Result<()> {
    w.u32(s.pipeline_id)?;
    w.u32(s.schedule_id)?;
    w.u32(s.n_stages)?;
    w.u32(s.edges.len() as u32)?;
    for &(a, b) in &s.edges {
        w.u32(a)?;
        w.u32(b)?;
    }
    for iv in &s.inv {
        w.f32s(iv)?;
    }
    for dv in &s.dep {
        w.f32s(dv)?;
    }
    w.f32s(&s.runs)?;
    Ok(())
}

/// Decode one sample record written by the given format `version`.
/// Purely structural — callers run [`GraphSample::validate`] themselves
/// so the error message can say *which* container held the sample.
pub(crate) fn read_sample<R: Read>(r: &mut Reader<R>, version: u32) -> Result<GraphSample> {
    let pipeline_id = r.u32()?;
    let schedule_id = r.u32()?;
    let n_stages =
        if version == VERSION_U16 { r.u16()? as u32 } else { r.u32()? };
    let n_edges = r.u32()? as usize;
    let mut edges = Vec::with_capacity(n_edges);
    for _ in 0..n_edges {
        if version == VERSION_U16 {
            edges.push((r.u16()? as u32, r.u16()? as u32));
        } else {
            edges.push((r.u32()?, r.u32()?));
        }
    }
    let ns = n_stages as usize;
    let mut inv = Vec::with_capacity(ns);
    for _ in 0..ns {
        let v = r.f32s(INV_DIM)?;
        let mut arr = [0f32; INV_DIM];
        arr.copy_from_slice(&v);
        inv.push(arr);
    }
    let mut dep = Vec::with_capacity(ns);
    for _ in 0..ns {
        let v = r.f32s(DEP_DIM)?;
        let mut arr = [0f32; DEP_DIM];
        arr.copy_from_slice(&v);
        dep.push(arr);
    }
    let rv = r.f32s(BENCH_RUNS)?;
    let mut runs = [0f32; BENCH_RUNS];
    runs.copy_from_slice(&rv);
    Ok(GraphSample { pipeline_id, schedule_id, n_stages, edges, inv, dep, runs })
}

/// Save a dataset (creates parent directories).
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = Writer { w: BufWriter::new(f) };
    w.w.write_all(MAGIC)?;
    w.u32(VERSION)?;
    w.u32(ds.samples.len() as u32)?;
    w.u8(ds.stats.is_some() as u8)?;
    if let Some(stats) = &ds.stats {
        w.f64s(&stats.to_flat())?;
    }
    for s in &ds.samples {
        write_sample(&mut w, s)?;
    }
    w.w.flush()?;
    Ok(())
}

/// Load a dataset saved by [`save`] (version 2, or the legacy version 1).
pub fn load(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = Reader { r: BufReader::new(f) };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a gcn-perf dataset: bad magic {magic:?}");
    }
    let version = r.u32()?;
    if version != VERSION && version != VERSION_U16 {
        bail!("unsupported dataset version {version}");
    }
    let n = r.u32()? as usize;
    let has_stats = r.u8()? != 0;
    let stats = if has_stats {
        Some(FeatureStats::from_flat(&r.f64s(2 * (INV_DIM + DEP_DIM))?))
    } else {
        None
    };
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let sample = read_sample(&mut r, version)?;
        // fail at load time on malformed graphs (e.g. edges referencing
        // stages that do not exist) instead of corrupting batches later
        sample
            .validate()
            .with_context(|| format!("sample {} of {path:?} is malformed", samples.len()))?;
        samples.push(sample);
    }
    Ok(Dataset { samples, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    #[test]
    fn roundtrip_preserves_everything() {
        let cfg = DataGenConfig {
            n_pipelines: 3,
            schedules_per_pipeline: 3,
            seed: 5,
            ..Default::default()
        };
        let ds = build_dataset(&cfg);
        let dir = std::env::temp_dir().join("gcn_perf_test_store");
        let path = dir.join("ds.bin");
        save(&ds, &path).unwrap();
        let rt = load(&path).unwrap();
        assert_eq!(rt.samples.len(), ds.samples.len());
        for (a, b) in ds.samples.iter().zip(&rt.samples) {
            assert_eq!(a.pipeline_id, b.pipeline_id);
            assert_eq!(a.schedule_id, b.schedule_id);
            assert_eq!(a.edges, b.edges);
            assert_eq!(a.inv, b.inv);
            assert_eq!(a.dep, b.dep);
            assert_eq!(a.runs, b.runs);
        }
        let s1 = ds.stats.unwrap().to_flat();
        let s2 = rt.stats.unwrap().to_flat();
        assert_eq!(s1, s2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // hand-encode a version-1 file (u16 stage ids) and check the
        // loader upconverts it to the widened in-memory sample
        let dir = std::env::temp_dir().join("gcn_perf_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy_v1.bin");
        let f = std::fs::File::create(&path).unwrap();
        let mut w = Writer { w: BufWriter::new(f) };
        w.w.write_all(MAGIC).unwrap();
        w.u32(VERSION_U16).unwrap();
        w.u32(1).unwrap(); // n_samples
        w.u8(0).unwrap(); // no stats
        w.u32(3).unwrap(); // pipeline_id
        w.u32(4).unwrap(); // schedule_id
        w.w.write_all(&2u16.to_le_bytes()).unwrap(); // n_stages
        w.u32(1).unwrap(); // n_edges
        w.w.write_all(&0u16.to_le_bytes()).unwrap();
        w.w.write_all(&1u16.to_le_bytes()).unwrap();
        for _ in 0..2 {
            w.f32s(&[0.5; INV_DIM]).unwrap();
        }
        for _ in 0..2 {
            w.f32s(&[1.5; DEP_DIM]).unwrap();
        }
        w.f32s(&[1e-3; BENCH_RUNS]).unwrap();
        w.w.flush().unwrap();
        drop(w);

        let ds = load(&path).unwrap();
        assert_eq!(ds.samples.len(), 1);
        let s = &ds.samples[0];
        assert_eq!(s.pipeline_id, 3);
        assert_eq!(s.schedule_id, 4);
        assert_eq!(s.n_stages, 2);
        assert_eq!(s.edges, vec![(0, 1)]);
        assert_eq!(s.inv[0][0], 0.5);
        assert_eq!(s.dep[1][DEP_DIM - 1], 1.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed_edges_at_load() {
        // save() is a dumb serializer; load() must catch a sample whose
        // edge references a stage that does not exist
        let bad = GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: 2,
            edges: vec![(0, 5)], // stage 5 of a 2-stage graph
            inv: vec![[0.0; INV_DIM]; 2],
            dep: vec![[0.0; DEP_DIM]; 2],
            runs: [1e-3; BENCH_RUNS],
        };
        let ds = Dataset { samples: vec![bad], stats: None };
        let dir = std::env::temp_dir().join("gcn_perf_test_store");
        let path = dir.join("malformed.bin");
        save(&ds, &path).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("malformed"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("gcn_perf_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a dataset at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load(Path::new("/nonexistent/nope.bin")).is_err());
    }
}
