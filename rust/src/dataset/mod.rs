//! Dataset pipeline (Fig 4): random ONNX model → Halide-like pipeline →
//! schedules → simulated benchmarking → stored samples.

pub mod sample;
pub mod builder;
pub mod json;
pub mod store;

pub use builder::{build_dataset, DataGenConfig};
pub use sample::{Dataset, GraphSample};
