//! Dataset pipeline (Fig 4): random ONNX model → Halide-like pipeline →
//! schedules → simulated benchmarking → stored samples.
//!
//! Two storage forms share one record encoding: [`store`] is the
//! monolithic single-file format (load-everything), [`shard`] the
//! chunked out-of-core format whose samples stream through [`stream`]'s
//! [`SampleSource`]/[`SampleStream`] with peak memory bounded by the
//! node budget instead of the corpus size.

pub mod sample;
pub mod builder;
pub mod json;
pub mod shard;
pub mod store;
pub mod stream;

pub use builder::{build_dataset, DataGenConfig};
pub use sample::{Dataset, GraphSample};
pub use shard::{ShardWriter, ShardedDataset};
pub use stream::{split_source, MemorySource, SampleSource, SampleStream, SourceView};
