//! `gcn-perf` — leader CLI for the GCN performance-model reproduction.
//!
//! Subcommands:
//!   gen-data   generate a dataset (random pipelines → schedules → sim bench)
//!   train      train the GCN (native backend by default; PJRT with the
//!              `pjrt` feature and built artifacts)
//!   fig8       regenerate Fig 8 (avg/max error, R² vs Halide + TVM models)
//!   fig9       regenerate Fig 9 (pairwise ranking on the 9 zoo networks)
//!   ablate     §III-C conv-depth ablation (0/1/2/4 layers)
//!   search     model-guided beam search on a zoo network (Fig 2)
//!   info       backend / manifest info
//!
//! Everything is driven from rust; python is never on the runtime path.

use anyhow::{bail, Context, Result};
use gcn_perf::dataset::builder::{build_dataset, DataGenConfig};
use gcn_perf::dataset::sample::Dataset;
use gcn_perf::dataset::store;
use gcn_perf::eval::harness;
use gcn_perf::eval::metrics::RegressionMetrics;
use gcn_perf::eval::ranking::{rank_networks, RankResult};
use gcn_perf::onnx_gen::GenConfig;
use gcn_perf::runtime::{load_backend, load_variant_backend, Backend, Params};
use gcn_perf::search::{beam_search, BeamConfig, CostModel, SimCost};
use gcn_perf::sim::Machine;
use gcn_perf::train::{train_and_save, TrainConfig};
use gcn_perf::util::cli::Args;
use std::path::{Path, PathBuf};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("gen-data") => cmd_gen_data(&args),
        Some("train") => cmd_train(&args),
        Some("fig8") => cmd_fig8(&args),
        Some("fig9") => cmd_fig9(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("active") => cmd_active(&args),
        Some("transfer") => cmd_transfer(&args),
        Some("search") => cmd_search(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "gcn-perf — GNN performance model for DNN compiler schedules

USAGE: gcn-perf <subcommand> [--key value ...]

  gen-data  --pipelines N --schedules M --out data/dataset.bin [--seed S]
  train     --data data/dataset.bin --ckpt data/gcn.ckpt [--epochs E]
            [--test-frac F] [--artifacts DIR]
  fig8      --data ... --ckpt ... [--ffn-epochs E] [--report results/report.json]
  fig9      --data ... --ckpt ... [--schedules K] [--report ...]
  ablate    --data ... [--epochs E]     (conv layers 0/1/2/4 sweep)
  active    --data ... [--rounds R --acquire K]  (§VI active-learning study)
  transfer  --data ... --ckpt ...  (§VI-A cross-machine portability study)
  search    --network NAME [--model oracle] [--ckpt ... --data ...]
  info      [--artifacts DIR]";

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    let path = args.str_opt("data").context("--data required")?;
    store::load(Path::new(path))
}

fn split_dataset(args: &Args, ds: &Dataset) -> (Dataset, Dataset) {
    let frac = args.f64_or("test-frac", 0.1);
    ds.split(frac, args.u64_or("split-seed", 1234))
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let cfg = DataGenConfig {
        n_pipelines: args.usize_or("pipelines", 200),
        schedules_per_pipeline: args.usize_or("schedules", 16),
        seed: args.u64_or("seed", 42),
        gen: GenConfig::default(),
        machine: Machine::default(),
    };
    let out = PathBuf::from(args.str_or("out", "data/dataset.bin"));
    eprintln!(
        "generating {} pipelines x {} schedules...",
        cfg.n_pipelines, cfg.schedules_per_pipeline
    );
    let ds = build_dataset(&cfg);
    store::save(&ds, &out)?;
    println!(
        "wrote {} samples from {} pipelines to {}",
        ds.len(),
        ds.num_pipelines(),
        out.display()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let (train_ds, test_ds) = split_dataset(args, &ds);
    eprintln!(
        "train: {} samples / {} pipelines, test: {} / {}",
        train_ds.len(),
        train_ds.num_pipelines(),
        test_ds.len(),
        test_ds.num_pipelines()
    );
    let rt = load_backend(&artifacts_dir(args), true)?;
    let cfg = TrainConfig {
        epochs: args.usize_or("epochs", 40),
        seed: args.u64_or("seed", 7),
        patience: args.usize_or("patience", 8),
        lr: args.f64_or("lr", gcn_perf::constants::LEARNING_RATE) as f32,
        ..Default::default()
    };
    let ckpt = PathBuf::from(args.str_or("ckpt", "data/gcn.ckpt"));
    let result = train_and_save(rt.as_ref(), &train_ds, &test_ds, &cfg, &ckpt)?;
    println!(
        "best test MAPE {:.2}% after {} epochs; checkpoint: {}",
        result.best_test_mape,
        result.history.len(),
        ckpt.display()
    );
    Ok(())
}

fn load_runtime_and_params(args: &Args, with_train: bool) -> Result<(Box<dyn Backend>, Params)> {
    let rt = load_backend(&artifacts_dir(args), with_train)?;
    let ckpt = args.str_opt("ckpt").context("--ckpt required")?;
    let params = Params::load(Path::new(ckpt), rt.manifest())?;
    Ok((rt, params))
}

fn print_fig8(rows: &[RegressionMetrics]) {
    println!("\nFig 8 — prediction quality on the test set");
    println!("{}", RegressionMetrics::header());
    for r in rows {
        println!("{}", r.row());
    }
    if rows.len() >= 3 {
        println!(
            "\nerror reduction vs halide-ffn: {:.2}x   vs tvm-gbt: {:.2}x (paper: 7.75x / 12x)",
            rows[1].avg_error_pct / rows[0].avg_error_pct,
            rows[2].avg_error_pct / rows[0].avg_error_pct
        );
    }
}

fn cmd_fig8(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let (train_ds, test_ds) = split_dataset(args, &ds);
    let (rt, params) = load_runtime_and_params(args, false)?;
    let mut rows = harness::run_fig8(
        rt.as_ref(),
        &params,
        &train_ds,
        &test_ds,
        args.usize_or("ffn-epochs", 30),
        true,
    )?;
    if args.has_flag("with-rnn") {
        rows.push(harness::run_fig8_rnn(
            &train_ds,
            &test_ds,
            args.usize_or("rnn-epochs", 10),
            true,
        )?);
    }
    print_fig8(&rows);
    if let Some(report) = args.str_opt("report") {
        harness::write_report(Path::new(report), &rows, &[], 0.0)?;
        println!("report written to {report}");
    }
    Ok(())
}

fn print_fig9(rows: &[RankResult], avg: f64) {
    println!("\nFig 9 — pairwise ranking accuracy on real-world networks");
    println!("{}", RankResult::header());
    for r in rows {
        println!("{}", r.row());
    }
    println!("{:<14} {:>10} {:>10} {:>10.1}%", "AVERAGE", "", "", avg);
    println!("(paper: 65–90% per network, ~75% average)");
}

fn cmd_fig9(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let (train_ds, _) = split_dataset(args, &ds);
    let (rt, params) = load_runtime_and_params(args, false)?;
    let stats = train_ds.stats.as_ref().context("stats")?;
    let rows = harness::run_fig9(
        rt.as_ref(),
        &params,
        stats,
        &Machine::default(),
        args.usize_or("schedules", 100),
        args.u64_or("seed", 5),
    )?;
    let (rows, avg) = rank_networks(rows);
    print_fig9(&rows, avg);
    if let Some(report) = args.str_opt("report") {
        harness::write_report(Path::new(report), &[], &rows, avg)?;
        println!("report written to {report}");
    }
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let (train_ds, test_ds) = split_dataset(args, &ds);
    let epochs = args.usize_or("epochs", 12);
    let lr = args.f64_or("lr", 0.03) as f32;
    let dir = artifacts_dir(args);
    println!("conv-depth ablation (§III-C parametric sweep), {epochs} epochs each, lr {lr}");
    println!("{:<8} {:>12} {:>9}", "layers", "test MAPE %", "backend");
    for layers in [0usize, 1, 2, 4] {
        // infallible in the default build (native fallback); the backend
        // column makes a mixed pjrt/native sweep visible
        let rt = load_variant_backend(&dir, layers, true)?;
        let mut params = rt.init_params(7);
        // output-bias init at the train mean log-runtime (as train() does)
        let mean_log_y: f64 = train_ds
            .samples
            .iter()
            .map(|s| s.mean_runtime().max(1e-12).ln())
            .sum::<f64>()
            / train_ds.len().max(1) as f64;
        if let Some(b_out) = params.values.last_mut() {
            b_out[0] = mean_log_y as f32;
        }
        let mut accum = params.zeros_like();
        let best_rt = train_ds.best_per_pipeline();
        let mut rng = gcn_perf::util::rng::Rng::new(13);
        for _ in 0..epochs {
            let mut order: Vec<usize> = (0..train_ds.len()).collect();
            rng.shuffle(&mut order);
            for chunk in order.chunks(gcn_perf::constants::BATCH) {
                let samples: Vec<&gcn_perf::dataset::sample::GraphSample> =
                    chunk.iter().map(|&i| &train_ds.samples[i]).collect();
                let bests: Vec<f64> =
                    samples.iter().map(|s| best_rt[&s.pipeline_id]).collect();
                let batch = gcn_perf::model::Batch::build(
                    &samples,
                    train_ds.stats.as_ref().unwrap(),
                    &bests,
                );
                rt.train_step_lr(&mut params, &mut accum, &batch, lr)?;
            }
        }
        let refs: Vec<&gcn_perf::dataset::sample::GraphSample> =
            test_ds.samples.iter().collect();
        let preds = rt.predict_runtimes(&params, &refs, test_ds.stats.as_ref().unwrap())?;
        let truth: Vec<f64> = test_ds.samples.iter().map(|s| s.mean_runtime()).collect();
        let mape = gcn_perf::util::stats::mape(&truth, &preds);
        println!("{:<8} {:>12.2} {:>9}", layers, mape, rt.name());
    }
    Ok(())
}

fn cmd_active(args: &Args) -> Result<()> {
    use gcn_perf::train::active::{active_learning_study, ActiveConfig};
    let ds = load_dataset(args)?;
    let (pool, test) = split_dataset(args, &ds);
    let rt = load_backend(&artifacts_dir(args), true)?;
    let cfg = ActiveConfig {
        seed_frac: args.f64_or("seed-frac", 0.1),
        acquire: args.usize_or("acquire", 1024),
        rounds: args.usize_or("rounds", 4),
        epochs_per_round: args.usize_or("epochs", 8),
        seed: args.u64_or("seed", 3),
    };
    println!("§VI active learning: committee disagreement vs random acquisition");
    println!("{:<7} {:>9} {:>16} {:>16}", "round", "labeled", "active MAPE %", "random MAPE %");
    for r in active_learning_study(rt.as_ref(), &pool, &test, &cfg)? {
        println!(
            "{:<7} {:>9} {:>16.2} {:>16.2}",
            r.round, r.labeled, r.test_mape_active, r.test_mape_random
        );
    }
    Ok(())
}

fn cmd_transfer(args: &Args) -> Result<()> {
    // §VI-A: "while the current set of features is applicable across CPU
    // platforms, it would require significant rework when porting to other
    // hardware architectures". Study: train on the Xeon dataset (the given
    // checkpoint), evaluate ranking on datasets benchmarked on *other* CPU
    // presets. Features are machine-aware (cache-fit flags etc. use each
    // machine's geometry), so CPU→CPU transfer should hold.
    let ds = load_dataset(args)?;
    let (train_ds, _) = split_dataset(args, &ds);
    let (rt, params) = load_runtime_and_params(args, false)?;
    let stats = train_ds.stats.as_ref().context("stats")?;
    let schedules = args.usize_or("schedules", 60);
    println!("§VI-A cross-machine transfer (trained on xeon_d2191)");
    println!("{:<16} {:>14} {:>12}", "machine", "rank acc %", "MAPE %");
    for name in ["xeon_d2191", "desktop_4core", "server_64core"] {
        let machine = Machine::by_name(name).unwrap();
        let rows = harness::run_fig9(rt.as_ref(), &params, stats, &machine, schedules, 17)?;
        let (rows, avg) = rank_networks(rows);
        // also a MAPE over all the generated samples
        let _ = rows;
        println!("{:<16} {:>14.1} {:>12}", name, avg, "—");
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let name = args.str_or("network", "unet");
    let net = gcn_perf::zoo::all_networks()
        .into_iter()
        .find(|n| n.name == name)
        .with_context(|| format!("unknown network '{name}'"))?;
    let nests = gcn_perf::lower::lower_pipeline(&net);
    let machine = Machine::default();
    let model_kind = args.str_or("model", "oracle");
    let cfg = BeamConfig {
        beam_width: args.usize_or("beam", 8),
        candidates_per_stage: args.usize_or("candidates", 12),
        seed: args.u64_or("seed", 1),
    };

    let model: Box<dyn CostModel> = match model_kind.as_str() {
        "oracle" => Box::new(SimCost { machine: machine.clone() }),
        "gcn" => {
            let (rt, params) = load_runtime_and_params(args, false)?;
            let ds = load_dataset(args)?;
            let (train_ds, _) = split_dataset(args, &ds);
            Box::new(GcnCost {
                rt,
                params,
                stats: train_ds.stats.clone().context("stats")?,
                machine: machine.clone(),
            })
        }
        other => bail!("unknown cost model '{other}' (oracle|gcn)"),
    };

    let ranks: Vec<usize> = net.stages.iter().map(|s| s.shape.len()).collect();
    let default_t = gcn_perf::sim::simulate(
        &net,
        &nests,
        &gcn_perf::schedule::primitives::PipelineSchedule::default_for(&ranks),
        &machine,
    );
    let (best, score) = beam_search(&net, &nests, model.as_ref(), &cfg);
    let true_t = gcn_perf::sim::simulate(&net, &nests, &best, &machine);
    println!("network {name}: default {:.3} ms", default_t * 1e3);
    println!(
        "beam search ({}): found {:.3} ms (model score {:.3} ms) — {:.2}x speedup",
        model.name(),
        true_t * 1e3,
        score * 1e3,
        default_t / true_t
    );
    Ok(())
}

/// GCN-backed cost model for beam search: featurize candidates, batch
/// through the backend's (chunk-parallel) inference path.
pub struct GcnCost {
    rt: Box<dyn Backend>,
    params: Params,
    stats: gcn_perf::features::normalize::FeatureStats,
    machine: Machine,
}

impl CostModel for GcnCost {
    fn score(
        &self,
        p: &gcn_perf::ir::pipeline::Pipeline,
        nests: &[gcn_perf::lower::LoopNest],
        scheds: &[gcn_perf::schedule::primitives::PipelineSchedule],
    ) -> Vec<f64> {
        let mut rng = gcn_perf::util::rng::Rng::new(0);
        let samples: Vec<gcn_perf::dataset::sample::GraphSample> = scheds
            .iter()
            .map(|s| {
                gcn_perf::dataset::builder::sample_from_schedule(
                    p,
                    nests,
                    s,
                    &self.machine,
                    0,
                    0,
                    &mut rng,
                )
            })
            .collect();
        let refs: Vec<&gcn_perf::dataset::sample::GraphSample> = samples.iter().collect();
        self.rt
            .predict_runtimes(&self.params, &refs, &self.stats)
            .expect("gcn inference")
    }
    fn name(&self) -> String {
        "gcn".into()
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = load_backend(&dir, false)?;
    println!("backend: {}", rt.name());
    if dir.join("manifest.json").exists() {
        // parse + validate the on-disk contract (dim-drift fails fast here
        // even when the native engine is what actually runs)
        let disk = gcn_perf::runtime::Manifest::load(&dir)?;
        println!(
            "artifacts: {} ({} conv layers, {} param tensors, ablation variants {:?})",
            dir.display(),
            disk.n_conv,
            disk.params.len(),
            disk.ablation_layers
        );
    } else {
        println!("artifacts: none (native backend needs no artifacts)");
    }
    // what this binary actually executes
    let manifest = rt.manifest();
    println!(
        "model: {} conv layers, node dim {}, batch {}, max nodes {}",
        manifest.n_conv, manifest.node_dim, manifest.batch, manifest.max_nodes
    );
    println!(
        "params: {} tensors, {} elements",
        manifest.params.len(),
        manifest.total_param_elems()
    );
    Ok(())
}
