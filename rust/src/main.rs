//! `gcn-perf` — leader CLI for the GCN performance-model reproduction.
//!
//! Subcommands:
//!   gen-data       generate a dataset (random pipelines → schedules → sim
//!                  bench), or with --scale a sharded out-of-core corpus
//!                  of TpuGraphs-scale synthetic graphs
//!   train          train the GCN and save a single-file model bundle
//!   predict        load any model bundle and serve predictions for a JSON
//!                  sample file (or a binary dataset)
//!   quantize       mint an int8 per-channel-quantized serving bundle from
//!                  a trained f32 gcn bundle (serve with --precision int8)
//!   export-samples write a binary dataset's samples as the JSON
//!                  interchange format `predict`/`serve` consume
//!   fig8           regenerate Fig 8 (avg/max error, R² vs Halide + TVM)
//!   fig9           regenerate Fig 9 (pairwise ranking on the zoo networks)
//!   ablate         §III-C conv-depth ablation (0/1/2/4 layers)
//!   active         §VI active-learning study
//!   transfer       §VI-A cross-machine portability study
//!   analyze        static analyzer: pipeline structure, schedule
//!                  legality, dependence/bounds warnings and data audits
//!                  over zoo networks, datasets, sample files or bundles
//!                  (exit 0 clean, 1 with findings, 2 on usage errors)
//!   search         model-guided beam search on a zoo network (Fig 2)
//!   autotune       fleet autotuner: tune many zoo networks concurrently
//!                  through one shared PredictService, with checkpoints,
//!                  bitwise --resume and search-trace harvesting
//!   bench          engine benchmarks: dense-vs-sparse (BENCH_3.json),
//!                  naive-vs-coalesced serving (BENCH_4.json), the
//!                  PR-5-vs-PR-4 engine micro-suite (BENCH_5.json), the
//!                  fleet-vs-sequential autotuner (BENCH_7.json), the
//!                  scalar/SIMD/int8 inference lanes (BENCH_8.json), the
//!                  analyzer validation-throughput compare (BENCH_9.json)
//!                  and the out-of-core scale tiers (BENCH_10.json)
//!   serve          long-lived prediction daemon: line-delimited JSON
//!                  requests on stdin — or, with --listen, a
//!                  multi-client TCP server with graceful drain
//!   loadgen        concurrent client fleet against the TCP server,
//!                  bitwise-verified; writes BENCH_6.json
//!   info           backend / manifest / bundle info
//!
//! Everything is driven from rust; python is never on the runtime path.
//! All model loading goes through `predictor` bundles, and every command
//! that answers prediction queries does so through the coalescing
//! `PredictService` serving layer.

use anyhow::{bail, Context, Result};
use gcn_perf::dataset::builder::{build_dataset, DataGenConfig};
use gcn_perf::dataset::sample::Dataset;
use gcn_perf::dataset::shard::ShardedDataset;
use gcn_perf::dataset::store;
use gcn_perf::dataset::stream::{split_source, SampleSource, SourceView};
use gcn_perf::eval::harness;
use gcn_perf::eval::metrics::RegressionMetrics;
use gcn_perf::eval::ranking::{rank_networks, RankResult};
use gcn_perf::model::partition::{combine_runtimes, partition_sample};
use gcn_perf::net::session::{prediction_report, sample_ids};
use gcn_perf::onnx_gen::GenConfig;
use gcn_perf::predictor::registry::{self, FitConfig};
use gcn_perf::predictor::{
    save_gcn_bundle, GcnPredictor, PredictRequest, PredictService, Predictor, PredictorCost,
    ServiceConfig,
};
use gcn_perf::runtime::{load_backend, load_variant_backend, Backend};
use gcn_perf::search::{beam_search, BeamConfig, CostModel, SimCost};
use gcn_perf::sim::Machine;
use gcn_perf::train::{train_and_save, train_source, TrainConfig};
use gcn_perf::zoo::large::{write_large_corpus, LargeConfig, LargeStyle};
use gcn_perf::util::cli::Args;
use std::path::{Path, PathBuf};
use std::sync::Arc;

// Counting allocator (relaxed-atomic + TLS adds over `System`): lets
// `bench --engine` report real allocations/op in BENCH_5.json. Installed
// in the binary — not the library — so embedders keep their own global
// allocator. The library's test harness installs its own copy (lib.rs).
#[global_allocator]
static GLOBAL_ALLOC: gcn_perf::util::alloc_count::CountingAlloc =
    gcn_perf::util::alloc_count::CountingAlloc;

/// Per-subcommand accepted `--key value` options and bare `--flags`.
/// `main` rejects anything outside this table with a nonzero exit, so a
/// typo'd flag cannot be silently swallowed by a default.
const KNOWN_ARGS: &[(&str, &[&str], &[&str])] = &[
    ("gen-data", &["pipelines", "schedules", "out", "seed", "scale", "style"], &[]),
    (
        "train",
        &[
            "data", "bundle", "ckpt", "epochs", "test-frac", "split-seed", "artifacts", "seed",
            "patience", "lr", "stream", "node-budget",
        ],
        &[],
    ),
    (
        "predict",
        &["bundle", "ckpt", "samples", "data", "out", "precision", "stream", "node-budget"],
        &[],
    ),
    ("quantize", &["bundle", "ckpt", "out"], &[]),
    ("export-samples", &["data", "out", "limit"], &[]),
    (
        "fig8",
        &[
            "data", "bundle", "ckpt", "test-frac", "split-seed", "ffn-epochs", "rnn-epochs",
            "report",
        ],
        &["with-rnn"],
    ),
    ("fig9", &["bundle", "ckpt", "schedules", "seed", "report"], &[]),
    ("ablate", &["data", "epochs", "lr", "artifacts", "test-frac", "split-seed"], &[]),
    (
        "active",
        &[
            "data", "seed-frac", "acquire", "rounds", "epochs", "seed", "test-frac", "split-seed",
            "artifacts",
        ],
        &[],
    ),
    ("transfer", &["bundle", "ckpt", "schedules"], &[]),
    (
        "analyze",
        &["network", "data", "samples", "bundle", "ckpt", "format", "schedules", "seed"],
        &["zoo", "strict"],
    ),
    (
        "search",
        &[
            "network", "model", "bundle", "ckpt", "data", "beam", "candidates", "seed",
            "test-frac", "split-seed", "ffn-epochs", "rnn-epochs", "gbt-trees", "fit-seed",
        ],
        &[],
    ),
    (
        "autotune",
        &[
            "networks", "strategy", "model", "bundle", "ckpt", "data", "seed", "machine",
            "generations", "population", "offspring", "immigrants", "beam", "candidates",
            "checkpoint-dir", "checkpoint-every", "step-limit", "trace-cap", "trace-out",
            "report-out", "workers", "queue-cap", "test-frac", "split-seed", "ffn-epochs",
            "rnn-epochs", "gbt-trees", "fit-seed",
        ],
        &["resume", "sequential", "require-improvement"],
    ),
    (
        "bench",
        &[
            "out", "serve-out", "engine-out", "autotune-out", "simd-out", "analysis-out",
            "scale-out", "seed", "bundle", "ckpt", "precision",
        ],
        &["fast", "require-speedup", "engine"],
    ),
    (
        "serve",
        &[
            "bundle", "ckpt", "workers", "queue-cap", "listen", "port-file", "read-timeout-ms",
            "max-line-bytes", "max-conns", "max-inflight", "precision",
        ],
        &[],
    ),
    (
        "loadgen",
        &[
            "addr", "bundle", "ckpt", "samples", "data", "clients", "requests", "per-request",
            "rate", "depth", "out", "min-rps", "seed",
        ],
        &["fast"],
    ),
    ("info", &["artifacts", "bundle", "ckpt"], &[]),
];

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let Some(cmd) = args.subcommand.as_deref() else {
        println!("{USAGE}");
        return;
    };
    match KNOWN_ARGS.iter().find(|(name, _, _)| *name == cmd) {
        None => {
            eprintln!("error: unknown subcommand '{cmd}'\n\n{USAGE}");
            std::process::exit(2);
        }
        Some((_, keys, flags)) => {
            if let Err(e) = args.check_known(cmd, keys, flags) {
                eprintln!("error: {e}\n\n{USAGE}");
                std::process::exit(2);
            }
        }
    }
    let result = match cmd {
        "gen-data" => cmd_gen_data(&args),
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "quantize" => cmd_quantize(&args),
        "export-samples" => cmd_export_samples(&args),
        "fig8" => cmd_fig8(&args),
        "fig9" => cmd_fig9(&args),
        "ablate" => cmd_ablate(&args),
        "active" => cmd_active(&args),
        "transfer" => cmd_transfer(&args),
        "analyze" => cmd_analyze(&args),
        "search" => cmd_search(&args),
        "autotune" => cmd_autotune(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "info" => cmd_info(&args),
        // unreachable: KNOWN_ARGS gates every dispatched name above
        other => Err(anyhow::anyhow!("unhandled subcommand '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "gcn-perf — GNN performance model for DNN compiler schedules

USAGE: gcn-perf <subcommand> [--key value ...]

  gen-data        --pipelines N --schedules M --out data/dataset.bin [--seed S]
                  | --scale STAGES [--style transformer|inception]
                  --out data/corpus (write an out-of-core sharded corpus
                  of STAGES-stage graphs instead of one in-RAM dataset)
  train           --data data/dataset.bin --bundle data/gcn.bundle [--epochs E]
                  [--test-frac F] [--artifacts DIR]
                  | --stream data/corpus (train from a sharded corpus;
                  peak memory is bounded by --node-budget N, and graphs
                  above the budget train through aligned partitions)
  predict         --bundle data/gcn.bundle (--samples s.json | --data ds.bin
                  | --stream data/corpus [--node-budget N])
                  [--out preds.json] [--precision f32|int8]
  quantize        --bundle data/gcn.bundle [--out data/gcn-int8.bundle]
                  (mint an int8 per-channel serving bundle from a trained
                   f32 gcn bundle; serve it with --precision int8)
  export-samples  --data ds.bin [--out samples.json] [--limit N]
                  (binary dataset → the JSON interchange predict/serve read)
  fig8            --data ... --bundle ... [--ffn-epochs E] [--with-rnn]
                  [--report results/report.json]
  fig9            --bundle ... [--schedules K] [--report ...]
  ablate          --data ... [--epochs E]     (conv layers 0/1/2/4 sweep)
  active          --data ... [--rounds R --acquire K]  (§VI active learning)
  transfer        --bundle ...  (§VI-A cross-machine portability study)
  analyze         [--zoo | --network NAME | --data ds.bin |
                   --samples s.json | --bundle b] [--format text|json]
                  [--schedules K --seed S] [--strict]
                  (static analyzer: structure, schedule legality,
                   dependence/bounds, data audit; exit 0 clean, 1 with
                   findings — warnings gate only under --strict — 2 on
                   usage errors)
  search          --network NAME [--model oracle|gcn|ffn|rnn|gbt]
                  [--bundle ... | --data ...] [--beam W --candidates C]
  autotune        [--networks a,b,c] [--strategy beam|evolution]
                  [--model oracle|gcn|ffn|rnn|gbt [--bundle ... | --data ...]]
                  [--generations G --population P --offspring L]
                  [--beam W --candidates C] [--seed S] [--sequential]
                  [--checkpoint-dir DIR [--checkpoint-every K] [--resume]]
                  [--step-limit N] [--trace-out t.json] [--report-out r.json]
                  [--workers N --queue-cap Q] [--require-improvement]
                  (tune a fleet of zoo networks concurrently through one
                   shared PredictService; fixed --seed is deterministic,
                   --resume restarts bitwise from checkpoints, the trace
                   file feeds `train --data`)
  bench           [--out BENCH_3.json] [--serve-out BENCH_4.json]
                  [--engine-out BENCH_5.json] [--autotune-out BENCH_7.json]
                  [--simd-out BENCH_8.json] [--analysis-out BENCH_9.json]
                  [--scale-out BENCH_10.json] [--fast] [--engine]
                  [--require-speedup] [--bundle ... --precision f32|int8]
                  (dense-vs-sparse + serving + engine micro-benches +
                   autotuner fleet + scalar/SIMD/int8 lanes + out-of-core
                   scale tiers; --engine runs only the engine + simd
                   suites; --bundle/--precision validate a serving
                   bundle's numeric mode up front)
  serve           --bundle data/gcn.bundle [--precision f32|int8]
                  [--workers N] [--queue-cap Q]
                  [--listen ADDR [--port-file F] [--read-timeout-ms T]
                   [--max-conns C] [--max-inflight W]] [--max-line-bytes B]
                  (daemon: one JSON sample-array request per line — stdin
                   by default, multi-client TCP with --listen; `STATS`
                   answers live counters; SIGTERM/ctrl-d drains cleanly)
  loadgen         [--addr HOST:PORT (--samples s.json | --data ds.bin)
                   [--bundle ...]] [--clients N] [--requests M] [--rate R]
                  [--depth W] [--min-rps F] [--out BENCH_6.json] [--fast]
                  (concurrent client fleet; without --addr, runs the
                   self-contained in-process net bench; responses are
                   verified bitwise against direct predictions)
  info            [--artifacts DIR] [--bundle ...]

Unknown subcommands, options or flags exit nonzero with the valid set.
(--ckpt is accepted as an alias for --bundle.)";

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    let path = args.str_opt("data").context("--data required")?;
    store::load(Path::new(path))
}

fn split_dataset(args: &Args, ds: &Dataset) -> (Dataset, Dataset) {
    let frac = args.f64_or("test-frac", 0.1);
    ds.split(frac, args.u64_or("split-seed", 1234))
}

/// Load the execution backend, printing any loader warnings — the one
/// place in the stack that decides warnings go to stderr.
fn load_backend_verbose(args: &Args, with_train: bool) -> Result<Box<dyn Backend>> {
    Ok(load_backend(&artifacts_dir(args), with_train)?.warn_to_stderr())
}

/// `--bundle`, with `--ckpt` as a compatibility alias.
fn bundle_path_opt(args: &Args) -> Option<PathBuf> {
    args.str_opt("bundle")
        .or_else(|| args.str_opt("ckpt"))
        .map(PathBuf::from)
}

fn bundle_path(args: &Args) -> Result<PathBuf> {
    bundle_path_opt(args).context("--bundle required (a model bundle saved by `gcn-perf train`)")
}

/// Reconcile `--precision` with the bundle's kind. Asking an f32 bundle
/// for int8 (or the reverse) is a *usage* error, so it exits 2 like
/// every other bad-flag path — not 1 like a runtime failure.
fn resolve_precision_or_exit(args: &Args, bundle_kind: &str) -> gcn_perf::predictor::Precision {
    match gcn_perf::predictor::quant::resolve_precision(args.str_opt("precision"), bundle_kind) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("error: {msg}");
            std::process::exit(2);
        }
    }
}

/// Load the GCN bundle and stand a serving layer in front of it: the eval
/// harnesses and figure commands are service clients, so their traffic
/// rides the same coalescing path the daemon serves.
fn load_gcn_service(args: &Args) -> Result<PredictService> {
    let gcn = GcnPredictor::load(&bundle_path(args)?)?;
    Ok(PredictService::with_defaults(Arc::new(gcn)))
}

fn fit_config(args: &Args) -> FitConfig {
    let defaults = FitConfig::default();
    FitConfig {
        ffn_epochs: args.usize_or("ffn-epochs", defaults.ffn_epochs),
        rnn_epochs: args.usize_or("rnn-epochs", defaults.rnn_epochs),
        rnn_hidden: defaults.rnn_hidden,
        gbt_trees: args.usize_or("gbt-trees", defaults.gbt_trees),
        seed: args.u64_or("fit-seed", defaults.seed),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    // --scale: TpuGraphs-scale synthetic graphs streamed straight to a
    // sharded on-disk corpus — never materialized in RAM, so 100k-stage
    // tiers generate in bounded memory
    let scale = args.usize_or("scale", 0);
    if scale > 0 {
        let style_name = args.str_or("style", "transformer");
        let style = LargeStyle::parse(style_name)
            .with_context(|| format!("unknown --style '{style_name}' (transformer|inception)"))?;
        let cfg = LargeConfig {
            style,
            n_stages: scale,
            n_pipelines: args.usize_or("pipelines", 2) as u32,
            schedules_per_pipeline: args.usize_or("schedules", 4) as u32,
            seed: args.u64_or("seed", 42),
        };
        let out = PathBuf::from(args.str_or("out", "data/corpus"));
        eprintln!(
            "generating {} corpus: {} pipelines x {} schedules at {} stages each...",
            style.name(),
            cfg.n_pipelines,
            cfg.schedules_per_pipeline,
            cfg.n_stages
        );
        let n = write_large_corpus(&out, &cfg)?;
        println!("wrote {n} samples ({scale} stages each) to sharded corpus {}", out.display());
        return Ok(());
    }
    let cfg = DataGenConfig {
        n_pipelines: args.usize_or("pipelines", 200),
        schedules_per_pipeline: args.usize_or("schedules", 16),
        seed: args.u64_or("seed", 42),
        gen: GenConfig::default(),
        machine: Machine::default(),
    };
    let out = PathBuf::from(args.str_or("out", "data/dataset.bin"));
    eprintln!(
        "generating {} pipelines x {} schedules...",
        cfg.n_pipelines, cfg.schedules_per_pipeline
    );
    let ds = build_dataset(&cfg);
    store::save(&ds, &out)?;
    println!(
        "wrote {} samples from {} pipelines to {}",
        ds.len(),
        ds.num_pipelines(),
        out.display()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = load_backend_verbose(args, true)?;
    let cfg = TrainConfig {
        epochs: args.usize_or("epochs", 40),
        seed: args.u64_or("seed", 7),
        patience: args.usize_or("patience", 8),
        lr: args.f64_or("lr", gcn_perf::constants::LEARNING_RATE) as f32,
        node_budget: args.usize_or("node-budget", gcn_perf::constants::node_budget()),
        ..Default::default()
    };
    let bundle = bundle_path_opt(args).unwrap_or_else(|| PathBuf::from("data/gcn.bundle"));

    // --stream: train straight from a sharded corpus. Batches decode one
    // at a time, so peak memory is bounded by the node budget — and the
    // loop is the same one the in-RAM path runs, so when the corpus fits
    // in RAM the two produce bitwise-identical bundles.
    if let Some(dir) = args.str_opt("stream") {
        let sd = ShardedDataset::open(Path::new(dir))?;
        let (tv, ev) = split_source(
            &sd,
            args.f64_or("test-frac", 0.1),
            args.u64_or("split-seed", 1234),
        )?;
        eprintln!(
            "streaming {dir}: train {} samples ({} nodes), test {} samples, node budget {}",
            tv.len(),
            tv.total_nodes(),
            ev.len(),
            cfg.node_budget
        );
        let result = train_source(rt.as_ref(), &tv, &ev, &cfg)?;
        save_gcn_bundle(&bundle, rt.manifest().n_conv, &result.params, &tv.stats)?;
        println!(
            "best test MAPE {:.2}% after {} epochs; bundle: {}",
            result.best_test_mape,
            result.history.len(),
            bundle.display()
        );
        return Ok(());
    }

    let ds = load_dataset(args)?;
    let (train_ds, test_ds) = split_dataset(args, &ds);
    eprintln!(
        "train: {} samples / {} pipelines, test: {} / {}",
        train_ds.len(),
        train_ds.num_pipelines(),
        test_ds.len(),
        test_ds.num_pipelines()
    );
    let result = train_and_save(rt.as_ref(), &train_ds, &test_ds, &cfg, &bundle)?;
    println!(
        "best test MAPE {:.2}% after {} epochs; bundle: {}",
        result.best_test_mape,
        result.history.len(),
        bundle.display()
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let path = bundle_path(args)?;
    resolve_precision_or_exit(args, &registry::bundle_kind(&path)?);
    // one-shot client of the same serving layer `serve` runs long-lived;
    // serving loads pick the best runtime-detected microkernel tier
    let service =
        PredictService::with_defaults(Arc::from(registry::load_bundle_serving(&path)?));
    let engine = service.engine_info();
    eprintln!("engine: {} kernels, {} precision", engine.kernel_variant, engine.precision);
    let (model, ids, predictions) = if let Some(dir) = args.str_opt("stream") {
        // sharded corpus: decode in node-budget chunks so resident memory
        // stays bounded no matter how large the corpus is; graphs above
        // the budget predict through aligned partitions and recombine
        let budget = args.usize_or("node-budget", gcn_perf::constants::node_budget());
        let sd = ShardedDataset::open(Path::new(dir))?;
        let stats = sd
            .stats()
            .cloned()
            .context("corpus index carries no feature stats (rewrite it with gen-data --scale)")?;
        let view = SourceView::whole(&sd, stats);
        let mut model = String::new();
        let mut ids = Vec::new();
        let mut predictions = Vec::new();
        for chunk in view.iter().budget_chunks(budget) {
            let chunk = chunk?;
            ids.extend(sample_ids(&chunk));
            if chunk.len() == 1 && chunk[0].n_stages as usize > budget {
                let part = partition_sample(&chunk[0], budget);
                let resp = service.predict_blocking(PredictRequest::new(part.parts))?;
                predictions.push(combine_runtimes(&resp.predictions));
                model = resp.model;
            } else {
                let resp = service.predict_blocking(PredictRequest::new(chunk))?;
                predictions.extend(resp.predictions);
                model = resp.model;
            }
        }
        (model, ids, predictions)
    } else {
        let samples = if let Some(f) = args.str_opt("samples") {
            let text = std::fs::read_to_string(f).with_context(|| format!("read {f}"))?;
            gcn_perf::dataset::json::samples_from_json(&text)?
        } else if args.str_opt("data").is_some() {
            load_dataset(args)?.samples
        } else {
            bail!("predict needs --samples file.json, --data dataset.bin or --stream corpus/");
        };
        let ids = sample_ids(&samples);
        let resp = service.predict_blocking(PredictRequest::new(samples))?;
        (resp.model, ids, resp.predictions)
    };
    let report = prediction_report(&model, &ids, &predictions);
    match args.str_opt("out") {
        Some(out) => {
            let out = Path::new(out);
            if let Some(dir) = out.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(out, report.to_string())?;
            eprintln!(
                "{} predictions ({}) written to {}",
                predictions.len(),
                model,
                out.display()
            );
        }
        None => println!("{}", report.to_string()),
    }
    Ok(())
}

/// Mint a reduced-precision serving bundle: every GEMM weight matrix
/// becomes per-output-channel int8 + f32 scales, everything else rides
/// along verbatim. The result is a first-class registry bundle (kind
/// "gcn-int8") that `predict`/`serve`/`bench` accept via `--precision
/// int8`; the original f32 bundle stays the full-precision reference.
fn cmd_quantize(args: &Args) -> Result<()> {
    let src_path = bundle_path(args)?;
    let out = PathBuf::from(args.str_or("out", "data/gcn-int8.bundle"));
    let src = gcn_perf::predictor::bundle::Bundle::load(&src_path)?;
    let qb = gcn_perf::predictor::quant::quantize_bundle(&src)?;
    qb.save(&out)?;
    println!(
        "quantized '{}' {} -> '{}' {} ({} int8 tensors, {} f32 tensors)",
        src.kind,
        src_path.display(),
        qb.kind,
        out.display(),
        qb.qtensors.len(),
        qb.tensors.len()
    );
    Ok(())
}

fn cmd_export_samples(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let n = args.usize_or("limit", ds.len()).min(ds.len());
    let text = gcn_perf::dataset::json::samples_to_json(&ds.samples[..n]);
    match args.str_opt("out") {
        Some(out) => {
            let out = Path::new(out);
            if let Some(dir) = out.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(out, &text)?;
            eprintln!("{n} samples written to {}", out.display());
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// The serving daemon. Two front-ends over the identical session loop
/// (`net::session::serve_session`): with `--listen ADDR`, a multi-client
/// TCP server — thread per connection, admission control, graceful drain
/// on SIGTERM/SIGINT — and otherwise the classic stdin/stdout mode.
/// Either way requests are *pipelined* into the shared service (so
/// concurrent lines coalesce into fused batches), malformed requests
/// answer with an `{"error": ...}` line without stopping the daemon, and
/// the `STATS` keyword answers live counters + latency percentiles.
fn cmd_serve(args: &Args) -> Result<()> {
    use gcn_perf::net::{serve_session, ServeShared, SessionOpts, TcpServer, TcpServerConfig};

    let path = bundle_path(args)?;
    resolve_precision_or_exit(args, &registry::bundle_kind(&path)?);
    let cfg = ServiceConfig {
        workers: args.usize_or("workers", 1),
        queue_cap: args.usize_or("queue-cap", 64),
        ..Default::default()
    };
    // the daemon serves on the best runtime-detected microkernel tier;
    // the engine in use is visible in `STATS` and the shutdown summary
    let service = Arc::new(PredictService::spawn(
        Arc::from(registry::load_bundle_serving(&path)?),
        cfg.clone(),
    ));
    let engine = service.engine_info();
    let shared = ServeShared::new(Arc::clone(&service));
    let max_line = args.usize_or("max-line-bytes", gcn_perf::net::DEFAULT_MAX_FRAME_BYTES);

    if let Some(listen) = args.str_opt("listen") {
        let shutdown = gcn_perf::net::signal::install_term_flag();
        let tcp_cfg = TcpServerConfig {
            max_conns: args.usize_or("max-conns", 256),
            max_frame_bytes: max_line,
            max_inflight_per_conn: args.usize_or("max-inflight", 32),
            read_timeout: match args.u64_or("read-timeout-ms", 0) {
                0 => None,
                ms => Some(std::time::Duration::from_millis(ms)),
            },
        };
        let server = TcpServer::bind(listen, shared.clone(), tcp_cfg, shutdown)?;
        eprintln!(
            "serving '{}' from {} on {} ({} kernels, {} precision) — line-delimited \
             JSON over TCP; SIGTERM/SIGINT drains and exits",
            service.model_name(),
            path.display(),
            server.local_addr(),
            engine.kernel_variant,
            engine.precision
        );
        if let Some(pf) = args.str_opt("port-file") {
            // scripts bind --listen 127.0.0.1:0 and read the real
            // address back from this file
            std::fs::write(pf, server.local_addr().to_string())
                .with_context(|| format!("write {pf}"))?;
        }
        let report = server.join()?;
        print_serve_stats(&shared, Some(&report));
    } else {
        eprintln!(
            "serving '{}' from {} ({} kernels, {} precision) — one JSON sample-array \
             request per stdin line; ctrl-d to stop",
            service.model_name(),
            path.display(),
            engine.kernel_variant,
            engine.precision
        );
        let opts = SessionOpts { max_frame_bytes: max_line, max_inflight: cfg.queue_cap.max(1) };
        let stdin = std::io::stdin();
        serve_session(stdin.lock(), std::io::stdout(), &shared, &opts)?;
        print_serve_stats(&shared, None);
    }
    Ok(())
}

/// The shutdown summary both serve modes print to stderr; the same
/// numbers are available live through the `STATS` command.
fn print_serve_stats(
    shared: &gcn_perf::net::ServeShared,
    report: Option<&gcn_perf::net::ServerReport>,
) {
    let stats = shared.service.stats();
    let lat = shared.latency.snapshot();
    let conns = match report {
        Some(r) => format!("; {} connections ({} rejected)", r.connections, r.rejected),
        None => String::new(),
    };
    eprintln!(
        "{}; latency p50 {:.1}us / p99 {:.1}us{conns}",
        stats.summary_line(),
        lat.p50_ns / 1e3,
        lat.p99_ns / 1e3
    );
}

/// The load-test client fleet. With `--addr`, hammers an external server
/// (verifying bitwise when `--bundle` supplies the server's own model);
/// without it, runs the self-contained `eval::net_bench` — in-process
/// TCP server + fleet over the mixed-size pool, always bitwise-verified.
/// Both paths write the BENCH_6.json latency-histogram report, and
/// `--min-rps` turns the run into a pass/fail throughput gate (the CI
/// smoke).
fn cmd_loadgen(args: &Args) -> Result<()> {
    use gcn_perf::eval::net_bench::{
        run_net_bench, write_net_report, NetBenchConfig, NetBenchReport,
    };
    use gcn_perf::net::{fetch_stats, run_loadgen, LoadgenConfig};

    let fast = args.has_flag("fast");
    let out = PathBuf::from(args.str_or("out", "BENCH_6.json"));
    let min_rps = args.f64_or("min-rps", 0.0);

    let report = if let Some(addr) = args.str_opt("addr") {
        let samples = if let Some(f) = args.str_opt("samples") {
            let text = std::fs::read_to_string(f).with_context(|| format!("read {f}"))?;
            gcn_perf::dataset::json::samples_from_json(&text)?
        } else if args.str_opt("data").is_some() {
            load_dataset(args)?.samples
        } else {
            bail!("loadgen --addr needs --samples file.json or --data dataset.bin");
        };
        // direct predictions for bitwise verification — only possible
        // when the server's own bundle is on hand
        let expected = match bundle_path_opt(args) {
            Some(b) => {
                let predictor = registry::load_bundle(&b)?;
                let refs: Vec<&gcn_perf::dataset::sample::GraphSample> = samples.iter().collect();
                Some(predictor.predict(&refs)?)
            }
            None => None,
        };
        let workload = LoadgenConfig {
            clients: args.usize_or("clients", if fast { 8 } else { 32 }),
            requests_per_client: args.usize_or("requests", if fast { 16 } else { 64 }),
            samples_per_request: args.usize_or("per-request", samples.len().min(4)),
            rate_per_client: args.f64_or("rate", 0.0),
            pipeline_depth: args.usize_or("depth", 8),
        };
        let loadgen = run_loadgen(addr, &samples, expected.as_deref(), &workload)?;
        let server_stats = fetch_stats(addr).ok();
        NetBenchReport { fast, workload, loadgen, server_stats }
    } else {
        run_net_bench(&NetBenchConfig { fast, seed: args.u64_or("seed", 3) })?
    };

    write_net_report(&report, &out)?;
    let l = &report.loadgen;
    println!(
        "loadgen report written to {} ({} clients x {} requests: {:.1} req/s, \
         {} responses bitwise-verified, latency p50 {:.1}us / p99 {:.1}us)",
        out.display(),
        report.workload.clients,
        report.workload.requests_per_client,
        l.requests_per_s,
        l.bitwise_verified,
        l.latency.p50_ns / 1e3,
        l.latency.p99_ns / 1e3
    );
    if min_rps > 0.0 {
        report.require_throughput(min_rps)?;
    }
    Ok(())
}

fn print_fig8(rows: &[RegressionMetrics]) {
    println!("\nFig 8 — prediction quality on the test set");
    println!("{}", RegressionMetrics::header());
    for r in rows {
        println!("{}", r.row());
    }
    if rows.len() >= 3 {
        println!(
            "\nerror reduction vs halide-ffn: {:.2}x   vs tvm-gbt: {:.2}x (paper: 7.75x / 12x)",
            rows[1].avg_error_pct / rows[0].avg_error_pct,
            rows[2].avg_error_pct / rows[0].avg_error_pct
        );
    }
}

fn cmd_fig8(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let (train_ds, test_ds) = split_dataset(args, &ds);
    let gcn = load_gcn_service(args)?;
    let mut rows = harness::run_fig8(
        &gcn,
        &train_ds,
        &test_ds,
        args.usize_or("ffn-epochs", 30),
        true,
    )?;
    if args.has_flag("with-rnn") {
        rows.push(harness::run_fig8_rnn(
            &train_ds,
            &test_ds,
            args.usize_or("rnn-epochs", 10),
            true,
        )?);
    }
    print_fig8(&rows);
    if let Some(report) = args.str_opt("report") {
        harness::write_report(Path::new(report), &rows, &[], 0.0)?;
        println!("report written to {report}");
    }
    Ok(())
}

fn print_fig9(rows: &[RankResult], avg: f64) {
    println!("\nFig 9 — pairwise ranking accuracy on real-world networks");
    println!("{}", RankResult::header());
    for r in rows {
        println!("{}", r.row());
    }
    println!("{:<14} {:>10} {:>10} {:>10.1}%", "AVERAGE", "", "", avg);
    println!("(paper: 65–90% per network, ~75% average)");
}

fn cmd_fig9(args: &Args) -> Result<()> {
    let gcn = load_gcn_service(args)?;
    let rows = harness::run_fig9(
        &gcn,
        &Machine::default(),
        args.usize_or("schedules", 100),
        args.u64_or("seed", 5),
    )?;
    let (rows, avg) = rank_networks(rows);
    print_fig9(&rows, avg);
    if let Some(report) = args.str_opt("report") {
        harness::write_report(Path::new(report), &[], &rows, avg)?;
        println!("report written to {report}");
    }
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let (train_ds, test_ds) = split_dataset(args, &ds);
    let epochs = args.usize_or("epochs", 12);
    let lr = args.f64_or("lr", 0.03) as f32;
    let dir = artifacts_dir(args);
    println!("conv-depth ablation (§III-C parametric sweep), {epochs} epochs each, lr {lr}");
    println!("{:<8} {:>12} {:>9}", "layers", "test MAPE %", "backend");
    for layers in [0usize, 1, 2, 4] {
        // infallible in the default build (native fallback); the backend
        // column makes a mixed pjrt/native sweep visible
        let rt = load_variant_backend(&dir, layers, true)?.warn_to_stderr();
        let mut params = rt.init_params(7);
        // output-bias init at the train mean log-runtime (as train() does)
        let mean_log_y: f64 = train_ds
            .samples
            .iter()
            .map(|s| s.mean_runtime().max(1e-12).ln())
            .sum::<f64>()
            / train_ds.len().max(1) as f64;
        if let Some(b_out) = params.values.last_mut() {
            b_out[0] = mean_log_y as f32;
        }
        let mut accum = params.zeros_like();
        let best_rt = train_ds.best_per_pipeline();
        let mut rng = gcn_perf::util::rng::Rng::new(13);
        for _ in 0..epochs {
            let mut order: Vec<usize> = (0..train_ds.len()).collect();
            rng.shuffle(&mut order);
            for chunk in order.chunks(gcn_perf::constants::BATCH) {
                let samples: Vec<&gcn_perf::dataset::sample::GraphSample> =
                    chunk.iter().map(|&i| &train_ds.samples[i]).collect();
                let bests: Vec<f64> =
                    samples.iter().map(|s| best_rt[&s.pipeline_id]).collect();
                let batch = gcn_perf::model::PackedBatch::build(
                    &samples,
                    train_ds.stats.as_ref().unwrap(),
                    &bests,
                )?;
                rt.train_step_lr(&mut params, &mut accum, &batch, lr)?;
            }
        }
        // evaluate this variant through the unified predictor path
        let view = gcn_perf::predictor::GcnView {
            backend: rt.as_ref(),
            params: &params,
            stats: test_ds.stats.as_ref().unwrap(),
        };
        let mape = gcn_perf::train::evaluate_predictor_mape(&view, &test_ds)?;
        println!("{:<8} {:>12.2} {:>9}", layers, mape, rt.name());
    }
    Ok(())
}

fn cmd_active(args: &Args) -> Result<()> {
    use gcn_perf::train::active::{active_learning_study, ActiveConfig};
    let ds = load_dataset(args)?;
    let (pool, test) = split_dataset(args, &ds);
    let rt = load_backend_verbose(args, true)?;
    let cfg = ActiveConfig {
        seed_frac: args.f64_or("seed-frac", 0.1),
        acquire: args.usize_or("acquire", 1024),
        rounds: args.usize_or("rounds", 4),
        epochs_per_round: args.usize_or("epochs", 8),
        seed: args.u64_or("seed", 3),
    };
    println!("§VI active learning: committee disagreement vs random acquisition");
    println!("{:<7} {:>9} {:>16} {:>16}", "round", "labeled", "active MAPE %", "random MAPE %");
    for r in active_learning_study(rt.as_ref(), &pool, &test, &cfg)? {
        println!(
            "{:<7} {:>9} {:>16.2} {:>16.2}",
            r.round, r.labeled, r.test_mape_active, r.test_mape_random
        );
    }
    Ok(())
}

fn cmd_transfer(args: &Args) -> Result<()> {
    // §VI-A: "while the current set of features is applicable across CPU
    // platforms, it would require significant rework when porting to other
    // hardware architectures". Study: train on the Xeon dataset (the given
    // bundle), evaluate ranking on datasets benchmarked on *other* CPU
    // presets. Features are machine-aware (cache-fit flags etc. use each
    // machine's geometry), so CPU→CPU transfer should hold.
    let gcn = load_gcn_service(args)?;
    let schedules = args.usize_or("schedules", 60);
    println!("§VI-A cross-machine transfer (trained on xeon_d2191)");
    println!("{:<16} {:>14}", "machine", "rank acc %");
    for name in ["xeon_d2191", "desktop_4core", "server_64core"] {
        let machine = Machine::by_name(name).unwrap();
        let rows = harness::run_fig9(&gcn, &machine, schedules, 17)?;
        let (_, avg) = rank_networks(rows);
        println!("{:<16} {:>14.1}", name, avg);
    }
    Ok(())
}

/// Pull the analyzer [`Diagnostic`] out of a loader error chain, if the
/// failure was a coded finding (as opposed to, say, an I/O error).
///
/// [`Diagnostic`]: gcn_perf::analysis::Diagnostic
fn diagnostic_in_chain(e: &anyhow::Error) -> Option<gcn_perf::analysis::Diagnostic> {
    e.chain().find_map(|c| c.downcast_ref::<gcn_perf::analysis::Diagnostic>()).cloned()
}

/// The `analyze` subcommand: run the static analyzer over one target and
/// render a diagnostics report. Exit policy: 0 when clean (warnings do
/// not gate unless `--strict`), 1 when findings, 2 on usage errors.
///
/// Targets, in precedence order: `--network NAME` (one zoo pipeline),
/// `--data ds.bin` (binary dataset audit), `--samples s.json` (JSON
/// interchange audit), `--bundle b` (model-bundle tensor/stats audit),
/// and the default `--zoo` (every zoo network). Pipeline targets verify
/// the default schedule plus `--schedules K` random ones.
fn cmd_analyze(args: &Args) -> Result<()> {
    use gcn_perf::analysis::{analyze_pipeline_schedule, Report};
    use gcn_perf::schedule::primitives::PipelineSchedule;
    use gcn_perf::schedule::random::random_pipeline_schedule;
    use gcn_perf::util::json::Json;

    let format = args.str_or("format", "text");
    if format != "text" && format != "json" {
        eprintln!("error: --format must be 'text' or 'json' (got '{format}')\n\n{USAGE}");
        std::process::exit(2);
    }
    let strict = args.has_flag("strict");
    let n_random = args.usize_or("schedules", 0);
    let seed = args.u64_or("seed", 0);

    // all four analyzer passes over one pipeline: structure, default-
    // schedule verification, dependence/bounds, plus K random schedules
    // through the same collect-every-violation verifier
    let analyze_network = |net: &gcn_perf::ir::pipeline::Pipeline| -> Report {
        let mut report = Report::new(format!("zoo/{}", net.name));
        let ranks: Vec<usize> = net.stages.iter().map(|s| s.shape.len()).collect();
        let ap = analyze_pipeline_schedule(net, &PipelineSchedule::default_for(&ranks), &mut report);
        if n_random > 0 {
            let nests = gcn_perf::lower::lower_pipeline(net);
            let mut rng = gcn_perf::util::rng::Rng::new(seed);
            for i in 0..n_random {
                let sched = random_pipeline_schedule(net, &nests, &mut rng);
                for mut d in ap.verify_schedule(&sched) {
                    d.location = Some(match d.location.take() {
                        Some(l) => format!("random schedule {i}, {l}"),
                        None => format!("random schedule {i}"),
                    });
                    report.push(d);
                }
            }
            report.note(format!("{n_random} random schedules verified"));
        }
        report
    };

    // a loader that rejected its input did the audit already — surface
    // its coded finding as the report instead of a bare error exit
    let report_or_loader_finding =
        |target: String, r: std::result::Result<Report, anyhow::Error>| -> Result<Report> {
            match r {
                Ok(rep) => Ok(rep),
                Err(e) => match diagnostic_in_chain(&e) {
                    Some(d) => {
                        let mut rep = Report::new(target);
                        rep.note(format!("rejected at load time: {e:#}"));
                        rep.push(d);
                        Ok(rep)
                    }
                    None => Err(e),
                },
            }
        };

    let mut reports: Vec<Report> = Vec::new();
    if let Some(name) = args.str_opt("network") {
        let net = gcn_perf::zoo::all_networks()
            .into_iter()
            .find(|n| n.name == name)
            .with_context(|| format!("unknown network '{name}'"))?;
        reports.push(analyze_network(&net));
    } else if let Some(path) = args.str_opt("data") {
        reports.push(report_or_loader_finding(
            format!("dataset {path}"),
            store::load(Path::new(path)).map(|ds| {
                let mut rep = Report::new(format!("dataset {path}"));
                rep.extend(gcn_perf::analysis::audit_dataset(&ds));
                rep.note(format!("{} samples audited", ds.len()));
                rep
            }),
        )?);
    } else if let Some(path) = args.str_opt("samples") {
        reports.push(report_or_loader_finding(
            format!("samples {path}"),
            std::fs::read_to_string(path)
                .with_context(|| format!("read {path}"))
                .and_then(|text| gcn_perf::dataset::json::samples_from_json(&text))
                .map(|samples| {
                    let mut rep = Report::new(format!("samples {path}"));
                    let ds = Dataset { samples, stats: None };
                    rep.extend(gcn_perf::analysis::audit_dataset(&ds));
                    rep.note(format!("{} samples audited", ds.len()));
                    rep
                }),
        )?);
    } else if let Some(path) = bundle_path_opt(args) {
        reports.push(report_or_loader_finding(
            format!("bundle {}", path.display()),
            gcn_perf::predictor::bundle::Bundle::load(&path).map(|b| {
                let mut rep = Report::new(format!("bundle {}", path.display()));
                rep.extend(gcn_perf::analysis::audit_bundle(&b));
                rep.note(format!(
                    "kind '{}', {} f32 + {} int8 tensors audited",
                    b.kind,
                    b.tensors.len(),
                    b.qtensors.len()
                ));
                rep
            }),
        )?);
    } else {
        // default: the whole zoo (also what --zoo spells explicitly)
        for net in gcn_perf::zoo::all_networks() {
            reports.push(analyze_network(&net));
        }
    }

    if format == "json" {
        let j = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        println!("{j}");
    } else {
        for r in &reports {
            print!("{}", r.to_text());
        }
    }
    let errors: usize = reports.iter().map(|r| r.errors()).sum();
    let warnings: usize = reports.iter().map(|r| r.warnings()).sum();
    eprintln!(
        "analyzed {} target(s): {errors} error(s), {warnings} warning(s)",
        reports.len()
    );
    if errors > 0 || (strict && warnings > 0) {
        std::process::exit(1);
    }
    Ok(())
}

/// The search cost model: the oracle scores schedules directly in the
/// simulator; every registered predictor goes through the caching
/// [`PredictorCost`] bridge.
enum SearchCost {
    Oracle(SimCost),
    Learned(PredictorCost),
}

impl SearchCost {
    fn as_cost_model(&self) -> &dyn CostModel {
        match self {
            SearchCost::Oracle(m) => m,
            SearchCost::Learned(m) => m,
        }
    }
}

fn cmd_search(args: &Args) -> Result<()> {
    let name = args.str_or("network", "unet");
    let net = gcn_perf::zoo::all_networks()
        .into_iter()
        .find(|n| n.name == name)
        .with_context(|| format!("unknown network '{name}'"))?;
    let nests = gcn_perf::lower::lower_pipeline(&net);
    let machine = Machine::default();
    let bundle = bundle_path_opt(args);
    let bundle_kind = match &bundle {
        Some(b) => Some(registry::bundle_kind(b)?),
        None => None,
    };
    // --model defaults to the bundle's own kind when one is given, and to
    // the oracle otherwise; an explicit --model must match the bundle
    let model_kind = args
        .str_opt("model")
        .map(str::to_string)
        .or_else(|| bundle_kind.clone())
        .unwrap_or_else(|| "oracle".to_string());
    let cfg = BeamConfig {
        beam_width: args.usize_or("beam", 8),
        candidates_per_stage: args.usize_or("candidates", 12),
        seed: args.u64_or("seed", 1),
    };

    let cost = if model_kind == "oracle" {
        if let Some(b) = &bundle {
            bail!(
                "--model oracle does not use a model bundle; drop --bundle {} or pick its model",
                b.display()
            );
        }
        SearchCost::Oracle(SimCost { machine: machine.clone() })
    } else {
        // any registered model: from a saved bundle when given, otherwise
        // fitted on the training split of --data (baselines only)
        let predictor: Box<dyn Predictor> = match &bundle {
            Some(b) => {
                let kind = bundle_kind.as_deref().unwrap_or_default();
                if kind != model_kind {
                    bail!(
                        "--model {model_kind} conflicts with bundle {} (kind '{kind}')",
                        b.display()
                    );
                }
                registry::load_bundle(b)?
            }
            None => {
                let ds = load_dataset(args).with_context(|| {
                    format!("model '{model_kind}' needs --bundle or --data to fit from")
                })?;
                let (train_ds, _) = split_dataset(args, &ds);
                registry::fit_model(&model_kind, &train_ds, &fit_config(args))?
            }
        };
        SearchCost::Learned(PredictorCost::new(predictor, machine.clone()))
    };

    let ranks: Vec<usize> = net.stages.iter().map(|s| s.shape.len()).collect();
    let default_t = gcn_perf::sim::simulate(
        &net,
        &nests,
        &gcn_perf::schedule::primitives::PipelineSchedule::default_for(&ranks),
        &machine,
    );
    let (best, score) = beam_search(&net, &nests, cost.as_cost_model(), &cfg)?;
    let true_t = gcn_perf::sim::simulate(&net, &nests, &best, &machine);
    println!("network {name}: default {:.3} ms", default_t * 1e3);
    println!(
        "beam search ({}): found {:.3} ms (model score {:.3} ms) — {:.2}x speedup",
        cost.as_cost_model().name(),
        true_t * 1e3,
        score * 1e3,
        default_t / true_t
    );
    if let SearchCost::Learned(m) = &cost {
        let (hits, evals) = m.cache_stats();
        println!(
            "cost cache: {hits} hits / {evals} model evaluations ({} unique schedules)",
            m.cache_len()
        );
    }
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    use gcn_perf::autotune::{run_fleet, EvolutionConfig, FleetConfig, FleetCost, StrategyKind};

    let defaults = FleetConfig::default();
    let networks: Vec<String> = match args.str_opt("networks") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => defaults.networks.clone(),
    };
    let machine = match args.str_opt("machine") {
        Some(m) => Machine::by_name(m).with_context(|| format!("unknown machine '{m}'"))?,
        None => Machine::default(),
    };
    let seed = args.u64_or("seed", 1);
    let cfg = FleetConfig {
        networks,
        strategy: StrategyKind::parse(args.str_or("strategy", "evolution"))?,
        beam: BeamConfig {
            beam_width: args.usize_or("beam", 8),
            candidates_per_stage: args.usize_or("candidates", 12),
            seed,
        },
        evolution: EvolutionConfig {
            population: args.usize_or("population", defaults.evolution.population),
            offspring: args.usize_or("offspring", defaults.evolution.offspring),
            immigrants: args.usize_or("immigrants", defaults.evolution.immigrants),
            generations: args.usize_or("generations", defaults.evolution.generations),
            seed,
        },
        machine: machine.clone(),
        seed,
        sequential: args.has_flag("sequential"),
        checkpoint_dir: args.str_opt("checkpoint-dir").map(PathBuf::from),
        checkpoint_every: args.usize_or("checkpoint-every", defaults.checkpoint_every),
        resume: args.has_flag("resume"),
        step_limit: args.usize_or("step-limit", 0),
        trace_cap: args.usize_or("trace-cap", defaults.trace_cap),
    };

    // cost model resolution mirrors `search`: oracle scores in the
    // simulator; any registered predictor serves through one shared
    // coalescing service that every fleet worker submits to
    let bundle = bundle_path_opt(args);
    let bundle_kind = match &bundle {
        Some(b) => Some(registry::bundle_kind(b)?),
        None => None,
    };
    let model_kind = args
        .str_opt("model")
        .map(str::to_string)
        .or_else(|| bundle_kind.clone())
        .unwrap_or_else(|| "oracle".to_string());
    let cost = if model_kind == "oracle" {
        if let Some(b) = &bundle {
            bail!(
                "--model oracle does not use a model bundle; drop --bundle {} or pick its model",
                b.display()
            );
        }
        FleetCost::Oracle
    } else {
        let predictor: Box<dyn Predictor> = match &bundle {
            Some(b) => {
                let kind = bundle_kind.as_deref().unwrap_or_default();
                if kind != model_kind {
                    bail!(
                        "--model {model_kind} conflicts with bundle {} (kind '{kind}')",
                        b.display()
                    );
                }
                registry::load_bundle(b)?
            }
            None => {
                let ds = load_dataset(args).with_context(|| {
                    format!("model '{model_kind}' needs --bundle or --data to fit from")
                })?;
                let (train_ds, _) = split_dataset(args, &ds);
                registry::fit_model(&model_kind, &train_ds, &fit_config(args))?
            }
        };
        let workers = args
            .usize_or("workers", gcn_perf::util::threadpool::num_threads().clamp(1, 4));
        let service = PredictService::spawn(
            Arc::from(predictor),
            ServiceConfig {
                workers,
                queue_cap: args.usize_or("queue-cap", 64),
                ..Default::default()
            },
        );
        FleetCost::Service(Arc::new(service))
    };

    let report = run_fleet(&cfg, &cost)?;
    for r in &report.results {
        let resumed = match r.resumed_from {
            Some(g) => format!(", resumed from gen {g}"),
            None => String::new(),
        };
        let status = if r.completed { "" } else { " [interrupted — resume to finish]" };
        println!(
            "{}: default {:.3} ms → tuned {:.3} ms ({:.2}x, {} gens, {} scored{resumed}){}{}",
            r.network,
            r.default_cost * 1e3,
            r.tuned_cost * 1e3,
            r.default_cost / r.tuned_cost,
            r.generations,
            r.candidates_scored,
            if r.adopted_default { " [kept default]" } else { "" },
            status
        );
    }
    if let Some(stats) = &report.service_stats {
        println!("shared service: {}", stats.summary_line());
    }
    println!(
        "fleet: {} pipelines in {:.2}s ({} mode, {} trace samples)",
        report.results.len(),
        report.wall_s,
        if cfg.sequential { "sequential" } else { "concurrent" },
        report.samples.len()
    );

    if let Some(path) = args.str_opt("trace-out") {
        std::fs::write(path, gcn_perf::dataset::json::samples_to_json(&report.samples))
            .with_context(|| format!("writing trace to {path}"))?;
        println!("search trace written to {path} (train with `gcn-perf train --data {path}`)");
    }
    if let Some(path) = args.str_opt("report-out") {
        std::fs::write(path, report.to_json(&cfg).to_string())
            .with_context(|| format!("writing report to {path}"))?;
        println!("fleet report written to {path}");
    }
    if args.has_flag("require-improvement") {
        for r in &report.results {
            anyhow::ensure!(r.completed, "{} did not finish (step limit hit)", r.network);
            anyhow::ensure!(
                r.tuned_cost <= r.default_cost,
                "{}: tuned {} worse than default {}",
                r.network,
                r.tuned_cost,
                r.default_cost
            );
        }
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let fast = args.has_flag("fast") || std::env::var("GCN_PERF_BENCH_FAST").is_ok();
    let seed = args.u64_or("seed", 3);
    // --engine: run only the engine + simd micro-suites (what
    // scripts/profile.sh wraps for flamegraph work — no serving threads
    // muddying the profile)
    let engine_only = args.has_flag("engine");

    let mut earlier_reports = None;
    if !engine_only {
        let cfg = gcn_perf::eval::perf::PerfBenchConfig { fast, seed };
        let report = gcn_perf::eval::perf::run_perf_bench(&cfg)?;
        let out = PathBuf::from(args.str_or("out", "BENCH_3.json"));
        gcn_perf::eval::perf::write_perf_report(&report, &out)?;
        println!(
            "bench report written to {} (padded-workload forward speedup {:.2}x dense/sparse)",
            out.display(),
            report.padded_forward_speedup()
        );

        // the serving trajectory: concurrent per-candidate calls vs the
        // coalescing service on the same mixed-size workload
        let serve_cfg = gcn_perf::eval::serve_bench::ServeBenchConfig { fast, seed };
        let serve_report = gcn_perf::eval::serve_bench::run_serve_bench(&serve_cfg)?;
        let serve_out = PathBuf::from(args.str_or("serve-out", "BENCH_4.json"));
        gcn_perf::eval::serve_bench::write_serve_report(&serve_report, &serve_out)?;
        println!(
            "serving report written to {} ({} clients x {} candidates: {:.2}x naive/coalesced, {} fused batches)",
            serve_out.display(),
            serve_report.clients,
            serve_report.candidates_per_client,
            serve_report.speedup,
            serve_report.coalesced_batches
        );
        // the autotuner trajectory: sequential single-pipeline tuning vs
        // the concurrent fleet sharing one service, cross-checked bitwise
        let at_cfg = gcn_perf::eval::autotune_bench::AutotuneBenchConfig { fast, seed };
        let at_report = gcn_perf::eval::autotune_bench::run_autotune_bench(&at_cfg)?;
        let at_out = PathBuf::from(args.str_or("autotune-out", "BENCH_7.json"));
        gcn_perf::eval::autotune_bench::write_autotune_report(&at_report, &at_out)?;
        println!(
            "autotune report written to {} ({} pipelines: fleet {:.2}s vs sequential {:.2}s, \
             {:.2}x)",
            at_out.display(),
            at_report.networks.len(),
            at_report.concurrent.wall_s,
            at_report.sequential.wall_s,
            at_report.speedup()
        );
        // the PR-9 analyzer trajectory: per-call legality validation vs
        // the precomputed AnalyzedPipeline tables the strategies now use,
        // verdict-checked over a mixed legal/illegal schedule corpus
        let an_cfg = gcn_perf::eval::analysis_bench::AnalysisBenchConfig { fast, seed };
        let an_report = gcn_perf::eval::analysis_bench::run_analysis_bench(&an_cfg)?;
        let an_out = PathBuf::from(args.str_or("analysis-out", "BENCH_9.json"));
        gcn_perf::eval::analysis_bench::write_analysis_report(&an_report, &an_out)?;
        println!(
            "analysis report written to {} ({} schedules ({} illegal) x {} rounds: \
             {:.2}x per-call/precomputed, {:.0} checks/s precomputed)",
            an_out.display(),
            an_report.n_schedules,
            an_report.n_illegal,
            an_report.rounds,
            an_report.speedup,
            an_report.precomputed_checks_per_s
        );
        earlier_reports = Some((report, serve_report, at_report, an_report));
    }

    // the out-of-core trajectory: in-RAM vs streamed training and
    // full-graph vs partitioned steps over the synthetic scale tiers
    // (bitwise-checked inside the bench before any number is reported)
    let mut scale_report = None;
    if !engine_only {
        let sc_cfg = gcn_perf::eval::scale_bench::ScaleBenchConfig {
            fast,
            seed,
            ..Default::default()
        };
        let sc = gcn_perf::eval::scale_bench::run_scale_bench(&sc_cfg)?;
        let sc_out = PathBuf::from(args.str_or("scale-out", "BENCH_10.json"));
        gcn_perf::eval::scale_bench::write_scale_report(&sc, &sc_out)?;
        if let Some(top) = sc.tiers.last() {
            println!(
                "scale report written to {} (top tier {} stages: streamed peak {:.1} MiB vs \
                 in-RAM {:.1} MiB, partitioned step {:.1} MiB vs full {:.1} MiB, \
                 {:.0} nodes/s streamed)",
                sc_out.display(),
                top.n_stages,
                top.streamed_peak_bytes as f64 / (1024.0 * 1024.0),
                top.in_ram_peak_bytes as f64 / (1024.0 * 1024.0),
                top.part_step_peak_bytes as f64 / (1024.0 * 1024.0),
                top.full_step_peak_bytes as f64 / (1024.0 * 1024.0),
                top.streamed_nodes_per_s
            );
        }
        scale_report = Some(sc);
    }

    // the PR-5 engine core: fast path / tiled kernels / parallel
    // backward vs the frozen PR-4 compute core
    let engine_cfg = gcn_perf::eval::engine_bench::EngineBenchConfig { fast, seed };
    let engine_report = gcn_perf::eval::engine_bench::run_engine_bench(&engine_cfg)?;
    let engine_out = PathBuf::from(args.str_or("engine-out", "BENCH_5.json"));
    gcn_perf::eval::engine_bench::write_engine_report(&engine_report, &engine_out)?;
    println!(
        "engine report written to {} (infer speedup vs PR-4: padded {:.2}x, resnet50 {:.2}x; \
         train-step {:.2}x/{:.2}x; {:.1} allocs/op steady-state)",
        engine_out.display(),
        engine_report.speedup("padded/infer"),
        engine_report.speedup("resnet50/infer"),
        engine_report.speedup("padded/train-step"),
        engine_report.speedup("resnet50/train-step"),
        engine_report.allocs_per_infer
    );

    // the PR-8 microkernel layer: scalar vs runtime-detected SIMD vs
    // int8 inference lanes, numeric-mode gates included. A serving
    // bundle given here is reconciled with --precision up front — a
    // mismatch (e.g. --precision int8 with a plain f32 bundle) is a
    // usage error and exits 2 before any timing runs.
    match bundle_path_opt(args) {
        Some(b) => {
            let kind = registry::bundle_kind(&b)?;
            let p = resolve_precision_or_exit(args, &kind);
            eprintln!(
                "bundle {} (kind '{kind}') serves at {} precision",
                b.display(),
                p.as_str()
            );
        }
        None => {
            // without a bundle, --precision int8 has nothing quantized
            // to validate against: rejected with the minting hint
            resolve_precision_or_exit(args, registry::KIND_GCN);
        }
    }
    let simd_cfg = gcn_perf::eval::simd_bench::SimdBenchConfig { fast, seed };
    let simd_report = gcn_perf::eval::simd_bench::run_simd_bench(&simd_cfg)?;
    let simd_out = PathBuf::from(args.str_or("simd-out", "BENCH_8.json"));
    gcn_perf::eval::simd_bench::write_simd_report(&simd_report, &simd_out)?;
    println!(
        "simd report written to {} ({} kernels: simd {:.2}x/{:.2}x vs scalar, int8 \
         {:.2}x/{:.2}x; int8 rank agreement {:.3}, mape {:.2}% f32 vs {:.2}% int8)",
        simd_out.display(),
        simd_report.variant,
        simd_report.speedup("padded/simd"),
        simd_report.speedup("resnet50/simd"),
        simd_report.speedup("padded/int8"),
        simd_report.speedup("resnet50/int8"),
        simd_report.int8_rank_agreement,
        simd_report.mape_f32,
        simd_report.mape_int8
    );

    if args.has_flag("require-speedup") {
        if let Some((report, serve_report, at_report, an_report)) = &earlier_reports {
            report.require_padded_speedup()?;
            serve_report.require_speedup()?;
            at_report.require_speedup()?;
            an_report.require_speedup()?;
        }
        if let Some(sc) = &scale_report {
            sc.require_speedup()?;
        }
        engine_report.require_speedup()?;
        simd_report.require_speedup()?;
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = load_backend_verbose(args, false)?;
    println!("backend: {}", rt.name());
    if dir.join("manifest.json").exists() {
        // parse + validate the on-disk contract (dim-drift fails fast here
        // even when the native engine is what actually runs)
        let disk = gcn_perf::runtime::Manifest::load(&dir)?;
        println!(
            "artifacts: {} ({} conv layers, {} param tensors, ablation variants {:?})",
            dir.display(),
            disk.n_conv,
            disk.params.len(),
            disk.ablation_layers
        );
    } else {
        println!("artifacts: none (native backend needs no artifacts)");
    }
    // what this binary actually executes
    let manifest = rt.manifest();
    println!(
        "model: {} conv layers, node dim {}, batch {}, max nodes {}",
        manifest.n_conv, manifest.node_dim, manifest.batch, manifest.max_nodes
    );
    println!(
        "params: {} tensors, {} elements",
        manifest.params.len(),
        manifest.total_param_elems()
    );
    if let Some(b) = bundle_path_opt(args) {
        let bundle = gcn_perf::predictor::bundle::Bundle::load(&b)?;
        let elems: usize = bundle.tensors.iter().map(|t| t.numel()).sum();
        println!(
            "bundle: {} — kind '{}', {} tensors ({} elements), stats {}, meta {:?}",
            b.display(),
            bundle.kind,
            bundle.tensors.len(),
            elems,
            if bundle.stats.is_some() { "present" } else { "absent" },
            bundle.meta
        );
    }
    Ok(())
}
