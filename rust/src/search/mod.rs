//! Model-guided schedule search (Fig 2): "the search technique generates a
//! pool of candidate schedules and uses the performance model to select the
//! most promising candidates for further exploration."
//!
//! Cost models implement [`CostModel`]; any [`crate::predictor::Predictor`]
//! becomes one through the re-exported caching [`PredictorCost`] bridge.

pub mod beam;

pub use crate::predictor::PredictorCost;
pub use beam::{beam_search, BeamConfig, CostModel, NoisySimCost, SimCost};
