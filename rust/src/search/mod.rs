//! Model-guided schedule search (Fig 2): "the search technique generates a
//! pool of candidate schedules and uses the performance model to select the
//! most promising candidates for further exploration."
//!
//! Cost models implement [`CostModel`]; any [`crate::predictor::Predictor`]
//! becomes one through the re-exported [`PredictorCost`] bridge, which
//! scores whole beam frontiers in one round-trip through the coalescing
//! [`crate::predictor::PredictService`] and shares its memo cache with
//! every other client of that service.

pub mod beam;

pub use crate::predictor::PredictorCost;
pub use beam::{beam_search, BeamConfig, CostModel, NoisySimCost, SimCost};
