//! Model-guided schedule search (Fig 2): "the search technique generates a
//! pool of candidate schedules and uses the performance model to select the
//! most promising candidates for further exploration."

pub mod beam;

pub use beam::{beam_search, BeamConfig, CostModel, NoisySimCost, SimCost};
