//! Beam search over per-stage schedule choices, pruned by a pluggable cost
//! model — the paper's Halide auto-scheduler loop (§II-B): stages are
//! scheduled one at a time from the output stage up the DAG; at each step
//! the beam expands with candidate schedules for the next stage and the
//! model keeps the top-k.
//!
//! [`CostModel::score`] is fallible and scores whole frontiers at once:
//! learned models serve through the coalescing
//! [`crate::predictor::PredictService`] (one service round-trip per
//! expansion), and an inference failure surfaces as an error to the
//! search caller instead of a panic that would take down every other
//! in-flight client of a shared service.

use crate::autotune::{BeamStrategy, SearchStrategy};
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::PipelineSchedule;
use crate::sim::{simulate, Machine};
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// Anything that can score complete pipeline schedules (lower = better).
pub trait CostModel {
    fn score(
        &self,
        p: &Pipeline,
        nests: &[LoopNest],
        scheds: &[PipelineSchedule],
    ) -> Result<Vec<f64>>;
    fn name(&self) -> String;
}

/// Oracle: the simulator itself (an upper bound no learned model beats).
pub struct SimCost {
    pub machine: Machine,
}

impl CostModel for SimCost {
    fn score(
        &self,
        p: &Pipeline,
        nests: &[LoopNest],
        scheds: &[PipelineSchedule],
    ) -> Result<Vec<f64>> {
        Ok(scheds.iter().map(|s| simulate(p, nests, s, &self.machine)).collect())
    }
    fn name(&self) -> String {
        "sim-oracle".into()
    }
}

/// Noise-injected simulator — the mechanism the paper uses to diversify the
/// schedules its dataset is built from (§III-A).
pub struct NoisySimCost {
    pub machine: Machine,
    pub sigma: f64,
    pub seed: u64,
}

impl CostModel for NoisySimCost {
    fn score(
        &self,
        p: &Pipeline,
        nests: &[LoopNest],
        scheds: &[PipelineSchedule],
    ) -> Result<Vec<f64>> {
        let mut rng = Rng::new(self.seed);
        Ok(scheds
            .iter()
            .map(|s| simulate(p, nests, s, &self.machine) * rng.lognormal(self.sigma))
            .collect())
    }
    fn name(&self) -> String {
        format!("noisy-sim(σ={})", self.sigma)
    }
}

#[derive(Debug, Clone)]
pub struct BeamConfig {
    /// Beam width (top-k survivors per step).
    pub beam_width: usize,
    /// Candidate stage schedules sampled per expansion.
    pub candidates_per_stage: usize,
    pub seed: u64,
}

impl Default for BeamConfig {
    fn default() -> Self {
        BeamConfig { beam_width: 8, candidates_per_stage: 12, seed: 1 }
    }
}

/// Run beam search; returns the best schedule found and its model score.
///
/// Unscheduled stages hold the Halide default (compute_root, scalar), so
/// every beam state is a *complete* legal schedule the model can score —
/// the same trick the Halide auto-scheduler plays. The model scores each
/// frontier in one call (one service round-trip for served models);
/// ranking uses `f64::total_cmp`, so a model emitting NaN sorts last
/// instead of panicking the search.
///
/// This is a thin driver over [`crate::autotune::BeamStrategy`] — the
/// same loop, made resumable for the fleet autotuner — run to
/// completion in one call. Behavior (RNG draw order, scores, picked
/// schedules) is identical to the pre-strategy implementation.
pub fn beam_search(
    p: &Pipeline,
    nests: &[LoopNest],
    model: &dyn CostModel,
    cfg: &BeamConfig,
) -> Result<(PipelineSchedule, f64)> {
    let mut strat = BeamStrategy::new(cfg.clone());
    while !strat.done() {
        strat.step(p, nests, model)?;
    }
    let (sched, score) = strat.best().context("beam search produced an empty beam")?;
    Ok((sched.clone(), score))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::lower_pipeline;
    use crate::schedule::legality::check_pipeline;

    fn test_pipeline() -> Pipeline {
        crate::zoo::unet()
    }

    #[test]
    fn beam_improves_over_default() {
        let p = test_pipeline();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
        let default_t = simulate(&p, &nests, &PipelineSchedule::default_for(&ranks), &m);
        let model = SimCost { machine: m.clone() };
        let (best, score) = beam_search(
            &p,
            &nests,
            &model,
            &BeamConfig { beam_width: 4, candidates_per_stage: 6, seed: 3 },
        )
        .unwrap();
        check_pipeline(&p, &nests, &best).unwrap();
        assert!(score < default_t, "beam {score} !< default {default_t}");
        // model score == true sim time for the oracle
        let true_t = simulate(&p, &nests, &best, &m);
        assert!((true_t - score).abs() / true_t < 1e-9);
    }

    #[test]
    fn wider_beam_never_worse_with_oracle() {
        let p = test_pipeline();
        let nests = lower_pipeline(&p);
        let model = SimCost { machine: Machine::default() };
        let (_, narrow) = beam_search(
            &p,
            &nests,
            &model,
            &BeamConfig { beam_width: 1, candidates_per_stage: 4, seed: 9 },
        )
        .unwrap();
        let (_, wide) = beam_search(
            &p,
            &nests,
            &model,
            &BeamConfig { beam_width: 8, candidates_per_stage: 4, seed: 9 },
        )
        .unwrap();
        assert!(wide <= narrow * 1.001, "wide {wide} vs narrow {narrow}");
    }

    #[test]
    fn noisy_cost_model_diversifies_results() {
        let p = test_pipeline();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let mut results = std::collections::HashSet::new();
        for seed in 0..4 {
            let model = NoisySimCost { machine: m.clone(), sigma: 0.5, seed };
            let (sched, _) = beam_search(
                &p,
                &nests,
                &model,
                &BeamConfig { beam_width: 2, candidates_per_stage: 4, seed },
            )
            .unwrap();
            results.insert(format!("{sched:?}"));
        }
        assert!(results.len() >= 2, "noise should diversify schedules");
    }

    #[test]
    fn failing_cost_model_errors_instead_of_panicking() {
        struct Broken;
        impl CostModel for Broken {
            fn score(
                &self,
                _: &Pipeline,
                _: &[LoopNest],
                _: &[PipelineSchedule],
            ) -> Result<Vec<f64>> {
                anyhow::bail!("model exploded")
            }
            fn name(&self) -> String {
                "broken".into()
            }
        }
        let p = test_pipeline();
        let nests = lower_pipeline(&p);
        let err = beam_search(&p, &nests, &Broken, &BeamConfig::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("broken"), "{err}");
    }
}
