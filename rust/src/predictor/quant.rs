//! The int8 serving path: minting quantized bundles (`gcn-perf
//! quantize`) and serving them ([`QuantGcnPredictor`]).
//!
//! A quantized bundle has kind [`registry::KIND_GCN_INT8`] and uses the
//! version-2 container: every dense GEMM weight `w` is stored as an i8
//! qtensor `<w>_q` plus an f32 per-output-channel `<w>_scale` tensor,
//! every other tensor (biases, channel-norm scale/shift) travels
//! verbatim under its manifest name. See [`crate::runtime::quant`] for
//! the quantization scheme and the declared numeric envelope.
//!
//! [`resolve_precision`] is the one place the `--precision {f32,int8}`
//! CLI flag is reconciled with what a bundle actually holds; mismatches
//! are usage errors (the CLI exits 2 on them), never silent fallbacks.

use crate::constants::{DEP_DIM, EMB_DEP, EMB_INV, INV_DIM, NODE_DIM};
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::predictor::bundle::{Bundle, NamedTensor, QuantNamedTensor};
use crate::predictor::{params_from_bundle, registry, EngineInfo, Predictor};
use crate::runtime::kernels_simd::KernelVariant;
use crate::runtime::native::NativeBackend;
use crate::runtime::quant::{QuantConv, QuantMatrix, QuantParams};
use crate::runtime::Backend;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// The numeric mode a model is served in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Int8,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// Reconcile a requested `--precision` value with the kind of the bundle
/// being loaded. `None` means "whatever the bundle holds". Mismatches are
/// usage errors — the caller should print the message and exit 2.
pub fn resolve_precision(
    requested: Option<&str>,
    bundle_kind: &str,
) -> std::result::Result<Precision, String> {
    let quantized = bundle_kind == registry::KIND_GCN_INT8;
    let requested = match requested {
        None => return Ok(if quantized { Precision::Int8 } else { Precision::F32 }),
        Some("f32") => Precision::F32,
        Some("int8") => Precision::Int8,
        Some(other) => {
            return Err(format!("unknown --precision '{other}' (expected 'f32' or 'int8')"))
        }
    };
    match (requested, quantized) {
        (Precision::F32, false) | (Precision::Int8, true) => Ok(requested),
        (Precision::Int8, false) => Err(format!(
            "--precision int8 needs a quantized bundle, but this bundle holds a \
             '{bundle_kind}' model — mint one with `gcn-perf quantize` first"
        )),
        (Precision::F32, true) => Err(
            "--precision f32 cannot serve an int8-quantized bundle; keep the original \
             f32 bundle for full-precision serving"
                .into(),
        ),
    }
}

/// Quantize a trained f32 GCN bundle into an int8 one (the `gcn-perf
/// quantize` subcommand). Validates the source against the manifest of
/// its declared conv depth before touching any weights.
pub fn quantize_bundle(src: &Bundle) -> Result<Bundle> {
    if src.kind != registry::KIND_GCN {
        bail!(
            "only '{}' bundles can be quantized, this one holds a '{}' model",
            registry::KIND_GCN,
            src.kind
        );
    }
    let n_conv = src.meta_usize("n_conv")?;
    let backend = NativeBackend::with_layers(n_conv);
    let params = params_from_bundle(src, &backend)?;
    let qp = QuantParams::from_params(&params, n_conv)?;
    let stats = src.stats.as_ref().context("gcn bundle carries no feature stats")?;
    Ok(bundle_from_quant(&qp, stats))
}

/// Serialize a [`QuantParams`] (plus feature stats) into the int8 bundle
/// layout described in the module docs.
fn bundle_from_quant(qp: &QuantParams, stats: &FeatureStats) -> Bundle {
    let mut b = Bundle::new(registry::KIND_GCN_INT8);
    b.stats = Some(stats.clone());
    b.meta.insert("n_conv".into(), qp.n_conv as f64);
    fn push_qm(b: &mut Bundle, name: &str, qm: &QuantMatrix) {
        b.qtensors.push(QuantNamedTensor {
            name: format!("{name}_q"),
            shape: vec![qm.n_in, qm.n_out],
            data: qm.q.clone(),
        });
        b.tensors.push(NamedTensor {
            name: format!("{name}_scale"),
            shape: vec![qm.n_out],
            data: qm.scale.clone(),
        });
    }
    fn push_fv(b: &mut Bundle, name: &str, v: &[f32]) {
        b.tensors.push(NamedTensor {
            name: name.into(),
            shape: vec![v.len()],
            data: v.to_vec(),
        });
    }
    push_qm(&mut b, "w_inv", &qp.w_inv);
    push_fv(&mut b, "b_inv", &qp.b_inv);
    push_qm(&mut b, "w_dep", &qp.w_dep);
    push_fv(&mut b, "b_dep", &qp.b_dep);
    for (k, qc) in qp.convs.iter().enumerate() {
        push_qm(&mut b, &format!("conv{k}_w"), &qc.w);
        push_fv(&mut b, &format!("conv{k}_b"), &qc.b);
        push_fv(&mut b, &format!("conv{k}_scale"), &qc.scale);
        push_fv(&mut b, &format!("conv{k}_shift"), &qc.shift);
    }
    push_qm(&mut b, "w_out", &qp.w_out);
    push_fv(&mut b, "b_out", &qp.b_out);
    b
}

/// Rebuild [`QuantParams`] from an int8 bundle, validating every tensor's
/// shape against the model dimensions of the declared conv depth.
fn quant_from_bundle(b: &Bundle) -> Result<QuantParams> {
    let n_conv = b.meta_usize("n_conv")?;
    let qm = |name: &str, n_in: usize, n_out: usize| -> Result<QuantMatrix> {
        let qt = b.qtensor(&format!("{name}_q"))?;
        if qt.shape != [n_in, n_out] {
            bail!(
                "int8 bundle qtensor '{name}_q' has shape {:?}, expected [{n_in}, {n_out}]",
                qt.shape
            );
        }
        let st = b.tensor(&format!("{name}_scale"))?;
        if st.shape != [n_out] {
            bail!(
                "int8 bundle tensor '{name}_scale' has shape {:?}, expected [{n_out}]",
                st.shape
            );
        }
        Ok(QuantMatrix { n_in, n_out, q: qt.data.clone(), scale: st.data.clone() })
    };
    let fv = |name: &str, len: usize| -> Result<Vec<f32>> {
        let t = b.tensor(name)?;
        if t.shape != [len] {
            bail!("int8 bundle tensor '{name}' has shape {:?}, expected [{len}]", t.shape);
        }
        Ok(t.data.clone())
    };
    let mut convs = Vec::with_capacity(n_conv);
    for k in 0..n_conv {
        convs.push(QuantConv {
            w: qm(&format!("conv{k}_w"), NODE_DIM, NODE_DIM)?,
            b: fv(&format!("conv{k}_b"), NODE_DIM)?,
            scale: fv(&format!("conv{k}_scale"), NODE_DIM)?,
            shift: fv(&format!("conv{k}_shift"), NODE_DIM)?,
        });
    }
    Ok(QuantParams {
        n_conv,
        w_inv: qm("w_inv", INV_DIM, EMB_INV)?,
        b_inv: fv("b_inv", EMB_INV)?,
        w_dep: qm("w_dep", DEP_DIM, EMB_DEP)?,
        b_dep: fv("b_dep", EMB_DEP)?,
        convs,
        w_out: qm("w_out", NODE_DIM * (n_conv + 1), 1)?,
        b_out: fv("b_out", 1)?,
    })
}

/// The int8 serving session: native backend + quantized parameters +
/// feature stats. Prediction runs the reduced-precision inference path
/// ([`NativeBackend::predict_runtimes_quant`]); like the f32 session, it
/// can be loaded on any microkernel tier.
pub struct QuantGcnPredictor {
    backend: NativeBackend,
    qp: QuantParams,
    stats: FeatureStats,
}

impl QuantGcnPredictor {
    /// Load an int8 bundle on the scalar kernels.
    pub fn load(path: &Path) -> Result<QuantGcnPredictor> {
        QuantGcnPredictor::load_with_variant(path, KernelVariant::Scalar)
    }

    /// Load an int8 bundle, requesting a microkernel tier (clamped down
    /// to what this build and CPU support).
    pub fn load_with_variant(path: &Path, variant: KernelVariant) -> Result<QuantGcnPredictor> {
        let b = Bundle::load(path)?;
        if b.kind != registry::KIND_GCN_INT8 {
            bail!("bundle {path:?} holds a '{}' model, not an int8 GCN", b.kind);
        }
        let qp = quant_from_bundle(&b)?;
        let stats = b.stats.context("int8 gcn bundle carries no feature stats")?;
        let backend = NativeBackend::with_layers_variant(qp.n_conv, variant);
        Ok(QuantGcnPredictor { backend, qp, stats })
    }

    pub fn quant_params(&self) -> &QuantParams {
        &self.qp
    }

    pub fn stats(&self) -> &FeatureStats {
        &self.stats
    }
}

impl Predictor for QuantGcnPredictor {
    fn name(&self) -> String {
        registry::KIND_GCN_INT8.into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        self.backend.predict_runtimes_quant(&self.qp, samples, &self.stats)
    }
    fn save(&self, path: &Path) -> Result<()> {
        bundle_from_quant(&self.qp, &self.stats).save(path)
    }
    fn engine_info(&self) -> EngineInfo {
        EngineInfo {
            kernel_variant: self.backend.kernel_variant().as_str().into(),
            precision: "int8".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::halide_ffn::FfnTrainConfig;
    use crate::dataset::builder::{build_dataset, DataGenConfig};
    use crate::predictor::{save_gcn_bundle, FfnPredictor, GcnPredictor};
    use crate::runtime::quant::{INT8_Z_ABS_TOL, INT8_Z_REL_TOL};

    #[test]
    fn resolve_precision_covers_the_full_request_table() {
        let gcn = registry::KIND_GCN;
        let int8 = registry::KIND_GCN_INT8;
        assert_eq!(resolve_precision(None, gcn), Ok(Precision::F32));
        assert_eq!(resolve_precision(None, int8), Ok(Precision::Int8));
        assert_eq!(resolve_precision(Some("f32"), gcn), Ok(Precision::F32));
        assert_eq!(resolve_precision(Some("int8"), int8), Ok(Precision::Int8));
        let err = resolve_precision(Some("int8"), gcn).unwrap_err();
        assert!(err.contains("gcn-perf quantize"), "{err}");
        let err = resolve_precision(Some("f32"), int8).unwrap_err();
        assert!(err.contains("f32 bundle"), "{err}");
        let err = resolve_precision(Some("fp16"), gcn).unwrap_err();
        assert!(err.contains("unknown --precision"), "{err}");
        assert_eq!(Precision::F32.as_str(), "f32");
        assert_eq!(Precision::Int8.as_str(), "int8");
    }

    #[test]
    fn quantize_roundtrip_stays_within_the_declared_envelope() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 6,
            schedules_per_pipeline: 5,
            seed: 83,
            ..Default::default()
        });
        let backend = NativeBackend::new();
        let params = backend.init_params(17);
        let stats = ds.stats.clone().unwrap();
        let n_conv = backend.manifest().n_conv;

        let f32_path = std::env::temp_dir().join("gcn_perf_quant_src.bundle");
        let int8_path = std::env::temp_dir().join("gcn_perf_quant_int8.bundle");
        save_gcn_bundle(&f32_path, n_conv, &params, &stats).unwrap();

        let qb = quantize_bundle(&Bundle::load(&f32_path).unwrap()).unwrap();
        assert_eq!(qb.kind, registry::KIND_GCN_INT8);
        qb.save(&int8_path).unwrap();

        let fp = GcnPredictor::load(&f32_path).unwrap();
        let qp = QuantGcnPredictor::load(&int8_path).unwrap();
        assert_eq!(qp.name(), "gcn-int8");
        assert_eq!(qp.engine_info().precision, "int8");

        let refs: Vec<&GraphSample> = ds.samples.iter().collect();
        let full = fp.predict(&refs).unwrap();
        let quant = qp.predict(&refs).unwrap();
        assert_eq!(full.len(), quant.len());
        for (f, q) in full.iter().zip(&quant) {
            let (zf, zq) = (f.ln(), q.ln());
            let tol = INT8_Z_ABS_TOL + INT8_Z_REL_TOL * zf.abs();
            assert!(
                (zf - zq).abs() <= tol,
                "int8 z {zq} drifted from f32 z {zf} beyond the envelope {tol}"
            );
        }

        // int8 bundles round-trip bit-exactly through their own save path,
        // and the registry dispatches on the new kind.
        qp.save(&int8_path).unwrap();
        let again = QuantGcnPredictor::load(&int8_path).unwrap();
        assert_eq!(quant, again.predict(&refs).unwrap());
        let via_registry = registry::load_bundle(&int8_path).unwrap();
        assert_eq!(via_registry.name(), "gcn-int8");
        assert_eq!(quant, via_registry.predict(&refs).unwrap());

        std::fs::remove_file(&f32_path).ok();
        std::fs::remove_file(&int8_path).ok();
    }

    #[test]
    fn quantize_rejects_non_gcn_bundles() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 4,
            seed: 97,
            ..Default::default()
        });
        let ffn = FfnPredictor::fit(&ds, &FfnTrainConfig { epochs: 1, ..Default::default() }, 3)
            .unwrap();
        let path = std::env::temp_dir().join("gcn_perf_quant_wrong_kind.bundle");
        ffn.save(&path).unwrap();
        let err = quantize_bundle(&Bundle::load(&path).unwrap()).unwrap_err().to_string();
        assert!(err.contains("can be quantized"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
