//! The crate's one prediction API.
//!
//! Historically the repo exposed three incompatible prediction interfaces:
//! `runtime::Backend` threaded `(backend, params, stats)` triples through
//! `train/` and `eval/`, `baselines::PerfModel` used per-sample `&mut self`
//! calls, and `search::CostModel` implementations were hand-wired in
//! `main.rs`. [`Predictor`] unifies them: every model — the GCN and all
//! three baselines — answers batched [`Predictor::predict`] calls behind
//! one object-safe trait, serializes to a single-file bundle
//! ([`bundle`]), resolves by name through [`registry`], and drives beam
//! search through the caching [`PredictorCost`] bridge ([`cost`]).
//!
//! * [`GcnPredictor`] — the owning GCN session: `Box<dyn Backend>` +
//!   [`Params`] + [`FeatureStats`] in one value, saved/loaded as a bundle.
//! * [`GcnView`] — the borrowing variant for code that still holds the
//!   parts separately (the training loop evaluates candidate params every
//!   epoch; cloning them into a session each time would be waste).
//! * [`FfnPredictor`] / [`GruPredictor`] / [`GbtPredictor`] — adapters
//!   giving the baselines the same batched `&self` interface (the FFN and
//!   GRU forward passes cache activations, so they keep interior scratch
//!   state behind a mutex).
//! * [`PredictService`] ([`service`]) — the concurrent serving layer:
//!   callers submit [`PredictRequest`]s to a bounded queue, a coalescer
//!   fuses in-flight requests into shared packed batches and scatters
//!   results back through completion handles. The shared memo cache the
//!   search bridge uses lives here.

pub mod bundle;
pub mod cost;
pub mod quant;
pub mod registry;
pub mod service;

use crate::baselines::gbt::{Gbt, GbtConfig};
use crate::baselines::halide_ffn::{FfnTrainConfig, HalideFfn};
use crate::baselines::nn::Linear;
use crate::baselines::rnn::{BiGru, BiGruWeights, RnnTrainConfig};
use crate::constants::{DEP_DIM, FFN_TERMS, INV_DIM};
use crate::dataset::sample::{Dataset, GraphSample};
use crate::features::normalize::FeatureStats;
use crate::runtime::kernels_simd::KernelVariant;
use crate::runtime::native::NativeBackend;
use crate::runtime::params::Params;
use crate::runtime::Backend;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use self::bundle::{Bundle, NamedTensor};
use std::path::Path;
use std::sync::Mutex;

pub use self::cost::PredictorCost;
pub use self::quant::{Precision, QuantGcnPredictor};
pub use self::service::{
    PredictHandle, PredictRequest, PredictResponse, PredictService, ServiceConfig, ServiceStats,
};

/// A ready-to-serve performance model. Object-safe: the CLI, the eval
/// harnesses and beam search all hold `&dyn Predictor` / `Box<dyn
/// Predictor>`.
///
/// `Send + Sync` is part of the contract: [`PredictService`] shares one
/// model across worker threads and concurrent callers, so prediction
/// state must be immutable or internally synchronized (the FFN/GRU
/// adapters keep their scratch activations behind a mutex for exactly
/// this reason).
pub trait Predictor: Send + Sync {
    /// Short identifier for tables and logs ("gcn", "halide-ffn", ...).
    fn name(&self) -> String;

    /// Predicted mean runtimes in seconds, one per sample, in order.
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>>;

    /// Serialize to a single-file model bundle (see [`bundle`]).
    fn save(&self, path: &Path) -> Result<()>;

    /// How this model computes: microkernel tier and numeric precision.
    /// Baselines (and the default) report the scalar f32 engine; the GCN
    /// predictors report their backend's resolved kernel variant, and the
    /// int8 predictor reports `precision: "int8"`.
    fn engine_info(&self) -> EngineInfo {
        EngineInfo::default()
    }
}

/// The engine a [`Predictor`] answers with: which microkernel tier
/// (`scalar`/`sse2`/`avx2`) and which numeric precision (`f32`/`int8`).
/// Surfaced by the serving stats so operators can tell at a glance what
/// numeric mode a process is running.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineInfo {
    pub kernel_variant: String,
    pub precision: String,
}

impl Default for EngineInfo {
    fn default() -> EngineInfo {
        EngineInfo {
            kernel_variant: KernelVariant::Scalar.as_str().into(),
            precision: "f32".into(),
        }
    }
}

// ---------------------------------------------------------------- GCN

/// Owning GCN session: execution backend, parameters and feature
/// normalization in one value. This is what `gcn-perf train` saves and
/// every downstream consumer (eval, search, `predict`) loads. Prediction
/// goes through the backend's packed sparse batching, so a session
/// serves graphs of any size — the old 48-stage cap is gone.
pub struct GcnPredictor {
    backend: Box<dyn Backend>,
    params: Params,
    stats: FeatureStats,
}

impl GcnPredictor {
    pub fn new(backend: Box<dyn Backend>, params: Params, stats: FeatureStats) -> GcnPredictor {
        GcnPredictor { backend, params, stats }
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    pub fn stats(&self) -> &FeatureStats {
        &self.stats
    }

    /// Load a GCN bundle on the scalar (bitwise-deterministic) kernels.
    /// The native backend serves it; the parameter list is validated
    /// tensor-by-tensor against the manifest of the bundled conv depth, so
    /// a stale or foreign bundle fails loudly.
    pub fn load(path: &Path) -> Result<GcnPredictor> {
        GcnPredictor::load_with_variant(path, KernelVariant::Scalar)
    }

    /// Like [`GcnPredictor::load`], but requesting a microkernel tier for
    /// inference (clamped down to what this build and CPU support).
    pub fn load_with_variant(path: &Path, variant: KernelVariant) -> Result<GcnPredictor> {
        let b = Bundle::load(path)?;
        if b.kind != registry::KIND_GCN {
            bail!("bundle {path:?} holds a '{}' model, not a GCN", b.kind);
        }
        let n_conv = b.meta_usize("n_conv")?;
        let backend: Box<dyn Backend> =
            Box::new(NativeBackend::with_layers_variant(n_conv, variant));
        let params = params_from_bundle(&b, backend.as_ref())?;
        let stats = b.stats.context("gcn bundle carries no feature stats")?;
        Ok(GcnPredictor { backend, params, stats })
    }
}

impl Predictor for GcnPredictor {
    fn name(&self) -> String {
        "gcn".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        self.backend.predict_runtimes(&self.params, samples, &self.stats)
    }
    fn save(&self, path: &Path) -> Result<()> {
        save_gcn_bundle(path, self.backend.manifest().n_conv, &self.params, &self.stats)
    }
    fn engine_info(&self) -> EngineInfo {
        EngineInfo {
            kernel_variant: self.backend.kernel_variant().as_str().into(),
            precision: "f32".into(),
        }
    }
}

/// Borrowing GCN view over separately-held parts. Same predict/save code
/// paths as [`GcnPredictor`], so the two cannot drift.
pub struct GcnView<'a> {
    pub backend: &'a dyn Backend,
    pub params: &'a Params,
    pub stats: &'a FeatureStats,
}

impl Predictor for GcnView<'_> {
    fn name(&self) -> String {
        "gcn".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        self.backend.predict_runtimes(self.params, samples, self.stats)
    }
    fn save(&self, path: &Path) -> Result<()> {
        save_gcn_bundle(path, self.backend.manifest().n_conv, self.params, self.stats)
    }
    fn engine_info(&self) -> EngineInfo {
        EngineInfo {
            kernel_variant: self.backend.kernel_variant().as_str().into(),
            precision: "f32".into(),
        }
    }
}

/// Write a GCN bundle from its parts (shared by [`GcnPredictor`],
/// [`GcnView`] and [`crate::train::train_and_save`]).
pub fn save_gcn_bundle(
    path: &Path,
    n_conv: usize,
    params: &Params,
    stats: &FeatureStats,
) -> Result<()> {
    let mut b = Bundle::new(registry::KIND_GCN);
    b.stats = Some(stats.clone());
    b.meta.insert("n_conv".into(), n_conv as f64);
    for ((name, shape), values) in
        params.names.iter().zip(&params.shapes).zip(&params.values)
    {
        b.tensors.push(NamedTensor {
            name: name.clone(),
            shape: shape.clone(),
            data: values.clone(),
        });
    }
    b.save(path)
}

/// Rebuild [`Params`] from a bundle, validating names and shapes against
/// the backend's manifest (order is the manifest's flat calling
/// convention).
pub(crate) fn params_from_bundle(b: &Bundle, backend: &dyn Backend) -> Result<Params> {
    let specs = &backend.manifest().params;
    if b.tensors.len() != specs.len() {
        bail!(
            "gcn bundle has {} tensors, manifest expects {}",
            b.tensors.len(),
            specs.len()
        );
    }
    let mut values = Vec::with_capacity(specs.len());
    let mut shapes = Vec::with_capacity(specs.len());
    let mut names = Vec::with_capacity(specs.len());
    for (spec, t) in specs.iter().zip(&b.tensors) {
        if t.name != spec.name {
            bail!("gcn bundle tensor '{}' where manifest expects '{}'", t.name, spec.name);
        }
        if t.shape != spec.shape {
            bail!(
                "gcn bundle tensor '{}' has shape {:?}, manifest expects {:?}",
                t.name,
                t.shape,
                spec.shape
            );
        }
        values.push(t.data.clone());
        shapes.push(t.shape.clone());
        names.push(t.name.clone());
    }
    Ok(Params { values, shapes, names })
}

// ---------------------------------------------------------- Halide FFN

/// [`Predictor`] adapter for the Halide FFN baseline. The FFN forward pass
/// caches layer activations for backprop, so prediction needs `&mut`
/// internally; the adapter keeps that scratch state behind a mutex and
/// presents the shared-reference batched interface.
pub struct FfnPredictor {
    inner: Mutex<HalideFfn>,
}

impl FfnPredictor {
    pub fn from_model(model: HalideFfn) -> FfnPredictor {
        FfnPredictor { inner: Mutex::new(model) }
    }

    /// Fit on a dataset (stats must be fitted) and wrap.
    pub fn fit(ds: &Dataset, cfg: &FfnTrainConfig, seed: u64) -> Result<FfnPredictor> {
        let stats = ds.stats.as_ref().context("dataset stats required to fit halide-ffn")?;
        let mut model = HalideFfn::new(stats.clone(), seed);
        model.fit(ds, cfg);
        Ok(FfnPredictor::from_model(model))
    }

    pub fn load(path: &Path) -> Result<FfnPredictor> {
        let b = Bundle::load(path)?;
        if b.kind != registry::KIND_FFN {
            bail!("bundle {path:?} holds a '{}' model, not the halide-ffn", b.kind);
        }
        use crate::baselines::halide_ffn::{FFN_CAT, FFN_EMB_DEP, FFN_EMB_INV, FFN_HIDDEN};
        let emb_inv = linear_from_bundle(&b, "emb_inv", INV_DIM, FFN_EMB_INV, true)?;
        let emb_dep = linear_from_bundle(&b, "emb_dep", DEP_DIM, FFN_EMB_DEP, true)?;
        let hidden = linear_from_bundle(&b, "hidden", FFN_CAT, FFN_HIDDEN, true)?;
        let head = linear_from_bundle(&b, "head", FFN_HIDDEN, FFN_TERMS, false)?;
        let stats = b.stats.context("ffn bundle carries no feature stats")?;
        Ok(FfnPredictor::from_model(HalideFfn::from_linears(
            stats,
            [emb_inv, emb_dep, hidden, head],
        )))
    }
}

impl Predictor for FfnPredictor {
    fn name(&self) -> String {
        "halide-ffn".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        let mut m = self.inner.lock().map_err(|_| anyhow!("ffn scratch state poisoned"))?;
        Ok(samples.iter().map(|s| m.predict_sample(s)).collect())
    }
    fn save(&self, path: &Path) -> Result<()> {
        let m = self.inner.lock().map_err(|_| anyhow!("ffn scratch state poisoned"))?;
        let mut b = Bundle::new(registry::KIND_FFN);
        b.stats = Some(m.stats().clone());
        for (prefix, l) in ["emb_inv", "emb_dep", "hidden", "head"]
            .into_iter()
            .zip(m.linears())
        {
            push_linear(&mut b, prefix, l);
        }
        b.save(path)
    }
}

fn push_linear(b: &mut Bundle, prefix: &str, l: &Linear) {
    b.tensors.push(NamedTensor {
        name: format!("{prefix}_w"),
        shape: vec![l.n_in, l.n_out],
        data: l.w.clone(),
    });
    b.tensors.push(NamedTensor {
        name: format!("{prefix}_b"),
        shape: vec![l.n_out],
        data: l.b.clone(),
    });
}

fn linear_from_bundle(
    b: &Bundle,
    prefix: &str,
    n_in: usize,
    n_out: usize,
    relu: bool,
) -> Result<Linear> {
    let w = b.tensor(&format!("{prefix}_w"))?;
    let bias = b.tensor(&format!("{prefix}_b"))?;
    if w.shape != [n_in, n_out] || bias.shape != [n_out] {
        bail!(
            "bundle layer '{prefix}' has shapes {:?}/{:?}, this build expects [{n_in}, {n_out}]/[{n_out}]",
            w.shape,
            bias.shape
        );
    }
    let mut l = Linear::new(n_in, n_out, relu, &mut Rng::new(0));
    l.w = w.data.clone();
    l.b = bias.data.clone();
    Ok(l)
}

// -------------------------------------------------------------- bi-GRU

/// [`Predictor`] adapter for the bi-GRU baseline (interior scratch state,
/// same reasoning as [`FfnPredictor`]).
pub struct GruPredictor {
    inner: Mutex<BiGru>,
}

impl GruPredictor {
    pub fn from_model(model: BiGru) -> GruPredictor {
        GruPredictor { inner: Mutex::new(model) }
    }

    pub fn fit(ds: &Dataset, cfg: &RnnTrainConfig, hidden: usize, seed: u64) -> Result<GruPredictor> {
        let stats = ds.stats.as_ref().context("dataset stats required to fit bi-gru")?;
        let mut model = BiGru::new(stats.clone(), hidden, seed);
        model.fit(ds, cfg);
        Ok(GruPredictor::from_model(model))
    }

    pub fn load(path: &Path) -> Result<GruPredictor> {
        let b = Bundle::load(path)?;
        if b.kind != registry::KIND_RNN {
            bail!("bundle {path:?} holds a '{}' model, not the bi-gru", b.kind);
        }
        let hidden = b.meta_usize("hidden")?;
        let in_dim = INV_DIM + DEP_DIM;
        let take = |name: &str, shape: &[usize]| -> Result<Vec<f32>> {
            let t = b.tensor(name)?;
            if t.shape != shape {
                bail!("rnn bundle tensor '{name}' has shape {:?}, expected {shape:?}", t.shape);
            }
            Ok(t.data.clone())
        };
        let weights = BiGruWeights {
            fwd_wx: take("fwd_wx", &[in_dim, 3 * hidden])?,
            fwd_wh: take("fwd_wh", &[hidden, 3 * hidden])?,
            fwd_b: take("fwd_b", &[3 * hidden])?,
            bwd_wx: take("bwd_wx", &[in_dim, 3 * hidden])?,
            bwd_wh: take("bwd_wh", &[hidden, 3 * hidden])?,
            bwd_b: take("bwd_b", &[3 * hidden])?,
            head_w: take("head_w", &[2 * hidden, 1])?,
            head_b: take("head_b", &[1])?,
        };
        let stats = b.stats.context("rnn bundle carries no feature stats")?;
        Ok(GruPredictor::from_model(BiGru::from_weights(stats, hidden, weights)))
    }
}

impl Predictor for GruPredictor {
    fn name(&self) -> String {
        "bi-gru".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        let mut m = self.inner.lock().map_err(|_| anyhow!("gru scratch state poisoned"))?;
        Ok(samples.iter().map(|s| m.predict_sample(s)).collect())
    }
    fn save(&self, path: &Path) -> Result<()> {
        let m = self.inner.lock().map_err(|_| anyhow!("gru scratch state poisoned"))?;
        let hidden = m.hidden();
        let in_dim = INV_DIM + DEP_DIM;
        let w = m.export_weights();
        let mut b = Bundle::new(registry::KIND_RNN);
        b.stats = Some(m.stats().clone());
        b.meta.insert("hidden".into(), hidden as f64);
        let tensors = [
            ("fwd_wx", vec![in_dim, 3 * hidden], w.fwd_wx),
            ("fwd_wh", vec![hidden, 3 * hidden], w.fwd_wh),
            ("fwd_b", vec![3 * hidden], w.fwd_b),
            ("bwd_wx", vec![in_dim, 3 * hidden], w.bwd_wx),
            ("bwd_wh", vec![hidden, 3 * hidden], w.bwd_wh),
            ("bwd_b", vec![3 * hidden], w.bwd_b),
            ("head_w", vec![2 * hidden, 1], w.head_w),
            ("head_b", vec![1], w.head_b),
        ];
        for (name, shape, data) in tensors {
            b.tensors.push(NamedTensor { name: name.into(), shape, data });
        }
        b.save(path)
    }
}

// ----------------------------------------------------------------- GBT

/// [`Predictor`] adapter for the TVM-style GBT baseline (stateless
/// prediction — no scratch mutex needed; the trees take raw features, so
/// the bundle carries no stats).
pub struct GbtPredictor {
    inner: Gbt,
}

impl GbtPredictor {
    pub fn from_model(model: Gbt) -> GbtPredictor {
        GbtPredictor { inner: model }
    }

    pub fn fit(ds: &Dataset, cfg: GbtConfig) -> GbtPredictor {
        GbtPredictor::from_model(Gbt::fit(ds, cfg))
    }

    pub fn load(path: &Path) -> Result<GbtPredictor> {
        let b = Bundle::load(path)?;
        if b.kind != registry::KIND_GBT {
            bail!("bundle {path:?} holds a '{}' model, not the tvm-gbt", b.kind);
        }
        let cfg = GbtConfig {
            n_trees: b.meta_usize("n_trees")?,
            max_depth: b.meta_usize("max_depth")?,
            learning_rate: b.meta_f64("learning_rate")? as f32,
            min_child_weight: b.meta_f64("min_child_weight")? as f32,
            lambda: b.meta_f64("lambda")? as f32,
            n_bins: b.meta_usize("n_bins")?,
            min_gain: b.meta_f64("min_gain")? as f32,
        };
        let base = b.meta_f64("base")? as f32;
        let mut trees = Vec::new();
        for (i, t) in b.tensors.iter().enumerate() {
            let expect = format!("tree{i}");
            if t.name != expect {
                bail!("gbt bundle tensor '{}' where '{expect}' was expected", t.name);
            }
            if t.shape.len() != 2 || t.shape[1] != 5 {
                bail!("gbt bundle tree '{}' has shape {:?}, expected [n, 5]", t.name, t.shape);
            }
            let nodes: Vec<[f32; 5]> = t
                .data
                .chunks_exact(5)
                .map(|c| [c[0], c[1], c[2], c[3], c[4]])
                .collect();
            trees.push(nodes);
        }
        Ok(GbtPredictor::from_model(Gbt::from_export(cfg, base, trees)?))
    }
}

impl Predictor for GbtPredictor {
    fn name(&self) -> String {
        "tvm-gbt".into()
    }
    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        Ok(samples.iter().map(|s| self.inner.predict_sample(s)).collect())
    }
    fn save(&self, path: &Path) -> Result<()> {
        let cfg = &self.inner.cfg;
        let mut b = Bundle::new(registry::KIND_GBT);
        b.meta.insert("n_trees".into(), cfg.n_trees as f64);
        b.meta.insert("max_depth".into(), cfg.max_depth as f64);
        b.meta.insert("learning_rate".into(), cfg.learning_rate as f64);
        b.meta.insert("min_child_weight".into(), cfg.min_child_weight as f64);
        b.meta.insert("lambda".into(), cfg.lambda as f64);
        b.meta.insert("n_bins".into(), cfg.n_bins as f64);
        b.meta.insert("min_gain".into(), cfg.min_gain as f64);
        b.meta.insert("base".into(), self.inner.base() as f64);
        for (i, nodes) in self.inner.export_trees().into_iter().enumerate() {
            b.tensors.push(NamedTensor {
                name: format!("tree{i}"),
                shape: vec![nodes.len(), 5],
                data: nodes.into_iter().flatten().collect(),
            });
        }
        b.save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    fn tiny_ds() -> Dataset {
        build_dataset(&DataGenConfig {
            n_pipelines: 6,
            schedules_per_pipeline: 6,
            seed: 51,
            ..Default::default()
        })
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(name)
    }

    #[test]
    fn gcn_predictor_roundtrip_is_bit_exact() {
        let ds = tiny_ds();
        let backend = NativeBackend::new();
        let params = backend.init_params(9);
        let stats = ds.stats.clone().unwrap();
        let refs: Vec<&GraphSample> = ds.samples.iter().collect();
        let p = GcnPredictor::new(Box::new(backend), params, stats);
        let before = p.predict(&refs).unwrap();

        let path = tmp("gcn_perf_predictor_gcn.bundle");
        p.save(&path).unwrap();
        let q = GcnPredictor::load(&path).unwrap();
        let after = q.predict(&refs).unwrap();
        assert_eq!(before, after, "bundle round trip must preserve predictions bit-exactly");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gcn_bundle_rejects_wrong_kind_and_shape() {
        let ds = tiny_ds();
        let ffn = FfnPredictor::fit(&ds, &FfnTrainConfig { epochs: 1, ..Default::default() }, 3)
            .unwrap();
        let path = tmp("gcn_perf_predictor_kind.bundle");
        ffn.save(&path).unwrap();
        let err = GcnPredictor::load(&path).unwrap_err().to_string();
        assert!(err.contains("not a GCN"), "{err}");

        // shape drift: a 2-conv bundle declared as 1-conv must fail cleanly
        let backend = NativeBackend::new();
        let params = backend.init_params(1);
        let mut b = Bundle::new(registry::KIND_GCN);
        b.stats = ds.stats.clone();
        b.meta.insert("n_conv".into(), 1.0);
        for ((name, shape), values) in
            params.names.iter().zip(&params.shapes).zip(&params.values)
        {
            b.tensors.push(NamedTensor {
                name: name.clone(),
                shape: shape.clone(),
                data: values.clone(),
            });
        }
        b.save(&path).unwrap();
        assert!(GcnPredictor::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ffn_and_gru_and_gbt_roundtrip_bit_exact() {
        let ds = tiny_ds();
        let refs: Vec<&GraphSample> = ds.samples.iter().collect();

        let ffn = FfnPredictor::fit(&ds, &FfnTrainConfig { epochs: 2, ..Default::default() }, 7)
            .unwrap();
        let path = tmp("gcn_perf_predictor_ffn.bundle");
        ffn.save(&path).unwrap();
        let before = ffn.predict(&refs).unwrap();
        let after = FfnPredictor::load(&path).unwrap().predict(&refs).unwrap();
        assert_eq!(before, after);

        let gru = GruPredictor::fit(&ds, &RnnTrainConfig { epochs: 1, ..Default::default() }, 8, 5)
            .unwrap();
        gru.save(&path).unwrap();
        let before = gru.predict(&refs).unwrap();
        let after = GruPredictor::load(&path).unwrap().predict(&refs).unwrap();
        assert_eq!(before, after);

        let gbt = GbtPredictor::fit(&ds, GbtConfig { n_trees: 12, ..Default::default() });
        gbt.save(&path).unwrap();
        let before = gbt.predict(&refs).unwrap();
        let after = GbtPredictor::load(&path).unwrap().predict(&refs).unwrap();
        assert_eq!(before, after);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn view_and_owner_predict_identically() {
        let ds = tiny_ds();
        let backend = NativeBackend::new();
        let params = backend.init_params(4);
        let stats = ds.stats.clone().unwrap();
        let refs: Vec<&GraphSample> = ds.samples.iter().collect();
        let view = GcnView { backend: &backend, params: &params, stats: &stats };
        let from_view = view.predict(&refs).unwrap();
        let owner = GcnPredictor::new(Box::new(backend), params, stats);
        assert_eq!(from_view, owner.predict(&refs).unwrap());
    }
}
