//! [`PredictorCost`] — the bridge from the serving layer to
//! [`crate::search::CostModel`].
//!
//! Beam search re-scores its surviving states at every expansion step: a
//! beam state that survives `k` steps is featurized and scored `k+1`
//! times by a naive cost model. The bridge scores a whole frontier with
//! **one service round-trip** ([`PredictService::predict_blocking`]) and
//! memoizes per-schedule results in the **service's shared cache**, keyed
//! on (pipeline identity, machine, schedule) — so concurrent searches
//! over the same pipeline share scores, and unchanged beam prefixes cost
//! one cache probe instead of a featurization plus a model evaluation.
//! The probe ([`PredictService::cache_lookup`]) happens *before*
//! featurization, which also goes through
//! [`crate::dataset::builder::featurize_schedule`] — no simulated
//! benchmark runs; the model only reads features.

use crate::dataset::builder::featurize_schedule;
use crate::dataset::sample::GraphSample;
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::predictor::service::{
    cache_key, CacheKey, PredictRequest, PredictService, ServiceConfig,
};
use crate::predictor::Predictor;
use crate::schedule::primitives::PipelineSchedule;
use crate::search::beam::CostModel;
use crate::sim::Machine;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Cost model over any predictor, served through a [`PredictService`].
/// Construct with [`PredictorCost::new`] for a private single-worker
/// service, or [`PredictorCost::with_service`] to share one service (and
/// its cache) across searches and other clients. Keys are namespaced by
/// pipeline identity and machine, so one cache safely serves any mix of
/// pipelines.
pub struct PredictorCost {
    service: Arc<PredictService>,
    machine: Machine,
    /// Cache-key namespace component for the machine, precomputed once —
    /// featurization is machine-aware (cache-fit flags etc.), so the same
    /// schedule scores differently per machine preset.
    machine_tag: String,
    caching: bool,
    /// In-frontier duplicate schedules deduplicated before submission
    /// (beam expansion re-proposes surviving states verbatim); counted as
    /// cache hits in [`PredictorCost::cache_stats`].
    dup_hits: AtomicUsize,
}

impl PredictorCost {
    /// Wrap a predictor in a private default service.
    pub fn new(predictor: Box<dyn Predictor>, machine: Machine) -> PredictorCost {
        let service = PredictService::spawn(Arc::from(predictor), ServiceConfig::default());
        PredictorCost::with_service(Arc::new(service), machine)
    }

    /// Score through an existing (possibly shared) service.
    pub fn with_service(service: Arc<PredictService>, machine: Machine) -> PredictorCost {
        PredictorCost {
            service,
            machine_tag: format!("{machine:?}"),
            machine,
            caching: true,
            dup_hits: AtomicUsize::new(0),
        }
    }

    /// Caching disabled — every score featurizes and runs the model
    /// (requests carry no cache keys, so the service memoizes nothing).
    /// Used by the benches and the cache-equivalence tests as the
    /// reference.
    pub fn uncached(predictor: Box<dyn Predictor>, machine: Machine) -> PredictorCost {
        PredictorCost { caching: false, ..PredictorCost::new(predictor, machine) }
    }

    /// The service this bridge scores through.
    pub fn service(&self) -> &Arc<PredictService> {
        &self.service
    }

    pub fn clear_cache(&self) {
        self.service.clear_cache();
    }

    /// (cache hits, model evaluations) observed by the backing service
    /// since its construction — shared across every client of a shared
    /// service — plus this bridge's in-frontier duplicate hits.
    pub fn cache_stats(&self) -> (usize, usize) {
        let s = self.service.stats();
        (s.cache_hits + self.dup_hits.load(Ordering::Relaxed), s.samples_evaluated)
    }

    pub fn cache_len(&self) -> usize {
        self.service.cache_len()
    }
}

/// Structural identity of a pipeline for cache namespacing: name plus
/// every stage's op (kind + attrs), output shape and inputs — anything
/// featurization reads. Cheap next to a model evaluation.
fn pipeline_identity(p: &Pipeline) -> String {
    use std::fmt::Write as _;
    let mut id = String::with_capacity(64 + 32 * p.stages.len());
    let _ = write!(id, "{}", p.name);
    for s in &p.stages {
        let _ = write!(id, "|{:?}{:?}{:?}", s.op, s.shape, s.inputs);
    }
    id
}

impl CostModel for PredictorCost {
    fn score(
        &self,
        p: &Pipeline,
        nests: &[LoopNest],
        scheds: &[PipelineSchedule],
    ) -> Result<Vec<f64>> {
        use std::fmt::Write as _;
        let identity = if self.caching { pipeline_identity(p) } else { String::new() };
        // reused per-candidate Debug buffer: the hot (all-hits) path pays
        // formatting but no per-schedule allocation
        let mut sched_buf = String::new();
        let mut out = vec![f64::NAN; scheds.len()];
        // (output index, position in the evaluation batch); duplicates
        // within one frontier share a position when caching is on
        let mut assign: Vec<(usize, usize)> = Vec::new();
        // representative scheds index + cache key per evaluation position
        let mut eval_idx: Vec<usize> = Vec::new();
        let mut eval_keys: Vec<Option<CacheKey>> = Vec::new();
        let mut pending: HashMap<CacheKey, usize> = HashMap::new();
        for (i, sched) in scheds.iter().enumerate() {
            if self.caching {
                sched_buf.clear();
                let _ = write!(sched_buf, "{sched:?}");
                let key = cache_key(&[&identity, &self.machine_tag, &sched_buf]);
                if let Some(v) = self.service.cache_lookup(key) {
                    out[i] = v;
                    continue;
                }
                if let Some(&pos) = pending.get(&key) {
                    assign.push((i, pos));
                    self.dup_hits.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                pending.insert(key, eval_idx.len());
                eval_keys.push(Some(key));
            } else {
                eval_keys.push(None);
            }
            assign.push((i, eval_idx.len()));
            eval_idx.push(i);
        }

        if !eval_idx.is_empty() {
            let samples: Vec<GraphSample> = eval_idx
                .iter()
                .map(|&i| featurize_schedule(p, nests, &scheds[i], &self.machine, 0, i as u32))
                .collect();
            let keys = if self.caching { eval_keys } else { Vec::new() };
            let resp = self.service.predict_blocking(PredictRequest::with_keys(samples, keys))?;
            ensure!(
                resp.predictions.len() == eval_idx.len(),
                "{} returned {} scores for {} schedules",
                resp.model,
                resp.predictions.len(),
                eval_idx.len()
            );
            for &(i, pos) in &assign {
                out[i] = resp.predictions[pos];
            }
        }
        Ok(out)
    }

    fn name(&self) -> String {
        self.service.model_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::gbt::GbtConfig;
    use crate::dataset::builder::{build_dataset, DataGenConfig};
    use crate::predictor::{GbtPredictor, GcnPredictor};
    use crate::runtime::{Backend, NativeBackend};
    use crate::schedule::random::random_pipeline_schedule;
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    fn gcn_predictor() -> GcnPredictor {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 4,
            seed: 61,
            ..Default::default()
        });
        let backend = NativeBackend::new();
        let params = backend.init_params(2);
        GcnPredictor::new(Box::new(backend), params, ds.stats.clone().unwrap())
    }

    fn gcn_cost(caching: bool) -> PredictorCost {
        if caching {
            PredictorCost::new(Box::new(gcn_predictor()), Machine::default())
        } else {
            PredictorCost::uncached(Box::new(gcn_predictor()), Machine::default())
        }
    }

    #[test]
    fn cached_scores_match_uncached_exactly() {
        let net = crate::zoo::unet();
        let nests = crate::lower::lower_pipeline(&net);
        let cached = gcn_cost(true);
        let uncached = gcn_cost(false);
        propcheck::check_rng("predictor-cost cache equivalence", 17, 12, |rng| {
            // batch with deliberate duplicates, as beam expansion produces
            let mut scheds = Vec::new();
            for _ in 0..3 {
                scheds.push(random_pipeline_schedule(&net, &nests, rng));
            }
            scheds.push(scheds[0].clone());
            scheds.push(scheds[1].clone());
            let a = cached.score(&net, &nests, &scheds).map_err(|e| e.to_string())?;
            let b = uncached.score(&net, &nests, &scheds).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("cached {a:?} != uncached {b:?}"));
            }
            // duplicates must agree within one batch too
            if a[0] != a[3] || a[1] != a[4] {
                return Err(format!("duplicate schedules scored differently: {a:?}"));
            }
            Ok(())
        });
        let (hits, misses) = cached.cache_stats();
        assert!(hits > 0, "repeated schedules should hit the cache");
        assert!(misses > 0);
        let (h2, _) = uncached.cache_stats();
        assert_eq!(h2, 0, "uncached reference must never hit");
    }

    #[test]
    fn shared_cache_namespaces_pipelines() {
        // one shared service serves two different pipelines: keys are
        // namespaced by pipeline identity, so entries coexist and a
        // schedule re-scored on its own pipeline hits while the other
        // pipeline's entries are never served for it
        let unet = crate::zoo::unet();
        let unet_nests = crate::lower::lower_pipeline(&unet);
        let sq = crate::zoo::squeezenet();
        let sq_nests = crate::lower::lower_pipeline(&sq);
        let cost = gcn_cost(true);
        let mut rng = Rng::new(3);
        let s1 = vec![random_pipeline_schedule(&unet, &unet_nests, &mut rng)];
        cost.score(&unet, &unet_nests, &s1).unwrap();
        assert_eq!(cost.cache_len(), 1);
        let s2 = vec![random_pipeline_schedule(&sq, &sq_nests, &mut rng)];
        cost.score(&sq, &sq_nests, &s2).unwrap();
        assert_eq!(cost.cache_len(), 2, "pipelines must not evict each other");
        // re-score the first pipeline's schedule: pure cache hit
        let evals_before = cost.cache_stats().1;
        let (hits_before, _) = cost.cache_stats();
        cost.score(&unet, &unet_nests, &s1).unwrap();
        let (hits_after, evals_after) = cost.cache_stats();
        assert_eq!(evals_after, evals_before, "hit must not re-evaluate");
        assert!(hits_after > hits_before);
        assert_eq!(cost.cache_len(), 2);
    }

    #[test]
    fn beam_search_runs_on_a_learned_cost() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 6,
            seed: 67,
            ..Default::default()
        });
        let gbt = GbtPredictor::fit(&ds, GbtConfig { n_trees: 10, ..Default::default() });
        let cost = PredictorCost::new(Box::new(gbt), Machine::default());
        let net = crate::zoo::unet();
        let nests = crate::lower::lower_pipeline(&net);
        let (sched, score) = crate::search::beam_search(
            &net,
            &nests,
            &cost,
            &crate::search::BeamConfig { beam_width: 2, candidates_per_stage: 3, seed: 5 },
        )
        .unwrap();
        crate::schedule::legality::check_pipeline(&net, &nests, &sched).unwrap();
        assert!(score.is_finite() && score > 0.0);
        let (hits, _) = cost.cache_stats();
        assert!(hits > 0, "beam prefixes must hit the cache");
    }

    #[test]
    fn beam_search_issues_one_service_call_per_frontier_expansion() {
        // the serving acceptance bar: scoring goes frontier-at-once, not
        // per candidate — ≤ 1 service round-trip per expansion plus the
        // final beam scoring
        let service = Arc::new(PredictService::with_defaults(Arc::new(gcn_predictor())));
        let cost = PredictorCost::with_service(Arc::clone(&service), Machine::default());
        let net = crate::zoo::unet();
        let nests = crate::lower::lower_pipeline(&net);
        let (sched, _) = crate::search::beam_search(
            &net,
            &nests,
            &cost,
            &crate::search::BeamConfig { beam_width: 2, candidates_per_stage: 3, seed: 9 },
        )
        .unwrap();
        crate::schedule::legality::check_pipeline(&net, &nests, &sched).unwrap();
        let stats = service.stats();
        let expansions = net.num_stages() + 1; // one per stage + final beam scoring
        assert!(
            stats.requests <= expansions,
            "beam search issued {} service calls for {} expansions",
            stats.requests,
            expansions
        );
        assert!(stats.requests > 0);
    }
}
