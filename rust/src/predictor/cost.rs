//! [`PredictorCost`] — the generic bridge from any [`Predictor`] to
//! [`crate::search::CostModel`], with a schedule-keyed memoization cache.
//!
//! Beam search re-scores its surviving states at every expansion step: a
//! beam state that survives `k` steps is featurized and scored `k+1` times
//! by a naive cost model. The cache keys on the complete
//! [`PipelineSchedule`] (hashable by construction — all-integer fields),
//! so unchanged beam prefixes cost one hash lookup instead of a
//! featurization plus a model evaluation. Scoring also goes through
//! [`crate::dataset::builder::featurize_schedule`], which skips the
//! simulated benchmark runs a training sample would need — the model only
//! reads features.

use crate::dataset::builder::featurize_schedule;
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::predictor::Predictor;
use crate::schedule::primitives::PipelineSchedule;
use crate::search::beam::CostModel;
use crate::sim::Machine;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;

/// Cost model over any predictor. Construct one per (pipeline, search);
/// the cache is invalidated automatically if a different pipeline shows
/// up, so a reused instance is safe, just no longer warm.
pub struct PredictorCost {
    predictor: Box<dyn Predictor>,
    machine: Machine,
    caching: bool,
    cache: RefCell<HashMap<PipelineSchedule, f64>>,
    /// Identity tag of the pipeline the cache entries belong to (see
    /// [`pipeline_identity`] — structural, so two different pipelines
    /// sharing a name do not serve each other's scores).
    cached_pipeline: RefCell<Option<String>>,
    hits: Cell<usize>,
    misses: Cell<usize>,
}

impl PredictorCost {
    pub fn new(predictor: Box<dyn Predictor>, machine: Machine) -> PredictorCost {
        PredictorCost {
            predictor,
            machine,
            caching: true,
            cache: RefCell::new(HashMap::new()),
            cached_pipeline: RefCell::new(None),
            hits: Cell::new(0),
            misses: Cell::new(0),
        }
    }

    /// Caching disabled — every score featurizes and runs the model. Used
    /// by the benches and the cache-equivalence tests as the reference.
    pub fn uncached(predictor: Box<dyn Predictor>, machine: Machine) -> PredictorCost {
        PredictorCost { caching: false, ..PredictorCost::new(predictor, machine) }
    }

    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
        *self.cached_pipeline.borrow_mut() = None;
    }

    /// (cache hits, model evaluations) since construction.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.hits.get(), self.misses.get())
    }

    pub fn cache_len(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Structural identity of a pipeline for cache invalidation: name plus
/// every stage's op (kind + attrs), output shape and inputs — anything
/// featurization reads. Cheap next to a model evaluation.
fn pipeline_identity(p: &Pipeline) -> String {
    use std::fmt::Write as _;
    let mut id = String::with_capacity(64 + 32 * p.stages.len());
    let _ = write!(id, "{}", p.name);
    for s in &p.stages {
        let _ = write!(id, "|{:?}{:?}{:?}", s.op, s.shape, s.inputs);
    }
    id
}

impl CostModel for PredictorCost {
    fn score(&self, p: &Pipeline, nests: &[LoopNest], scheds: &[PipelineSchedule]) -> Vec<f64> {
        if self.caching {
            let identity = pipeline_identity(p);
            let mut tag = self.cached_pipeline.borrow_mut();
            if tag.as_deref() != Some(identity.as_str()) {
                self.cache.borrow_mut().clear();
                *tag = Some(identity);
            }
        }

        let mut out = vec![f64::NAN; scheds.len()];
        // (output index, position in the evaluation batch); duplicates
        // within one call share a position when caching is on
        let mut assign: Vec<(usize, usize)> = Vec::new();
        // representative scheds index per evaluation-batch position
        let mut evals: Vec<usize> = Vec::new();
        {
            let cache = self.cache.borrow();
            let mut pending: HashMap<&PipelineSchedule, usize> = HashMap::new();
            for (i, sched) in scheds.iter().enumerate() {
                if self.caching {
                    if let Some(&v) = cache.get(sched) {
                        out[i] = v;
                        self.hits.set(self.hits.get() + 1);
                        continue;
                    }
                    if let Some(&pos) = pending.get(sched) {
                        assign.push((i, pos));
                        self.hits.set(self.hits.get() + 1);
                        continue;
                    }
                    pending.insert(sched, evals.len());
                }
                assign.push((i, evals.len()));
                evals.push(i);
            }
        }

        if !evals.is_empty() {
            self.misses.set(self.misses.get() + evals.len());
            let samples: Vec<_> = evals
                .iter()
                .map(|&i| featurize_schedule(p, nests, &scheds[i], &self.machine, 0, i as u32))
                .collect();
            let refs: Vec<&crate::dataset::sample::GraphSample> = samples.iter().collect();
            let preds = self.predictor.predict(&refs).unwrap_or_else(|e| {
                panic!("{} cost model inference failed: {e:#}", self.predictor.name())
            });
            for &(i, pos) in &assign {
                out[i] = preds[pos];
            }
            if self.caching {
                let mut cache = self.cache.borrow_mut();
                for (&i, pred) in evals.iter().zip(&preds) {
                    cache.insert(scheds[i].clone(), *pred);
                }
            }
        }
        out
    }

    fn name(&self) -> String {
        self.predictor.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::gbt::GbtConfig;
    use crate::dataset::builder::{build_dataset, DataGenConfig};
    use crate::predictor::{GbtPredictor, GcnPredictor};
    use crate::runtime::{Backend, NativeBackend};
    use crate::schedule::random::random_pipeline_schedule;
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    fn gcn_cost(caching: bool) -> PredictorCost {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 4,
            seed: 61,
            ..Default::default()
        });
        let backend = NativeBackend::new();
        let params = backend.init_params(2);
        let p = GcnPredictor::new(Box::new(backend), params, ds.stats.clone().unwrap());
        if caching {
            PredictorCost::new(Box::new(p), Machine::default())
        } else {
            PredictorCost::uncached(Box::new(p), Machine::default())
        }
    }

    #[test]
    fn cached_scores_match_uncached_exactly() {
        let net = crate::zoo::unet();
        let nests = crate::lower::lower_pipeline(&net);
        let cached = gcn_cost(true);
        let uncached = gcn_cost(false);
        propcheck::check_rng("predictor-cost cache equivalence", 17, 12, |rng| {
            // batch with deliberate duplicates, as beam expansion produces
            let mut scheds = Vec::new();
            for _ in 0..3 {
                scheds.push(random_pipeline_schedule(&net, &nests, rng));
            }
            scheds.push(scheds[0].clone());
            scheds.push(scheds[1].clone());
            let a = cached.score(&net, &nests, &scheds);
            let b = uncached.score(&net, &nests, &scheds);
            if a != b {
                return Err(format!("cached {a:?} != uncached {b:?}"));
            }
            // duplicates must agree within one batch too
            if a[0] != a[3] || a[1] != a[4] {
                return Err(format!("duplicate schedules scored differently: {a:?}"));
            }
            Ok(())
        });
        let (hits, misses) = cached.cache_stats();
        assert!(hits > 0, "repeated schedules should hit the cache");
        assert!(misses > 0);
        let (h2, _) = uncached.cache_stats();
        assert_eq!(h2, 0, "uncached reference must never hit");
    }

    #[test]
    fn cache_invalidates_across_pipelines() {
        let unet = crate::zoo::unet();
        let unet_nests = crate::lower::lower_pipeline(&unet);
        let sq = crate::zoo::squeezenet();
        let sq_nests = crate::lower::lower_pipeline(&sq);
        let cost = gcn_cost(true);
        let mut rng = Rng::new(3);
        let s1 = vec![random_pipeline_schedule(&unet, &unet_nests, &mut rng)];
        cost.score(&unet, &unet_nests, &s1);
        assert_eq!(cost.cache_len(), 1);
        let s2 = vec![random_pipeline_schedule(&sq, &sq_nests, &mut rng)];
        cost.score(&sq, &sq_nests, &s2);
        assert_eq!(cost.cache_len(), 1, "switching pipelines must clear the cache");
    }

    #[test]
    fn beam_search_runs_on_a_learned_cost() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 6,
            seed: 67,
            ..Default::default()
        });
        let gbt = GbtPredictor::fit(&ds, GbtConfig { n_trees: 10, ..Default::default() });
        let cost = PredictorCost::new(Box::new(gbt), Machine::default());
        let net = crate::zoo::unet();
        let nests = crate::lower::lower_pipeline(&net);
        let (sched, score) = crate::search::beam_search(
            &net,
            &nests,
            &cost,
            &crate::search::BeamConfig { beam_width: 2, candidates_per_stage: 3, seed: 5 },
        );
        crate::schedule::legality::check_pipeline(&net, &nests, &sched).unwrap();
        assert!(score.is_finite() && score > 0.0);
        let (hits, _) = cost.cache_stats();
        assert!(hits > 0, "beam prefixes must hit the cache");
    }
}
