//! Name → [`Predictor`] resolution for the CLI and any embedding caller.
//!
//! Two entry points:
//!
//! * [`load_bundle`] — open any saved bundle and dispatch on its kind tag;
//! * [`fit_model`] — fit a baseline from a training dataset by registry
//!   name (the GCN trains through `gcn-perf train`, not here).
//!
//! `gcn-perf search --model <name>` accepts every name in [`REGISTERED`]
//! plus `"oracle"` (the simulator itself, which scores schedules directly
//! and therefore lives in `search`, not behind [`Predictor`]).

use crate::baselines::gbt::GbtConfig;
use crate::baselines::halide_ffn::FfnTrainConfig;
use crate::baselines::rnn::RnnTrainConfig;
use crate::dataset::sample::Dataset;
use crate::predictor::bundle::Bundle;
use crate::predictor::quant::QuantGcnPredictor;
use crate::predictor::{FfnPredictor, GbtPredictor, GcnPredictor, GruPredictor, Predictor};
use crate::runtime::kernels_simd::{self, KernelVariant};
use anyhow::{bail, Result};
use std::path::Path;

pub const KIND_GCN: &str = "gcn";
pub const KIND_GCN_INT8: &str = "gcn-int8";
pub const KIND_FFN: &str = "ffn";
pub const KIND_RNN: &str = "rnn";
pub const KIND_GBT: &str = "gbt";

/// Every model the registry can resolve (bundle kinds double as names).
pub const REGISTERED: &[&str] = &[KIND_GCN, KIND_GCN_INT8, KIND_FFN, KIND_RNN, KIND_GBT];

/// Knobs for fitting baselines on the fly (e.g. for model-guided search
/// without a pre-saved bundle).
#[derive(Debug, Clone)]
pub struct FitConfig {
    pub ffn_epochs: usize,
    pub rnn_epochs: usize,
    pub rnn_hidden: usize,
    pub gbt_trees: usize,
    pub seed: u64,
}

impl Default for FitConfig {
    fn default() -> Self {
        FitConfig { ffn_epochs: 20, rnn_epochs: 8, rnn_hidden: 64, gbt_trees: 80, seed: 99 }
    }
}

/// The kind tag of a saved bundle ("gcn", "ffn", ...), read from the
/// header without deserializing the model.
pub fn bundle_kind(path: &Path) -> Result<String> {
    Bundle::peek_kind(path)
}

/// Load any saved bundle, dispatching on its kind tag. GCN-family models
/// come up on the scalar (bitwise-deterministic) kernels — the default
/// for training, autotune checkpoints and loadgen verification.
pub fn load_bundle(path: &Path) -> Result<Box<dyn Predictor>> {
    load_bundle_variant(path, KernelVariant::Scalar)
}

/// Load a bundle for serving: like [`load_bundle`], but GCN-family
/// models dispatch their microkernels through the best tier this build
/// and CPU support ([`kernels_simd::detected`] — always Scalar unless
/// the `simd` cargo feature is enabled; overridable down via the
/// `GCN_PERF_KERNELS` env var). Other kinds are unaffected.
pub fn load_bundle_serving(path: &Path) -> Result<Box<dyn Predictor>> {
    load_bundle_variant(path, kernels_simd::detected())
}

/// Load any saved bundle with an explicitly requested microkernel tier
/// for GCN-family models (clamped to build/CPU capability).
pub fn load_bundle_variant(path: &Path, variant: KernelVariant) -> Result<Box<dyn Predictor>> {
    let kind = bundle_kind(path)?;
    Ok(match kind.as_str() {
        KIND_GCN => Box::new(GcnPredictor::load_with_variant(path, variant)?),
        KIND_GCN_INT8 => Box::new(QuantGcnPredictor::load_with_variant(path, variant)?),
        KIND_FFN => Box::new(FfnPredictor::load(path)?),
        KIND_RNN => Box::new(GruPredictor::load(path)?),
        KIND_GBT => Box::new(GbtPredictor::load(path)?),
        other => bail!(
            "bundle {path:?} has unknown model kind '{other}' (this build knows {REGISTERED:?})"
        ),
    })
}

/// Fit a registered baseline on `train_ds`. The GCN is the one model that
/// cannot be fitted here (it trains through `gcn-perf train` and arrives
/// as a bundle).
pub fn fit_model(name: &str, train_ds: &Dataset, cfg: &FitConfig) -> Result<Box<dyn Predictor>> {
    Ok(match name {
        KIND_FFN => Box::new(FfnPredictor::fit(
            train_ds,
            &FfnTrainConfig { epochs: cfg.ffn_epochs, ..Default::default() },
            cfg.seed,
        )?),
        KIND_RNN => Box::new(GruPredictor::fit(
            train_ds,
            &RnnTrainConfig { epochs: cfg.rnn_epochs, ..Default::default() },
            cfg.rnn_hidden,
            cfg.seed,
        )?),
        KIND_GBT => Box::new(GbtPredictor::fit(
            train_ds,
            GbtConfig { n_trees: cfg.gbt_trees, ..Default::default() },
        )),
        KIND_GCN => bail!(
            "the gcn is trained via `gcn-perf train`; pass its bundle with --bundle"
        ),
        KIND_GCN_INT8 => bail!(
            "int8 models are not trained directly: train an f32 gcn, then mint a \
             quantized bundle with `gcn-perf quantize`"
        ),
        other => bail!("unknown model '{other}' (registered: {REGISTERED:?}, plus 'oracle')"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    #[test]
    fn fits_every_baseline_by_name() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 5,
            schedules_per_pipeline: 5,
            seed: 71,
            ..Default::default()
        });
        let cfg = FitConfig { ffn_epochs: 1, rnn_epochs: 1, gbt_trees: 8, ..Default::default() };
        let refs: Vec<&crate::dataset::sample::GraphSample> =
            ds.samples.iter().take(4).collect();
        for name in [KIND_FFN, KIND_RNN, KIND_GBT] {
            let p = fit_model(name, &ds, &cfg).unwrap();
            let preds = p.predict(&refs).unwrap();
            assert_eq!(preds.len(), 4);
            assert!(preds.iter().all(|v| v.is_finite() && *v > 0.0), "{name}: {preds:?}");
        }
        assert!(fit_model("gcn", &ds, &cfg).is_err());
        assert!(fit_model("nope", &ds, &cfg).is_err());
    }

    #[test]
    fn load_bundle_dispatches_on_kind() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 4,
            seed: 73,
            ..Default::default()
        });
        let cfg = FitConfig { ffn_epochs: 1, rnn_epochs: 1, gbt_trees: 6, ..Default::default() };
        let path = std::env::temp_dir().join("gcn_perf_registry_dispatch.bundle");
        for name in [KIND_FFN, KIND_RNN, KIND_GBT] {
            let p = fit_model(name, &ds, &cfg).unwrap();
            p.save(&path).unwrap();
            let q = load_bundle(&path).unwrap();
            assert_eq!(p.name(), q.name());
            let refs: Vec<&crate::dataset::sample::GraphSample> =
                ds.samples.iter().take(3).collect();
            assert_eq!(p.predict(&refs).unwrap(), q.predict(&refs).unwrap());
        }
        std::fs::remove_file(&path).ok();
    }
}
