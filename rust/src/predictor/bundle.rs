//! Single-file model bundles — the on-disk format behind
//! [`crate::predictor::Predictor::save`] and the loaders in
//! [`crate::predictor::registry`].
//!
//! A bundle replaces the loose params/stats files of the pre-`Predictor`
//! CLI: one file carries everything needed to serve a model — a versioned
//! header, the model kind, the training-set feature statistics and the
//! model payload as named tensors plus scalar metadata.
//!
//! Layout (little-endian):
//!
//! ```text
//!   magic "GCNPBNDL" + u32 format version
//!   kind string                  (u32 len + utf8: "gcn" | "ffn" | ...)
//!   u8 has_stats [+ u32 len + f64*len]   feature mean/std, dims checked
//!   meta:    u32 count, (string key, f64 value)*
//!   tensors: u32 count, (string name, u32 rank, u32 dims*, f32 data)*
//!   [v2+] qtensors: u32 count, (string name, u32 rank, u32 dims*, i8 data)*
//! ```
//!
//! Version 2 appends an int8 tensor section for quantized models
//! (`gcn-perf quantize`); bundles without quantized tensors are still
//! written as version 1, byte-identical to pre-quantization builds, and
//! version-1 files load with an empty `qtensors` list.
//!
//! The container is model-agnostic: every in-tree model (GCN, Halide FFN,
//! bi-GRU, GBT) flattens into named tensors + metadata, so one reader
//! serves them all and version/shape mismatches fail with a clear error
//! instead of garbage predictions.

use crate::constants::{DEP_DIM, INV_DIM};
use crate::features::normalize::FeatureStats;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GCNPBNDL";

/// Current bundle format version. Bump on any layout change; loaders
/// accept [`MIN_SUPPORTED_VERSION`]..=[`FORMAT_VERSION`] and reject
/// anything else outright. The writer emits the oldest version that can
/// represent the bundle (v1 unless quantized tensors are present).
pub const FORMAT_VERSION: u32 = 2;

/// Oldest format version this build still reads.
pub const MIN_SUPPORTED_VERSION: u32 = 1;

/// One named parameter tensor of a bundled model.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NamedTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One named int8 tensor of a quantized model (format v2+). Scales and
/// other f32 payload ride in the regular [`NamedTensor`] section.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantNamedTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
}

impl QuantNamedTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An in-memory model bundle: kind tag + stats + metadata + tensors.
#[derive(Debug, Clone)]
pub struct Bundle {
    /// Registry kind ("gcn", "ffn", "rnn", "gbt").
    pub kind: String,
    /// Feature normalization fitted on the training set (models that take
    /// raw features, like the GBT, carry `None`).
    pub stats: Option<FeatureStats>,
    /// Scalar metadata (e.g. `n_conv` for the GCN, `hidden` for the GRU).
    pub meta: BTreeMap<String, f64>,
    pub tensors: Vec<NamedTensor>,
    /// Int8 tensors of a quantized model (empty for f32 bundles; forces
    /// the v2 on-disk layout when non-empty).
    pub qtensors: Vec<QuantNamedTensor>,
}

impl Bundle {
    pub fn new(kind: &str) -> Bundle {
        Bundle {
            kind: kind.to_string(),
            stats: None,
            meta: BTreeMap::new(),
            tensors: Vec::new(),
            qtensors: Vec::new(),
        }
    }

    /// Required metadata entry as usize.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        let v = *self
            .meta
            .get(key)
            .with_context(|| format!("bundle missing meta key '{key}'"))?;
        Ok(v as usize)
    }

    /// Required metadata entry as f64.
    pub fn meta_f64(&self, key: &str) -> Result<f64> {
        self.meta
            .get(key)
            .copied()
            .with_context(|| format!("bundle missing meta key '{key}'"))
    }

    /// Required tensor by name.
    pub fn tensor(&self, name: &str) -> Result<&NamedTensor> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("bundle missing tensor '{name}'"))
    }

    /// Required int8 tensor by name (quantized bundles only).
    pub fn qtensor(&self, name: &str) -> Result<&QuantNamedTensor> {
        self.qtensors
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("bundle missing quantized tensor '{name}'"))
    }

    /// Write the bundle to one file (parent directories are created).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = Bw { w: BufWriter::new(f) };
        w.bytes(MAGIC)?;
        // oldest version that can represent this bundle: plain f32
        // bundles stay byte-identical to what version-1-only readers
        // (and older builds) expect
        let version = if self.qtensors.is_empty() { 1 } else { FORMAT_VERSION };
        w.u32(version)?;
        w.string(&self.kind)?;
        match &self.stats {
            None => w.u8(0)?,
            Some(stats) => {
                w.u8(1)?;
                let flat = stats.to_flat();
                w.u32(flat.len() as u32)?;
                w.f64s(&flat)?;
            }
        }
        w.u32(self.meta.len() as u32)?;
        for (k, v) in &self.meta {
            w.string(k)?;
            w.f64s(&[*v])?;
        }
        w.u32(self.tensors.len() as u32)?;
        for t in &self.tensors {
            if t.data.len() != t.numel() {
                bail!("tensor '{}': {} values but shape {:?}", t.name, t.data.len(), t.shape);
            }
            w.string(&t.name)?;
            w.u32(t.shape.len() as u32)?;
            for &d in &t.shape {
                w.u32(d as u32)?;
            }
            w.f32s(&t.data)?;
        }
        if version >= 2 {
            w.u32(self.qtensors.len() as u32)?;
            for t in &self.qtensors {
                if t.data.len() != t.numel() {
                    bail!(
                        "qtensor '{}': {} values but shape {:?}",
                        t.name,
                        t.data.len(),
                        t.shape
                    );
                }
                w.string(&t.name)?;
                w.u32(t.shape.len() as u32)?;
                for &d in &t.shape {
                    w.u32(d as u32)?;
                }
                w.i8s(&t.data)?;
            }
        }
        w.w.flush()?;
        Ok(())
    }

    /// Read just the header (magic, version, kind) — for dispatching on
    /// the model kind without deserializing tensors.
    pub fn peek_kind(path: &Path) -> Result<String> {
        let f = std::fs::File::open(path).with_context(|| format!("open bundle {path:?}"))?;
        let mut r = Br { r: BufReader::new(f) };
        Ok(Bundle::read_header(&mut r, path)?.1)
    }

    fn read_header<R: Read>(r: &mut Br<R>, path: &Path) -> Result<(u32, String)> {
        let mut magic = [0u8; 8];
        r.r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a gcn-perf model bundle (bad magic)");
        }
        let version = r.u32()?;
        if !(MIN_SUPPORTED_VERSION..=FORMAT_VERSION).contains(&version) {
            bail!(
                "bundle {path:?} has format version {version}, this build reads \
                 {MIN_SUPPORTED_VERSION}..={FORMAT_VERSION}"
            );
        }
        Ok((version, r.string()?))
    }

    /// Read a bundle; fails cleanly on bad magic, unknown format version or
    /// a feature-dimension mismatch with this build.
    pub fn load(path: &Path) -> Result<Bundle> {
        let f = std::fs::File::open(path).with_context(|| format!("open bundle {path:?}"))?;
        let mut r = Br { r: BufReader::new(f) };
        let (version, kind) = Bundle::read_header(&mut r, path)?;
        let stats = if r.u8()? != 0 {
            let n = r.u32()? as usize;
            if n != 2 * (INV_DIM + DEP_DIM) {
                bail!(
                    "bundle feature stats have {n} entries, this build expects {} \
                     (INV_DIM/DEP_DIM drift — retrain the model)",
                    2 * (INV_DIM + DEP_DIM)
                );
            }
            Some(FeatureStats::from_flat(&r.f64s(n)?))
        } else {
            None
        };
        let n_meta = r.u32()? as usize;
        let mut meta = BTreeMap::new();
        for _ in 0..n_meta {
            let k = r.string()?;
            let v = r.f64s(1)?[0];
            meta.insert(k, v);
        }
        let n_tensors = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name = r.string()?;
            let rank = r.u32()? as usize;
            if rank > 8 {
                bail!("tensor '{name}': implausible rank {rank} (corrupt bundle?)");
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.u32()? as usize);
            }
            let numel = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .with_context(|| format!("tensor '{name}': shape {shape:?} overflows"))?;
            if numel > 64 << 20 {
                bail!("tensor '{name}': implausible size {numel} (corrupt bundle?)");
            }
            let data = r.f32s(numel)?;
            tensors.push(NamedTensor { name, shape, data });
        }
        let mut qtensors = Vec::new();
        if version >= 2 {
            let n_q = r.u32()? as usize;
            qtensors.reserve(n_q.min(1024));
            for _ in 0..n_q {
                let name = r.string()?;
                let rank = r.u32()? as usize;
                if rank > 8 {
                    bail!("qtensor '{name}': implausible rank {rank} (corrupt bundle?)");
                }
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(r.u32()? as usize);
                }
                let numel = shape
                    .iter()
                    .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                    .with_context(|| format!("qtensor '{name}': shape {shape:?} overflows"))?;
                if numel > 64 << 20 {
                    bail!("qtensor '{name}': implausible size {numel} (corrupt bundle?)");
                }
                let data = r.i8s(numel)?;
                qtensors.push(QuantNamedTensor { name, shape, data });
            }
        }
        let bundle = Bundle { kind, stats, meta, tensors, qtensors };
        // analyzer data audit: reject NaN/Inf weights and malformed stats
        // at load time (D005/D006) — a single poisoned tensor value would
        // otherwise silently corrupt every downstream prediction
        if let Some(diag) = crate::analysis::audit_bundle(&bundle).into_iter().next() {
            return Err(anyhow::Error::new(diag));
        }
        Ok(bundle)
    }
}

struct Bw<W: Write> {
    w: W,
}

impl<W: Write> Bw<W> {
    fn bytes(&mut self, b: &[u8]) -> Result<()> {
        self.w.write_all(b)?;
        Ok(())
    }
    fn u8(&mut self, v: u8) -> Result<()> {
        self.bytes(&[v])
    }
    fn u32(&mut self, v: u32) -> Result<()> {
        self.bytes(&v.to_le_bytes())
    }
    fn string(&mut self, s: &str) -> Result<()> {
        self.u32(s.len() as u32)?;
        self.bytes(s.as_bytes())
    }
    fn f32s(&mut self, vs: &[f32]) -> Result<()> {
        for v in vs {
            self.bytes(&v.to_le_bytes())?;
        }
        Ok(())
    }
    fn i8s(&mut self, vs: &[i8]) -> Result<()> {
        for v in vs {
            self.bytes(&[*v as u8])?;
        }
        Ok(())
    }
    fn f64s(&mut self, vs: &[f64]) -> Result<()> {
        for v in vs {
            self.bytes(&v.to_le_bytes())?;
        }
        Ok(())
    }
}

struct Br<R: Read> {
    r: R,
}

impl<R: Read> Br<R> {
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.r.read_exact(&mut b)?;
        Ok(b[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > 4096 {
            bail!("implausible string length {n} (corrupt bundle?)");
        }
        let mut buf = vec![0u8; n];
        self.r.read_exact(&mut buf)?;
        Ok(String::from_utf8(buf)?)
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut buf = vec![0u8; n * 4];
        self.r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn i8s(&mut self, n: usize) -> Result<Vec<i8>> {
        let mut buf = vec![0u8; n];
        self.r.read_exact(&mut buf)?;
        Ok(buf.iter().map(|&b| b as i8).collect())
    }
    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let mut buf = vec![0u8; n * 8];
        self.r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::StageFeatures;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(name)
    }

    fn some_stats() -> FeatureStats {
        let feats: Vec<StageFeatures> = (0..4)
            .map(|i| StageFeatures {
                invariant: [i as f32; INV_DIM],
                dependent: [i as f32 * 0.5; DEP_DIM],
            })
            .collect();
        FeatureStats::fit(feats.iter())
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut b = Bundle::new("gcn");
        b.stats = Some(some_stats());
        b.meta.insert("n_conv".into(), 2.0);
        b.tensors.push(NamedTensor {
            name: "w".into(),
            shape: vec![2, 3],
            data: vec![1.0, -2.5, 3.25, 0.0, 5.0, -0.125],
        });
        let path = tmp("gcn_perf_bundle_rt.bundle");
        b.save(&path).unwrap();
        let r = Bundle::load(&path).unwrap();
        assert_eq!(r.kind, "gcn");
        assert_eq!(r.meta_usize("n_conv").unwrap(), 2);
        assert_eq!(r.tensors, b.tensors);
        assert_eq!(r.stats.unwrap().to_flat(), b.stats.unwrap().to_flat());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_non_finite_tensor_with_d006() {
        let mut b = Bundle::new("ffn");
        b.tensors.push(NamedTensor {
            name: "w".into(),
            shape: vec![2],
            data: vec![1.0, f32::NAN],
        });
        let path = tmp("gcn_perf_bundle_nan.bundle");
        b.save(&path).unwrap();
        let err = Bundle::load(&path).unwrap_err();
        assert!(err.to_string().contains("D006"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let path = tmp("gcn_perf_bundle_bad.bundle");
        std::fs::write(&path, b"NOTABNDL rest").unwrap();
        assert!(Bundle::load(&path).unwrap_err().to_string().contains("bad magic"));

        let mut b = Bundle::new("gcn");
        b.tensors.push(NamedTensor { name: "w".into(), shape: vec![1], data: vec![1.0] });
        b.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = Bundle::load(&path).unwrap_err().to_string();
        assert!(err.contains("format version 99"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn plain_bundles_still_write_version_1_bytes() {
        let mut b = Bundle::new("gcn");
        b.tensors.push(NamedTensor { name: "w".into(), shape: vec![1], data: vec![1.0] });
        let path = tmp("gcn_perf_bundle_v1.bundle");
        b.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[8..12], &1u32.to_le_bytes(), "f32-only bundles stay v1");
        let r = Bundle::load(&path).unwrap();
        assert!(r.qtensors.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quantized_bundle_roundtrips_as_version_2() {
        let mut b = Bundle::new("gcn-int8");
        b.meta.insert("n_conv".into(), 2.0);
        b.tensors.push(NamedTensor {
            name: "w_scale".into(),
            shape: vec![3],
            data: vec![0.5, 0.25, 1.0],
        });
        b.qtensors.push(QuantNamedTensor {
            name: "w_q".into(),
            shape: vec![2, 3],
            data: vec![1, -2, 127, -128, 0, 64],
        });
        let path = tmp("gcn_perf_bundle_v2.bundle");
        b.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[8..12], &2u32.to_le_bytes(), "quantized bundles are v2");
        let r = Bundle::load(&path).unwrap();
        assert_eq!(r.kind, "gcn-int8");
        assert_eq!(r.qtensors, b.qtensors);
        assert_eq!(r.tensors, b.tensors);
        assert_eq!(r.qtensor("w_q").unwrap().numel(), 6);
        assert!(r.qtensor("missing").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn qtensor_shape_data_consistency_enforced_on_save() {
        let mut b = Bundle::new("gcn-int8");
        b.qtensors.push(QuantNamedTensor { name: "q".into(), shape: vec![2, 2], data: vec![1] });
        assert!(b.save(&tmp("gcn_perf_bundle_qbad.bundle")).is_err());
    }

    #[test]
    fn tensor_shape_data_consistency_enforced_on_save() {
        let mut b = Bundle::new("gcn");
        b.tensors.push(NamedTensor { name: "w".into(), shape: vec![2, 2], data: vec![1.0] });
        assert!(b.save(&tmp("gcn_perf_bundle_inconsistent.bundle")).is_err());
    }

    #[test]
    fn missing_meta_and_tensor_are_clean_errors() {
        let b = Bundle::new("gcn");
        assert!(b.meta_usize("n_conv").is_err());
        assert!(b.tensor("w_inv").is_err());
    }
}
