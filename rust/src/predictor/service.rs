//! `PredictService` — the concurrent serving seam over any [`Predictor`].
//!
//! The paper's deployment story is throughput: an auto-scheduler scores
//! enormous candidate sets, so the model must sustain as many queries per
//! second as the serving path allows. A bare [`Predictor`] is a function
//! call — concurrent callers each pack their own (often tiny) batches and
//! the sparse packed engine never sees the traffic it was built for. The
//! service turns the function call into a shared, coalescing pipeline:
//!
//! ```text
//!   caller A ──┐  submit(PredictRequest)            ┌─> PredictHandle A
//!   caller B ──┤     │                              ├─> PredictHandle B
//!   caller C ──┘     v                              │
//!            bounded queue ─> coalescer ─> one fused predict ─> scatter
//!                            (worker thread; drains every in-flight
//!                             request, dedups against the shared cache,
//!                             packs the misses into variable-size
//!                             `PackedBatch` chunks via `Predictor::predict`)
//! ```
//!
//! * **Backpressure.** The queue is bounded ([`ServiceConfig::queue_cap`]
//!   requests): [`PredictService::submit`] blocks until space frees up,
//!   [`PredictService::try_submit`] fails fast instead. Either way a full
//!   queue slows producers down rather than growing without bound.
//! * **Coalescing.** A worker drains up to [`ServiceConfig::max_coalesce`]
//!   queued requests at once and evaluates all their samples through a
//!   single `Predictor::predict` call — heterogeneous graphs from
//!   different callers share one block-diagonal packed batch (chunked at
//!   `BATCH` graphs by the backend). Per-graph results are independent of
//!   batch composition, so coalesced predictions are bitwise-equal to
//!   direct single-caller calls (pinned by the integration stress test).
//! * **Shared cache.** Callers may attach a [`CacheKey`] per sample;
//!   keyed results are memoized in one service-wide map, so e.g. two beam
//!   searches over the same pipeline share scores. In-flight duplicates
//!   (same key, same drain) are evaluated once. [`crate::predictor::PredictorCost`]
//!   keys on (pipeline, machine, schedule) and checks
//!   [`PredictService::cache_lookup`] *before* featurizing, so hits skip
//!   featurization entirely.
//! * **No panics across the seam.** Inference errors — and even panics in
//!   a model implementation — are caught and delivered to every affected
//!   handle as an error; one bad request cannot take down unrelated
//!   in-flight callers or the worker itself.
//! * **Clean shutdown.** Dropping the service closes the queue, lets the
//!   workers drain every already-accepted request, and joins them — no
//!   handle is left waiting forever.
//!
//! Everything is `std::sync` (mutex + condvar + atomics); no new
//! dependencies.

use crate::dataset::sample::GraphSample;
use crate::predictor::Predictor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Stable 128-bit cache key. Wide enough that hash collisions are not a
/// practical concern for a memo cache (compare: the pre-service cache
/// stored whole `PipelineSchedule` keys to avoid collisions at much
/// higher per-entry cost).
pub type CacheKey = u128;

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

/// FNV-1a over the concatenated parts (with separators, so
/// `["ab", "c"]` and `["a", "bc"]` hash differently). This is how
/// [`crate::predictor::PredictorCost`] derives its (pipeline, machine,
/// schedule) keys; any caller-side key derivation works as long as equal
/// keys imply equal predictions.
pub fn cache_key(parts: &[&str]) -> CacheKey {
    let mut h = FNV128_OFFSET;
    for part in parts {
        for &b in part.as_bytes() {
            h ^= b as u128;
            h = h.wrapping_mul(FNV128_PRIME);
        }
        // fold each part's length as the separator, so shifting bytes
        // across a part boundary changes the key
        h ^= part.len() as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

/// One caller's batch of samples to score. `keys` is either empty (no
/// caching for this request) or one optional [`CacheKey`] per sample.
#[derive(Debug, Clone, Default)]
pub struct PredictRequest {
    pub samples: Vec<GraphSample>,
    pub keys: Vec<Option<CacheKey>>,
}

impl PredictRequest {
    /// A request with no cache participation.
    pub fn new(samples: Vec<GraphSample>) -> PredictRequest {
        PredictRequest { samples, keys: Vec::new() }
    }

    /// A request whose samples carry cache keys (`keys.len()` must equal
    /// `samples.len()`; enforced at submit time).
    pub fn with_keys(samples: Vec<GraphSample>, keys: Vec<Option<CacheKey>>) -> PredictRequest {
        PredictRequest { samples, keys }
    }
}

/// The answer to one [`PredictRequest`]: mean runtimes in seconds, one
/// per sample, in request order.
#[derive(Debug, Clone)]
pub struct PredictResponse {
    pub predictions: Vec<f64>,
    /// The serving model's name (e.g. "gcn").
    pub model: String,
    /// How many of this request's samples were answered from the shared
    /// cache (or deduplicated against an in-flight twin).
    pub cache_hits: usize,
}

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Coalescing worker threads. One worker maximizes coalescing (the
    /// predictor itself parallelizes over batch chunks); more workers
    /// trade batch size for pipeline overlap.
    pub workers: usize,
    /// Bounded queue depth, in requests. Submissions past this block (or
    /// fail, via [`PredictService::try_submit`]).
    pub queue_cap: usize,
    /// Maximum requests drained into one fused evaluation.
    pub max_coalesce: usize,
    /// Cache entry budget; the cache is wiped when an insert would
    /// exceed it. `0` disables caching entirely.
    pub cache_cap: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 1, queue_cap: 64, max_coalesce: 64, cache_cap: 1 << 20 }
    }
}

/// Monotonic service counters (snapshot via [`PredictService::stats`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests accepted into the queue.
    pub requests: usize,
    /// Fused `Predictor::predict` calls issued by the coalescer.
    pub batches: usize,
    /// Samples that reached the model (keyed *and* keyless misses).
    pub samples_evaluated: usize,
    /// Samples answered from the cache, an in-flight duplicate, or a
    /// caller-side [`PredictService::cache_lookup`] hit.
    pub cache_hits: usize,
    /// Keyed samples that probed the memo cache and missed (so were
    /// evaluated and then memoized). Keyless samples are not counted —
    /// they never probe the cache.
    pub cache_misses: usize,
    /// Deepest the bounded queue has ever been, in requests. Shows how
    /// close the service has come to its `queue_cap` backpressure bound.
    pub peak_queue: usize,
    /// Microkernel tier the served model computes with ("scalar",
    /// "sse2", "avx2") — from [`crate::predictor::EngineInfo`].
    pub kernel_variant: String,
    /// Numeric precision the served model computes with ("f32", "int8").
    pub precision: String,
}

impl ServiceStats {
    /// The canonical JSON shape of the counters. Every front-end that
    /// reports service counters — the `STATS` response in both serve
    /// modes, the autotune fleet report, BENCH_7.json — embeds exactly
    /// this object, so field names can never drift between them (pinned
    /// by a parity test in `net::session`).
    pub fn to_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        Json::obj(vec![
            ("requests", n(self.requests)),
            ("batches", n(self.batches)),
            ("samples_evaluated", n(self.samples_evaluated)),
            ("cache_hits", n(self.cache_hits)),
            ("cache_misses", n(self.cache_misses)),
            ("peak_queue", n(self.peak_queue)),
            ("kernel_variant", Json::Str(self.kernel_variant.clone())),
            ("precision", Json::Str(self.precision.clone())),
        ])
    }

    /// The canonical one-line human rendering of the counters, shared by
    /// the serve shutdown summary and autotune progress output.
    pub fn summary_line(&self) -> String {
        format!(
            "served {} requests: {} samples evaluated in {} fused batches; \
             memo cache {} hits / {} misses; peak queue depth {}; \
             engine {}/{}",
            self.requests,
            self.samples_evaluated,
            self.batches,
            self.cache_hits,
            self.cache_misses,
            self.peak_queue,
            self.kernel_variant,
            self.precision
        )
    }
}

// ------------------------------------------------------------- promise

/// One-shot completion slot: the worker fulfills it, the caller waits on
/// it. Errors travel as `String` so one failed batch can fan out to every
/// affected caller (anyhow errors are not cloneable). Fulfillment is
/// idempotent (first value wins) so the worker's panic safety net can
/// blanket-fail a drained batch without clobbering results already
/// delivered.
struct Promise {
    slot: Mutex<Option<Result<PredictResponse, String>>>,
    ready: Condvar,
    done: std::sync::atomic::AtomicBool,
}

impl Promise {
    fn new() -> Promise {
        Promise {
            slot: Mutex::new(None),
            ready: Condvar::new(),
            done: std::sync::atomic::AtomicBool::new(false),
        }
    }

    fn fulfill(&self, value: Result<PredictResponse, String>) {
        if self.done.swap(true, Ordering::AcqRel) {
            return; // already fulfilled — first value wins
        }
        let mut slot = lock(&self.slot);
        *slot = Some(value);
        self.ready.notify_all();
    }

    fn wait(&self) -> Result<PredictResponse, String> {
        let mut slot = lock(&self.slot);
        loop {
            if let Some(v) = slot.take() {
                return v;
            }
            slot = self.ready.wait(slot).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Completion handle for a submitted request. [`PredictHandle::wait`]
/// blocks until the coalescer has answered (or failed) the request.
pub struct PredictHandle {
    promise: Arc<Promise>,
}

impl PredictHandle {
    pub fn wait(self) -> Result<PredictResponse> {
        self.promise.wait().map_err(|e| anyhow!(e))
    }
}

/// Poison-tolerant lock: a panicked *other* thread must not cascade into
/// panics here (the whole point of the service is that one caller's
/// failure stays contained).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ------------------------------------------------------------- service

struct Job {
    req: PredictRequest,
    promise: Arc<Promise>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
    /// Deepest `jobs` has ever been; maintained under the queue lock so
    /// the high-water mark is exact.
    peak: usize,
}

struct Shared {
    predictor: Arc<dyn Predictor>,
    cfg: ServiceConfig,
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cache: Mutex<HashMap<CacheKey, f64>>,
    requests: AtomicUsize,
    batches: AtomicUsize,
    samples_evaluated: AtomicUsize,
    cache_hits: AtomicUsize,
    cache_misses: AtomicUsize,
}

/// The shared, concurrency-first serving layer. See the module docs for
/// the architecture. The service itself implements [`Predictor`], so any
/// consumer written against `&dyn Predictor` (the eval harnesses, the
/// CLI) becomes a service client without code changes.
pub struct PredictService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl PredictService {
    /// Spawn the worker threads and return the ready service.
    pub fn spawn(predictor: Arc<dyn Predictor>, cfg: ServiceConfig) -> PredictService {
        let n_workers = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            predictor,
            cfg,
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false, peak: 0 }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cache: Mutex::new(HashMap::new()),
            requests: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            samples_evaluated: AtomicUsize::new(0),
            cache_hits: AtomicUsize::new(0),
            cache_misses: AtomicUsize::new(0),
        });
        let workers = (0..n_workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                crate::util::threadpool::spawn_named(format!("predict-worker-{i}"), move || {
                    worker_loop(&s)
                })
            })
            .collect();
        PredictService { shared, workers }
    }

    /// Convenience: spawn with the default configuration.
    pub fn with_defaults(predictor: Arc<dyn Predictor>) -> PredictService {
        PredictService::spawn(predictor, ServiceConfig::default())
    }

    /// Enqueue a request, blocking while the queue is full (backpressure).
    pub fn submit(&self, req: PredictRequest) -> Result<PredictHandle> {
        self.submit_inner(req, true)
    }

    /// Enqueue a request, failing immediately if the queue is full.
    pub fn try_submit(&self, req: PredictRequest) -> Result<PredictHandle> {
        self.submit_inner(req, false)
    }

    /// Submit and wait — the synchronous client path.
    pub fn predict_blocking(&self, req: PredictRequest) -> Result<PredictResponse> {
        self.submit(req)?.wait()
    }

    fn submit_inner(&self, req: PredictRequest, block: bool) -> Result<PredictHandle> {
        if !req.keys.is_empty() && req.keys.len() != req.samples.len() {
            bail!(
                "predict request has {} samples but {} cache keys",
                req.samples.len(),
                req.keys.len()
            );
        }
        let mut q = lock(&self.shared.queue);
        loop {
            if q.closed {
                bail!("predict service is shut down");
            }
            if q.jobs.len() < self.shared.cfg.queue_cap.max(1) {
                break;
            }
            if !block {
                bail!(
                    "predict service queue is full ({} requests)",
                    self.shared.cfg.queue_cap.max(1)
                );
            }
            q = self.shared.not_full.wait(q).unwrap_or_else(|e| e.into_inner());
        }
        let promise = Arc::new(Promise::new());
        q.jobs.push_back(Job { req, promise: Arc::clone(&promise) });
        q.peak = q.peak.max(q.jobs.len());
        drop(q);
        self.shared.requests.fetch_add(1, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        Ok(PredictHandle { promise })
    }

    /// Probe the shared cache without submitting. The cost bridge uses
    /// this to skip featurization for already-scored schedules.
    pub fn cache_lookup(&self, key: CacheKey) -> Option<f64> {
        if self.shared.cfg.cache_cap == 0 {
            return None;
        }
        let hit = lock(&self.shared.cache).get(&key).copied();
        if hit.is_some() {
            self.shared.cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    pub fn cache_len(&self) -> usize {
        lock(&self.shared.cache).len()
    }

    pub fn clear_cache(&self) {
        lock(&self.shared.cache).clear();
    }

    /// Snapshot of the monotonic counters (plus the served model's
    /// engine identity, so `STATS` lines show what numeric mode the
    /// process is actually running).
    pub fn stats(&self) -> ServiceStats {
        let peak_queue = lock(&self.shared.queue).peak;
        let engine = self.shared.predictor.engine_info();
        ServiceStats {
            requests: self.shared.requests.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            samples_evaluated: self.shared.samples_evaluated.load(Ordering::Relaxed),
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.shared.cache_misses.load(Ordering::Relaxed),
            peak_queue,
            kernel_variant: engine.kernel_variant,
            precision: engine.precision,
        }
    }

    /// The served model's name.
    pub fn model_name(&self) -> String {
        self.shared.predictor.name()
    }

    /// The configured queue bound, as enforced (zero is clamped to one) —
    /// the serving front-ends report it next to `peak_queue` in `STATS`.
    pub fn queue_cap(&self) -> usize {
        self.shared.cfg.queue_cap.max(1)
    }
}

impl Drop for PredictService {
    /// Close the queue, drain every accepted request, join the workers.
    fn drop(&mut self) {
        {
            let mut q = lock(&self.shared.queue);
            q.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// A service is itself a predictor (submit + wait per call), so
/// `&dyn Predictor` consumers become service clients transparently.
/// Requests are owned, so this path clones the samples once; callers on
/// a hot loop with huge sample sets can build owned [`PredictRequest`]s
/// themselves and keep the copies out of the loop.
impl Predictor for PredictService {
    fn name(&self) -> String {
        self.shared.predictor.name()
    }

    fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
        let owned: Vec<GraphSample> = samples.iter().copied().cloned().collect();
        Ok(self.predict_blocking(PredictRequest::new(owned))?.predictions)
    }

    fn save(&self, path: &Path) -> Result<()> {
        self.shared.predictor.save(path)
    }

    fn engine_info(&self) -> crate::predictor::EngineInfo {
        self.shared.predictor.engine_info()
    }
}

// ------------------------------------------------------------ coalescer

fn worker_loop(shared: &Shared) {
    loop {
        let jobs: Vec<Job> = {
            let mut q = lock(&shared.queue);
            loop {
                if !q.jobs.is_empty() {
                    break;
                }
                if q.closed {
                    return;
                }
                q = shared.not_empty.wait(q).unwrap_or_else(|e| e.into_inner());
            }
            let take = q.jobs.len().min(shared.cfg.max_coalesce.max(1));
            q.jobs.drain(..take).collect()
        };
        shared.not_full.notify_all();
        // safety net beyond the predict-level guard inside run_coalesced:
        // if *any* coalescer code unwinds (a panicking `name()`, a future
        // bookkeeping bug), fail whatever promises are still pending —
        // fulfill is idempotent, so delivered results are untouched — and
        // keep the worker alive for the next drain
        if catch_unwind(AssertUnwindSafe(|| run_coalesced(shared, &jobs))).is_err() {
            for job in &jobs {
                job.promise
                    .fulfill(Err("predict service worker panicked serving this batch".into()));
            }
        }
    }
}

/// Evaluate one drained set of requests: resolve cache hits, dedup
/// in-flight twins, run every remaining sample through **one**
/// `Predictor::predict` call, scatter the results back and memoize the
/// keyed ones.
fn run_coalesced(shared: &Shared, jobs: &[Job]) {
    let caching = shared.cfg.cache_cap > 0;
    let mut outs: Vec<Vec<f64>> =
        jobs.iter().map(|j| vec![f64::NAN; j.req.samples.len()]).collect();
    let mut hits: Vec<usize> = vec![0; jobs.len()];

    // gather the evaluation set (job index, sample index) per miss
    let mut eval_refs: Vec<&GraphSample> = Vec::new();
    let mut eval_slots: Vec<(usize, usize)> = Vec::new();
    let mut eval_keys: Vec<Option<CacheKey>> = Vec::new();
    // (job, sample, eval position) for in-flight duplicates
    let mut dup_slots: Vec<(usize, usize, usize)> = Vec::new();
    {
        let cache = lock(&shared.cache);
        let mut in_flight: HashMap<CacheKey, usize> = HashMap::new();
        for (ji, job) in jobs.iter().enumerate() {
            for (si, sample) in job.req.samples.iter().enumerate() {
                let key = job.req.keys.get(si).copied().flatten().filter(|_| caching);
                if let Some(k) = key {
                    if let Some(&v) = cache.get(&k) {
                        outs[ji][si] = v;
                        hits[ji] += 1;
                        continue;
                    }
                    if let Some(&pos) = in_flight.get(&k) {
                        dup_slots.push((ji, si, pos));
                        hits[ji] += 1;
                        continue;
                    }
                    in_flight.insert(k, eval_refs.len());
                }
                eval_slots.push((ji, si));
                eval_keys.push(key);
                eval_refs.push(sample);
            }
        }
    }
    let total_hits: usize = hits.iter().sum();
    if total_hits > 0 {
        shared.cache_hits.fetch_add(total_hits, Ordering::Relaxed);
    }
    // keyed samples that probed the cache and lost; counted into
    // `cache_misses` only once their evaluation succeeds (below), so a
    // failing batch does not inflate the miss count for keys that were
    // never memoized
    let keyed_misses = eval_keys.iter().flatten().count();

    let outcome: Result<Vec<f64>, String> = if eval_refs.is_empty() {
        Ok(Vec::new())
    } else {
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.samples_evaluated.fetch_add(eval_refs.len(), Ordering::Relaxed);
        // a panicking model must fail its callers, not kill the worker
        // (and with it every future request)
        match catch_unwind(AssertUnwindSafe(|| shared.predictor.predict(&eval_refs))) {
            Ok(Ok(p)) if p.len() == eval_refs.len() => Ok(p),
            Ok(Ok(p)) => Err(format!(
                "{} returned {} predictions for {} samples",
                shared.predictor.name(),
                p.len(),
                eval_refs.len()
            )),
            Ok(Err(e)) => Err(format!("{} inference failed: {e:#}", shared.predictor.name())),
            Err(_) => Err(format!("{} inference panicked", shared.predictor.name())),
        }
    };
    let model = shared.predictor.name();

    let preds = match outcome {
        Ok(preds) => preds,
        Err(msg) => {
            // the failed evaluation only dooms the jobs that needed it;
            // jobs answered entirely from the cache still succeed
            let mut needed = vec![false; jobs.len()];
            for &(ji, _) in &eval_slots {
                needed[ji] = true;
            }
            for &(ji, _, _) in &dup_slots {
                needed[ji] = true;
            }
            for (((job, out), h), job_needed) in jobs.iter().zip(outs).zip(hits).zip(needed) {
                if job_needed {
                    job.promise.fulfill(Err(msg.clone()));
                } else {
                    job.promise.fulfill(Ok(PredictResponse {
                        predictions: out,
                        model: model.clone(),
                        cache_hits: h,
                    }));
                }
            }
            return;
        }
    };

    for (pos, &(ji, si)) in eval_slots.iter().enumerate() {
        outs[ji][si] = preds[pos];
    }
    for &(ji, si, pos) in &dup_slots {
        outs[ji][si] = preds[pos];
    }
    if keyed_misses > 0 {
        shared.cache_misses.fetch_add(keyed_misses, Ordering::Relaxed);
    }

    // only keyed results enter the cache — size the wipe check on those,
    // so a large keyless batch cannot evict the shared memo entries
    let new_keyed = keyed_misses;
    if caching && new_keyed > 0 {
        let mut cache = lock(&shared.cache);
        if cache.len() + new_keyed > shared.cfg.cache_cap {
            // crude but bounded: a memo cache may be wiped at any time
            cache.clear();
        }
        for (key, &p) in eval_keys.iter().zip(&preds) {
            if let Some(k) = key {
                cache.insert(*k, p);
            }
        }
    }

    for ((job, out), h) in jobs.iter().zip(outs).zip(hits) {
        job.promise.fulfill(Ok(PredictResponse {
            predictions: out,
            model: model.clone(),
            cache_hits: h,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};

    /// n-stage chain sample with features derived from `tag` so distinct
    /// samples are distinguishable.
    fn chain_sample(n: u32, tag: f32) -> GraphSample {
        GraphSample {
            pipeline_id: tag as u32,
            schedule_id: n,
            n_stages: n,
            edges: (1..n).map(|i| (i - 1, i)).collect(),
            inv: vec![[tag; INV_DIM]; n as usize],
            dep: vec![[tag * 0.5; DEP_DIM]; n as usize],
            runs: [1e-3; BENCH_RUNS],
        }
    }

    /// Deterministic stand-in model: prediction = n_stages * scale.
    struct ConstPredictor {
        scale: f64,
    }

    impl Predictor for ConstPredictor {
        fn name(&self) -> String {
            "const".into()
        }
        fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
            Ok(samples.iter().map(|s| s.n_stages as f64 * self.scale).collect())
        }
        fn save(&self, _: &Path) -> Result<()> {
            bail!("const predictor cannot be saved")
        }
    }

    fn const_service(scale: f64) -> PredictService {
        PredictService::with_defaults(Arc::new(ConstPredictor { scale }))
    }

    /// Blocks inside `predict` until released; signals entry so tests can
    /// wait for the worker to be mid-flight deterministically.
    struct GatedPredictor {
        entered: Arc<(Mutex<usize>, Condvar)>,
        release: Arc<(Mutex<bool>, Condvar)>,
    }

    impl GatedPredictor {
        fn new() -> (GatedPredictor, Arc<(Mutex<usize>, Condvar)>, Arc<(Mutex<bool>, Condvar)>) {
            let entered = Arc::new((Mutex::new(0usize), Condvar::new()));
            let release = Arc::new((Mutex::new(false), Condvar::new()));
            let p = GatedPredictor { entered: Arc::clone(&entered), release: Arc::clone(&release) };
            (p, entered, release)
        }
    }

    impl Predictor for GatedPredictor {
        fn name(&self) -> String {
            "gated".into()
        }
        fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
            {
                let (m, c) = &*self.entered;
                *lock(m) += 1;
                c.notify_all();
            }
            let (m, c) = &*self.release;
            let mut open = lock(m);
            while !*open {
                open = c.wait(open).unwrap_or_else(|e| e.into_inner());
            }
            Ok(vec![1.0; samples.len()])
        }
        fn save(&self, _: &Path) -> Result<()> {
            bail!("gated predictor cannot be saved")
        }
    }

    // the tentpole's object-safety + thread-safety contract
    #[test]
    fn predictor_trait_objects_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync + ?Sized>() {}
        assert_send_sync::<dyn Predictor>();
        assert_send_sync::<dyn crate::runtime::Backend>();
        assert_send_sync::<PredictService>();
    }

    #[test]
    fn coalesced_requests_scatter_back_in_order() {
        let service = const_service(2.0);
        let a = service
            .submit(PredictRequest::new(vec![chain_sample(1, 0.1), chain_sample(3, 0.2)]))
            .unwrap();
        let b = service.submit(PredictRequest::new(vec![chain_sample(5, 0.3)])).unwrap();
        let ra = a.wait().unwrap();
        let rb = b.wait().unwrap();
        assert_eq!(ra.predictions, vec![2.0, 6.0]);
        assert_eq!(ra.model, "const");
        assert_eq!(rb.predictions, vec![10.0]);
        let stats = service.stats();
        assert!(stats.requests >= 2);
        // a plain predictor reports the default engine identity, and the
        // canonical counter JSON carries it
        assert_eq!(stats.kernel_variant, "scalar");
        assert_eq!(stats.precision, "f32");
        let j = stats.to_json().to_string();
        assert!(j.contains("\"kernel_variant\""), "{j}");
        assert!(j.contains("\"precision\""), "{j}");
        assert!(stats.summary_line().contains("engine scalar/f32"));
    }

    #[test]
    fn empty_request_resolves_immediately() {
        let service = const_service(1.0);
        let r = service.predict_blocking(PredictRequest::new(Vec::new())).unwrap();
        assert!(r.predictions.is_empty());
    }

    #[test]
    fn keyed_results_are_cached_and_shared() {
        let service = const_service(3.0);
        let k = cache_key(&["pipeline-x", "schedule-7"]);
        let req = PredictRequest::with_keys(vec![chain_sample(2, 0.5)], vec![Some(k)]);
        let r1 = service.predict_blocking(req.clone()).unwrap();
        assert_eq!(r1.cache_hits, 0);
        assert_eq!(service.cache_len(), 1);
        // second identical request: answered from the cache, no new batch
        let batches_before = service.stats().batches;
        let r2 = service.predict_blocking(req).unwrap();
        assert_eq!(r2.predictions, r1.predictions);
        assert_eq!(r2.cache_hits, 1);
        assert_eq!(service.stats().batches, batches_before);
        assert!(service.cache_lookup(k).is_some());
        service.clear_cache();
        assert_eq!(service.cache_len(), 0);
    }

    #[test]
    fn in_flight_duplicates_evaluate_once() {
        let service = const_service(1.0);
        let k = cache_key(&["dup"]);
        // one request carrying the same key twice: the coalescer must
        // evaluate a single representative
        let req = PredictRequest::with_keys(
            vec![chain_sample(4, 0.1), chain_sample(4, 0.1)],
            vec![Some(k), Some(k)],
        );
        let r = service.predict_blocking(req).unwrap();
        assert_eq!(r.predictions, vec![4.0, 4.0]);
        assert_eq!(r.cache_hits, 1, "the twin should dedup in flight");
        assert_eq!(service.stats().samples_evaluated, 1);
    }

    #[test]
    fn stats_report_peak_queue_depth() {
        // park the worker so queued requests pile up deterministically
        let (gated, entered, release) = GatedPredictor::new();
        let service = PredictService::spawn(
            Arc::new(gated),
            ServiceConfig { workers: 1, queue_cap: 16, ..Default::default() },
        );
        let h0 = service.submit(PredictRequest::new(vec![chain_sample(1, 0.0)])).unwrap();
        {
            let (m, c) = &*entered;
            let mut n = lock(m);
            while *n == 0 {
                n = c.wait(n).unwrap_or_else(|e| e.into_inner());
            }
        }
        // exactly 3 requests queue up behind the parked worker
        let handles: Vec<PredictHandle> = (0..3u32)
            .map(|i| {
                service.submit(PredictRequest::new(vec![chain_sample(2 + i, 0.0)])).unwrap()
            })
            .collect();
        assert_eq!(service.stats().peak_queue, 3);
        {
            let (m, c) = &*release;
            *lock(m) = true;
            c.notify_all();
        }
        h0.wait().unwrap();
        for h in handles {
            h.wait().unwrap();
        }
        assert_eq!(
            service.stats().peak_queue,
            3,
            "peak is a high-water mark, not the current depth"
        );
    }

    #[test]
    fn stress_keyed_traffic_accounts_hits_and_misses() {
        // concurrent clients hammer 5 distinct keys: every keyed sample
        // must be accounted as exactly one hit or one miss, and each
        // distinct key must be evaluated exactly once (workers = 1, so
        // drains are sequential and memoization races cannot double-count)
        let service = Arc::new(const_service(1.0));
        let n_threads = 6usize;
        let per_thread = 20usize;
        std::thread::scope(|scope| {
            for th in 0..n_threads {
                let svc = Arc::clone(&service);
                scope.spawn(move || {
                    for i in 0..per_thread {
                        let kix = (th + i) % 5;
                        let tag = kix.to_string();
                        let k = cache_key(&["stress", tag.as_str()]);
                        let req = PredictRequest::with_keys(
                            vec![chain_sample((1 + kix) as u32, 0.1)],
                            vec![Some(k)],
                        );
                        let r = svc.predict_blocking(req).unwrap();
                        assert_eq!(r.predictions, vec![(1 + kix) as f64]);
                    }
                });
            }
        });
        let stats = service.stats();
        let total = n_threads * per_thread;
        assert_eq!(stats.requests, total);
        assert_eq!(
            stats.cache_hits + stats.cache_misses,
            total,
            "every keyed sample is exactly one hit or one miss: {stats:?}"
        );
        assert_eq!(stats.cache_misses, 5, "each distinct key misses exactly once");
        assert_eq!(stats.samples_evaluated, 5);
        assert!(stats.peak_queue >= 1, "concurrent clients must have queued");
    }

    #[test]
    fn mismatched_keys_are_rejected() {
        let service = const_service(1.0);
        let bad = PredictRequest::with_keys(vec![chain_sample(1, 0.0)], vec![None, None]);
        assert!(service.submit(bad).is_err());
    }

    #[test]
    fn full_queue_backpressure_and_try_submit() {
        let (gated, entered, release) = GatedPredictor::new();
        let service = PredictService::spawn(
            Arc::new(gated),
            ServiceConfig { workers: 1, queue_cap: 2, ..Default::default() },
        );
        // first request: wait until the worker is inside predict, so the
        // queue is empty again and its capacity is exactly 2
        let h0 = service.submit(PredictRequest::new(vec![chain_sample(1, 0.0)])).unwrap();
        {
            let (m, c) = &*entered;
            let mut n = lock(m);
            while *n == 0 {
                n = c.wait(n).unwrap_or_else(|e| e.into_inner());
            }
        }
        let h1 = service.submit(PredictRequest::new(vec![chain_sample(2, 0.0)])).unwrap();
        let h2 = service.submit(PredictRequest::new(vec![chain_sample(3, 0.0)])).unwrap();
        // queue holds 2 requests — the bound — so a non-blocking submit
        // must fail with a helpful error
        let err = service
            .try_submit(PredictRequest::new(vec![chain_sample(4, 0.0)]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("full"), "{err}");
        // release the model; everything in flight completes
        {
            let (m, c) = &*release;
            *lock(m) = true;
            c.notify_all();
        }
        for h in [h0, h1, h2] {
            assert_eq!(h.wait().unwrap().predictions, vec![1.0]);
        }
    }

    #[test]
    fn drop_drains_accepted_requests() {
        let service = const_service(1.0);
        let handles: Vec<PredictHandle> = (0..16)
            .map(|i| {
                service
                    .submit(PredictRequest::new(vec![chain_sample(1 + (i % 5), 0.1)]))
                    .unwrap()
            })
            .collect();
        drop(service); // close + drain + join
        for h in handles {
            let r = h.wait().unwrap();
            assert_eq!(r.predictions.len(), 1);
            assert!(r.predictions[0].is_finite());
        }
    }

    #[test]
    fn submit_after_shutdown_fails_cleanly() {
        struct Hollow;
        impl Predictor for Hollow {
            fn name(&self) -> String {
                "hollow".into()
            }
            fn predict(&self, s: &[&GraphSample]) -> Result<Vec<f64>> {
                Ok(vec![0.0; s.len()])
            }
            fn save(&self, _: &Path) -> Result<()> {
                bail!("nope")
            }
        }
        let service = PredictService::with_defaults(Arc::new(Hollow));
        // simulate a caller holding the shared state across shutdown
        let shared = Arc::clone(&service.shared);
        drop(service);
        let orphan = PredictService { shared, workers: Vec::new() };
        let err = orphan
            .submit(PredictRequest::new(vec![chain_sample(1, 0.0)]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("shut down"), "{err}");
    }

    #[test]
    fn model_errors_fail_every_coalesced_caller_without_killing_the_worker() {
        struct Flaky;
        impl Predictor for Flaky {
            fn name(&self) -> String {
                "flaky".into()
            }
            fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
                if samples.iter().any(|s| s.n_stages == 13) {
                    bail!("unlucky batch");
                }
                Ok(vec![1.0; samples.len()])
            }
            fn save(&self, _: &Path) -> Result<()> {
                bail!("nope")
            }
        }
        let service = PredictService::with_defaults(Arc::new(Flaky));
        let bad = service.predict_blocking(PredictRequest::new(vec![chain_sample(13, 0.0)]));
        let msg = bad.unwrap_err().to_string();
        assert!(msg.contains("unlucky"), "{msg}");
        // the worker survives and serves the next request
        let good = service.predict_blocking(PredictRequest::new(vec![chain_sample(2, 0.0)]));
        assert_eq!(good.unwrap().predictions, vec![1.0]);
    }

    #[test]
    fn cache_hit_only_jobs_survive_a_failing_coalesced_batch() {
        // Gated so we can coalesce deterministically, poisoned on
        // n_stages == 13: a cached-only request drained together with a
        // failing one must still succeed.
        struct GatedFlaky {
            entered: Arc<(Mutex<usize>, Condvar)>,
            release: Arc<(Mutex<bool>, Condvar)>,
        }
        impl Predictor for GatedFlaky {
            fn name(&self) -> String {
                "gated-flaky".into()
            }
            fn predict(&self, samples: &[&GraphSample]) -> Result<Vec<f64>> {
                {
                    let (m, c) = &*self.entered;
                    *lock(m) += 1;
                    c.notify_all();
                }
                let (m, c) = &*self.release;
                let mut open = lock(m);
                while !*open {
                    open = c.wait(open).unwrap_or_else(|e| e.into_inner());
                }
                drop(open);
                if samples.iter().any(|s| s.n_stages == 13) {
                    bail!("poisoned batch");
                }
                Ok(samples.iter().map(|s| s.n_stages as f64).collect())
            }
            fn save(&self, _: &Path) -> Result<()> {
                bail!("nope")
            }
        }
        let entered = Arc::new((Mutex::new(0usize), Condvar::new()));
        let release = Arc::new((Mutex::new(true), Condvar::new()));
        let service = PredictService::spawn(
            Arc::new(GatedFlaky { entered: Arc::clone(&entered), release: Arc::clone(&release) }),
            ServiceConfig { workers: 1, ..Default::default() },
        );
        let k = cache_key(&["good"]);
        // prime the cache while the gate is open
        let keyed = PredictRequest::with_keys(vec![chain_sample(2, 0.3)], vec![Some(k)]);
        let primed = service.predict_blocking(keyed.clone()).unwrap();
        assert_eq!(primed.predictions, vec![2.0]);
        // close the gate and park the worker on an unrelated request
        *lock(&release.0) = false;
        let entered_before = *lock(&entered.0);
        let parked = service.submit(PredictRequest::new(vec![chain_sample(5, 0.0)])).unwrap();
        {
            let (m, c) = &*entered;
            let mut n = lock(m);
            while *n == entered_before {
                n = c.wait(n).unwrap_or_else(|e| e.into_inner());
            }
        }
        // these two queue up and will be drained together: one poisoned,
        // one answerable purely from the cache
        let bad = service.submit(PredictRequest::new(vec![chain_sample(13, 0.0)])).unwrap();
        let cached = service.submit(keyed).unwrap();
        {
            let (m, c) = &*release;
            *lock(m) = true;
            c.notify_all();
        }
        assert_eq!(parked.wait().unwrap().predictions, vec![5.0]);
        let err = bad.wait().unwrap_err().to_string();
        assert!(err.contains("poisoned"), "{err}");
        let ok = cached.wait().unwrap();
        assert_eq!(ok.predictions, vec![2.0], "cache-hit-only job must survive the bad batch");
        assert_eq!(ok.cache_hits, 1);
    }

    #[test]
    fn cache_key_separators_matter() {
        assert_ne!(cache_key(&["ab", "c"]), cache_key(&["a", "bc"]));
        assert_ne!(cache_key(&["x"]), cache_key(&["x", ""]));
        assert_eq!(cache_key(&["x", "y"]), cache_key(&["x", "y"]));
    }
}
