//! The real-world networks of Fig 9, built from the op set in
//! [`crate::ir`]: the paper's nine (resnet, mobilenet, shufflenet,
//! squeezenet, alexnet, vgg, unet, wavenet, a transformer block stack)
//! plus a deep bottleneck resnet that exceeds the old 48-stage cap.
//!
//! The nine paper networks are reduced ("-lite") variants that were
//! originally sized to the MAX_NODES = 48 stage budget the dense GCN
//! artifacts are padded to — the macro-structure (residual adds, fire
//! modules, channel shuffles, encoder-decoder skips, gated dilated
//! convs, attention) is preserved; block counts are trimmed. Input
//! resolutions are reduced accordingly (DESIGN.md §Substitutions).
//! [`resnet50`] deliberately breaks that budget: the sparse packed-batch
//! engine has no stage cap, and the zoo keeps one network past the old
//! limit so the whole train/predict/search stack is exercised beyond it
//! (only the pjrt dense path still refuses such graphs).

pub mod large;

#[cfg(test)]
use crate::constants::MAX_NODES;
use crate::ir::op::{Op, OpAttrs, OpKind};
use crate::ir::pipeline::{Pipeline, SourceRef};

/// Small builder wrapper so network definitions read like model code.
struct Net {
    p: Pipeline,
}

impl Net {
    fn new(name: &str) -> Net {
        Net { p: Pipeline::new(name) }
    }

    fn input(&mut self, shape: Vec<usize>) -> SourceRef {
        self.p.add_input(shape)
    }

    fn conv(&mut self, x: SourceRef, name: &str, out_c: usize, k: usize, stride: usize) -> SourceRef {
        let mut a = OpAttrs::default();
        a.kernel = (k, k);
        a.pad = k / 2;
        a.stride = stride;
        a.out_channels = out_c;
        self.p.add_stage(name, Op::with_attrs(OpKind::Conv2d, a), vec![x]).expect(name)
    }

    fn conv_hw(&mut self, x: SourceRef, name: &str, out_c: usize, kh: usize, kw: usize) -> SourceRef {
        let mut a = OpAttrs::default();
        a.kernel = (kh, kw);
        a.pad = 0;
        a.stride = 1;
        a.out_channels = out_c;
        self.p.add_stage(name, Op::with_attrs(OpKind::Conv2d, a), vec![x]).expect(name)
    }

    fn dwconv(&mut self, x: SourceRef, name: &str, k: usize) -> SourceRef {
        let mut a = OpAttrs::default();
        a.kernel = (k, k);
        a.pad = k / 2;
        a.stride = 1;
        self.p.add_stage(name, Op::with_attrs(OpKind::DepthwiseConv2d, a), vec![x]).expect(name)
    }

    fn bn(&mut self, x: SourceRef, name: &str) -> SourceRef {
        self.p.add_stage(name, Op::new(OpKind::BatchNorm), vec![x]).expect(name)
    }

    fn relu(&mut self, x: SourceRef, name: &str) -> SourceRef {
        self.p.add_stage(name, Op::new(OpKind::Relu), vec![x]).expect(name)
    }

    fn unary(&mut self, x: SourceRef, name: &str, kind: OpKind) -> SourceRef {
        self.p.add_stage(name, Op::new(kind), vec![x]).expect(name)
    }

    fn pool(&mut self, x: SourceRef, name: &str, k: usize) -> SourceRef {
        let mut a = OpAttrs::default();
        a.kernel = (k, k);
        a.stride = k;
        a.pad = 0;
        self.p.add_stage(name, Op::with_attrs(OpKind::MaxPool, a), vec![x]).expect(name)
    }

    fn gap(&mut self, x: SourceRef, name: &str) -> SourceRef {
        self.p.add_stage(name, Op::new(OpKind::GlobalAveragePool), vec![x]).expect(name)
    }

    fn add(&mut self, a: SourceRef, b: SourceRef, name: &str) -> SourceRef {
        self.p.add_stage(name, Op::new(OpKind::Add), vec![a, b]).expect(name)
    }

    fn mul(&mut self, a: SourceRef, b: SourceRef, name: &str) -> SourceRef {
        self.p.add_stage(name, Op::new(OpKind::Mul), vec![a, b]).expect(name)
    }

    fn flatten(&mut self, x: SourceRef, name: &str) -> SourceRef {
        let mut a = OpAttrs::default();
        a.axis = 1;
        self.p.add_stage(name, Op::with_attrs(OpKind::Flatten, a), vec![x]).expect(name)
    }

    fn gemm(&mut self, x: SourceRef, name: &str, out: usize) -> SourceRef {
        let mut a = OpAttrs::default();
        a.out_channels = out;
        self.p.add_stage(name, Op::with_attrs(OpKind::Gemm, a), vec![x]).expect(name)
    }

    fn concat(&mut self, a: SourceRef, b: SourceRef, name: &str, axis: usize) -> SourceRef {
        let mut at = OpAttrs::default();
        at.axis = axis;
        self.p.add_stage(name, Op::with_attrs(OpKind::Concat, at), vec![a, b]).expect(name)
    }

    fn transpose(&mut self, x: SourceRef, name: &str, perm: Vec<usize>) -> SourceRef {
        let mut at = OpAttrs::default();
        at.perm = perm;
        self.p.add_stage(name, Op::with_attrs(OpKind::Transpose, at), vec![x]).expect(name)
    }

    fn softmax(&mut self, x: SourceRef, name: &str, axis: usize) -> SourceRef {
        let mut at = OpAttrs::default();
        at.axis = axis;
        self.p.add_stage(name, Op::with_attrs(OpKind::Softmax, at), vec![x]).expect(name)
    }

    fn upsample(&mut self, x: SourceRef, name: &str) -> SourceRef {
        let a = OpAttrs::default(); // scale 2
        self.p.add_stage(name, Op::with_attrs(OpKind::Upsample, a), vec![x]).expect(name)
    }

    fn matmul(&mut self, a: SourceRef, b: SourceRef, name: &str) -> SourceRef {
        self.p.add_stage(name, Op::new(OpKind::MatMul), vec![a, b]).expect(name)
    }

    fn slice_to(&mut self, x: SourceRef, name: &str, axis: usize, num: usize, den: usize) -> SourceRef {
        let mut a = OpAttrs::default();
        a.axis = axis;
        a.slice_frac = (num, den);
        self.p.add_stage(name, Op::with_attrs(OpKind::Slice, a), vec![x]).expect(name)
    }

    /// conv → bn → relu, the ubiquitous block.
    fn cbr(&mut self, x: SourceRef, name: &str, out_c: usize, k: usize, stride: usize) -> SourceRef {
        let c = self.conv(x, &format!("{name}_conv"), out_c, k, stride);
        let b = self.bn(c, &format!("{name}_bn"));
        self.relu(b, &format!("{name}_relu"))
    }

    /// conv → relu.
    fn cr(&mut self, x: SourceRef, name: &str, out_c: usize, k: usize, stride: usize) -> SourceRef {
        let c = self.conv(x, &format!("{name}_conv"), out_c, k, stride);
        self.relu(c, &format!("{name}_relu"))
    }
}

// --------------------------------------------------------------- networks

pub fn alexnet() -> Pipeline {
    let mut n = Net::new("alexnet");
    let x = n.input(vec![1, 3, 64, 64]);
    let c1 = n.cr(x, "c1", 48, 7, 2);
    let p1 = n.pool(c1, "pool1", 2);
    let c2 = n.cr(p1, "c2", 96, 5, 1);
    let p2 = n.pool(c2, "pool2", 2);
    let c3 = n.cr(p2, "c3", 128, 3, 1);
    let c4 = n.cr(c3, "c4", 128, 3, 1);
    let c5 = n.cr(c4, "c5", 96, 3, 1);
    let p3 = n.pool(c5, "pool3", 2);
    let f = n.flatten(p3, "flatten");
    let g1 = n.gemm(f, "fc6", 512);
    let r1 = n.relu(g1, "relu6");
    let g2 = n.gemm(r1, "fc7", 256);
    let r2 = n.relu(g2, "relu7");
    let g3 = n.gemm(r2, "fc8", 100);
    n.softmax(g3, "softmax", 1);
    n.p
}

pub fn vgg16() -> Pipeline {
    let mut n = Net::new("vgg16");
    let x = n.input(vec![1, 3, 64, 64]);
    let mut cur = x;
    let blocks: &[(usize, usize)] = &[(32, 2), (64, 2), (128, 2), (128, 2)];
    for (bi, &(ch, reps)) in blocks.iter().enumerate() {
        for ci in 0..reps {
            cur = n.cr(cur, &format!("b{bi}c{ci}"), ch, 3, 1);
        }
        cur = n.pool(cur, &format!("pool{bi}"), 2);
    }
    let f = n.flatten(cur, "flatten");
    let g1 = n.gemm(f, "fc1", 512);
    let r1 = n.relu(g1, "fc1_relu");
    n.gemm(r1, "fc2", 100);
    n.p
}

pub fn resnet18() -> Pipeline {
    let mut n = Net::new("resnet18");
    let x = n.input(vec![1, 3, 56, 56]);
    let stem = n.cbr(x, "stem", 32, 7, 2);
    let mut cur = n.pool(stem, "stem_pool", 2);
    let mut ch = 32;
    for blk in 0..4 {
        if blk == 2 {
            ch *= 2;
            cur = n.conv(cur, &format!("down{blk}"), ch, 1, 1);
        }
        let c1 = n.cbr(cur, &format!("b{blk}a"), ch, 3, 1);
        let c2 = n.conv(c1, &format!("b{blk}b_conv"), ch, 3, 1);
        let b2 = n.bn(c2, &format!("b{blk}b_bn"));
        let res = n.add(b2, cur, &format!("b{blk}_add"));
        cur = n.relu(res, &format!("b{blk}_relu"));
    }
    let g = n.gap(cur, "gap");
    let f = n.flatten(g, "flatten");
    n.gemm(f, "fc", 100);
    n.p
}

/// Deep bottleneck resnet — the one zoo network past the old 48-stage
/// cap (59 stages): stem + 5 bottleneck blocks (1×1 reduce → 3×3 →
/// 1×1 expand, residual add) + head. Representable only by the sparse
/// packed-batch layout.
pub fn resnet50() -> Pipeline {
    let mut n = Net::new("resnet50");
    let x = n.input(vec![1, 3, 56, 56]);
    let stem = n.cbr(x, "stem", 32, 7, 2);
    let mut cur = n.pool(stem, "stem_pool", 2);
    let mut ch = 32;
    for blk in 0..5 {
        if blk == 2 {
            ch *= 2;
        }
        let expanded = ch * 2;
        // projection shortcut where the channel count changes
        let identity = if blk == 0 || blk == 2 {
            n.conv(cur, &format!("r{blk}_proj"), expanded, 1, 1)
        } else {
            cur
        };
        let c1 = n.cbr(cur, &format!("r{blk}a"), ch, 1, 1);
        let c2 = n.cbr(c1, &format!("r{blk}b"), ch, 3, 1);
        let c3 = n.conv(c2, &format!("r{blk}c_conv"), expanded, 1, 1);
        let b3 = n.bn(c3, &format!("r{blk}c_bn"));
        let res = n.add(b3, identity, &format!("r{blk}_add"));
        cur = n.relu(res, &format!("r{blk}_relu"));
    }
    let g = n.gap(cur, "gap");
    let f = n.flatten(g, "flatten");
    n.gemm(f, "fc", 100);
    n.p
}

pub fn squeezenet() -> Pipeline {
    let mut n = Net::new("squeezenet");
    let x = n.input(vec![1, 3, 56, 56]);
    let stem = n.cr(x, "stem", 48, 3, 2);
    let mut cur = n.pool(stem, "pool0", 2);
    for (fi, sq) in [16usize, 16, 24, 24].iter().enumerate() {
        let s = n.cr(cur, &format!("f{fi}s"), *sq, 1, 1);
        let e1 = n.cr(s, &format!("f{fi}e1"), sq * 2, 1, 1);
        let e3 = n.cr(s, &format!("f{fi}e3"), sq * 2, 3, 1);
        cur = n.concat(e1, e3, &format!("f{fi}cat"), 1);
        if fi == 1 {
            cur = n.pool(cur, "pool1", 2);
        }
    }
    let head = n.conv(cur, "head_conv", 100, 1, 1);
    n.gap(head, "gap");
    n.p
}

pub fn mobilenet_v2() -> Pipeline {
    let mut n = Net::new("mobilenet_v2");
    let x = n.input(vec![1, 3, 56, 56]);
    let mut cur = n.cbr(x, "stem", 16, 3, 2);
    let ch = 16;
    for blk in 0..3 {
        let ex = n.cbr(cur, &format!("m{blk}ex"), ch * 4, 1, 1);
        let dwc = n.dwconv(ex, &format!("m{blk}dw_conv"), 3);
        let dwb = n.bn(dwc, &format!("m{blk}dw_bn"));
        let dw = n.relu(dwb, &format!("m{blk}dw_relu"));
        let prc = n.conv(dw, &format!("m{blk}pr_conv"), ch, 1, 1);
        let pr = n.bn(prc, &format!("m{blk}pr_bn"));
        cur = n.add(pr, cur, &format!("m{blk}_add"));
    }
    let head = n.cr(cur, "head", 64, 1, 1);
    let g = n.gap(head, "gap");
    let f = n.flatten(g, "flatten");
    n.gemm(f, "fc", 100);
    n.p
}

pub fn shufflenet() -> Pipeline {
    let mut n = Net::new("shufflenet");
    let x = n.input(vec![1, 3, 56, 56]);
    let stem = n.cr(x, "stem", 24, 3, 2);
    let mut cur = n.pool(stem, "stem_pool", 2);
    for blk in 0..3 {
        let c1 = n.cbr(cur, &format!("s{blk}a"), 24, 1, 1);
        // channel shuffle ≈ transpose (C,H) and back in our IR
        let sh = n.transpose(c1, &format!("s{blk}_shuffle"), vec![0, 2, 1, 3]);
        let sh2 = n.transpose(sh, &format!("s{blk}_unshuffle"), vec![0, 2, 1, 3]);
        let dwc = n.dwconv(sh2, &format!("s{blk}dw_conv"), 3);
        let dw = n.bn(dwc, &format!("s{blk}dw_bn"));
        let c2c = n.conv(dw, &format!("s{blk}b_conv"), 24, 1, 1);
        let c2 = n.bn(c2c, &format!("s{blk}b_bn"));
        let res = n.add(c2, cur, &format!("s{blk}_add"));
        cur = n.relu(res, &format!("s{blk}_relu"));
    }
    let g = n.gap(cur, "gap");
    let f = n.flatten(g, "flatten");
    n.gemm(f, "fc", 100);
    n.p
}

pub fn unet() -> Pipeline {
    let mut n = Net::new("unet");
    let x = n.input(vec![1, 3, 64, 64]);
    let e1 = n.cr(x, "e1a", 16, 3, 1);
    let e1b = n.cr(e1, "e1b", 16, 3, 1);
    let d1 = n.pool(e1b, "down1", 2);
    let e2 = n.cr(d1, "e2a", 32, 3, 1);
    let e2b = n.cr(e2, "e2b", 32, 3, 1);
    let d2 = n.pool(e2b, "down2", 2);
    let b = n.cr(d2, "bott", 64, 3, 1);
    let u2 = n.upsample(b, "up2");
    let cat2 = n.concat(u2, e2b, "cat2", 1);
    let dc2 = n.cr(cat2, "d2a", 32, 3, 1);
    let dc2b = n.cr(dc2, "d2b", 32, 3, 1);
    let u1 = n.upsample(dc2b, "up1");
    let cat1 = n.concat(u1, e1b, "cat1", 1);
    let dc1 = n.cr(cat1, "d1a", 16, 3, 1);
    let dc1b = n.cr(dc1, "d1b", 16, 3, 1);
    n.conv(dc1b, "out_conv", 1, 1, 1);
    n.p
}

pub fn wavenet() -> Pipeline {
    let mut n = Net::new("wavenet");
    // 1-D audio as [1, C, 1, T]; causal convs shrink T by kw-1 per layer
    let x = n.input(vec![1, 16, 1, 256]);
    let mut cur = n.conv_hw(x, "in_conv", 24, 1, 2);
    let mut skip: Option<SourceRef> = None;
    for blk in 0..4 {
        let f = n.conv_hw(cur, &format!("w{blk}f"), 24, 1, 2);
        let filt = n.unary(f, &format!("w{blk}tanh"), OpKind::Tanh);
        let g = n.conv_hw(cur, &format!("w{blk}g"), 24, 1, 2);
        let gate = n.unary(g, &format!("w{blk}sig"), OpKind::Sigmoid);
        let gated = n.mul(filt, gate, &format!("w{blk}mul"));
        let res = n.conv_hw(gated, &format!("w{blk}res"), 24, 1, 1);
        skip = Some(match skip {
            None => res,
            Some(s) => {
                let s_t = n.p.shape_of(s)[3];
                let r_t = n.p.shape_of(res)[3];
                let cut = if s_t != r_t {
                    n.slice_to(s, &format!("w{blk}cut"), 3, r_t, s_t)
                } else {
                    s
                };
                n.add(cut, res, &format!("w{blk}skip"))
            }
        });
        cur = res;
    }
    let sk = skip.unwrap();
    let r = n.relu(sk, "post_relu");
    let h = n.conv_hw(r, "post_conv", 32, 1, 1);
    let r2 = n.relu(h, "post_relu2");
    n.conv_hw(r2, "out_conv", 16, 1, 1);
    n.p
}

pub fn transformer() -> Pipeline {
    let mut n = Net::new("transformer");
    let (t, d) = (64usize, 128usize);
    let x = n.input(vec![t, d]);
    let mut cur = x;
    for blk in 0..2 {
        let ln = n.unary(cur, &format!("t{blk}_ln1"), OpKind::LayerNorm);
        let q = n.gemm(ln, &format!("t{blk}_q"), d);
        let k = n.gemm(ln, &format!("t{blk}_k"), d);
        let v = n.gemm(ln, &format!("t{blk}_v"), d);
        let kt = n.transpose(k, &format!("t{blk}_kt"), vec![1, 0]);
        let scores = n.matmul(q, kt, &format!("t{blk}_qk"));
        let attn = n.softmax(scores, &format!("t{blk}_sm"), 1);
        let ctx = n.matmul(attn, v, &format!("t{blk}_av"));
        let proj = n.gemm(ctx, &format!("t{blk}_proj"), d);
        let res1 = n.add(proj, cur, &format!("t{blk}_add1"));
        let ln2 = n.unary(res1, &format!("t{blk}_ln2"), OpKind::LayerNorm);
        let f1 = n.gemm(ln2, &format!("t{blk}_ff1"), d * 2);
        let fr = n.relu(f1, &format!("t{blk}_ffr"));
        let f2 = n.gemm(fr, &format!("t{blk}_ff2"), d);
        cur = n.add(f2, res1, &format!("t{blk}_add2"));
    }
    n.gemm(cur, "head", 100);
    n.p
}

/// Look a zoo network up by its pipeline name (e.g. `"unet"`).
pub fn by_name(name: &str) -> Option<Pipeline> {
    all_networks().into_iter().find(|p| p.name == name)
}

/// All zoo networks: the nine Fig 9 networks plus the >48-stage
/// [`resnet50`].
pub fn all_networks() -> Vec<Pipeline> {
    vec![
        resnet18(),
        mobilenet_v2(),
        shufflenet(),
        squeezenet(),
        alexnet(),
        vgg16(),
        unet(),
        wavenet(),
        transformer(),
        resnet50(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_networks_valid_and_sized() {
        for net in all_networks() {
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
            if net.name == "resnet50" {
                // deliberately past the old dense cap — the sparse layout
                // has no limit, and the zoo keeps one such network
                assert!(
                    net.num_stages() > MAX_NODES,
                    "resnet50 must exceed the old {MAX_NODES}-stage cap, has {}",
                    net.num_stages()
                );
            } else {
                assert!(
                    net.num_stages() <= MAX_NODES,
                    "{} has {} stages > {MAX_NODES} (pjrt-compatible lite variant)",
                    net.name,
                    net.num_stages()
                );
            }
            assert!(net.depth() >= 5, "{} depth {} < 5", net.name, net.depth());
        }
    }

    #[test]
    fn ten_distinct_networks() {
        let nets = all_networks();
        assert_eq!(nets.len(), 10);
        let names: std::collections::BTreeSet<&str> =
            nets.iter().map(|n| n.name.as_str()).collect();
        assert_eq!(names.len(), 10);
    }

    #[test]
    fn residual_networks_have_joins() {
        for net in [resnet18(), mobilenet_v2(), shufflenet()] {
            let has_join = net.stages.iter().any(|s| {
                s.op.kind == OpKind::Add
                    && s.inputs
                        .iter()
                        .all(|i| matches!(i, crate::ir::pipeline::SourceRef::Stage(_)))
            });
            assert!(has_join, "{} lacks residual joins", net.name);
        }
    }

    #[test]
    fn networks_lower_and_schedule() {
        use crate::lower::lower_pipeline;
        use crate::schedule::random::random_pipeline_schedule;
        use crate::sim::{simulate, Machine};
        use crate::util::rng::Rng;
        let m = Machine::default();
        let mut rng = Rng::new(5);
        for net in all_networks() {
            let nests = lower_pipeline(&net);
            let sched = random_pipeline_schedule(&net, &nests, &mut rng);
            let t = simulate(&net, &nests, &sched, &m);
            assert!(t.is_finite() && t > 0.0, "{}: t = {t}", net.name);
        }
    }
}
