//! TpuGraphs-scale synthetic graphs (1k–100k stages).
//!
//! The zoo's real networks top out around 60 stages — big enough to
//! exercise the model, three orders of magnitude short of the TpuGraphs
//! regime the paper's lineage targets. This module generates stage
//! graphs at 1k/10k/100k nodes with two topology styles:
//!
//! * [`LargeStyle::Transformer`] — repeated 12-stage attention blocks
//!   (qkv fan-out, two residual adds) chained end to end, the
//!   "deep repeated structure" shape;
//! * [`LargeStyle::Inception`] — repeated 10-stage groups of one stem
//!   fanning into 8 parallel branches re-joined by a concat, the
//!   "wide fan-out" shape.
//!
//! Both emit only local edges (within a block, or to the previous
//! block's output), so block-aligned partitioning cuts a small, bounded
//! fraction of edges — the property `model::partition`'s approximation
//! leans on. Features and runtimes are deterministic in
//! `(seed, pipeline, schedule)`: features are seeded pseudo-random
//! (invariant features depend on the pipeline only, dependent features
//! on pipeline + schedule, mirroring the real featurizer's split), and
//! runtimes are a simulated O(n) per-stage cost sum times a
//! per-schedule factor plus per-run noise.
//!
//! [`write_large_corpus`] streams samples straight into a sharded
//! corpus (one sample resident at a time — generating a 100k-stage
//! corpus never holds it in RAM); [`build_large_dataset`] collects the
//! small tiers in-RAM for parity benches. [`large_pipeline`] produces
//! an *IR* pipeline of the same scale for the analyzer scaling guards.

use crate::constants::{BENCH_RUNS, DEP_DIM, INV_DIM};
use crate::dataset::sample::{Dataset, GraphSample};
use crate::dataset::shard::ShardWriter;
use crate::features::normalize::StatsAccumulator;
use crate::ir::pipeline::Pipeline;
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// Topology family of a generated graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LargeStyle {
    Transformer,
    Inception,
}

impl LargeStyle {
    pub fn parse(s: &str) -> Option<LargeStyle> {
        match s {
            "transformer" => Some(LargeStyle::Transformer),
            "inception" => Some(LargeStyle::Inception),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LargeStyle::Transformer => "transformer",
            LargeStyle::Inception => "inception",
        }
    }
}

/// Generator configuration. `n_stages` is exact — blocks repeat while
/// they fit and a plain chain fills the tail.
#[derive(Debug, Clone)]
pub struct LargeConfig {
    pub style: LargeStyle,
    pub n_stages: usize,
    pub n_pipelines: u32,
    pub schedules_per_pipeline: u32,
    pub seed: u64,
}

impl Default for LargeConfig {
    fn default() -> Self {
        LargeConfig {
            style: LargeStyle::Transformer,
            n_stages: 1_000,
            n_pipelines: 2,
            schedules_per_pipeline: 4,
            seed: 42,
        }
    }
}

/// Stage 0 is the embed/input stage; blocks of 12 follow:
/// ln → {q,k,v} → score(q,k) → softmax → attn(·,v) → proj →
/// +residual → ln → mlp → +residual. All edges stay inside the block
/// except the two taps on the previous block's output.
fn transformer_edges(n: usize) -> Vec<(u32, u32)> {
    let mut e = Vec::with_capacity(n + n / 3);
    let mut prev_out = 0u32;
    let mut s = 1usize;
    while s + 12 <= n {
        let b = s as u32;
        e.push((prev_out, b)); // ln1
        e.push((b, b + 1)); // q
        e.push((b, b + 2)); // k
        e.push((b, b + 3)); // v
        e.push((b + 1, b + 4)); // score ← q
        e.push((b + 2, b + 4)); // score ← k
        e.push((b + 4, b + 5)); // softmax
        e.push((b + 5, b + 6)); // attn ← weights
        e.push((b + 3, b + 6)); // attn ← v
        e.push((b + 6, b + 7)); // proj
        e.push((b + 7, b + 8)); // res1 ← proj
        e.push((prev_out, b + 8)); // res1 ← block input
        e.push((b + 8, b + 9)); // ln2
        e.push((b + 9, b + 10)); // mlp
        e.push((b + 10, b + 11)); // res2 ← mlp
        e.push((b + 8, b + 11)); // res2 ← res1
        prev_out = b + 11;
        s += 12;
    }
    for i in s..n {
        e.push((prev_out, i as u32));
        prev_out = i as u32;
    }
    e
}

/// Stage 0 is the input; groups of 10 follow: one stem fans into 8
/// parallel branches, all re-joined by a concat.
fn inception_edges(n: usize) -> Vec<(u32, u32)> {
    let mut e = Vec::with_capacity(2 * n);
    let mut prev_out = 0u32;
    let mut s = 1usize;
    while s + 10 <= n {
        let b = s as u32;
        e.push((prev_out, b)); // stem
        for k in 1..=8u32 {
            e.push((b, b + k)); // branch
            e.push((b + k, b + 9)); // concat
        }
        prev_out = b + 9;
        s += 10;
    }
    for i in s..n {
        e.push((prev_out, i as u32));
        prev_out = i as u32;
    }
    e
}

/// One deterministic sample: topology from the style, features seeded by
/// `(seed, pid)` (invariant) and `(seed, pid, sid)` (dependent),
/// runtimes an O(n) simulated cost.
pub fn large_sample(cfg: &LargeConfig, pid: u32, sid: u32) -> GraphSample {
    let n = cfg.n_stages.max(2);
    let edges = match cfg.style {
        LargeStyle::Transformer => transformer_edges(n),
        LargeStyle::Inception => inception_edges(n),
    };
    let mut inv_rng = Rng::new(cfg.seed ^ 0x1A26E5EED ^ ((pid as u64) << 20));
    let mut dep_rng =
        Rng::new(cfg.seed ^ 0xDE9B0B ^ ((pid as u64) << 20) ^ ((sid as u64) + 1));
    let mut inv = vec![[0f32; INV_DIM]; n];
    let mut dep = vec![[0f32; DEP_DIM]; n];
    // simulated cost: each stage contributes a feature-correlated amount,
    // so runtime mass really is ~proportional to node count (the node-
    // share assumption the partition labels make)
    let mut cost = 0f64;
    for st in 0..n {
        for v in inv[st].iter_mut() {
            *v = inv_rng.f32() * 2.0 - 1.0;
        }
        for v in dep[st].iter_mut() {
            *v = dep_rng.f32() * 2.0 - 1.0;
        }
        cost += 1e-7 * (1.0 + inv[st][0].abs() as f64 + 0.5 * dep[st][0].abs() as f64);
    }
    // per-schedule speed factor and per-run measurement noise, both from
    // the schedule-dependent stream (deterministic in (seed, pid, sid))
    let factor = 1.0 + 0.8 * dep_rng.f64();
    let mut runs = [0f32; BENCH_RUNS];
    for r in &mut runs {
        *r = (cost * factor * (1.0 + 0.02 * (dep_rng.f64() - 0.5))) as f32;
    }
    GraphSample {
        pipeline_id: pid,
        schedule_id: sid,
        n_stages: n as u32,
        edges,
        inv,
        dep,
        runs,
    }
}

/// Generate the corpus straight into a sharded directory (see
/// [`crate::dataset::shard`]): one sample in memory at a time, corpus
/// feature stats folded incrementally into the index. Returns the
/// sample count.
pub fn write_large_corpus(dir: &Path, cfg: &LargeConfig) -> Result<usize> {
    let mut w = ShardWriter::create(dir)?;
    let mut acc = StatsAccumulator::new();
    for pid in 0..cfg.n_pipelines {
        for sid in 0..cfg.schedules_per_pipeline {
            let s = large_sample(cfg, pid, sid);
            for (iv, dv) in s.inv.iter().zip(&s.dep) {
                acc.push(iv, dv);
            }
            w.push(&s)?;
        }
    }
    let n = w.len();
    let stats = if acc.count() > 0 { Some(acc.finish()) } else { None };
    w.finish(stats.as_ref())?;
    Ok(n)
}

/// In-RAM counterpart of [`write_large_corpus`] for the small tiers and
/// the in-RAM-vs-streamed parity lanes.
pub fn build_large_dataset(cfg: &LargeConfig) -> Dataset {
    let mut ds = Dataset::default();
    for pid in 0..cfg.n_pipelines {
        for sid in 0..cfg.schedules_per_pipeline {
            ds.samples.push(large_sample(cfg, pid, sid));
        }
    }
    ds.fit_stats();
    ds
}

/// An *IR* pipeline with exactly `n_stages` stages (residual
/// bn→relu→add blocks over a conv stem, chain tail) — the fixture the
/// analyzer scaling guards run `analyze_pipeline` /
/// `AnalyzedPipeline::build` against at 1k–10k stages.
pub fn large_pipeline(n_stages: usize) -> Pipeline {
    let n_stages = n_stages.max(2);
    let mut net = super::Net::new("large-synth");
    let x = net.input(vec![1, 8, 16, 16]);
    let mut cur = net.conv(x, "stem", 8, 3, 1);
    let mut count = 1usize;
    while count + 3 <= n_stages {
        let saved = cur;
        let a = net.bn(cur, &format!("bn{count}"));
        let b = net.relu(a, &format!("relu{count}"));
        cur = net.add(b, saved, &format!("res{count}"));
        count += 3;
    }
    while count < n_stages {
        cur = net.relu(cur, &format!("tail{count}"));
        count += 1;
    }
    net.p
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn samples_are_valid_exact_sized_and_deterministic() {
        for style in [LargeStyle::Transformer, LargeStyle::Inception] {
            let cfg = LargeConfig { style, n_stages: 1_000, ..Default::default() };
            let s = large_sample(&cfg, 0, 0);
            assert_eq!(s.n_stages, 1_000);
            s.validate().unwrap();
            // deterministic in (seed, pid, sid)
            let again = large_sample(&cfg, 0, 0);
            assert_eq!(s.edges, again.edges);
            assert_eq!(s.inv, again.inv);
            assert_eq!(s.runs, again.runs);
            // schedule changes dependent features + runtimes, not topology
            let other = large_sample(&cfg, 0, 1);
            assert_eq!(s.edges, other.edges);
            assert_eq!(s.inv, other.inv);
            assert_ne!(s.dep, other.dep);
            assert_ne!(s.runs, other.runs);
            // different pipeline: different invariant features
            let p1 = large_sample(&cfg, 1, 0);
            assert_ne!(s.inv, p1.inv);
        }
    }

    #[test]
    fn edges_are_local_enough_for_block_partitioning() {
        for style in [LargeStyle::Transformer, LargeStyle::Inception] {
            let cfg = LargeConfig { style, n_stages: 4_096, ..Default::default() };
            let s = large_sample(&cfg, 0, 0);
            let p = crate::model::partition::partition_sample(&s, 512);
            assert!(p.parts.len() >= 8);
            // local topology ⇒ only a handful of edges span any boundary
            assert!(
                p.cut_edge_fraction() < 0.02,
                "{} cut fraction {:.4}",
                style.name(),
                p.cut_edge_fraction()
            );
            for q in &p.parts {
                q.validate().unwrap();
            }
        }
    }

    #[test]
    fn corpus_streams_to_shards_and_back() {
        let cfg = LargeConfig {
            n_stages: 200,
            n_pipelines: 2,
            schedules_per_pipeline: 3,
            ..Default::default()
        };
        let dir = std::env::temp_dir().join("gcn_perf_large_corpus");
        std::fs::remove_dir_all(&dir).ok();
        let n = write_large_corpus(&dir, &cfg).unwrap();
        assert_eq!(n, 6);
        let sd = crate::dataset::shard::ShardedDataset::open(&dir).unwrap();
        assert_eq!(sd.len(), 6);
        let ds = build_large_dataset(&cfg);
        // the streamed write and the in-RAM build see the same samples
        // and fold the same corpus stats (identical op order)
        assert_eq!(
            sd.stats().unwrap().to_flat(),
            ds.stats.as_ref().unwrap().to_flat()
        );
        let got = sd.fetch(3).unwrap();
        assert_eq!(got.dep, ds.samples[3].dep);
        assert_eq!(got.runs, ds.samples[3].runs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn large_pipeline_has_exact_stage_count_and_is_clean() {
        for n in [2usize, 50, 1_000] {
            let p = large_pipeline(n);
            assert_eq!(p.num_stages(), n, "requested {n}");
        }
        let p = large_pipeline(300);
        let diags = crate::analysis::analyze_pipeline(&p);
        assert!(diags.is_empty(), "{diags:?}");
    }

    /// The scaling guard: `analysis::structure`'s reachability/dead-stage
    /// scans and `analysis::analyzed`'s table construction must stay
    /// O(V+E) — a 10k-stage pipeline may cost ~10× a 1k-stage one, never
    /// ~100× (quadratic). Generously bounded for loaded CI runners.
    #[test]
    fn analysis_passes_scale_linearly_to_10k_stages() {
        let run = |n: usize| -> Duration {
            let p = large_pipeline(n);
            let t = Instant::now();
            let diags = crate::analysis::analyze_pipeline(&p);
            let nests = crate::lower::lower_pipeline(&p);
            let ap = crate::analysis::AnalyzedPipeline::build(&p, &nests);
            std::hint::black_box(&ap);
            assert!(diags.is_empty());
            t.elapsed()
        };
        run(1_000); // warm-up, untimed
        let t1k = run(1_000).max(Duration::from_millis(2));
        let t10k = run(10_000);
        assert!(
            t10k < t1k * 30,
            "10k-stage analysis took {t10k:?} vs {t1k:?} at 1k — quadratic blowup?"
        );
    }
}
