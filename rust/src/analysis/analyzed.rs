//! Precomputed per-pipeline analysis tables and the schedule-verification
//! pass built on them.
//!
//! [`AnalyzedPipeline::build`] walks a pipeline + its lowered loop nests
//! once and captures everything per-candidate legality needs — spatial
//! extents, inlinability, the consumer table, output-buffer sizes. After
//! that, [`AnalyzedPipeline::check_schedule`] is pure table lookups: no
//! consumer-list reallocation per candidate, which is what makes it the
//! search-side fast path ([`crate::autotune::BeamStrategy`] and
//! [`crate::autotune::EvolutionStrategy`] build one per pipeline and the
//! `analysis` micro-bench in [`crate::eval`] records the throughput
//! delta vs the legacy per-call [`crate::schedule::legality`] path).
//!
//! Two entry points with one rule set:
//!
//! * [`AnalyzedPipeline::check_schedule`] — first error only, `Result`
//!   (exact accept/reject twin of `legality::check_pipeline`, which is
//!   now a shim over it; property-pinned).
//! * [`AnalyzedPipeline::verify_schedule`] — *all* `S0xx` violations as
//!   diagnostics, for the `gcn-perf analyze` renderers.

use crate::analysis::diag::{Code, Diagnostic};
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};

/// Per-stage facts the schedule checks consult.
#[derive(Debug, Clone)]
pub struct StageInfo {
    /// Op kind name, for diagnostics.
    pub opname: &'static str,
    /// Spatial loop extents (= output shape).
    pub spatial: Vec<usize>,
    /// True when the stage may be inlined (pointwise, no reduction).
    pub inlinable: bool,
    /// Stage ids that consume this stage's output.
    pub consumers: Vec<usize>,
    /// Bytes of the stage's output buffer at compute_root.
    pub out_bytes: f64,
}

/// A pipeline with its dependence/legality tables computed once.
#[derive(Debug, Clone)]
pub struct AnalyzedPipeline {
    stages: Vec<StageInfo>,
}

impl AnalyzedPipeline {
    /// Precompute the tables from a pipeline and its lowered nests.
    pub fn build(p: &Pipeline, nests: &[LoopNest]) -> AnalyzedPipeline {
        debug_assert_eq!(p.num_stages(), nests.len(), "nests must match the pipeline");
        let consumers = p.consumers();
        let stages = p
            .stages
            .iter()
            .zip(nests)
            .zip(consumers)
            .map(|((s, nest), cons)| StageInfo {
                opname: s.op.kind.name(),
                spatial: nest.spatial.clone(),
                inlinable: nest.pointwise && nest.reduction.is_empty(),
                consumers: cons,
                out_bytes: nest.out_bytes,
            })
            .collect();
        AnalyzedPipeline { stages }
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn stage(&self, i: usize) -> &StageInfo {
        &self.stages[i]
    }

    pub fn stage_opt(&self, i: usize) -> Option<&StageInfo> {
        self.stages.get(i)
    }

    /// Consumer ids of stage `i` — identical values to
    /// `Pipeline::consumers()[i]`, without the per-call allocation.
    pub fn consumers(&self, i: usize) -> &[usize] {
        &self.stages[i].consumers
    }

    /// Fast single-candidate legality: first violation as a [`Diagnostic`].
    ///
    /// Accept/reject-equivalent to the legacy `legality::check_pipeline`
    /// (which delegates here); rule order matches the historical checks so
    /// the *first* error is the same rule too.
    pub fn check_schedule(&self, sched: &PipelineSchedule) -> Result<(), Diagnostic> {
        if sched.stages.len() != self.stages.len() {
            return Err(Diagnostic::new(
                Code::ScheduleLenMismatch,
                format!(
                    "schedule covers {} stages, pipeline has {}",
                    sched.stages.len(),
                    self.stages.len()
                ),
            ));
        }
        for (i, s) in sched.stages.iter().enumerate() {
            self.check_stage_fast(i, s, &sched.stages)?;
        }
        Ok(())
    }

    fn check_stage_fast(
        &self,
        i: usize,
        s: &StageSchedule,
        all: &[StageSchedule],
    ) -> Result<(), Diagnostic> {
        let info = &self.stages[i];
        let rank = info.spatial.len();
        let fail = |code: Code, msg: String| -> Result<(), Diagnostic> {
            Err(Diagnostic::at_stage(code, i, info.opname, msg))
        };
        if s.order.len() != rank {
            return fail(
                Code::OrderNotPermutation,
                format!("order len {} != rank {rank}", s.order.len()),
            );
        }
        // ranks are tiny (tensor ranks), so a u64 bitmask replaces the
        // legacy `vec![false; rank]` seen-set without allocating
        debug_assert!(rank < 64);
        let mut seen = 0u64;
        for &d in &s.order {
            if d >= rank || seen & (1 << d) != 0 {
                return fail(
                    Code::OrderNotPermutation,
                    format!("order {:?} is not a permutation", s.order),
                );
            }
            seen |= 1 << d;
        }
        if s.tile.len() != rank {
            return fail(Code::BadTile, format!("tile len {} != rank {rank}", s.tile.len()));
        }
        if s.tile.iter().any(|&f| f == 0) {
            return fail(Code::BadTile, "zero split factor".into());
        }
        match s.vector_width {
            1 | 4 | 8 => {}
            w => return fail(Code::BadVectorWidth, format!("unsupported vector width {w}")),
        }
        if s.vector_width > 1 {
            let Some(inner) = s.innermost_dim() else {
                return fail(Code::VectorExceedsExtent, "vectorize on rank-0 stage".into());
            };
            let extent =
                if s.tile[inner] > 1 { s.tile[inner] } else { info.spatial[inner] };
            if extent < s.vector_width {
                return fail(
                    Code::VectorExceedsExtent,
                    format!("vector width {} exceeds innermost extent {extent}", s.vector_width),
                );
            }
        }
        match s.unroll {
            1 | 2 | 4 | 8 => {}
            u => return fail(Code::BadUnroll, format!("unsupported unroll factor {u}")),
        }
        let n_loops = s.loop_extents(&info.spatial).len();
        if s.parallel_depth > n_loops.min(3) {
            return fail(
                Code::ParallelTooDeep,
                format!("parallel depth {} exceeds limit (loops={n_loops})", s.parallel_depth),
            );
        }
        match s.compute {
            ComputeLoc::Root => {}
            ComputeLoc::Inline => {
                if !info.inlinable {
                    return fail(Code::InlineNonPointwise, "inline of non-pointwise stage".into());
                }
                if info.consumers.is_empty() {
                    return fail(Code::InlineOutputStage, "inline of an output stage".into());
                }
            }
            ComputeLoc::At { consumer, level } => {
                if !info.consumers.contains(&consumer) {
                    return fail(
                        Code::ComputeAtNonConsumer,
                        format!("compute_at non-consumer {consumer}"),
                    );
                }
                if consumer < all.len() && matches!(all[consumer].compute, ComputeLoc::Inline) {
                    return fail(Code::ComputeAtInlined, "compute_at an inlined consumer".into());
                }
                if level == 0 || level > 3 {
                    return fail(
                        Code::ComputeAtBadLevel,
                        format!("compute_at level {level} out of range"),
                    );
                }
            }
        }
        Ok(())
    }

    /// Full verification: every `S0xx` violation in the schedule, not just
    /// the first. Dependent rules are guarded (e.g. the vector-extent rule
    /// is only evaluated once order and tile are individually valid), so a
    /// single root cause does not cascade into spurious findings.
    pub fn verify_schedule(&self, sched: &PipelineSchedule) -> Vec<Diagnostic> {
        let mut out = Vec::new();
        if sched.stages.len() != self.stages.len() {
            out.push(Diagnostic::new(
                Code::ScheduleLenMismatch,
                format!(
                    "schedule covers {} stages, pipeline has {}",
                    sched.stages.len(),
                    self.stages.len()
                ),
            ));
            return out;
        }
        for (i, s) in sched.stages.iter().enumerate() {
            self.verify_stage(i, s, &sched.stages, &mut out);
        }
        out
    }

    fn verify_stage(
        &self,
        i: usize,
        s: &StageSchedule,
        all: &[StageSchedule],
        out: &mut Vec<Diagnostic>,
    ) {
        let info = &self.stages[i];
        let rank = info.spatial.len();
        let mut push = |code: Code, msg: String| {
            out.push(Diagnostic::at_stage(code, i, info.opname, msg));
        };

        let order_ok = s.order.len() == rank && {
            let mut seen = vec![false; rank];
            s.order.iter().all(|&d| d < rank && !std::mem::replace(&mut seen[d], true))
        };
        if !order_ok {
            push(
                Code::OrderNotPermutation,
                format!("order {:?} is not a permutation of 0..{rank}", s.order),
            );
        }
        let tile_ok = s.tile.len() == rank && s.tile.iter().all(|&f| f > 0);
        if !tile_ok {
            push(Code::BadTile, format!("tile {:?} invalid for rank {rank}", s.tile));
        }
        let width_ok = matches!(s.vector_width, 1 | 4 | 8);
        if !width_ok {
            push(Code::BadVectorWidth, format!("unsupported vector width {}", s.vector_width));
        }
        if width_ok && s.vector_width > 1 && order_ok && tile_ok {
            match s.innermost_dim() {
                None => push(Code::VectorExceedsExtent, "vectorize on rank-0 stage".into()),
                Some(inner) => {
                    let extent =
                        if s.tile[inner] > 1 { s.tile[inner] } else { info.spatial[inner] };
                    if extent < s.vector_width {
                        push(
                            Code::VectorExceedsExtent,
                            format!(
                                "vector width {} exceeds innermost extent {extent}",
                                s.vector_width
                            ),
                        );
                    }
                }
            }
        }
        if !matches!(s.unroll, 1 | 2 | 4 | 8) {
            push(Code::BadUnroll, format!("unsupported unroll factor {}", s.unroll));
        }
        if order_ok && tile_ok {
            let n_loops = s.loop_extents(&info.spatial).len();
            if s.parallel_depth > n_loops.min(3) {
                push(
                    Code::ParallelTooDeep,
                    format!("parallel depth {} exceeds limit (loops={n_loops})", s.parallel_depth),
                );
            }
        }
        match s.compute {
            ComputeLoc::Root => {}
            ComputeLoc::Inline => {
                if !info.inlinable {
                    push(Code::InlineNonPointwise, "inline of non-pointwise stage".into());
                }
                if info.consumers.is_empty() {
                    push(Code::InlineOutputStage, "inline of an output stage".into());
                }
            }
            ComputeLoc::At { consumer, level } => {
                if !info.consumers.contains(&consumer) {
                    push(
                        Code::ComputeAtNonConsumer,
                        format!("compute_at non-consumer {consumer}"),
                    );
                }
                if consumer < all.len() && matches!(all[consumer].compute, ComputeLoc::Inline) {
                    push(Code::ComputeAtInlined, "compute_at an inlined consumer".into());
                }
                if level == 0 || level > 3 {
                    push(Code::ComputeAtBadLevel, format!("compute_at level {level} out of range"));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;

    fn two_stage() -> (Pipeline, Vec<LoopNest>) {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 8;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let nests = lower_pipeline(&p);
        (p, nests)
    }

    fn analyzed() -> (AnalyzedPipeline, PipelineSchedule) {
        let (p, nests) = two_stage();
        let ap = AnalyzedPipeline::build(&p, &nests);
        let sched = PipelineSchedule::default_for(&[4, 4]);
        (ap, sched)
    }

    /// Assert the mutated schedule triggers exactly `code`, through both
    /// the first-error and the collect-all paths.
    fn expect_code(sched: &PipelineSchedule, code: Code) {
        let (p, nests) = two_stage();
        let ap = AnalyzedPipeline::build(&p, &nests);
        let err = ap.check_schedule(sched).expect_err("schedule must be illegal");
        assert_eq!(err.code, code, "first error: {err}");
        let all = ap.verify_schedule(sched);
        assert_eq!(all.len(), 1, "exactly one finding expected: {all:?}");
        assert_eq!(all[0].code, code);
    }

    #[test]
    fn default_schedule_is_clean() {
        let (ap, sched) = analyzed();
        ap.check_schedule(&sched).unwrap();
        assert!(ap.verify_schedule(&sched).is_empty());
    }

    #[test]
    fn consumers_match_pipeline_consumers() {
        let (p, nests) = two_stage();
        let ap = AnalyzedPipeline::build(&p, &nests);
        let legacy = p.consumers();
        for i in 0..p.num_stages() {
            assert_eq!(ap.consumers(i), &legacy[i][..]);
        }
    }

    #[test]
    fn s001_len_mismatch() {
        let (ap, mut sched) = analyzed();
        sched.stages.pop();
        let err = ap.check_schedule(&sched).unwrap_err();
        assert_eq!(err.code, Code::ScheduleLenMismatch);
        assert_eq!(ap.verify_schedule(&sched)[0].code, Code::ScheduleLenMismatch);
    }

    #[test]
    fn s002_order_not_permutation() {
        let (_, mut sched) = analyzed();
        sched.stages[0].order = vec![0, 0, 1, 2];
        expect_code(&sched, Code::OrderNotPermutation);
    }

    #[test]
    fn s003_bad_tile() {
        let (_, mut sched) = analyzed();
        sched.stages[0].tile = vec![1, 0, 1, 1];
        expect_code(&sched, Code::BadTile);
    }

    #[test]
    fn s004_bad_vector_width() {
        let (_, mut sched) = analyzed();
        sched.stages[0].vector_width = 3;
        expect_code(&sched, Code::BadVectorWidth);
    }

    #[test]
    fn s005_vector_exceeds_extent() {
        let (_, mut sched) = analyzed();
        // innermost becomes the batch dim (extent 1) — width 8 cannot fit
        sched.stages[0].order = vec![1, 2, 3, 0];
        sched.stages[0].vector_width = 8;
        expect_code(&sched, Code::VectorExceedsExtent);
    }

    #[test]
    fn s006_bad_unroll() {
        let (_, mut sched) = analyzed();
        sched.stages[1].unroll = 5;
        expect_code(&sched, Code::BadUnroll);
    }

    #[test]
    fn s007_parallel_too_deep() {
        let (_, mut sched) = analyzed();
        sched.stages[0].parallel_depth = 9;
        expect_code(&sched, Code::ParallelTooDeep);
    }

    #[test]
    fn s008_inline_non_pointwise() {
        let (_, mut sched) = analyzed();
        sched.stages[0].compute = ComputeLoc::Inline; // conv has a reduction
        expect_code(&sched, Code::InlineNonPointwise);
    }

    #[test]
    fn s009_inline_output_stage() {
        let (_, mut sched) = analyzed();
        sched.stages[1].compute = ComputeLoc::Inline; // relu is the output
        expect_code(&sched, Code::InlineOutputStage);
    }

    #[test]
    fn s010_compute_at_non_consumer() {
        let (_, mut sched) = analyzed();
        sched.stages[0].compute = ComputeLoc::At { consumer: 0, level: 2 };
        expect_code(&sched, Code::ComputeAtNonConsumer);
    }

    #[test]
    fn s011_compute_at_inlined_consumer() {
        // needs three stages: conv -> relu (inlined) -> abs
        let mut p = Pipeline::new("t3");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 8;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        let r = p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        p.add_stage("abs", Op::new(OpKind::Abs), vec![r]).unwrap();
        let nests = lower_pipeline(&p);
        let ap = AnalyzedPipeline::build(&p, &nests);
        let mut sched = PipelineSchedule::default_for(&[4, 4, 4]);
        sched.stages[1].compute = ComputeLoc::Inline;
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 2 };
        let err = ap.check_schedule(&sched).unwrap_err();
        assert_eq!(err.code, Code::ComputeAtInlined);
        let all = ap.verify_schedule(&sched);
        assert_eq!(all.len(), 1, "{all:?}");
        assert_eq!(all[0].code, Code::ComputeAtInlined);
    }

    #[test]
    fn s012_compute_at_bad_level() {
        let (_, mut sched) = analyzed();
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 0 };
        expect_code(&sched, Code::ComputeAtBadLevel);
    }

    #[test]
    fn verify_reports_all_violations_at_once() {
        let (ap, mut sched) = analyzed();
        sched.stages[0].vector_width = 3;
        sched.stages[0].unroll = 7;
        sched.stages[1].compute = ComputeLoc::Inline;
        let all = ap.verify_schedule(&sched);
        let codes: Vec<Code> = all.iter().map(|d| d.code).collect();
        assert!(codes.contains(&Code::BadVectorWidth), "{codes:?}");
        assert!(codes.contains(&Code::BadUnroll), "{codes:?}");
        assert!(codes.contains(&Code::InlineOutputStage), "{codes:?}");
        assert_eq!(all.len(), 3, "{all:?}");
        // the fast path reports only the first
        assert_eq!(ap.check_schedule(&sched).unwrap_err().code, Code::BadVectorWidth);
    }
}
