//! Numeric/data audit pass over untrusted inputs: dataset samples
//! (`D001`–`D004`, `D008`), normalization stats (`D005`), bundle tensors
//! (`D006`), and CSR adjacency (`D007`).
//!
//! The dataset loaders ([`crate::dataset::store`], [`crate::dataset::json`])
//! and [`crate::predictor::bundle`] run the relevant audits at load time so
//! corrupt files fail with a coded diagnostic instead of panicking or
//! silently skewing training; `gcn-perf analyze --data/--samples/--bundle`
//! runs them on demand and renders the full report.

use crate::analysis::diag::{Code, Diagnostic};
use crate::constants::{DEP_DIM, INV_DIM};
use crate::dataset::{Dataset, GraphSample};
use crate::features::normalize::FeatureStats;
use crate::model::graph::Csr;
use crate::predictor::bundle::Bundle;

/// Audit one sample: structure (`D001`), edge ranges (`D002`), edge
/// topology (`D008` — stage graphs are producer→consumer with producer id
/// strictly below consumer id, so `src >= dst` means a forward ref or
/// cycle), feature finiteness (`D003`), and runtime labels (`D004` — NaN,
/// Inf, or negative; zero is allowed because JSON samples may omit runs).
pub fn audit_sample(s: &GraphSample) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let n = s.n_stages as usize;
    if n == 0 {
        out.push(Diagnostic::new(Code::SampleStructure, "sample has zero stages".into()));
        return out;
    }
    if s.inv.len() != n || s.dep.len() != n {
        out.push(Diagnostic::new(
            Code::SampleStructure,
            format!(
                "sample has {n} stages but {}/{} feature rows",
                s.inv.len(),
                s.dep.len()
            ),
        ));
    }
    for &(src, dst) in &s.edges {
        if (src as usize) >= n || (dst as usize) >= n {
            out.push(Diagnostic::new(
                Code::EdgeOutOfRange,
                format!("edge ({src}, {dst}) out of range for a {n}-stage graph"),
            ));
        } else if src >= dst {
            out.push(Diagnostic::new(
                Code::NonTopologicalEdge,
                format!("edge ({src}, {dst}) is not topological (src must precede dst)"),
            ));
        }
    }
    let bad_rows = s
        .inv
        .iter()
        .flat_map(|r| r.iter())
        .chain(s.dep.iter().flat_map(|r| r.iter()))
        .filter(|x| !x.is_finite())
        .count();
    if bad_rows > 0 {
        out.push(Diagnostic::new(
            Code::NonFiniteFeature,
            format!("{bad_rows} non-finite feature value(s)"),
        ));
    }
    for &r in &s.runs {
        if !r.is_finite() || r < 0.0 {
            out.push(Diagnostic::new(
                Code::BadRuntimeLabel,
                format!("runtime measurement {r} is not a valid label"),
            ));
            break;
        }
    }
    out
}

/// Audit normalization stats: dimension counts, finiteness, positive stds.
pub fn audit_stats(stats: &FeatureStats) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if stats.inv_mean.len() != INV_DIM
        || stats.inv_std.len() != INV_DIM
        || stats.dep_mean.len() != DEP_DIM
        || stats.dep_std.len() != DEP_DIM
    {
        out.push(Diagnostic::new(
            Code::BadStats,
            format!(
                "stats dims {}/{}/{}/{} != expected {INV_DIM}/{INV_DIM}/{DEP_DIM}/{DEP_DIM}",
                stats.inv_mean.len(),
                stats.inv_std.len(),
                stats.dep_mean.len(),
                stats.dep_std.len()
            ),
        ));
        return out;
    }
    let bad_mean = stats
        .inv_mean
        .iter()
        .chain(&stats.dep_mean)
        .filter(|x| !x.is_finite())
        .count();
    let bad_std = stats
        .inv_std
        .iter()
        .chain(&stats.dep_std)
        .filter(|x| !x.is_finite() || **x <= 0.0)
        .count();
    if bad_mean > 0 {
        out.push(Diagnostic::new(
            Code::BadStats,
            format!("{bad_mean} non-finite normalization mean(s)"),
        ));
    }
    if bad_std > 0 {
        out.push(Diagnostic::new(
            Code::BadStats,
            format!("{bad_std} non-finite or non-positive normalization std(s)"),
        ));
    }
    out
}

/// Audit a whole dataset: each sample (tagged with its index) plus the
/// fitted stats when present.
pub fn audit_dataset(ds: &Dataset) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (i, s) in ds.samples.iter().enumerate() {
        for mut d in audit_sample(s) {
            if d.location.is_none() {
                d.location = Some(format!("sample {i}"));
            }
            out.push(d);
        }
    }
    if let Some(stats) = &ds.stats {
        out.extend(audit_stats(stats));
    }
    out
}

/// Audit a model bundle: NaN/Inf over every f32 tensor (`D006`) and the
/// embedded normalization stats (`D005`). Int8 payloads cannot encode
/// non-finite values, so qtensors only contribute through their f32 scale
/// tensors, which live in the regular tensor section.
pub fn audit_bundle(b: &Bundle) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for t in &b.tensors {
        let bad = t.data.iter().filter(|x| !x.is_finite()).count();
        if bad > 0 {
            out.push(Diagnostic::at(
                Code::NonFiniteTensor,
                format!("tensor '{}'", t.name),
                format!("{bad} of {} values are non-finite", t.data.len()),
            ));
        }
    }
    for (k, v) in &b.meta {
        if !v.is_finite() {
            out.push(Diagnostic::at(
                Code::NonFiniteTensor,
                format!("meta '{k}'"),
                format!("metadata value {v} is non-finite"),
            ));
        }
    }
    if let Some(stats) = &b.stats {
        out.extend(audit_stats(stats));
    }
    out
}

/// Audit CSR well-formedness against an expected column count (`D007`):
/// row_ptr must start at 0, be monotonic, and end at nnz; col/val arrays
/// must agree in length; columns in range; values finite.
pub fn audit_csr(m: &Csr, n_cols: usize) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut push = |msg: String| out.push(Diagnostic::new(Code::MalformedCsr, msg));
    match m.row_ptr.first() {
        None => {
            push("row_ptr is empty".into());
            return out;
        }
        Some(&f) if f != 0 => push(format!("row_ptr starts at {f}, not 0")),
        _ => {}
    }
    if m.row_ptr.windows(2).any(|w| w[0] > w[1]) {
        push("row_ptr is not monotonically non-decreasing".into());
    }
    let last = *m.row_ptr.last().unwrap() as usize;
    if last != m.col_idx.len() {
        push(format!("row_ptr ends at {last} but nnz is {}", m.col_idx.len()));
    }
    if m.val.len() != m.col_idx.len() {
        push(format!("{} values for {} column indices", m.val.len(), m.col_idx.len()));
    }
    if let Some(&c) = m.col_idx.iter().find(|&&c| (c as usize) >= n_cols) {
        push(format!("column index {c} out of range for {n_cols} columns"));
    }
    let bad = m.val.iter().filter(|x| !x.is_finite()).count();
    if bad > 0 {
        push(format!("{bad} non-finite value(s)"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constants::BENCH_RUNS;

    fn sample() -> GraphSample {
        GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: 3,
            edges: vec![(0, 1), (1, 2)],
            inv: vec![[0.5; INV_DIM]; 3],
            dep: vec![[0.5; DEP_DIM]; 3],
            runs: [1e-3; BENCH_RUNS],
        }
    }

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code.as_str()).collect()
    }

    #[test]
    fn clean_sample_passes() {
        assert!(audit_sample(&sample()).is_empty());
    }

    #[test]
    fn d001_structure() {
        let mut s = sample();
        s.inv.pop();
        assert_eq!(codes(&audit_sample(&s)), vec!["D001"]);
        let mut s = sample();
        s.n_stages = 0;
        assert_eq!(codes(&audit_sample(&s)), vec!["D001"]);
    }

    #[test]
    fn d002_edge_out_of_range() {
        let mut s = sample();
        s.edges.push((1, 7));
        assert_eq!(codes(&audit_sample(&s)), vec!["D002"]);
    }

    #[test]
    fn d008_non_topological_edge() {
        let mut s = sample();
        s.edges.push((2, 1)); // backward: cycle with (1, 2)
        assert_eq!(codes(&audit_sample(&s)), vec!["D008"]);
        let mut s = sample();
        s.edges.push((1, 1)); // self loop
        assert_eq!(codes(&audit_sample(&s)), vec!["D008"]);
    }

    #[test]
    fn d003_non_finite_feature() {
        let mut s = sample();
        s.dep[1][3] = f32::NAN;
        assert_eq!(codes(&audit_sample(&s)), vec!["D003"]);
    }

    #[test]
    fn d004_bad_runtime_label() {
        let mut s = sample();
        s.runs[2] = f32::INFINITY;
        assert_eq!(codes(&audit_sample(&s)), vec!["D004"]);
        let mut s = sample();
        s.runs[0] = -1.0;
        assert_eq!(codes(&audit_sample(&s)), vec!["D004"]);
        // all-zero runs are allowed: JSON samples may omit measurements
        let mut s = sample();
        s.runs = [0.0; BENCH_RUNS];
        assert!(audit_sample(&s).is_empty());
    }

    #[test]
    fn d005_bad_stats() {
        let mut ds = Dataset { samples: vec![sample()], stats: None };
        ds.fit_stats();
        assert!(audit_dataset(&ds).is_empty());
        let stats = ds.stats.as_mut().unwrap();
        stats.inv_std[0] = 0.0;
        assert_eq!(codes(&audit_dataset(&ds)), vec!["D005"]);
    }

    #[test]
    fn dataset_audit_tags_sample_locations() {
        let mut bad = sample();
        bad.edges.push((0, 9));
        let ds = Dataset { samples: vec![sample(), bad], stats: None };
        let diags = audit_dataset(&ds);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].location.as_deref(), Some("sample 1"));
    }

    #[test]
    fn d006_non_finite_tensor() {
        let mut b = Bundle::new("ffn");
        b.tensors.push(crate::predictor::bundle::NamedTensor {
            name: "w0".into(),
            shape: vec![2, 2],
            data: vec![1.0, f32::NAN, 0.0, f32::NEG_INFINITY],
        });
        let diags = audit_bundle(&b);
        assert_eq!(codes(&diags), vec!["D006"]);
        assert!(diags[0].message.contains("2 of 4"));
    }

    #[test]
    fn d007_malformed_csr() {
        let good = Csr { row_ptr: vec![0, 1, 2], col_idx: vec![1, 0], val: vec![0.5, 0.5] };
        assert!(audit_csr(&good, 2).is_empty());
        let bad = Csr { row_ptr: vec![0, 2, 1], col_idx: vec![1, 0], val: vec![0.5, 0.5] };
        assert!(codes(&audit_csr(&bad, 2)).contains(&"D007"));
        let bad = Csr { row_ptr: vec![0, 1, 2], col_idx: vec![1, 9], val: vec![0.5, 0.5] };
        assert!(codes(&audit_csr(&bad, 2)).contains(&"D007"));
        let bad = Csr { row_ptr: vec![0, 1, 2], col_idx: vec![1, 0], val: vec![0.5, f32::NAN] };
        assert!(codes(&audit_csr(&bad, 2)).contains(&"D007"));
    }
}
