//! Dependence + bounds pass: producer→consumer footprint regions under
//! each [`ComputeLoc`], storage-footprint estimates the cost model can
//! cross-check, and fusion hazards the first-error legality checks never
//! report (`W003` compute_at deeper than the consumer nest, `W004`
//! fusing into one of several consumers).
//!
//! Everything here reads the tables of an
//! [`AnalyzedPipeline`](crate::analysis::AnalyzedPipeline) — no pipeline
//! or nest walks per candidate.

use crate::analysis::analyzed::AnalyzedPipeline;
use crate::analysis::diag::{Code, Diagnostic};
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};

/// True when order/tile are individually valid for `rank` — the guard for
/// anything that calls [`StageSchedule::loop_extents`] (which indexes
/// `spatial` by the order entries and would panic on a malformed order).
fn loops_computable(s: &StageSchedule, rank: usize) -> bool {
    s.order.len() == rank
        && s.tile.len() == rank
        && s.order.iter().all(|&d| d < rank)
        && s.tile.iter().all(|&f| f > 0)
}

/// Estimated resident bytes of each stage's output buffer under its
/// scheduled [`ComputeLoc`]:
///
/// * `Root` — the whole buffer is materialized: `out_bytes`.
/// * `Inline` — no buffer at all: `0`.
/// * `At { consumer, level }` — one tile per consumer iteration: the full
///   buffer shrunk by the extents of the consumer loops the producer sits
///   under, floored at one point's worth of bytes.
///
/// Malformed schedules (wrong length, bad order/tile, dangling consumer)
/// fall back to `out_bytes` for the affected stage — this pass estimates,
/// the legality passes reject.
pub fn storage_footprints(ap: &AnalyzedPipeline, sched: &PipelineSchedule) -> Vec<f64> {
    (0..ap.num_stages())
        .map(|i| {
            let info = ap.stage(i);
            let Some(s) = sched.stages.get(i) else {
                return info.out_bytes;
            };
            match s.compute {
                ComputeLoc::Root => info.out_bytes,
                ComputeLoc::Inline => 0.0,
                ComputeLoc::At { consumer, level } => {
                    let Some(cs) = sched.stages.get(consumer) else {
                        return info.out_bytes;
                    };
                    let cspatial = match ap.stage_opt(consumer) {
                        Some(c) if loops_computable(cs, c.spatial.len()) => &c.spatial,
                        _ => return info.out_bytes,
                    };
                    let extents = cs.loop_extents(cspatial);
                    let shrink: f64 = extents
                        .iter()
                        .take(level.min(extents.len()))
                        .map(|&e| e.max(1) as f64)
                        .product();
                    let numel: usize = info.spatial.iter().product::<usize>().max(1);
                    let per_point = info.out_bytes / numel as f64;
                    (info.out_bytes / shrink.max(1.0)).max(per_point)
                }
            }
        })
        .collect()
}

/// Sum of [`storage_footprints`] — the pipeline's estimated peak
/// intermediate-buffer residency under this schedule.
pub fn total_footprint_bytes(ap: &AnalyzedPipeline, sched: &PipelineSchedule) -> f64 {
    storage_footprints(ap, sched).iter().sum()
}

/// Dependence warnings for a schedule: findings that are *legal* today but
/// flag fusion placements the cost model treats pessimistically.
///
/// * `W003` — `compute_at` level deeper than the consumer's loop nest:
///   the placement clamps to the innermost loop, so the extra depth buys
///   nothing.
/// * `W004` — a producer fused `At` one consumer while other stages also
///   read it: the other consumers force either recompute or a full
///   materialization anyway.
pub fn dependence_diagnostics(ap: &AnalyzedPipeline, sched: &PipelineSchedule) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let n = ap.num_stages();
    if sched.stages.len() != n {
        return out; // S001 territory — the schedule pass reports it
    }
    for (i, s) in sched.stages.iter().enumerate() {
        let info = ap.stage(i);
        if let ComputeLoc::At { consumer, level } = s.compute {
            if info.consumers.len() > 1 && info.consumers.contains(&consumer) {
                let others: Vec<usize> =
                    info.consumers.iter().copied().filter(|&c| c != consumer).collect();
                out.push(Diagnostic::at_stage(
                    Code::FusedMultiConsumer,
                    i,
                    info.opname,
                    format!("fused into stage {consumer} but also consumed by {others:?}"),
                ));
            }
            if let Some(c) = ap.stage_opt(consumer) {
                let cs = &sched.stages[consumer];
                if loops_computable(cs, c.spatial.len()) {
                    let n_loops = cs.loop_extents(&c.spatial).len();
                    if level > n_loops {
                        out.push(Diagnostic::at_stage(
                            Code::ComputeAtDeep,
                            i,
                            info.opname,
                            format!(
                                "compute_at level {level} deeper than consumer {consumer}'s \
                                 {n_loops}-loop nest"
                            ),
                        ));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpKind};
    use crate::ir::pipeline::Pipeline;
    use crate::lower::lower_pipeline;
    use crate::schedule::primitives::PipelineSchedule;

    /// relu -> abs over a rank-2 input: two pointwise stages.
    fn chain2() -> (AnalyzedPipeline, PipelineSchedule) {
        let mut p = Pipeline::new("b");
        let x = p.add_input(vec![8, 32]);
        let r = p.add_stage("relu", Op::new(OpKind::Relu), vec![x]).unwrap();
        p.add_stage("abs", Op::new(OpKind::Abs), vec![r]).unwrap();
        let nests = lower_pipeline(&p);
        let ap = AnalyzedPipeline::build(&p, &nests);
        let sched = PipelineSchedule::default_for(&[2, 2]);
        (ap, sched)
    }

    #[test]
    fn root_footprint_is_full_buffer_and_inline_is_zero() {
        let (ap, mut sched) = chain2();
        let full = storage_footprints(&ap, &sched);
        assert_eq!(full[0], ap.stage(0).out_bytes);
        assert!(full[0] > 0.0);
        sched.stages[0].compute = ComputeLoc::Inline;
        let fused = storage_footprints(&ap, &sched);
        assert_eq!(fused[0], 0.0);
        assert_eq!(fused[1], full[1]);
        assert!(total_footprint_bytes(&ap, &sched) < total_footprint_bytes(&ap, &chain2().1));
    }

    #[test]
    fn compute_at_shrinks_footprint_by_consumer_extents() {
        let (ap, mut sched) = chain2();
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 1 };
        let fp = storage_footprints(&ap, &sched);
        // consumer loop 0 has extent 8 -> one row resident at a time
        assert!((fp[0] - ap.stage(0).out_bytes / 8.0).abs() < 1e-9, "{fp:?}");
    }

    #[test]
    fn compute_at_footprint_floors_at_one_point() {
        let (ap, mut sched) = chain2();
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 3 };
        // deeper than the 2-loop nest: shrink clamps, floor >= bytes/point
        let fp = storage_footprints(&ap, &sched);
        let numel = ap.stage(0).spatial.iter().product::<usize>() as f64;
        assert!(fp[0] >= ap.stage(0).out_bytes / numel - 1e-9);
    }

    #[test]
    fn w003_compute_at_deeper_than_consumer_nest() {
        let (ap, mut sched) = chain2();
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 3 };
        // level 3 is *legal* (1..=3) but the rank-2 consumer only has 2 loops
        ap.check_schedule(&sched).unwrap();
        let diags = dependence_diagnostics(&ap, &sched);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, Code::ComputeAtDeep);
    }

    #[test]
    fn w004_fused_producer_with_other_consumers() {
        let mut p = Pipeline::new("m");
        let x = p.add_input(vec![8, 32]);
        let r = p.add_stage("relu", Op::new(OpKind::Relu), vec![x]).unwrap();
        let a = p.add_stage("abs", Op::new(OpKind::Abs), vec![r]).unwrap();
        p.add_stage("sum", Op::new(OpKind::Add), vec![r, a]).unwrap();
        let nests = lower_pipeline(&p);
        let ap = AnalyzedPipeline::build(&p, &nests);
        let mut sched = PipelineSchedule::default_for(&[2, 2, 2]);
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 1 };
        ap.check_schedule(&sched).unwrap();
        let diags = dependence_diagnostics(&ap, &sched);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, Code::FusedMultiConsumer);
        assert_eq!(diags[0].stage, Some(0));
    }

    #[test]
    fn clean_default_schedule_has_no_dependence_findings() {
        let (ap, sched) = chain2();
        assert!(dependence_diagnostics(&ap, &sched).is_empty());
    }
}
