//! Pipeline well-formedness pass: the DAG-structure analogue of
//! [`crate::ir::pipeline::Pipeline::validate`], reporting *all* findings
//! (validate stops at the first) plus liveness warnings the first-error
//! path never looks for — unused inputs and stages that cannot reach the
//! pipeline's final output (dead stages and orphan subgraphs alike).

use crate::analysis::diag::{Code, Diagnostic};
use crate::ir::pipeline::{Pipeline, SourceRef};

/// Run the structure pass over one pipeline. An empty result means the
/// pipeline is well-formed; [`Pipeline::validate`] accepts exactly the
/// pipelines this pass reports no error-severity findings for
/// (property-pinned in the test suite).
pub fn analyze_pipeline(p: &Pipeline) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for s in &p.stages {
        let opname = s.op.kind.name();
        let mut refs_ok = true;
        if s.inputs.len() != s.op.kind.graph_arity() {
            out.push(Diagnostic::at_stage(
                Code::ArityMismatch,
                s.id,
                opname,
                format!("arity {} != expected {}", s.inputs.len(), s.op.kind.graph_arity()),
            ));
            refs_ok = false;
        }
        for &inp in &s.inputs {
            match inp {
                SourceRef::Input(i) if i >= p.inputs.len() => {
                    out.push(Diagnostic::at_stage(
                        Code::DanglingInputRef,
                        s.id,
                        opname,
                        format!("dangling input ref {i} (pipeline has {})", p.inputs.len()),
                    ));
                    refs_ok = false;
                }
                SourceRef::Stage(i) if i >= s.id => {
                    out.push(Diagnostic::at_stage(
                        Code::ForwardStageRef,
                        s.id,
                        opname,
                        format!("forward/self reference to stage {i}"),
                    ));
                    refs_ok = false;
                }
                _ => {}
            }
        }
        // shape re-inference only makes sense over resolvable operands
        if refs_ok {
            let shapes: Vec<&[usize]> = s.inputs.iter().map(|&x| p.shape_of(x)).collect();
            match s.op.infer_shape(&shapes) {
                Some(sh) if sh == s.shape => {}
                Some(sh) => out.push(Diagnostic::at_stage(
                    Code::ShapeMismatch,
                    s.id,
                    opname,
                    format!("stored shape {:?} != inferred {:?}", s.shape, sh),
                )),
                None => out.push(Diagnostic::at_stage(
                    Code::ShapeInferenceFailed,
                    s.id,
                    opname,
                    format!("shape inference fails on operand shapes {shapes:?}"),
                )),
            }
        }
    }

    // W001: inputs no stage ever reads
    let mut input_used = vec![false; p.inputs.len()];
    for s in &p.stages {
        for &inp in &s.inputs {
            if let SourceRef::Input(i) = inp {
                if i < input_used.len() {
                    input_used[i] = true;
                }
            }
        }
    }
    for (i, used) in input_used.iter().enumerate() {
        if !used {
            out.push(Diagnostic::new(
                Code::UnusedInput,
                format!("pipeline input {i} (shape {:?}) is never read", p.inputs[i]),
            ));
        }
    }

    // W002: stages whose value cannot reach the final output — covers both
    // dead interior stages and whole orphaned subgraphs
    if let Some(last) = p.stages.last() {
        let mut live = vec![false; p.stages.len()];
        let mut stack = vec![last.id];
        while let Some(i) = stack.pop() {
            if live[i] {
                continue;
            }
            live[i] = true;
            for &inp in &p.stages[i].inputs {
                if let SourceRef::Stage(j) = inp {
                    if j < p.stages.len() && !live[j] {
                        stack.push(j);
                    }
                }
            }
        }
        for (i, alive) in live.iter().enumerate() {
            if !alive {
                out.push(Diagnostic::at_stage(
                    Code::DeadStage,
                    i,
                    p.stages[i].op.kind.name(),
                    format!("output of '{}' never reaches the final stage", p.stages[i].name),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::diag::Severity;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::util::propcheck;

    fn chain() -> Pipeline {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 8, 16, 16]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 4;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        p
    }

    fn codes(p: &Pipeline) -> Vec<&'static str> {
        analyze_pipeline(p).iter().map(|d| d.code.as_str()).collect()
    }

    #[test]
    fn well_formed_pipeline_is_clean() {
        assert!(analyze_pipeline(&chain()).is_empty());
        for net in crate::zoo::all_networks() {
            let diags = analyze_pipeline(&net);
            assert!(diags.is_empty(), "{}: {diags:?}", net.name);
        }
    }

    #[test]
    fn a001_arity_mismatch() {
        let mut p = chain();
        p.stages[1].inputs.clear();
        assert_eq!(codes(&p), vec!["A001"]);
    }

    #[test]
    fn a002_dangling_input_ref() {
        let mut p = chain();
        p.stages[0].inputs[0] = SourceRef::Input(9);
        assert_eq!(codes(&p), vec!["A002"]);
    }

    #[test]
    fn a003_forward_and_self_refs() {
        let mut p = chain();
        p.stages[1].inputs[0] = SourceRef::Stage(1);
        assert!(codes(&p).contains(&"A003"));
        let mut p = chain();
        p.stages[0].inputs[0] = SourceRef::Stage(1);
        assert!(codes(&p).contains(&"A003"));
    }

    #[test]
    fn a004_shape_mismatch() {
        let mut p = chain();
        p.stages[1].shape = vec![9, 9];
        // the corrupted relu also breaks nothing else: exactly one finding
        assert_eq!(codes(&p), vec!["A004"]);
    }

    #[test]
    fn a005_shape_inference_failure() {
        let mut p = chain();
        // Add requires two compatible operands; force arity-compatible but
        // shape-incompatible operands through a raw stage edit
        let y = p.add_input(vec![3, 5]);
        let relu = SourceRef::Stage(1);
        p.add_stage("mix", Op::new(OpKind::Add), vec![relu, relu]).unwrap();
        p.stages[2].inputs[1] = y;
        assert_eq!(codes(&p), vec!["A005"]);
    }

    #[test]
    fn w001_unused_input_warns() {
        let mut p = chain();
        p.add_input(vec![4, 4]);
        let diags = analyze_pipeline(&p);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, Code::UnusedInput);
        assert_eq!(diags[0].severity(), Severity::Warning);
    }

    #[test]
    fn w002_dead_stage_warns() {
        let mut p = chain();
        let relu = SourceRef::Stage(1);
        p.add_stage("dead", Op::new(OpKind::Exp), vec![relu]).unwrap();
        p.add_stage("out", Op::new(OpKind::Abs), vec![relu]).unwrap();
        let diags = analyze_pipeline(&p);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, Code::DeadStage);
        assert_eq!(diags[0].stage, Some(2));
    }

    #[test]
    fn prop_structure_pass_agrees_with_validate() {
        // analyzer errors <=> validate() rejection, over generated models
        // and seeded corruptions of them
        let cases = propcheck::default_cases().min(24);
        propcheck::check_rng("structure pass == validate", 0xA11, cases, |rng| {
            let cfg = crate::onnx_gen::GenConfig::default();
            let mut p = crate::onnx_gen::generate_model(&cfg, rng, 0);
            if rng.gen_range(2) == 1 && !p.stages.is_empty() {
                // corrupt one stage at random
                let sid = rng.gen_range(p.stages.len());
                match rng.gen_range(3) {
                    0 => p.stages[sid].shape = vec![7, 7, 7],
                    1 => p.stages[sid].inputs = vec![SourceRef::Stage(sid)],
                    _ => p.stages[sid].inputs = vec![SourceRef::Input(99)],
                }
            }
            let errs = analyze_pipeline(&p)
                .iter()
                .filter(|d| d.severity() == Severity::Error)
                .count();
            let valid = p.validate().is_ok();
            if valid != (errs == 0) {
                return Err(format!(
                    "validate says {valid}, analyzer found {errs} errors for {}",
                    p.name
                ));
            }
            Ok(())
        });
    }
}
