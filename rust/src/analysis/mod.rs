//! Static analyzer over pipelines, schedules, and serialized artifacts.
//!
//! A multi-pass verifier with a diagnostics engine: stable error codes
//! (`A0xx` pipeline structure, `S0xx` schedule legality, `D0xx` data,
//! `W0xx` warnings), severities, per-stage locations, and text/JSON
//! renderers ([`Report`]). The passes:
//!
//! 1. **Structure** ([`structure::analyze_pipeline`]) — arity, dangling
//!    and forward/self refs, shape re-inference agreement, dead stages,
//!    unused inputs, orphan subgraphs.
//! 2. **Dependence + bounds** ([`bounds`]) — per-[`ComputeLoc`] storage
//!    footprints and fusion hazards (`W003`/`W004`).
//! 3. **Schedule verification** ([`AnalyzedPipeline::verify_schedule`]) —
//!    every `S0xx` violation; [`AnalyzedPipeline::check_schedule`] is the
//!    first-error fast path `schedule::legality` now shims onto and the
//!    search strategies use for per-candidate pruning.
//! 4. **Data audit** ([`data_audit`]) — NaN/Inf scans over samples, stats,
//!    bundle tensors; CSR well-formedness; edge/stage-ref validation.
//!
//! Entry points: the `gcn-perf analyze` subcommand (exit 0 clean, 1 with
//! findings, 2 on usage errors), load-time checks in the dataset/bundle
//! loaders, and [`AnalyzedPipeline`] inside beam/evolution search.
//!
//! [`ComputeLoc`]: crate::schedule::primitives::ComputeLoc

pub mod analyzed;
pub mod bounds;
pub mod data_audit;
pub mod diag;
pub mod structure;

pub use analyzed::{AnalyzedPipeline, StageInfo};
pub use bounds::{dependence_diagnostics, storage_footprints, total_footprint_bytes};
pub use data_audit::{audit_bundle, audit_csr, audit_dataset, audit_sample, audit_stats};
pub use diag::{Code, Diagnostic, Report, Severity};
pub use structure::analyze_pipeline;

use crate::ir::pipeline::Pipeline;
use crate::lower::lower_pipeline;
use crate::schedule::primitives::PipelineSchedule;

/// Pull the stable code (`"D002"`, ...) of an analyzer [`Diagnostic`]
/// out of an `anyhow` error chain, if the failure was a coded finding
/// (as opposed to, say, a bare I/O error). Loaders attach the
/// [`Diagnostic`] itself as a chain link, so callers that only need the
/// code — tests, the streaming shard reader's fixtures — get it without
/// string-matching rendered messages.
pub fn diag_code_in_chain(e: &anyhow::Error) -> Option<String> {
    e.chain()
        .find_map(|c| c.downcast_ref::<Diagnostic>())
        .map(|d| d.code.as_str().to_string())
}

/// Run every applicable pass over one pipeline + schedule and collect the
/// findings into `report`: structure, schedule verification, dependence
/// warnings, and a footprint note.
pub fn analyze_pipeline_schedule(
    p: &Pipeline,
    sched: &PipelineSchedule,
    report: &mut Report,
) -> AnalyzedPipeline {
    report.extend(structure::analyze_pipeline(p));
    let nests = lower_pipeline(p);
    let ap = AnalyzedPipeline::build(p, &nests);
    report.extend(ap.verify_schedule(sched));
    report.extend(bounds::dependence_diagnostics(&ap, sched));
    report.note(format!(
        "estimated peak intermediate footprint: {:.0} bytes",
        bounds::total_footprint_bytes(&ap, sched)
    ));
    ap
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_analysis_is_clean_on_every_zoo_network() {
        for net in crate::zoo::all_networks() {
            let ranks: Vec<usize> = net.stages.iter().map(|s| s.shape.len()).collect();
            let sched = PipelineSchedule::default_for(&ranks);
            let mut report = Report::new(&net.name);
            analyze_pipeline_schedule(&net, &sched, &mut report);
            assert!(report.is_clean(true), "{}: {}", net.name, report.to_text());
        }
    }
}
