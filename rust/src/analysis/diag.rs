//! The diagnostics engine: stable codes, severities, per-stage locations
//! and the text/JSON renderers every analyzer pass reports through.
//!
//! Codes are grouped by prefix and never renumbered:
//!
//! * `A0xx` — pipeline structure (DAG well-formedness, shape agreement)
//! * `S0xx` — schedule legality (the [`crate::schedule::legality`] rules)
//! * `D0xx` — data integrity (samples, datasets, stats, bundles, CSR)
//! * `W0xx` — warnings (suspicious but executable constructs)
//!
//! `A`/`S`/`D` codes are [`Severity::Error`]; `W` codes are
//! [`Severity::Warning`]. The `gcn-perf analyze` exit policy keys off
//! that split: errors exit 1, warnings exit 0 unless `--strict`.

use crate::util::json::Json;

/// How bad a finding is. Errors make a target invalid (exit 1 from the
/// CLI, rejection from loaders); warnings are advisory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Stable diagnostic codes. The wire strings (`"A001"`, ...) are part of
/// the CLI contract — scripts grep them — so variants are append-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Code {
    // ---- A0xx: pipeline structure ----
    /// Stage operand count does not match the op's graph arity.
    ArityMismatch,
    /// Stage references a pipeline input index that does not exist.
    DanglingInputRef,
    /// Stage references itself or a later stage (breaks topological order).
    ForwardStageRef,
    /// Stored output shape disagrees with re-inferred shape.
    ShapeMismatch,
    /// Shape inference fails on the stage's operand shapes.
    ShapeInferenceFailed,
    // ---- S0xx: schedule legality ----
    /// Schedule stage count differs from the pipeline stage count.
    ScheduleLenMismatch,
    /// Loop order is not a permutation of the stage's spatial dims.
    OrderNotPermutation,
    /// Tile vector has the wrong length or a zero split factor.
    BadTile,
    /// Vector width outside the supported {1, 4, 8} set.
    BadVectorWidth,
    /// Vector width exceeds the innermost loop extent.
    VectorExceedsExtent,
    /// Unroll factor outside the supported {1, 2, 4, 8} set.
    BadUnroll,
    /// Parallel depth exceeds the loop count (capped at 3).
    ParallelTooDeep,
    /// Inline of a stage with a reduction or non-pointwise body.
    InlineNonPointwise,
    /// Inline of an output stage (no consumer to inline into).
    InlineOutputStage,
    /// `compute_at` targets a stage that is not a consumer.
    ComputeAtNonConsumer,
    /// `compute_at` targets an inlined (non-materializing) consumer.
    ComputeAtInlined,
    /// `compute_at` level outside the supported 1..=3 range.
    ComputeAtBadLevel,
    // ---- D0xx: data integrity ----
    /// Sample structure broken (zero stages, feature-row count mismatch).
    SampleStructure,
    /// Edge endpoint outside the sample's stage range.
    EdgeOutOfRange,
    /// NaN/Inf in a feature row.
    NonFiniteFeature,
    /// NaN/Inf/negative runtime measurement.
    BadRuntimeLabel,
    /// Normalization stats malformed (non-finite mean/std, zero std).
    BadStats,
    /// NaN/Inf in a bundle tensor.
    NonFiniteTensor,
    /// CSR matrix malformed (row_ptr/col_idx/val inconsistency).
    MalformedCsr,
    /// Edge violates topological order (src >= dst: cycle/self/forward).
    NonTopologicalEdge,
    // ---- W0xx: warnings ----
    /// Pipeline input never read by any stage.
    UnusedInput,
    /// Stage output cannot reach the pipeline's final output.
    DeadStage,
    /// `compute_at` level deeper than the consumer's loop nest.
    ComputeAtDeep,
    /// Producer fused into one consumer while other consumers remain.
    FusedMultiConsumer,
}

impl Code {
    /// Every documented code, in wire order (the DESIGN.md table).
    pub const ALL: &'static [Code] = &[
        Code::ArityMismatch,
        Code::DanglingInputRef,
        Code::ForwardStageRef,
        Code::ShapeMismatch,
        Code::ShapeInferenceFailed,
        Code::ScheduleLenMismatch,
        Code::OrderNotPermutation,
        Code::BadTile,
        Code::BadVectorWidth,
        Code::VectorExceedsExtent,
        Code::BadUnroll,
        Code::ParallelTooDeep,
        Code::InlineNonPointwise,
        Code::InlineOutputStage,
        Code::ComputeAtNonConsumer,
        Code::ComputeAtInlined,
        Code::ComputeAtBadLevel,
        Code::SampleStructure,
        Code::EdgeOutOfRange,
        Code::NonFiniteFeature,
        Code::BadRuntimeLabel,
        Code::BadStats,
        Code::NonFiniteTensor,
        Code::MalformedCsr,
        Code::NonTopologicalEdge,
        Code::UnusedInput,
        Code::DeadStage,
        Code::ComputeAtDeep,
        Code::FusedMultiConsumer,
    ];

    /// The stable wire string ("A001", "S005", ...).
    pub fn as_str(&self) -> &'static str {
        match self {
            Code::ArityMismatch => "A001",
            Code::DanglingInputRef => "A002",
            Code::ForwardStageRef => "A003",
            Code::ShapeMismatch => "A004",
            Code::ShapeInferenceFailed => "A005",
            Code::ScheduleLenMismatch => "S001",
            Code::OrderNotPermutation => "S002",
            Code::BadTile => "S003",
            Code::BadVectorWidth => "S004",
            Code::VectorExceedsExtent => "S005",
            Code::BadUnroll => "S006",
            Code::ParallelTooDeep => "S007",
            Code::InlineNonPointwise => "S008",
            Code::InlineOutputStage => "S009",
            Code::ComputeAtNonConsumer => "S010",
            Code::ComputeAtInlined => "S011",
            Code::ComputeAtBadLevel => "S012",
            Code::SampleStructure => "D001",
            Code::EdgeOutOfRange => "D002",
            Code::NonFiniteFeature => "D003",
            Code::BadRuntimeLabel => "D004",
            Code::BadStats => "D005",
            Code::NonFiniteTensor => "D006",
            Code::MalformedCsr => "D007",
            Code::NonTopologicalEdge => "D008",
            Code::UnusedInput => "W001",
            Code::DeadStage => "W002",
            Code::ComputeAtDeep => "W003",
            Code::FusedMultiConsumer => "W004",
        }
    }

    /// Severity implied by the prefix: `W` codes warn, all others error.
    pub fn severity(&self) -> Severity {
        match self.as_str().as_bytes()[0] {
            b'W' => Severity::Warning,
            _ => Severity::Error,
        }
    }

    /// One-line summary for the code table renderer.
    pub fn description(&self) -> &'static str {
        match self {
            Code::ArityMismatch => "stage operand count != op graph arity",
            Code::DanglingInputRef => "stage reads a nonexistent pipeline input",
            Code::ForwardStageRef => "stage references itself or a later stage",
            Code::ShapeMismatch => "stored output shape != re-inferred shape",
            Code::ShapeInferenceFailed => "shape inference fails on operand shapes",
            Code::ScheduleLenMismatch => "schedule stage count != pipeline stage count",
            Code::OrderNotPermutation => "loop order is not a permutation of the dims",
            Code::BadTile => "tile vector wrong length or zero split factor",
            Code::BadVectorWidth => "vector width outside {1, 4, 8}",
            Code::VectorExceedsExtent => "vector width exceeds innermost extent",
            Code::BadUnroll => "unroll factor outside {1, 2, 4, 8}",
            Code::ParallelTooDeep => "parallel depth exceeds loop count",
            Code::InlineNonPointwise => "inline of a non-pointwise/reduction stage",
            Code::InlineOutputStage => "inline of an output stage",
            Code::ComputeAtNonConsumer => "compute_at a non-consumer stage",
            Code::ComputeAtInlined => "compute_at an inlined consumer",
            Code::ComputeAtBadLevel => "compute_at level outside 1..=3",
            Code::SampleStructure => "sample structure broken",
            Code::EdgeOutOfRange => "edge endpoint outside the stage range",
            Code::NonFiniteFeature => "NaN/Inf feature value",
            Code::BadRuntimeLabel => "NaN/Inf/negative runtime measurement",
            Code::BadStats => "malformed normalization statistics",
            Code::NonFiniteTensor => "NaN/Inf bundle tensor value",
            Code::MalformedCsr => "malformed CSR adjacency",
            Code::NonTopologicalEdge => "edge violates topological order",
            Code::UnusedInput => "pipeline input never read",
            Code::DeadStage => "stage unreachable from the final output",
            Code::ComputeAtDeep => "compute_at level deeper than consumer nest",
            Code::FusedMultiConsumer => "fused producer has other consumers",
        }
    }
}

/// One finding: a code, an optional source location (stage id + name) and
/// the human-readable detail.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: Code,
    /// Stage id the finding anchors to, if any.
    pub stage: Option<usize>,
    /// Stage (or tensor/sample) name for the location rendering.
    pub location: Option<String>,
    pub message: String,
}

impl Diagnostic {
    /// A finding with no stage location (whole-target findings).
    pub fn new(code: Code, message: impl Into<String>) -> Diagnostic {
        Diagnostic { code, stage: None, location: None, message: message.into() }
    }

    /// A finding anchored to a stage.
    pub fn at_stage(
        code: Code,
        stage: usize,
        name: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic { code, stage: Some(stage), location: Some(name.into()), message: message.into() }
    }

    /// A finding anchored to a named location without a stage id.
    pub fn at(code: Code, location: impl Into<String>, message: impl Into<String>) -> Diagnostic {
        Diagnostic { code, stage: None, location: Some(location.into()), message: message.into() }
    }

    pub fn severity(&self) -> Severity {
        self.code.severity()
    }

    fn location_str(&self) -> String {
        match (self.stage, &self.location) {
            (Some(i), Some(n)) => format!(" stage {i} ({n}):"),
            (Some(i), None) => format!(" stage {i}:"),
            (None, Some(n)) => format!(" {n}:"),
            (None, None) => String::new(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::Str(self.code.as_str().into())),
            ("severity", Json::Str(self.severity().as_str().into())),
            (
                "stage",
                match self.stage {
                    Some(i) => Json::Num(i as f64),
                    None => Json::Null,
                },
            ),
            (
                "location",
                match &self.location {
                    Some(n) => Json::Str(n.clone()),
                    None => Json::Null,
                },
            ),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}[{}]{} {}",
            self.severity().as_str(),
            self.code.as_str(),
            self.location_str(),
            self.message
        )
    }
}

impl std::error::Error for Diagnostic {}

/// All findings for one analyzed target, plus informational notes (e.g.
/// storage-footprint estimates) that render without affecting the verdict.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// What was analyzed ("zoo/resnet18", "dataset data/ds.bin", ...).
    pub target: String,
    pub diags: Vec<Diagnostic>,
    /// Informational lines (no severity, never affect the exit code).
    pub info: Vec<String>,
}

impl Report {
    pub fn new(target: impl Into<String>) -> Report {
        Report { target: target.into(), diags: Vec::new(), info: Vec::new() }
    }

    pub fn push(&mut self, d: Diagnostic) {
        self.diags.push(d);
    }

    pub fn extend(&mut self, ds: impl IntoIterator<Item = Diagnostic>) {
        self.diags.extend(ds);
    }

    pub fn note(&mut self, line: impl Into<String>) {
        self.info.push(line.into());
    }

    pub fn errors(&self) -> usize {
        self.diags.iter().filter(|d| d.severity() == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diags.iter().filter(|d| d.severity() == Severity::Warning).count()
    }

    /// Clean = no errors; under `strict`, warnings also fail.
    pub fn is_clean(&self, strict: bool) -> bool {
        self.errors() == 0 && (!strict || self.warnings() == 0)
    }

    /// Multi-line human rendering (errors first, then warnings, then notes).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{}: {} error(s), {} warning(s)\n",
            self.target,
            self.errors(),
            self.warnings()
        ));
        let mut sorted: Vec<&Diagnostic> = self.diags.iter().collect();
        sorted.sort_by_key(|d| std::cmp::Reverse(d.severity()));
        for d in sorted {
            out.push_str(&format!("  {d}\n"));
        }
        for n in &self.info {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("target", Json::Str(self.target.clone())),
            ("errors", Json::Num(self.errors() as f64)),
            ("warnings", Json::Num(self.warnings() as f64)),
            ("diagnostics", Json::Arr(self.diags.iter().map(|d| d.to_json()).collect())),
            ("info", Json::Arr(self.info.iter().map(|n| Json::Str(n.clone())).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_prefixed_consistently() {
        let mut seen = std::collections::BTreeSet::new();
        for c in Code::ALL {
            assert!(seen.insert(c.as_str()), "duplicate wire code {}", c.as_str());
            let warn = c.as_str().starts_with('W');
            assert_eq!(
                c.severity() == Severity::Warning,
                warn,
                "{} severity disagrees with its prefix",
                c.as_str()
            );
        }
        assert!(Code::ALL.len() >= 10, "the contract documents at least 10 codes");
    }

    #[test]
    fn diagnostic_renders_code_and_location() {
        let d = Diagnostic::at_stage(Code::VectorExceedsExtent, 2, "conv2d", "width 8 > extent 1");
        let s = d.to_string();
        assert!(s.contains("error[S005]"), "{s}");
        assert!(s.contains("stage 2 (conv2d)"), "{s}");
        let j = d.to_json().to_string();
        assert!(j.contains("\"S005\""), "{j}");
    }

    #[test]
    fn report_verdict_and_strict_mode() {
        let mut r = Report::new("t");
        assert!(r.is_clean(true));
        r.push(Diagnostic::new(Code::UnusedInput, "input 1 never read"));
        assert!(r.is_clean(false) && !r.is_clean(true));
        r.push(Diagnostic::new(Code::ShapeMismatch, "bad"));
        assert!(!r.is_clean(false));
        assert_eq!(r.errors(), 1);
        assert_eq!(r.warnings(), 1);
        let text = r.to_text();
        assert!(text.contains("error[A004]") && text.contains("warning[W001]"), "{text}");
    }
}
