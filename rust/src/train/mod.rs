//! Training driver: mini-batch epochs over any [`Backend`] train step,
//! test-set evaluation, early stopping and checkpointing.

pub mod active;

use crate::constants::BATCH;
use crate::dataset::sample::Dataset;
use crate::model::PackedBatch;
use crate::predictor::{save_gcn_bundle, GcnView, Predictor};
use crate::runtime::{Backend, Params};
use crate::util::rng::Rng;
use crate::util::stats;
use anyhow::{Context, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub seed: u64,
    /// Stop after this many epochs without test-MAPE improvement.
    pub patience: usize,
    /// Evaluate on the test set every `eval_every` epochs.
    pub eval_every: usize,
    pub verbose: bool,
    /// Adagrad learning rate (paper: 0.0075).
    pub lr: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 40,
            seed: 7,
            patience: 8,
            eval_every: 1,
            verbose: true,
            lr: crate::constants::LEARNING_RATE as f32,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_mape: f64,
}

pub struct TrainResult {
    pub params: Params,
    pub history: Vec<EpochStats>,
    pub best_test_mape: f64,
}

/// Build all packed batches for an epoch from shuffled sample indices
/// (`BATCH` graphs per batch — a chunking policy, not a layout cap).
fn epoch_batches(
    ds: &Dataset,
    order: &[usize],
    best: &std::collections::BTreeMap<u32, f64>,
) -> Result<Vec<PackedBatch>> {
    let stats = ds.stats.as_ref().context("dataset stats fitted")?;
    order
        .chunks(BATCH)
        .map(|chunk| {
            let samples: Vec<&crate::dataset::sample::GraphSample> =
                chunk.iter().map(|&i| &ds.samples[i]).collect();
            let bests: Vec<f64> = samples.iter().map(|s| best[&s.pipeline_id]).collect();
            PackedBatch::build(&samples, stats, &bests)
        })
        .collect()
}

/// Mean-absolute-percentage error of a predictor's runtime predictions on
/// `ds`.
pub fn evaluate_predictor_mape(p: &dyn Predictor, ds: &Dataset) -> Result<f64> {
    let refs: Vec<&crate::dataset::sample::GraphSample> = ds.samples.iter().collect();
    let preds = p.predict(&refs)?;
    let truth: Vec<f64> = ds.samples.iter().map(|s| s.mean_runtime()).collect();
    Ok(stats::mape(&truth, &preds))
}

/// [`evaluate_predictor_mape`] for the training loop's loose
/// (backend, params) pairs, viewed through [`GcnView`] so the prediction
/// path is the same one the served session uses.
pub fn evaluate_mape(rt: &dyn Backend, params: &Params, ds: &Dataset) -> Result<f64> {
    let stats = ds.stats.as_ref().context("dataset stats")?;
    evaluate_predictor_mape(&GcnView { backend: rt, params, stats }, ds)
}

/// Train the GCN on `train`, tracking MAPE on `test`; returns the params
/// from the best epoch.
pub fn train(
    rt: &dyn Backend,
    train_ds: &Dataset,
    test_ds: &Dataset,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    let mut params = rt.init_params(cfg.seed);
    // initialize the output bias to the train-set mean log-runtime so the
    // model starts at the right scale instead of e^|ȳ_log| off (standard
    // output-bias initialization; cuts ~10 epochs of pure rescaling)
    let mean_log_y: f64 = train_ds
        .samples
        .iter()
        .map(|s| s.mean_runtime().max(1e-12).ln())
        .sum::<f64>()
        / train_ds.len().max(1) as f64;
    if let Some(b_out) = params.values.last_mut() {
        if b_out.len() == 1 {
            b_out[0] = mean_log_y as f32;
        }
    }
    let mut accum = params.zeros_like();
    let mut rng = Rng::new(cfg.seed ^ 0xABCD);
    let best_rt = train_ds.best_per_pipeline();

    let mut history = Vec::new();
    let mut best_mape = f64::INFINITY;
    let mut best_params = params.clone();
    let mut since_best = 0;

    for epoch in 0..cfg.epochs {
        let mut order: Vec<usize> = (0..train_ds.len()).collect();
        rng.shuffle(&mut order);
        let batches = epoch_batches(train_ds, &order, &best_rt)?;
        let mut losses = Vec::with_capacity(batches.len());
        for b in &batches {
            losses.push(rt.train_step_lr(&mut params, &mut accum, b, cfg.lr)? as f64);
        }
        let train_loss = stats::mean(&losses);

        let mut ep = EpochStats { epoch, train_loss, test_mape: f64::NAN };
        if epoch % cfg.eval_every == 0 || epoch == cfg.epochs - 1 {
            let mape = evaluate_mape(rt, &params, test_ds)?;
            ep.test_mape = mape;
            if mape < best_mape {
                best_mape = mape;
                best_params = params.clone();
                since_best = 0;
            } else {
                since_best += 1;
            }
            if cfg.verbose {
                eprintln!(
                    "epoch {epoch:>3}  train_loss {train_loss:>9.4}  test MAPE {mape:>7.2}%"
                );
            }
            if since_best >= cfg.patience {
                if cfg.verbose {
                    eprintln!("early stop at epoch {epoch} (patience {})", cfg.patience);
                }
                history.push(ep);
                break;
            }
        } else if cfg.verbose {
            eprintln!("epoch {epoch:>3}  train_loss {train_loss:>9.4}");
        }
        history.push(ep);
    }

    Ok(TrainResult { params: best_params, history, best_test_mape: best_mape })
}

/// Convenience: train and write a single-file model bundle (params +
/// training-set feature stats) that [`crate::predictor::GcnPredictor::load`]
/// serves directly — no loose stats file, no dataset re-split at eval
/// time.
pub fn train_and_save(
    rt: &dyn Backend,
    train_ds: &Dataset,
    test_ds: &Dataset,
    cfg: &TrainConfig,
    bundle_path: &Path,
) -> Result<TrainResult> {
    let result = train(rt, train_ds, test_ds, cfg)?;
    let stats = train_ds.stats.as_ref().context("train stats")?;
    save_gcn_bundle(bundle_path, rt.manifest().n_conv, &result.params, stats)?;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    #[test]
    fn epoch_batches_cover_all_samples() {
        let cfg = DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 10,
            seed: 3,
            ..Default::default()
        };
        let ds = build_dataset(&cfg);
        let best = ds.best_per_pipeline();
        let order: Vec<usize> = (0..ds.len()).collect();
        let batches = epoch_batches(&ds, &order, &best).unwrap();
        let covered: usize = batches.iter().map(|b| b.n_graphs()).sum();
        assert_eq!(covered, ds.len());
        // no batch exceeds the chunk size; every graph keeps its own nodes
        for b in &batches {
            assert!(b.n_graphs() <= BATCH);
            let nodes: usize = (0..b.n_graphs()).map(|g| b.graph_nodes(g).len()).sum();
            assert_eq!(nodes, b.total_nodes());
        }
    }
}
