//! Training driver: mini-batch epochs over any [`Backend`] train step,
//! test-set evaluation, early stopping and checkpointing.
//!
//! The loop consumes a [`SourceView`] (see [`crate::dataset::stream`]),
//! not a `Vec` of samples: batches are planned from index metadata and
//! decoded one at a time, so peak memory is bounded by the node budget
//! regardless of corpus size. [`train`] wraps an in-RAM [`Dataset`] in a
//! [`MemorySource`] and runs the *same* [`train_source`] loop — the two
//! paths differ only in where `fetch` reads from, which is what makes
//! streamed training bitwise-identical to in-RAM training whenever the
//! corpus fits (pinned by `streamed_training_matches_in_ram_bitwise`).
//! Graphs above the node budget train through block-aligned partitions
//! ([`crate::model::partition`]) with share-scaled labels.

pub mod active;

use crate::constants::BATCH;
use crate::dataset::sample::{Dataset, GraphSample};
use crate::dataset::stream::{plan_batches, MemorySource, SampleSource, SourceView};
use crate::model::partition::{combine_runtimes, partition_sample};
use crate::model::PackedBatch;
use crate::predictor::{save_gcn_bundle, GcnView, Predictor};
use crate::runtime::{Backend, Params};
use crate::util::rng::Rng;
use crate::util::stats;
use anyhow::{ensure, Context, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub seed: u64,
    /// Stop after this many epochs without test-MAPE improvement.
    pub patience: usize,
    /// Evaluate on the test set every `eval_every` epochs.
    pub eval_every: usize,
    pub verbose: bool,
    /// Adagrad learning rate (paper: 0.0075).
    pub lr: f32,
    /// Per-batch packed-node ceiling: batches cut at [`BATCH`] graphs or
    /// this many nodes, whichever binds first, and single graphs above
    /// it train through the partition-sampled path. Defaults to
    /// [`crate::constants::node_budget`].
    pub node_budget: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 40,
            seed: 7,
            patience: 8,
            eval_every: 1,
            verbose: true,
            lr: crate::constants::LEARNING_RATE as f32,
            node_budget: crate::constants::node_budget(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_mape: f64,
}

pub struct TrainResult {
    pub params: Params,
    pub history: Vec<EpochStats>,
    pub best_test_mape: f64,
}

/// Mean-absolute-percentage error of a predictor's runtime predictions on
/// `ds`.
pub fn evaluate_predictor_mape(p: &dyn Predictor, ds: &Dataset) -> Result<f64> {
    let refs: Vec<&crate::dataset::sample::GraphSample> = ds.samples.iter().collect();
    let preds = p.predict(&refs)?;
    let truth: Vec<f64> = ds.samples.iter().map(|s| s.mean_runtime()).collect();
    Ok(stats::mape(&truth, &preds))
}

/// [`evaluate_predictor_mape`] for the training loop's loose
/// (backend, params) pairs, viewed through [`GcnView`] so the prediction
/// path is the same one the served session uses.
pub fn evaluate_mape(rt: &dyn Backend, params: &Params, ds: &Dataset) -> Result<f64> {
    let stats = ds.stats.as_ref().context("dataset stats")?;
    evaluate_predictor_mape(&GcnView { backend: rt, params, stats }, ds)
}

/// Streaming MAPE over a [`SourceView`]: samples decode in node-budget
/// chunks (one chunk resident at a time), graphs above the budget are
/// predicted per partition and recombined. Predictions are chunk-
/// invariant (block-diagonal packing), so this matches [`evaluate_mape`]
/// bitwise on any view whose graphs fit the budget.
pub fn evaluate_mape_source(
    rt: &dyn Backend,
    params: &Params,
    view: &SourceView,
    node_budget: usize,
) -> Result<f64> {
    let p = GcnView { backend: rt, params, stats: &view.stats };
    let mut truth = Vec::with_capacity(view.len());
    let mut preds = Vec::with_capacity(view.len());
    for chunk in view.iter().budget_chunks(node_budget) {
        let chunk = chunk?;
        if chunk.len() == 1 && chunk[0].n_stages as usize > node_budget {
            let part = partition_sample(&chunk[0], node_budget);
            let refs: Vec<&GraphSample> = part.parts.iter().collect();
            let part_preds = p.predict(&refs)?;
            truth.push(chunk[0].mean_runtime());
            preds.push(combine_runtimes(&part_preds));
        } else {
            let refs: Vec<&GraphSample> = chunk.iter().collect();
            let ys = p.predict(&refs)?;
            for (s, y) in chunk.iter().zip(ys) {
                truth.push(s.mean_runtime());
                preds.push(y);
            }
        }
    }
    Ok(stats::mape(&truth, &preds))
}

/// One training step over a slice of decoded samples with their α
/// denominators. Builds the packed batch, steps, returns the loss.
fn step_batch(
    rt: &dyn Backend,
    params: &mut Params,
    accum: &mut Params,
    refs: &[&GraphSample],
    bests: &[f64],
    stats: &crate::features::normalize::FeatureStats,
    lr: f32,
) -> Result<f64> {
    let b = PackedBatch::build(refs, stats, bests)?;
    Ok(rt.train_step_lr(params, accum, &b, lr)? as f64)
}

/// Train the GCN over streaming sources, tracking MAPE on `test`;
/// returns the params from the best epoch. Peak memory: one decoded
/// batch (≤ the node budget, plus one over-budget graph's partitions
/// when the corpus has any).
pub fn train_source(
    rt: &dyn Backend,
    train: &SourceView,
    test: &SourceView,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    ensure!(!train.is_empty(), "empty training source");
    let node_budget = cfg.node_budget.max(1);
    let mut params = rt.init_params(cfg.seed);
    // initialize the output bias to the train-set mean log-runtime so the
    // model starts at the right scale instead of e^|ȳ_log| off (standard
    // output-bias initialization; cuts ~10 epochs of pure rescaling)
    let mut sum_log_y = 0.0f64;
    for s in train.iter() {
        sum_log_y += s?.mean_runtime().max(1e-12).ln();
    }
    let mean_log_y = sum_log_y / train.len().max(1) as f64;
    if let Some(b_out) = params.values.last_mut() {
        if b_out.len() == 1 {
            b_out[0] = mean_log_y as f32;
        }
    }
    let mut accum = params.zeros_like();
    let mut rng = Rng::new(cfg.seed ^ 0xABCD);
    let best_rt = train.best_per_pipeline()?;

    let mut history = Vec::new();
    let mut best_mape = f64::INFINITY;
    let mut best_params = params.clone();
    let mut since_best = 0;

    for epoch in 0..cfg.epochs {
        let mut order: Vec<usize> = (0..train.len()).collect();
        rng.shuffle(&mut order);
        let mut losses = Vec::new();
        for batch_idx in plan_batches(train, &order, BATCH, node_budget) {
            let samples: Vec<GraphSample> =
                batch_idx.iter().map(|&i| train.fetch(i)).collect::<Result<_>>()?;
            if samples.len() == 1 && samples[0].n_stages as usize > node_budget {
                // partition-sampled path: block-aligned sub-graphs with
                // share-scaled labels and α denominators (the pinned
                // approximation — see model::partition)
                let best = best_rt[&samples[0].pipeline_id];
                let part = partition_sample(&samples[0], node_budget);
                let mut start = 0;
                while start < part.parts.len() {
                    let mut nodes = 0usize;
                    let mut end = start;
                    while end < part.parts.len() && end - start < BATCH {
                        let n = part.parts[end].n_stages as usize;
                        if end > start && nodes + n > node_budget {
                            break;
                        }
                        nodes += n;
                        end += 1;
                    }
                    let refs: Vec<&GraphSample> = part.parts[start..end].iter().collect();
                    let bests: Vec<f64> =
                        part.shares[start..end].iter().map(|&sh| best * sh).collect();
                    losses.push(step_batch(
                        rt, &mut params, &mut accum, &refs, &bests, &train.stats, cfg.lr,
                    )?);
                    start = end;
                }
            } else {
                let refs: Vec<&GraphSample> = samples.iter().collect();
                let bests: Vec<f64> =
                    samples.iter().map(|s| best_rt[&s.pipeline_id]).collect();
                losses.push(step_batch(
                    rt, &mut params, &mut accum, &refs, &bests, &train.stats, cfg.lr,
                )?);
            }
        }
        let train_loss = stats::mean(&losses);

        let mut ep = EpochStats { epoch, train_loss, test_mape: f64::NAN };
        if epoch % cfg.eval_every == 0 || epoch == cfg.epochs - 1 {
            let mape = evaluate_mape_source(rt, &params, test, node_budget)?;
            ep.test_mape = mape;
            if mape < best_mape {
                best_mape = mape;
                best_params = params.clone();
                since_best = 0;
            } else {
                since_best += 1;
            }
            if cfg.verbose {
                eprintln!(
                    "epoch {epoch:>3}  train_loss {train_loss:>9.4}  test MAPE {mape:>7.2}%"
                );
            }
            if since_best >= cfg.patience {
                if cfg.verbose {
                    eprintln!("early stop at epoch {epoch} (patience {})", cfg.patience);
                }
                history.push(ep);
                break;
            }
        } else if cfg.verbose {
            eprintln!("epoch {epoch:>3}  train_loss {train_loss:>9.4}");
        }
        history.push(ep);
    }

    Ok(TrainResult { params: best_params, history, best_test_mape: best_mape })
}

/// Train the GCN on `train`, tracking MAPE on `test`; returns the params
/// from the best epoch. In-RAM front-end of [`train_source`] — same
/// loop, same numbers.
pub fn train(
    rt: &dyn Backend,
    train_ds: &Dataset,
    test_ds: &Dataset,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    let tstats = train_ds.stats.as_ref().context("dataset stats fitted")?;
    let estats = test_ds.stats.as_ref().unwrap_or(tstats);
    let tsrc = MemorySource(train_ds);
    let esrc = MemorySource(test_ds);
    let tview = SourceView::whole(&tsrc, tstats.clone());
    let eview = SourceView::whole(&esrc, estats.clone());
    train_source(rt, &tview, &eview, cfg)
}

/// Convenience: train and write a single-file model bundle (params +
/// training-set feature stats) that [`crate::predictor::GcnPredictor::load`]
/// serves directly — no loose stats file, no dataset re-split at eval
/// time.
pub fn train_and_save(
    rt: &dyn Backend,
    train_ds: &Dataset,
    test_ds: &Dataset,
    cfg: &TrainConfig,
    bundle_path: &Path,
) -> Result<TrainResult> {
    let result = train(rt, train_ds, test_ds, cfg)?;
    let stats = train_ds.stats.as_ref().context("train stats")?;
    save_gcn_bundle(bundle_path, rt.manifest().n_conv, &result.params, stats)?;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};
    use crate::dataset::shard::{ShardWriter, ShardedDataset};
    use crate::dataset::stream::split_source;
    use crate::runtime::NativeBackend;

    #[test]
    fn streamed_training_matches_in_ram_bitwise() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 6,
            seed: 3,
            ..Default::default()
        });
        let dir = std::env::temp_dir().join("gcn_perf_train_stream_parity");
        std::fs::remove_dir_all(&dir).ok();
        let mut w = ShardWriter::create(&dir).unwrap();
        for s in &ds.samples {
            w.push(s).unwrap();
        }
        w.finish(None).unwrap();
        let sd = ShardedDataset::open(&dir).unwrap();

        let cfg =
            TrainConfig { epochs: 2, patience: 8, verbose: false, ..Default::default() };
        let rt = NativeBackend::new();

        let (train_ds, test_ds) = ds.split(0.25, 7);
        let in_ram = train(&rt, &train_ds, &test_ds, &cfg).unwrap();

        let (tv, ev) = split_source(&sd, 0.25, 7).unwrap();
        let streamed = train_source(&rt, &tv, &ev, &cfg).unwrap();

        // the whole point of the shared loop: same split, same stats,
        // same shuffles, same batches — bitwise-identical results
        assert_eq!(in_ram.params.values, streamed.params.values);
        assert_eq!(in_ram.best_test_mape.to_bits(), streamed.best_test_mape.to_bits());
        assert_eq!(in_ram.history.len(), streamed.history.len());
        for (a, b) in in_ram.history.iter().zip(&streamed.history) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn over_budget_graph_trains_and_evaluates_through_partitions() {
        let mut big = crate::testfix::chain_sample(1500, 1e-3);
        big.pipeline_id = 1;
        // de-constant the runs so β has a real std to normalize
        for (i, r) in big.runs.iter_mut().enumerate() {
            *r += i as f32 * 1e-5;
        }
        let mut small = crate::testfix::chain_sample(40, 2e-3);
        small.pipeline_id = 2;
        for (i, r) in small.runs.iter_mut().enumerate() {
            *r += i as f32 * 1e-5;
        }
        let mut ds = Dataset { samples: vec![big, small], stats: None };
        ds.fit_stats();

        let src = MemorySource(&ds);
        let view = SourceView::whole(&src, ds.stats.clone().unwrap());
        let cfg = TrainConfig {
            epochs: 1,
            verbose: false,
            node_budget: 512,
            ..Default::default()
        };
        let rt = NativeBackend::new();
        let r = train_source(&rt, &view, &view, &cfg).unwrap();
        // the 1500-node graph stepped as 3 partitions + the small graph:
        // training completed inside the 512-node budget with finite loss
        assert!(r.history[0].train_loss.is_finite());
        assert!(r.best_test_mape.is_finite());
    }
}
