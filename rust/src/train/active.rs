//! Active learning (paper §VI-B future work: "examine the role active
//! learning can play … and help retain the same accuracy with a smaller
//! training set").
//!
//! Committee-disagreement acquisition: train the GCN on a seed subset, fit
//! a cheap GBT committee member on the same subset, and at each round move
//! the pool samples where the two models disagree most (in log-runtime)
//! into the labeled set. Compare against random acquisition at equal
//! budget.

use crate::baselines::gbt::{Gbt, GbtConfig};
use crate::dataset::sample::Dataset;
use crate::predictor::{GcnView, Predictor};
use crate::runtime::Backend;
use crate::train::{evaluate_predictor_mape, train, TrainConfig};
use crate::util::rng::Rng;
use anyhow::{Context, Result};

#[derive(Debug, Clone)]
pub struct ActiveConfig {
    /// Initial labeled fraction of the training pool.
    pub seed_frac: f64,
    /// Samples acquired per round.
    pub acquire: usize,
    pub rounds: usize,
    /// GCN epochs per round (short — this is a sample-efficiency study).
    pub epochs_per_round: usize,
    pub seed: u64,
}

impl Default for ActiveConfig {
    fn default() -> Self {
        ActiveConfig { seed_frac: 0.1, acquire: 1024, rounds: 4, epochs_per_round: 8, seed: 3 }
    }
}

#[derive(Debug, Clone)]
pub struct ActiveRound {
    pub round: usize,
    pub labeled: usize,
    pub test_mape_active: f64,
    pub test_mape_random: f64,
}

fn subset(ds: &Dataset, idx: &[usize]) -> Dataset {
    let mut out = Dataset {
        samples: idx.iter().map(|&i| ds.samples[i].clone()).collect(),
        stats: None,
    };
    out.fit_stats();
    out
}

/// The round's GCN as a borrowing predictor session (stats come from the
/// labeled subset the round trained on).
fn round_view<'a>(
    rt: &'a dyn Backend,
    params: &'a crate::runtime::Params,
    ds: &'a Dataset,
) -> Result<GcnView<'a>> {
    let stats = ds.stats.as_ref().context("labeled subset stats")?;
    Ok(GcnView { backend: rt, params, stats })
}

/// Run the active-learning study; returns per-round test MAPE for the
/// committee-disagreement strategy vs random acquisition.
pub fn active_learning_study(
    rt: &dyn Backend,
    pool: &Dataset,
    test: &Dataset,
    cfg: &ActiveConfig,
) -> Result<Vec<ActiveRound>> {
    let mut rng = Rng::new(cfg.seed);
    let n = pool.len();
    let n_seed = ((n as f64 * cfg.seed_frac) as usize).max(crate::constants::BATCH);

    let mut all: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut all);
    let seed_idx: Vec<usize> = all[..n_seed].to_vec();

    let mut labeled_active = seed_idx.clone();
    let mut pool_active: Vec<usize> = all[n_seed..].to_vec();
    let mut labeled_random = seed_idx;
    let mut pool_random: Vec<usize> = all[n_seed..].to_vec();

    let tcfg = TrainConfig {
        epochs: cfg.epochs_per_round,
        seed: cfg.seed,
        patience: cfg.epochs_per_round + 1,
        verbose: false,
        eval_every: cfg.epochs_per_round.max(1),
        ..Default::default()
    };

    let mut rounds = Vec::new();
    for round in 0..cfg.rounds {
        // --- active arm
        let ds_a = subset(pool, &labeled_active);
        let res_a = train(rt, &ds_a, test, &tcfg)?;
        let mape_a = evaluate_predictor_mape(&round_view(rt, &res_a.params, &ds_a)?, test)?;

        // --- random arm (same budget)
        let ds_r = subset(pool, &labeled_random);
        let res_r = train(rt, &ds_r, test, &tcfg)?;
        let mape_r = evaluate_predictor_mape(&round_view(rt, &res_r.params, &ds_r)?, test)?;

        rounds.push(ActiveRound {
            round,
            labeled: labeled_active.len(),
            test_mape_active: mape_a,
            test_mape_random: mape_r,
        });

        if round + 1 == cfg.rounds {
            break;
        }

        // --- acquisition: committee disagreement on the remaining pool
        let gbt = Gbt::fit(&ds_a, GbtConfig { n_trees: 40, ..Default::default() });
        let pool_refs: Vec<&crate::dataset::sample::GraphSample> =
            pool_active.iter().map(|&i| &pool.samples[i]).collect();
        let gcn_pred = round_view(rt, &res_a.params, &ds_a)?.predict(&pool_refs)?;
        let mut scored: Vec<(usize, f64)> = pool_active
            .iter()
            .zip(&gcn_pred)
            .map(|(&i, &g)| {
                let t = gbt.predict_sample(&pool.samples[i]);
                let disagreement = (g.max(1e-12).ln() - t.max(1e-12).ln()).abs();
                (i, disagreement)
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let take = cfg.acquire.min(scored.len());
        let acquired: Vec<usize> = scored[..take].iter().map(|(i, _)| *i).collect();
        labeled_active.extend(&acquired);
        pool_active.retain(|i| !acquired.contains(i));

        // random arm acquires the same count uniformly
        let take_r = cfg.acquire.min(pool_random.len());
        for _ in 0..take_r {
            let j = rng.gen_range(pool_random.len());
            labeled_random.push(pool_random.swap_remove(j));
        }
    }
    Ok(rounds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_refits_stats() {
        use crate::dataset::builder::{build_dataset, DataGenConfig};
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 4,
            schedules_per_pipeline: 4,
            seed: 3,
            ..Default::default()
        });
        let sub = subset(&ds, &[0, 3, 7]);
        assert_eq!(sub.len(), 3);
        assert!(sub.stats.is_some());
    }
}
