//! Data-parallel helpers built on `std::thread::scope`.
//!
//! The dataset pipeline and evaluation harnesses are embarrassingly parallel;
//! scoped threads with work-stealing-by-chunks cover everything we need
//! without an external runtime.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (respects `GCN_PERF_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("GCN_PERF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Spawn a named detached OS thread (the predict-service workers use
/// this so stack traces and debuggers show which subsystem a thread
/// belongs to). Thread-spawn failure means OS resource exhaustion, which
/// nothing above this layer can recover from — it aborts loudly rather
/// than limping on with fewer workers than the caller sized for.
pub fn spawn_named<F>(name: String, f: F) -> std::thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.clone())
        .spawn(f)
        .unwrap_or_else(|e| panic!("failed to spawn thread '{name}': {e}"))
}

/// Apply `f` to every index in `0..n` in parallel, collecting results in
/// order. Work is claimed one index at a time from a shared atomic counter,
/// which load-balances well when per-item cost varies (e.g. benchmarking
/// schedules of very different pipelines). One scheduler serves every
/// parallel-map flavor: this is [`parallel_map_vec_threads`] over the
/// index sequence.
pub fn parallel_map_indexed<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    parallel_map_vec_threads((0..n).collect(), num_threads(), f)
}

/// Parallel map over a slice, preserving order.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_indexed(items.len(), |i| f(&items[i]))
}

/// Parallel map that passes each item *by value*, preserving order. This
/// is what lets the native engine hand every worker an owned bundle of
/// disjoint `&mut` sub-slices of one shared output buffer (a `Fn(&T)`
/// map cannot mutate through a shared reference to the item).
///
/// Results are written by item index, so the output — and any reduction
/// folded over it in index order — is independent of how workers
/// interleave.
pub fn parallel_map_vec<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    parallel_map_vec_threads(items, num_threads(), f)
}

/// [`parallel_map_vec`] with an explicit worker count. The native
/// engine's determinism tests run the same work at 1 and N threads and
/// assert bitwise-equal results; production callers use
/// [`parallel_map_vec`], which picks [`num_threads`].
pub fn parallel_map_vec_threads<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = lock_item(&slots[i]).take().expect("each item is claimed once");
                let r = f(item);
                // Short critical section: store one result.
                let mut guard = out_slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

fn lock_item<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Split `data` (a row-major `[n, width]` matrix) into one mutable
/// sub-slice per range. `ranges` must tile `0..n` contiguously in order
/// (as [`chunk_ranges`] and `PackedBatch::graph_blocks` produce); the
/// native engine uses this to let parallel workers write row blocks
/// directly into one preallocated output with no per-block staging
/// buffers.
pub fn split_rows<'a, T>(
    data: &'a mut [T],
    ranges: &[std::ops::Range<usize>],
    width: usize,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = data;
    let mut next = 0usize;
    for r in ranges {
        assert_eq!(r.start, next, "ranges must tile the rows contiguously");
        let (head, tail) = rest.split_at_mut(r.len() * width);
        out.push(head);
        rest = tail;
        next = r.end;
    }
    assert!(rest.is_empty(), "ranges must cover every row of the buffer");
    out
}

/// Contiguous index ranges covering `0..n`: at most [`num_threads`] of
/// them, each at least `min_len` long (the last may be shorter). The
/// native engine uses these as its parallel row blocks — callers get one
/// range back (i.e. "stay sequential") whenever `n` is below the point
/// where fan-out pays for itself.
pub fn chunk_ranges(n: usize, min_len: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let t = num_threads().max(1);
    let len = n.div_ceil(t).max(min_len.max(1));
    (0..n).step_by(len).map(|s| s..(s + len).min(n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let v: Vec<usize> = (0..257).collect();
        let out = parallel_map(&v, |x| x * 2);
        assert_eq!(out, (0..257).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map_indexed(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item() {
        let out = parallel_map_indexed(1, |i| i + 10);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, min) in [(0usize, 16usize), (1, 16), (15, 16), (16, 16), (1000, 64), (1000, 1)] {
            let ranges = chunk_ranges(n, min);
            let covered: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(covered, n, "n={n} min={min}");
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next);
                assert!(!r.is_empty());
                next = r.end;
            }
            assert!(ranges.len() <= num_threads().max(1));
        }
        assert_eq!(chunk_ranges(15, 16).len(), 1, "below min_len stays one block");
    }

    #[test]
    fn parallel_map_vec_matches_serial_bitwise() {
        // per-item floating-point sums must be identical at any worker
        // count: items are computed independently and stored by index
        let items: Vec<Vec<f64>> =
            (0..13).map(|i| (0..257).map(|j| (i * j) as f64 * 0.1).collect()).collect();
        let serial = parallel_map_vec_threads(items.clone(), 1, |v| v.iter().sum::<f64>());
        for threads in [2, 4, 8] {
            let par = parallel_map_vec_threads(items.clone(), threads, |v| v.iter().sum::<f64>());
            assert_eq!(serial, par, "results must be bitwise thread-count-independent");
        }
        assert_eq!(parallel_map_vec(items.clone(), |v| v.len()), vec![257; 13]);
        let empty: Vec<Vec<f64>> = Vec::new();
        assert!(parallel_map_vec(empty, |v: Vec<f64>| v.len()).is_empty());
    }

    #[test]
    fn parallel_map_vec_passes_mut_slices() {
        // the engine's pattern: disjoint &mut blocks of one buffer, each
        // filled by whichever worker claims the item
        let mut buf = vec![0u32; 100];
        let ranges = chunk_ranges(10, 1);
        let parts = split_rows(&mut buf, &ranges, 10);
        let tasks: Vec<(std::ops::Range<usize>, &mut [u32])> =
            ranges.iter().cloned().zip(parts).collect();
        parallel_map_vec(tasks, |(range, block)| {
            for (i, v) in block.iter_mut().enumerate() {
                *v = ((range.start + i / 10) * 10 + i % 10) as u32;
            }
        });
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
    }

    #[test]
    #[should_panic(expected = "contiguously")]
    fn split_rows_rejects_gaps() {
        let mut buf = vec![0u8; 30];
        let _ = split_rows(&mut buf, &[0..1, 2..3], 10);
    }

    #[test]
    fn uneven_work_balances() {
        // items with wildly different costs still all complete, in order
        let out = parallel_map_indexed(64, |i| {
            let mut acc = 0u64;
            for k in 0..(i * 1000) {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, item) in out.iter().enumerate() {
            assert_eq!(item.0, i);
        }
    }
}
