//! Data-parallel helpers built on `std::thread::scope`.
//!
//! The dataset pipeline and evaluation harnesses are embarrassingly parallel;
//! scoped threads with work-stealing-by-chunks cover everything we need
//! without an external runtime.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (respects `GCN_PERF_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("GCN_PERF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Spawn a named detached OS thread (the predict-service workers use
/// this so stack traces and debuggers show which subsystem a thread
/// belongs to). Thread-spawn failure means OS resource exhaustion, which
/// nothing above this layer can recover from — it aborts loudly rather
/// than limping on with fewer workers than the caller sized for.
pub fn spawn_named<F>(name: String, f: F) -> std::thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.clone())
        .spawn(f)
        .unwrap_or_else(|e| panic!("failed to spawn thread '{name}': {e}"))
}

/// Apply `f` to every index in `0..n` in parallel, collecting results in
/// order. Work is claimed one index at a time from a shared atomic counter,
/// which load-balances well when per-item cost varies (e.g. benchmarking
/// schedules of very different pipelines).
pub fn parallel_map_indexed<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                // Short critical section: store one result.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

/// Parallel map over a slice, preserving order.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_indexed(items.len(), |i| f(&items[i]))
}

/// Contiguous index ranges covering `0..n`: at most [`num_threads`] of
/// them, each at least `min_len` long (the last may be shorter). The
/// native engine uses these as its parallel row blocks — callers get one
/// range back (i.e. "stay sequential") whenever `n` is below the point
/// where fan-out pays for itself.
pub fn chunk_ranges(n: usize, min_len: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let t = num_threads().max(1);
    let len = n.div_ceil(t).max(min_len.max(1));
    (0..n).step_by(len).map(|s| s..(s + len).min(n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let v: Vec<usize> = (0..257).collect();
        let out = parallel_map(&v, |x| x * 2);
        assert_eq!(out, (0..257).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map_indexed(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item() {
        let out = parallel_map_indexed(1, |i| i + 10);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, min) in [(0usize, 16usize), (1, 16), (15, 16), (16, 16), (1000, 64), (1000, 1)] {
            let ranges = chunk_ranges(n, min);
            let covered: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(covered, n, "n={n} min={min}");
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next);
                assert!(!r.is_empty());
                next = r.end;
            }
            assert!(ranges.len() <= num_threads().max(1));
        }
        assert_eq!(chunk_ranges(15, 16).len(), 1, "below min_len stays one block");
    }

    #[test]
    fn uneven_work_balances() {
        // items with wildly different costs still all complete, in order
        let out = parallel_map_indexed(64, |i| {
            let mut acc = 0u64;
            for k in 0..(i * 1000) {
                acc = acc.wrapping_add(k as u64);
            }
            (i, acc)
        });
        for (i, item) in out.iter().enumerate() {
            assert_eq!(item.0, i);
        }
    }
}
