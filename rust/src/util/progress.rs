//! Terse stderr progress reporting for long-running pipeline stages.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

pub struct Progress {
    label: String,
    total: usize,
    done: AtomicUsize,
    start: Instant,
    every: usize,
    quiet: bool,
}

impl Progress {
    pub fn new(label: &str, total: usize) -> Self {
        let quiet = std::env::var("GCN_PERF_QUIET").is_ok();
        Progress {
            label: label.to_string(),
            total,
            done: AtomicUsize::new(0),
            start: Instant::now(),
            every: (total / 20).max(1),
            quiet,
        }
    }

    /// Record one completed unit; prints roughly every 5%.
    pub fn tick(&self) {
        let d = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        if !self.quiet && (d % self.every == 0 || d == self.total) {
            let elapsed = self.start.elapsed().as_secs_f64();
            let rate = d as f64 / elapsed.max(1e-9);
            let eta = (self.total - d) as f64 / rate.max(1e-9);
            eprintln!(
                "[{}] {}/{} ({:.0}%) {:.1}/s eta {:.0}s",
                self.label,
                d,
                self.total,
                100.0 * d as f64 / self.total.max(1) as f64,
                rate,
                eta
            );
        }
    }

    pub fn finish(&self) -> f64 {
        let elapsed = self.start.elapsed().as_secs_f64();
        if !self.quiet {
            eprintln!("[{}] done in {:.1}s", self.label, elapsed);
        }
        elapsed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_to_completion() {
        std::env::set_var("GCN_PERF_QUIET", "1");
        let p = Progress::new("test", 10);
        for _ in 0..10 {
            p.tick();
        }
        assert!(p.finish() >= 0.0);
    }
}
