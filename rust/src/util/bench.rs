//! Criterion-like micro-benchmark harness (criterion itself is not in the
//! offline vendor set). Warmup, fixed-duration sampling, and a summary with
//! mean / median / p95 and throughput.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ns)
    }
    pub fn median_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 50.0)
    }
    pub fn p95_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 95.0)
    }
    pub fn std_ns(&self) -> f64 {
        crate::util::stats::std_dev(&self.samples_ns)
    }

    pub fn report(&self) -> String {
        let q = crate::util::stats::Quantiles::new(&self.samples_ns);
        format!(
            "{:<42} {:>12} {:>12} {:>12} {:>10}",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(q.quantile(50.0)),
            fmt_ns(q.quantile(95.0)),
            format!("±{:.1}%", 100.0 * self.std_ns() / self.mean_ns().max(1e-12)),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{:.1} ns", ns)
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark a closure: warm up for `warmup`, then collect samples until
/// `measure` elapses (at least 10 samples). Each sample times `iters`
/// consecutive calls, where `iters` is auto-calibrated so one sample takes
/// roughly 1–10 ms.
pub fn bench<F: FnMut()>(name: &str, warmup: Duration, measure: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let wstart = Instant::now();
    let mut calib_iters = 0u64;
    while wstart.elapsed() < warmup || calib_iters == 0 {
        f();
        calib_iters += 1;
    }
    let per_call_ns = (wstart.elapsed().as_nanos() as f64 / calib_iters as f64).max(1.0);
    let iters = ((2e6 / per_call_ns).ceil() as u64).clamp(1, 1_000_000);

    let mut samples = Vec::new();
    let mstart = Instant::now();
    while mstart.elapsed() < measure || samples.len() < 10 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        if samples.len() >= 5000 {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        samples_ns: samples,
        iters_per_sample: iters,
    }
}

/// Convenience wrapper with default durations honoring `GCN_PERF_BENCH_FAST`.
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let fast = std::env::var("GCN_PERF_BENCH_FAST").is_ok();
    let (w, m) = if fast {
        (Duration::from_millis(50), Duration::from_millis(200))
    } else {
        (Duration::from_millis(300), Duration::from_secs(2))
    };
    bench(name, w, m, f)
}

pub fn header() -> String {
    format!(
        "{:<42} {:>12} {:>12} {:>12} {:>10}",
        "benchmark", "mean", "median", "p95", "stddev"
    )
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench(
            "noop",
            Duration::from_millis(5),
            Duration::from_millis(20),
            || {
                black_box(1 + 1);
            },
        );
        assert!(r.samples_ns.len() >= 10);
        assert!(r.mean_ns() >= 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).ends_with("s"));
    }
}
