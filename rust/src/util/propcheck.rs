//! Lightweight property-based testing (proptest is not in the offline vendor
//! set). Generates random cases from a seeded `Rng`, reports the failing
//! seed + iteration so a failure replays deterministically.

use crate::util::rng::Rng;

/// Number of cases per property (respects `GCN_PERF_PROPTEST_CASES`).
pub fn default_cases() -> usize {
    std::env::var("GCN_PERF_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` random inputs produced by `gen`. Panics with the
/// seed and case index on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = root.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (seed={seed}, case={case}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Variant for properties that want the rng themselves (e.g. to drive a
/// random sequence of operations rather than a single value).
pub fn check_rng(
    name: &str,
    seed: u64,
    cases: usize,
    mut prop: impl FnMut(&mut Rng) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = root.fork(case as u64);
        if let Err(msg) = prop(&mut case_rng) {
            panic!("property '{name}' failed (seed={seed}, case={case}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            "reverse-twice",
            1,
            32,
            |r| (0..r.gen_range(20)).map(|_| r.gen_range(100)).collect::<Vec<_>>(),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v {
                    Ok(())
                } else {
                    Err("reverse∘reverse != id".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 2, 8, |r| r.gen_range(10), |_| Err("nope".into()));
    }
}
