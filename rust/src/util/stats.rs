//! Summary statistics used by the simulator, evaluation and bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Sort-once quantile view over a set of observations.
///
/// The repo used to re-sort the same slice for every percentile asked of it
/// (latency snapshots computed p50/p90/p99 as three independent sorts); this
/// is the one shared implementation that `net::latency`, `util::bench`, and
/// the autotune fleet report all route through — sort once, query many.
#[derive(Debug, Clone)]
pub struct Quantiles {
    sorted: Vec<f64>,
}

impl Quantiles {
    /// Sort a copy of `xs` (NaN-safe `total_cmp` order). Input may be empty;
    /// queries on an empty view return 0.0.
    pub fn new(xs: &[f64]) -> Quantiles {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Quantiles { sorted }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn min(&self) -> f64 {
        self.sorted.first().copied().unwrap_or(0.0)
    }

    pub fn max(&self) -> f64 {
        self.sorted.last().copied().unwrap_or(0.0)
    }

    /// p-th percentile (0..=100) by linear interpolation; 0.0 when empty.
    pub fn quantile(&self, p: f64) -> f64 {
        let v = &self.sorted;
        if v.is_empty() {
            return 0.0;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            let w = rank - lo as f64;
            v[lo] * (1.0 - w) + v[hi] * w
        }
    }
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
/// One-shot convenience over [`Quantiles`]; build the struct when you need
/// several quantiles of the same data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    Quantiles::new(xs).quantile(p)
}

/// Coefficient of determination R² of predictions vs observations.
pub fn r2_score(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    assert!(!y_true.is_empty());
    let m = mean(y_true);
    let ss_res: f64 = y_true
        .iter()
        .zip(y_pred)
        .map(|(t, p)| (t - p) * (t - p))
        .sum();
    let ss_tot: f64 = y_true.iter().map(|t| (t - m) * (t - m)).sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Mean absolute percentage error: mean(|pred - true| / |true|) * 100.
pub fn mape(y_true: &[f64], y_pred: &[f64]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    let xs: Vec<f64> = y_true
        .iter()
        .zip(y_pred)
        .filter(|(t, _)| t.abs() > 0.0)
        .map(|(t, p)| ((p - t) / t).abs() * 100.0)
        .collect();
    mean(&xs)
}

/// Maximum absolute percentage error.
pub fn max_ape(y_true: &[f64], y_pred: &[f64]) -> f64 {
    y_true
        .iter()
        .zip(y_pred)
        .filter(|(t, _)| t.abs() > 0.0)
        .map(|(t, p)| ((p - t) / t).abs() * 100.0)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
    }

    #[test]
    fn quantiles_match_percentile_and_handle_empty() {
        let xs = [9.0, 1.0, 5.0, 3.0, 7.0];
        let q = Quantiles::new(&xs);
        for p in [0.0, 10.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(q.quantile(p), percentile(&xs, p), "p{p}");
        }
        assert_eq!(q.min(), 1.0);
        assert_eq!(q.max(), 9.0);
        assert_eq!(q.len(), 5);
        let empty = Quantiles::new(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.quantile(50.0), 0.0);
        assert_eq!(empty.min(), 0.0);
        assert_eq!(empty.max(), 0.0);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let t = [1.0, 2.0, 3.0, 4.0];
        assert!((r2_score(&t, &t) - 1.0).abs() < 1e-12);
        let m = [2.5, 2.5, 2.5, 2.5];
        assert!(r2_score(&t, &m).abs() < 1e-12);
    }

    #[test]
    fn mape_basic() {
        let t = [100.0, 200.0];
        let p = [110.0, 180.0];
        assert!((mape(&t, &p) - 10.0).abs() < 1e-9);
        assert!((max_ape(&t, &p) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn empty_mean_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }
}
