//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component of the system (model generator, schedule
//! sampler, simulator noise, train shuffling, baselines) takes an explicit
//! `Rng` so whole experiments replay bit-identically from one seed.

/// xoshiro256++ by Blackman & Vigna. Not cryptographic; fast and solid for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-worker determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the raw xoshiro256++ state for checkpointing.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot. Only feed this
    /// states captured from a live generator: the all-zero state is a fixed
    /// point of xoshiro and would emit zeros forever (`Rng::new` never
    /// produces it).
    pub fn from_state(s: [u64; 4]) -> Rng {
        assert!(s.iter().any(|&w| w != 0), "all-zero xoshiro state");
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift with rejection.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128).wrapping_mul(n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn gen_range_incl(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Log-normal multiplicative noise factor with sigma in log-space.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero total weight");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Uniformly choose an element of a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len())]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.0..4.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(20, 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(idx.iter().all(|&i| i < 20));
    }

    #[test]
    fn state_round_trip_resumes_stream() {
        let mut a = Rng::new(77);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    #[should_panic(expected = "all-zero")]
    fn from_state_rejects_zero() {
        let _ = Rng::from_state([0; 4]);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
