//! Dependency-free infrastructure.
//!
//! The offline build environment only vendors the `xla` crate's dependency
//! closure, so the usual ecosystem crates (rand, rayon, serde, clap,
//! criterion, proptest) are unavailable. This module provides the small
//! subset of their functionality the rest of the crate needs.

pub mod alloc_count;
pub mod rng;
pub mod threadpool;
pub mod json;
pub mod stats;
pub mod cli;
pub mod bench;
pub mod propcheck;
pub mod progress;
