//! Global + per-thread allocation counters — the measurement substrate
//! behind the "zero steady-state allocation" claim of the native
//! engine's workspace arena (see `runtime::workspace`).
//!
//! [`CountingAlloc`] wraps [`System`]: every heap allocation bumps a
//! relaxed process-wide atomic *and* a thread-local counter, then
//! delegates. The overhead is a couple of uncontended adds per
//! allocation — far below measurement noise for anything this crate
//! benches — and in exchange `gcn-perf bench --engine` can report real
//! allocations/op numbers in `BENCH_5.json` and the engine tests can
//! pin the steady-state allocation budget of the inference fast path.
//!
//! It is installed as the global allocator in exactly two places: the
//! `gcn-perf` binary (`main.rs`) and the library's own test harness
//! (`lib.rs`, under `#[cfg(test)]`). The plain library build does *not*
//! install it, so embedders keep their own global allocator; in that
//! configuration the counters simply stay at zero.
//!
//! Measurement windows: [`alloc_count`] is process-wide, so concurrent
//! threads pollute it (fine for a serial bench run, useless under
//! `cargo test`). [`thread_alloc_count`] counts only the calling
//! thread's allocations, which makes single-threaded windows exact no
//! matter what sibling tests are doing. The thread-local uses `const`
//! initialization, so reading or bumping it never allocates (no lazy
//! init) — the allocator cannot recurse into itself.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
static PEAK_BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TLS_COUNT: Cell<u64> = const { Cell::new(0) };
    static TLS_BYTES: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn bump(bytes: usize) {
    ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
    ALLOC_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    let live = LIVE_BYTES.fetch_add(bytes as u64, Ordering::Relaxed) + bytes as u64;
    PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
    // `try_with` instead of `with`: never panic inside the allocator,
    // even if a late allocation races thread teardown.
    let _ = TLS_COUNT.try_with(|c| c.set(c.get() + 1));
    let _ = TLS_BYTES.try_with(|c| c.set(c.get() + bytes as u64));
}

#[inline]
fn drop_bytes(bytes: usize) {
    // saturating: a buffer allocated before the counting allocator was
    // installed (or handed across the ffi boundary) must not underflow
    LIVE_BYTES
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(bytes as u64))
        })
        .ok();
}

/// Process-wide heap allocations since start (allocs + reallocs; frees
/// are not counted — this measures churn, not live bytes).
pub fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// Process-wide bytes requested from the allocator since start.
pub fn alloc_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// Heap allocations performed by the *calling thread* since it started.
/// Exact even while other threads allocate concurrently.
pub fn thread_alloc_count() -> u64 {
    TLS_COUNT.try_with(|c| c.get()).unwrap_or(0)
}

/// Bytes requested from the allocator by the calling thread.
pub fn thread_alloc_bytes() -> u64 {
    TLS_BYTES.try_with(|c| c.get()).unwrap_or(0)
}

/// Currently-live heap bytes (allocations minus frees), process-wide.
pub fn live_bytes() -> u64 {
    LIVE_BYTES.load(Ordering::Relaxed)
}

/// High-water mark of [`live_bytes`] since start (or since the last
/// [`reset_peak_bytes`]). This is the in-process analogue of MaxRSS the
/// scale bench reports per measurement lane.
pub fn peak_bytes() -> u64 {
    PEAK_BYTES.load(Ordering::Relaxed)
}

/// Restart the peak-tracking window at the current live level, so a
/// bench lane's peak is not dominated by whatever ran before it.
/// Process-wide — only meaningful around a serial measurement region.
pub fn reset_peak_bytes() {
    PEAK_BYTES.store(LIVE_BYTES.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// [`System`] with allocation counting. Installed as the crate's global
/// allocator so allocation budgets are observable in tests and benches.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump(layout.size());
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        drop_bytes(layout.size());
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump(new_size);
        drop_bytes(layout.size());
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump(layout.size());
        System.alloc_zeroed(layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_observe_allocations() {
        let count0 = thread_alloc_count();
        let bytes0 = thread_alloc_bytes();
        let global0 = alloc_count();
        let v: Vec<u8> = Vec::with_capacity(4096);
        let count1 = thread_alloc_count();
        let bytes1 = thread_alloc_bytes();
        assert!(count1 > count0, "allocation was not counted");
        assert!(bytes1 >= bytes0 + 4096, "allocation bytes were not counted");
        assert!(alloc_count() > global0);
        drop(v);
    }

    #[test]
    fn live_and_peak_track_a_large_buffer() {
        // other tests allocate and free concurrently, so only absolute
        // lower bounds are race-free: while the buffer is alive, the
        // process-wide live count must cover it, and the peak must too
        let v: Vec<u8> = Vec::with_capacity(1 << 20);
        // both hold even if a sibling test resets the peak window right
        // now: reset lands the peak at the live level, which covers `v`
        assert!(live_bytes() >= 1 << 20, "live bytes missed the buffer");
        assert!(peak_bytes() >= 1 << 20, "peak missed the buffer");
        drop(v);
    }

    #[test]
    fn thread_counter_ignores_other_threads() {
        let before = thread_alloc_count();
        std::thread::scope(|s| {
            s.spawn(|| {
                let big: Vec<u64> = Vec::with_capacity(1 << 16);
                drop(big);
            });
        });
        // the scope itself allocates on this thread (join handles), but
        // the worker's 512 KiB buffer must not land on our counter
        let delta = thread_alloc_count() - before;
        assert!(delta < 64, "spawned-thread allocations leaked into the TLS counter: {delta}");
    }
}
