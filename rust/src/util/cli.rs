//! Tiny argument parser: `prog <subcommand> --key value --flag`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(tok) = iter.peek() {
            if !tok.starts_with("--") {
                args.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // --key=value or --key value or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(key.to_string(), iter.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                return Err(format!("unexpected positional argument '{tok}'"));
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str_opt(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Validate that every `--key value` option and bare `--flag` the user
    /// passed is one `cmd` understands. A value option given without a
    /// value parses as a flag, so a flag matching a value key gets a
    /// "expects a value" message rather than "unknown".
    pub fn check_known(&self, cmd: &str, keys: &[&str], flags: &[&str]) -> Result<(), String> {
        let list = |xs: &[&str]| {
            xs.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", ")
        };
        for k in self.options.keys() {
            if !keys.contains(&k.as_str()) {
                return Err(format!(
                    "unknown option --{k} for '{cmd}' (valid options: {})",
                    list(keys)
                ));
            }
        }
        for f in &self.flags {
            if keys.contains(&f.as_str()) {
                return Err(format!("--{f} expects a value (e.g. --{f} <value>)"));
            }
            if !flags.contains(&f.as_str()) {
                let valid = if flags.is_empty() {
                    "none".to_string()
                } else {
                    list(flags)
                };
                return Err(format!("unknown flag --{f} for '{cmd}' (valid flags: {valid})"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --epochs 5 --lr=0.01 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("epochs", 0), 5);
        assert!((a.f64_or("lr", 0.0) - 0.01).abs() < 1e-12);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("eval");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("name", "x"), "x");
        assert!(!a.has_flag("nope"));
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse("gen --fast --out file.bin");
        assert!(a.has_flag("fast"));
        assert_eq!(a.str_opt("out"), Some("file.bin"));
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(vec!["train".into(), "oops".into()]).is_err());
    }

    #[test]
    fn check_known_accepts_declared_and_rejects_unknown() {
        let a = parse("bench --out x.json --fast");
        assert!(a.check_known("bench", &["out", "seed"], &["fast"]).is_ok());
        let err = a.check_known("bench", &["seed"], &["fast"]).unwrap_err();
        assert!(err.contains("--out") && err.contains("bench"), "{err}");
        let err = a.check_known("bench", &["out", "seed"], &[]).unwrap_err();
        assert!(err.contains("--fast"), "{err}");
    }

    #[test]
    fn check_known_flags_that_want_values_get_a_hint() {
        // `--out` at end of line parses as a flag; the message should say
        // a value is expected, not "unknown flag"
        let a = parse("bench --out");
        let err = a.check_known("bench", &["out"], &[]).unwrap_err();
        assert!(err.contains("expects a value"), "{err}");
    }
}
