//! Minimal JSON: enough to read `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and to emit experiment result files.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing input at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or("truncated utf8")?;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{}': {}", text, e))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err("expected ',' or ']'".into()),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err("expected ',' or '}'".into()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64(), Some(4.0));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(48.0).to_string(), "48");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
