//! Fleet autotuner: concurrent multi-pipeline schedule search driving
//! the shared [`crate::predictor::PredictService`].
//!
//! The paper's search loop (Fig 2) tunes one pipeline at a time. This
//! subsystem scales it out: a fleet of searches — one per pipeline, each
//! a resumable [`SearchStrategy`] — runs concurrently with every worker
//! scoring candidates through one shared service, so the coalescer fuses
//! frontiers from different searches into shared batches and the memo
//! cache is exercised by real cross-search load. Along the way each
//! search checkpoints its complete state to disk ([`checkpoint`]) and
//! records every scored candidate for cost-to-go trace harvesting
//! ([`trace`]), producing training data in the standard dataset format.
//!
//! * [`strategy`] — the [`SearchStrategy`] trait, the refactored
//!   [`BeamStrategy`] (what [`crate::search::beam_search`] now drives)
//!   and the seeded (μ+λ) [`EvolutionStrategy`].
//! * [`checkpoint`] — per-pipeline JSON checkpoints; resume is bitwise
//!   equivalent to an uninterrupted run.
//! * [`trace`] — search-trace recording with suffix-minimum cost-to-go
//!   labels (the Steiner-style value-head target).
//! * [`fleet`] — the driver: seeding, concurrency, the incumbent rule
//!   (never adopt a schedule the simulator says is worse than the
//!   default), and the fleet report.

pub mod checkpoint;
pub mod fleet;
pub mod strategy;
pub mod trace;

pub use checkpoint::Checkpoint;
pub use fleet::{run_fleet, FleetConfig, FleetCost, FleetReport, PipelineResult};
pub use strategy::{
    make_strategy, BeamStrategy, EvolutionConfig, EvolutionStrategy, SearchStrategy, StrategyKind,
};
pub use trace::TraceRecorder;
