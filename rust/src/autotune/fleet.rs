//! The fleet driver: tune many pipelines concurrently, all search
//! workers scoring through one shared [`PredictService`].
//!
//! Each pipeline gets its own strategy instance seeded from the fleet
//! seed (`seed ^ idx·golden-ratio`, the dataset builder's stream-split
//! idiom) and steps to completion on its own thread. Candidate scoring
//! funnels through the shared service, so the PR-4 coalescer fuses
//! frontiers from *different* searches into shared batches and the memo
//! cache serves repeat schedules across workers — real concurrent search
//! load on the serving stack. Because service predictions are bitwise
//! independent of batch composition (pinned since PR 4), the fleet's
//! results are deterministic for a fixed seed no matter how the workers
//! interleave, and `--sequential` mode reaches identical schedules.
//!
//! The incumbent rule makes tuning safe to apply blindly: the tuned
//! schedule is the search's best only if the *simulator* confirms it
//! beats the default schedule; otherwise the default is kept and
//! `adopted_default` is set. `tuned_cost <= default_cost` therefore holds
//! for every pipeline, whatever the cost model's quality.

use crate::autotune::checkpoint::Checkpoint;
use crate::autotune::strategy::{make_strategy, EvolutionConfig, SearchStrategy, StrategyKind};
use crate::autotune::trace::TraceRecorder;
use crate::dataset::GraphSample;
use crate::ir::pipeline::Pipeline;
use crate::lower::{lower_pipeline, LoopNest};
use crate::predictor::{PredictService, PredictorCost, ServiceStats};
use crate::schedule::primitives::PipelineSchedule;
use crate::search::{BeamConfig, CostModel, SimCost};
use crate::sim::{simulate, Machine};
use crate::util::json::Json;
use crate::util::stats::Quantiles;
use crate::util::threadpool::parallel_map_indexed;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// What scores the fleet's candidates.
pub enum FleetCost {
    /// The simulator itself (no service; baseline and tests).
    Oracle,
    /// A learned model behind a shared [`PredictService`] — every worker
    /// scores through this one service.
    Service(Arc<PredictService>),
}

/// Fleet-level configuration (`gcn-perf autotune`).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Zoo names of the pipelines to tune.
    pub networks: Vec<String>,
    pub strategy: StrategyKind,
    pub beam: BeamConfig,
    pub evolution: EvolutionConfig,
    pub machine: Machine,
    /// Fleet seed; per-pipeline strategy seeds derive from it.
    pub seed: u64,
    /// Tune pipelines one at a time instead of concurrently (the
    /// baseline `eval::autotune_bench` compares against).
    pub sequential: bool,
    /// Where per-pipeline checkpoints live; `None` disables them.
    pub checkpoint_dir: Option<PathBuf>,
    /// Save a checkpoint every this many generations (and always at
    /// completion).
    pub checkpoint_every: usize,
    /// Restart from existing checkpoints instead of from scratch.
    pub resume: bool,
    /// Stop each pipeline after this many generations *this invocation*
    /// (0 = run to completion). With checkpoints this scripts an
    /// interrupted run: hit the limit, save, `--resume` later.
    pub step_limit: usize,
    /// Max scored candidates recorded per pipeline for trace harvesting.
    pub trace_cap: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            networks: ["unet", "squeezenet", "alexnet", "resnet18"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            strategy: StrategyKind::Evolution,
            beam: BeamConfig::default(),
            evolution: EvolutionConfig::default(),
            machine: Machine::default(),
            seed: 1,
            sequential: false,
            checkpoint_dir: None,
            checkpoint_every: 2,
            resume: false,
            step_limit: 0,
            trace_cap: 256,
        }
    }
}

/// One pipeline's tuning outcome.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub network: String,
    /// False when `step_limit` stopped the search early (resume later).
    pub completed: bool,
    /// Total generations (across resumed invocations).
    pub generations: usize,
    /// Candidates scored in *this* invocation.
    pub candidates_scored: usize,
    /// Simulated cost of the default (compute_root, scalar) schedule.
    pub default_cost: f64,
    /// The cost model's score for the search's best, if any.
    pub model_best_cost: Option<f64>,
    /// Simulated cost of the search's best schedule, if any.
    pub searched_cost: Option<f64>,
    /// Simulated cost of the schedule actually adopted (incumbent rule:
    /// never worse than `default_cost`).
    pub tuned_cost: f64,
    /// True when the search's best did not beat the default.
    pub adopted_default: bool,
    pub best_schedule: Option<PipelineSchedule>,
    /// Generation the run resumed from, when `--resume` found a
    /// checkpoint.
    pub resumed_from: Option<usize>,
}

/// The whole fleet's outcome.
pub struct FleetReport {
    pub results: Vec<PipelineResult>,
    /// Harvested search-trace samples (cost-to-go labels), all
    /// pipelines, in fleet order.
    pub samples: Vec<GraphSample>,
    /// Shared-service counters after the run ([`FleetCost::Service`]
    /// only).
    pub service_stats: Option<ServiceStats>,
    pub wall_s: f64,
}

fn derive_seed(fleet_seed: u64, idx: usize) -> u64 {
    fleet_seed ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

fn build_strategy(cfg: &FleetConfig, seed: u64) -> Box<dyn SearchStrategy> {
    let beam = BeamConfig { seed, ..cfg.beam.clone() };
    let evolution = EvolutionConfig { seed, ..cfg.evolution.clone() };
    make_strategy(cfg.strategy, &beam, &evolution)
}

/// Tune one pipeline: restore, step to done (or `step_limit`),
/// checkpoint, evaluate against the default, harvest the trace.
fn tune_one(
    cfg: &FleetConfig,
    cost: &FleetCost,
    idx: usize,
    p: &Pipeline,
    nests: &[LoopNest],
) -> Result<(PipelineResult, Vec<GraphSample>)> {
    let seed = derive_seed(cfg.seed, idx);
    let mut strat = build_strategy(cfg, seed);
    let model: Box<dyn CostModel> = match cost {
        FleetCost::Oracle => Box::new(SimCost { machine: cfg.machine.clone() }),
        FleetCost::Service(svc) => {
            Box::new(PredictorCost::with_service(Arc::clone(svc), cfg.machine.clone()))
        }
    };

    let mut resumed_from = None;
    if cfg.resume {
        if let Some(dir) = &cfg.checkpoint_dir {
            if let Some(ckpt) = Checkpoint::load(dir, &p.name)? {
                if ckpt.strategy != strat.name() {
                    bail!(
                        "checkpoint for {} was written by strategy {:?}, this run uses {:?}",
                        p.name,
                        ckpt.strategy,
                        strat.name()
                    );
                }
                if ckpt.seed != seed {
                    bail!(
                        "checkpoint for {} was written with seed {}, this run derives {seed}",
                        p.name,
                        ckpt.seed
                    );
                }
                strat
                    .restore_state(&ckpt.state)
                    .with_context(|| format!("restoring {}'s search state", p.name))?;
                resumed_from = Some(ckpt.generation);
            }
        }
    }

    let save_ckpt = |strat: &dyn SearchStrategy| -> Result<()> {
        if let Some(dir) = &cfg.checkpoint_dir {
            Checkpoint {
                pipeline: p.name.clone(),
                strategy: strat.name().to_string(),
                seed,
                generation: strat.generation(),
                done: strat.done(),
                best: strat.best().map(|(s, c)| (s.clone(), c)),
                state: strat.save_state(),
            }
            .save(dir)
            .with_context(|| format!("checkpointing {}", p.name))?;
        }
        Ok(())
    };

    let mut trace = TraceRecorder::new(cfg.trace_cap);
    let mut candidates_scored = 0usize;
    let mut steps = 0usize;
    while !strat.done() && (cfg.step_limit == 0 || steps < cfg.step_limit) {
        let gen = strat.generation();
        let scored = strat
            .step(p, nests, model.as_ref())
            .with_context(|| format!("tuning {}", p.name))?;
        candidates_scored += scored.len();
        trace.record(gen, &scored);
        steps += 1;
        if cfg.checkpoint_every > 0 && strat.generation() % cfg.checkpoint_every == 0 {
            save_ckpt(strat.as_ref())?;
        }
    }
    save_ckpt(strat.as_ref())?;

    let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
    let default_sched = PipelineSchedule::default_for(&ranks);
    let default_cost = simulate(p, nests, &default_sched, &cfg.machine);
    let best = strat.best().map(|(s, c)| (s.clone(), c));
    let (model_best_cost, searched_cost) = match &best {
        Some((s, c)) => (Some(*c), Some(simulate(p, nests, s, &cfg.machine))),
        None => (None, None),
    };
    // incumbent rule: adopt the search's best only when the simulator
    // confirms it beats the default
    let (tuned_cost, adopted_default, best_schedule) = match (&best, searched_cost) {
        (Some((s, _)), Some(sc)) if strat.done() && sc <= default_cost => {
            (sc, false, Some(s.clone()))
        }
        _ => (default_cost, true, best.map(|(s, _)| s)),
    };

    let samples = trace.harvest(p, nests, &cfg.machine, idx as u32);
    Ok((
        PipelineResult {
            network: p.name.clone(),
            completed: strat.done(),
            generations: strat.generation(),
            candidates_scored,
            default_cost,
            model_best_cost,
            searched_cost,
            tuned_cost,
            adopted_default,
            best_schedule,
            resumed_from,
        },
        samples,
    ))
}

/// Run the whole fleet. Deterministic for a fixed `cfg.seed`: concurrent
/// and sequential modes, and interrupted-then-resumed runs, all reach
/// identical best schedules and costs.
pub fn run_fleet(cfg: &FleetConfig, cost: &FleetCost) -> Result<FleetReport> {
    if cfg.networks.is_empty() {
        bail!("autotune fleet needs at least one network");
    }
    let pipelines: Vec<(Pipeline, Vec<LoopNest>)> = cfg
        .networks
        .iter()
        .map(|name| {
            let p = crate::zoo::by_name(name).with_context(|| {
                let known: Vec<String> =
                    crate::zoo::all_networks().iter().map(|p| p.name.clone()).collect();
                format!("unknown network {name:?} (zoo has: {})", known.join(", "))
            })?;
            let nests = lower_pipeline(&p);
            Ok((p, nests))
        })
        .collect::<Result<_>>()?;

    let start = std::time::Instant::now();
    let outcomes: Vec<Result<(PipelineResult, Vec<GraphSample>)>> = if cfg.sequential {
        pipelines
            .iter()
            .enumerate()
            .map(|(i, (p, nests))| tune_one(cfg, cost, i, p, nests))
            .collect()
    } else {
        parallel_map_indexed(pipelines.len(), |i| {
            let (p, nests) = &pipelines[i];
            tune_one(cfg, cost, i, p, nests)
        })
    };
    let wall_s = start.elapsed().as_secs_f64();

    let mut results = Vec::with_capacity(outcomes.len());
    let mut samples = Vec::new();
    for (i, outcome) in outcomes.into_iter().enumerate() {
        let (r, s) =
            outcome.with_context(|| format!("fleet member {} failed", cfg.networks[i]))?;
        results.push(r);
        samples.extend(s);
    }
    let service_stats = match cost {
        FleetCost::Service(svc) => Some(svc.stats()),
        FleetCost::Oracle => None,
    };
    Ok(FleetReport { results, samples, service_stats, wall_s })
}

impl PipelineResult {
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("network", Json::Str(self.network.clone())),
            ("completed", Json::Bool(self.completed)),
            ("generations", Json::Num(self.generations as f64)),
            ("candidates_scored", Json::Num(self.candidates_scored as f64)),
            ("default_cost", Json::Num(self.default_cost)),
            ("model_best_cost", opt(self.model_best_cost)),
            ("searched_cost", opt(self.searched_cost)),
            ("tuned_cost", Json::Num(self.tuned_cost)),
            ("speedup", Json::Num(self.default_cost / self.tuned_cost)),
            ("adopted_default", Json::Bool(self.adopted_default)),
            (
                "resumed_from",
                self.resumed_from.map(|g| Json::Num(g as f64)).unwrap_or(Json::Null),
            ),
        ])
    }
}

impl FleetReport {
    /// Tuned-vs-default speedup per pipeline (>= 1 by the incumbent
    /// rule).
    pub fn speedups(&self) -> Vec<f64> {
        self.results.iter().map(|r| r.default_cost / r.tuned_cost).collect()
    }

    /// Full report as JSON (the `--report-out` file and the fleet
    /// section of BENCH_7.json).
    pub fn to_json(&self, cfg: &FleetConfig) -> Json {
        let q = Quantiles::new(&self.speedups());
        Json::obj(vec![
            (
                "mode",
                Json::Str(if cfg.sequential { "sequential" } else { "concurrent" }.into()),
            ),
            (
                "strategy",
                Json::Str(match cfg.strategy {
                    StrategyKind::Beam => "beam",
                    StrategyKind::Evolution => "evolution",
                }
                .into()),
            ),
            ("seed", Json::Str(cfg.seed.to_string())),
            ("wall_s", Json::Num(self.wall_s)),
            ("pipelines", Json::Arr(self.results.iter().map(|r| r.to_json()).collect())),
            (
                "speedup",
                Json::obj(vec![
                    ("min", Json::Num(q.min())),
                    ("p50", Json::Num(q.quantile(50.0))),
                    ("max", Json::Num(q.max())),
                ]),
            ),
            ("trace_samples", Json::Num(self.samples.len() as f64)),
            (
                "service",
                match &self.service_stats {
                    Some(s) => s.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> FleetConfig {
        FleetConfig {
            networks: vec!["alexnet".into(), "squeezenet".into()],
            evolution: EvolutionConfig {
                population: 3,
                offspring: 4,
                immigrants: 1,
                generations: 3,
                seed: 1,
            },
            seed: 77,
            ..Default::default()
        }
    }

    #[test]
    fn oracle_fleet_tunes_and_never_regresses_the_default() {
        let cfg = tiny_cfg();
        let report = run_fleet(&cfg, &FleetCost::Oracle).unwrap();
        assert_eq!(report.results.len(), 2);
        for r in &report.results {
            assert!(r.completed);
            assert!(
                r.tuned_cost <= r.default_cost,
                "{}: tuned {} > default {}",
                r.network,
                r.tuned_cost,
                r.default_cost
            );
            assert!(r.candidates_scored > 0);
        }
        assert!(report.service_stats.is_none());
        assert!(!report.samples.is_empty(), "trace harvest produced samples");
        for s in &report.samples {
            s.validate().unwrap();
        }
        // report JSON is well-formed and re-parses
        let j = report.to_json(&cfg).to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.get("pipelines").and_then(|v| v.as_arr()).unwrap().len(), 2);
    }

    #[test]
    fn concurrent_and_sequential_fleets_agree_bitwise() {
        let cfg = tiny_cfg();
        let conc = run_fleet(&cfg, &FleetCost::Oracle).unwrap();
        let seq_cfg = FleetConfig { sequential: true, ..cfg };
        let seq = run_fleet(&seq_cfg, &FleetCost::Oracle).unwrap();
        for (a, b) in conc.results.iter().zip(&seq.results) {
            assert_eq!(a.network, b.network);
            assert_eq!(a.tuned_cost.to_bits(), b.tuned_cost.to_bits());
            assert_eq!(a.best_schedule, b.best_schedule);
            assert_eq!(a.generations, b.generations);
        }
    }

    #[test]
    fn unknown_network_fails_with_the_zoo_listing() {
        let cfg = FleetConfig { networks: vec!["not-a-net".into()], ..tiny_cfg() };
        let err = run_fleet(&cfg, &FleetCost::Oracle).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown network") && msg.contains("unet"), "{msg}");
    }
}
