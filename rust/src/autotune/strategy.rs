//! Pluggable, resumable search strategies over pipeline schedules.
//!
//! [`SearchStrategy`] is the generation-at-a-time contract the fleet
//! driver runs: each `step` proposes one frontier of complete candidate
//! schedules and scores them through a single [`CostModel::score`] call —
//! one coalesced round-trip when the model serves through a shared
//! [`crate::predictor::PredictService`]. Between steps the strategy's
//! whole state (frontier, best-so-far, raw RNG words) serializes to JSON,
//! which is what makes `--resume` bitwise-equivalent to an uninterrupted
//! run.
//!
//! Two strategies implement it:
//! * [`BeamStrategy`] — the paper's beam search (§II-B), refactored out
//!   of the old monolithic loop; [`crate::search::beam_search`] is now a
//!   thin driver over it and behaves identically draw-for-draw.
//! * [`EvolutionStrategy`] — seeded (μ+λ) mutation search built on
//!   `schedule::random` sampling and repaired against
//!   `schedule::legality`: survivors breed stage-resampled mutants,
//!   immigrants keep diversity, the default schedule seeds generation
//!   zero so tuning never regresses the incumbent out of the gene pool.

use crate::analysis::AnalyzedPipeline;
use crate::autotune::checkpoint::{
    rng_state_from_json, rng_state_to_json, schedule_from_json, schedule_to_json,
};
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};
use crate::schedule::random::{random_pipeline_schedule, random_stage_schedule};
use crate::search::{BeamConfig, CostModel};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::sync::Arc;

/// A resumable, generation-at-a-time schedule search.
///
/// Implementations must be deterministic functions of (config, restored
/// state, model scores): the fleet leans on that for fixed-seed
/// reproducibility and for checkpoint-resume equivalence.
pub trait SearchStrategy {
    /// Stable strategy name (recorded in checkpoints; resume refuses a
    /// mismatch).
    fn name(&self) -> &'static str;

    /// Advance one generation: propose candidates for `p`, score them all
    /// in one `model.score` call, fold them into internal state. Returns
    /// the scored candidates (the trace recorder's feed). A no-op
    /// returning an empty frontier once [`SearchStrategy::done`] is true.
    fn step(
        &mut self,
        p: &Pipeline,
        nests: &[LoopNest],
        model: &dyn CostModel,
    ) -> Result<Vec<(PipelineSchedule, f64)>>;

    /// True once the strategy will make no further progress.
    fn done(&self) -> bool;

    /// Generations completed so far.
    fn generation(&self) -> usize;

    /// Best (schedule, model cost) found so far.
    fn best(&self) -> Option<(&PipelineSchedule, f64)>;

    /// Serialize the complete resumable state (checkpoint payload).
    fn save_state(&self) -> Json;

    /// Restore state saved by [`SearchStrategy::save_state`]. The
    /// strategy must then continue exactly as the saving run would have.
    fn restore_state(&mut self, state: &Json) -> Result<()>;
}

/// Which strategy the fleet runs (CLI `--strategy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Beam,
    Evolution,
}

impl StrategyKind {
    pub fn parse(s: &str) -> Result<StrategyKind> {
        match s {
            "beam" => Ok(StrategyKind::Beam),
            "evolution" | "evo" => Ok(StrategyKind::Evolution),
            other => bail!("unknown strategy {other:?} (expected beam|evolution)"),
        }
    }
}

// ------------------------------------------------------------- helpers

fn pair_to_json(sched: &PipelineSchedule, cost: f64) -> Json {
    Json::obj(vec![("schedule", schedule_to_json(sched)), ("cost", Json::Num(cost))])
}

fn pair_from_json(j: &Json) -> Result<(PipelineSchedule, f64)> {
    let sched = schedule_from_json(j.get("schedule").context("pair missing 'schedule'")?)?;
    let cost = j.get("cost").and_then(|v| v.as_f64()).context("pair missing 'cost'")?;
    Ok((sched, cost))
}

fn best_to_json(best: &Option<(PipelineSchedule, f64)>) -> Json {
    match best {
        Some((s, c)) => pair_to_json(s, *c),
        None => Json::Null,
    }
}

fn best_from_json(j: Option<&Json>) -> Result<Option<(PipelineSchedule, f64)>> {
    match j {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(pair_from_json(v)?)),
    }
}

// ------------------------------------------------------- beam strategy

/// The paper's beam search, one stage expansion per [`SearchStrategy::step`].
///
/// Stages are scheduled output-first; unscheduled stages keep the Halide
/// default so every beam state is a complete, legal, scorable schedule.
/// The final step re-scores the surviving beam and locks in the best.
/// Draw-for-draw identical to the pre-refactor `beam_search` loop (its
/// tests still pass unchanged through the [`crate::search::beam_search`]
/// wrapper).
pub struct BeamStrategy {
    cfg: BeamConfig,
    rng: Rng,
    /// Current beam; empty until the first step seeds it with the
    /// default schedule.
    beam: Vec<PipelineSchedule>,
    /// Stages already expanded (stage ids count down from the output).
    scheduled: usize,
    /// Whether the final re-score has run.
    finalized: bool,
    best: Option<(PipelineSchedule, f64)>,
    gen: usize,
    /// Per-pipeline legality tables, built lazily on the first step and
    /// reused every generation (per-candidate legality is table lookups,
    /// no consumer reallocation). Deterministically recomputed after a
    /// checkpoint restore, so it is never serialized.
    analysis: Option<Arc<AnalyzedPipeline>>,
}

impl BeamStrategy {
    pub fn new(cfg: BeamConfig) -> BeamStrategy {
        let rng = Rng::new(cfg.seed);
        BeamStrategy {
            cfg,
            rng,
            beam: Vec::new(),
            scheduled: 0,
            finalized: false,
            best: None,
            gen: 0,
            analysis: None,
        }
    }
}

impl SearchStrategy for BeamStrategy {
    fn name(&self) -> &'static str {
        "beam"
    }

    fn step(
        &mut self,
        p: &Pipeline,
        nests: &[LoopNest],
        model: &dyn CostModel,
    ) -> Result<Vec<(PipelineSchedule, f64)>> {
        if self.finalized {
            return Ok(Vec::new());
        }
        if self.beam.is_empty() {
            let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
            self.beam = vec![PipelineSchedule::default_for(&ranks)];
        }
        let n = p.num_stages();
        let ap = Arc::clone(
            self.analysis.get_or_insert_with(|| Arc::new(AnalyzedPipeline::build(p, nests))),
        );
        let scored = if self.scheduled < n {
            // expand: schedule the next stage, output-first
            let stage_id = n - 1 - self.scheduled;
            let mut candidates: Vec<PipelineSchedule> = Vec::new();
            for state in &self.beam {
                // keep-default is always a candidate
                candidates.push(state.clone());
                for _ in 0..self.cfg.candidates_per_stage {
                    let mut next = state.clone();
                    let mut ss: StageSchedule = random_stage_schedule(
                        &nests[stage_id],
                        ap.consumers(stage_id),
                        &mut self.rng,
                    );
                    // compute_at an inlined consumer is illegal — retarget
                    if let ComputeLoc::At { consumer, .. } = ss.compute {
                        if matches!(next.stages[consumer].compute, ComputeLoc::Inline) {
                            ss.compute = ComputeLoc::Root;
                        }
                    }
                    next.stages[stage_id] = ss;
                    debug_assert!(
                        ap.check_schedule(&next).is_ok(),
                        "beam expansion produced illegal schedule: {:?}",
                        ap.check_schedule(&next)
                    );
                    candidates.push(next);
                }
            }
            // prune with the model — one frontier, one score call
            let scores = model.score(p, nests, &candidates).with_context(|| {
                format!("{} failed scoring stage {stage_id}'s frontier", model.name())
            })?;
            let mut idx: Vec<usize> = (0..candidates.len()).collect();
            idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
            self.beam = idx
                .iter()
                .take(self.cfg.beam_width)
                .map(|&i| candidates[i].clone())
                .collect();
            self.scheduled += 1;
            candidates.into_iter().zip(scores).collect()
        } else {
            // final re-score of the surviving beam
            let scores = model
                .score(p, nests, &self.beam)
                .with_context(|| format!("{} failed scoring the final beam", model.name()))?;
            let (best_i, best_s) = scores
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .context("beam search produced an empty beam")?;
            self.best = Some((self.beam[best_i].clone(), *best_s));
            self.finalized = true;
            self.beam.iter().cloned().zip(scores).collect()
        };
        self.gen += 1;
        Ok(scored)
    }

    fn done(&self) -> bool {
        self.finalized
    }

    fn generation(&self) -> usize {
        self.gen
    }

    fn best(&self) -> Option<(&PipelineSchedule, f64)> {
        self.best.as_ref().map(|(s, c)| (s, *c))
    }

    fn save_state(&self) -> Json {
        Json::obj(vec![
            ("rng", rng_state_to_json(self.rng.state())),
            ("beam", Json::Arr(self.beam.iter().map(schedule_to_json).collect())),
            ("scheduled", Json::Num(self.scheduled as f64)),
            ("finalized", Json::Bool(self.finalized)),
            ("best", best_to_json(&self.best)),
            ("generation", Json::Num(self.gen as f64)),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.rng =
            Rng::from_state(rng_state_from_json(state.get("rng").context("state missing 'rng'")?)?);
        self.beam = state
            .get("beam")
            .and_then(|v| v.as_arr())
            .context("state missing 'beam'")?
            .iter()
            .map(schedule_from_json)
            .collect::<Result<Vec<_>>>()?;
        self.scheduled =
            state.get("scheduled").and_then(|v| v.as_usize()).context("state missing 'scheduled'")?;
        self.finalized =
            state.get("finalized").and_then(|v| v.as_bool()).context("state missing 'finalized'")?;
        self.best = best_from_json(state.get("best"))?;
        self.gen = state
            .get("generation")
            .and_then(|v| v.as_usize())
            .context("state missing 'generation'")?;
        // analysis tables are a pure function of (pipeline, nests) — drop
        // any cached ones and rebuild on the next step
        self.analysis = None;
        Ok(())
    }
}

// -------------------------------------------------- evolution strategy

/// Knobs for [`EvolutionStrategy`] ((μ+λ) mutation search).
#[derive(Debug, Clone)]
pub struct EvolutionConfig {
    /// μ: survivors kept between generations.
    pub population: usize,
    /// λ: mutants bred from survivors per generation.
    pub offspring: usize,
    /// Fresh `random_pipeline_schedule` entrants per generation (keeps
    /// the gene pool from collapsing onto one basin).
    pub immigrants: usize,
    /// Total generations before [`SearchStrategy::done`].
    pub generations: usize,
    pub seed: u64,
}

impl Default for EvolutionConfig {
    fn default() -> Self {
        EvolutionConfig { population: 8, offspring: 24, immigrants: 4, generations: 12, seed: 1 }
    }
}

/// Seeded evolutionary search over complete schedules.
///
/// Generation 0 scores the default schedule plus μ+λ−1 random samples;
/// each later generation breeds λ mutants (1–2 stages re-sampled via
/// `random_stage_schedule`, then repaired against cross-stage legality)
/// plus fresh immigrants, scores them all in one `model.score` call, and
/// keeps the μ best distinct schedules. Every emitted candidate passes
/// `schedule::legality::check_pipeline` (property-tested).
pub struct EvolutionStrategy {
    cfg: EvolutionConfig,
    rng: Rng,
    /// Survivors, sorted best-first by model cost.
    population: Vec<(PipelineSchedule, f64)>,
    gen: usize,
    /// Per-pipeline legality tables (see [`BeamStrategy::analysis`]).
    analysis: Option<Arc<AnalyzedPipeline>>,
}

impl EvolutionStrategy {
    pub fn new(cfg: EvolutionConfig) -> EvolutionStrategy {
        let rng = Rng::new(cfg.seed);
        EvolutionStrategy { cfg, rng, population: Vec::new(), gen: 0, analysis: None }
    }

    /// Re-sample 1–2 stage schedules of a parent, then repair the one
    /// cross-stage constraint a local mutation can break (`compute_at`
    /// targeting a now-inlined consumer).
    fn mutate(
        &mut self,
        parent: &PipelineSchedule,
        nests: &[LoopNest],
        ap: &AnalyzedPipeline,
    ) -> PipelineSchedule {
        let n = ap.num_stages();
        let mut child = parent.clone();
        let n_mut = 1 + self.rng.gen_range(2.min(n));
        for _ in 0..n_mut {
            let sid = self.rng.gen_range(n);
            child.stages[sid] = random_stage_schedule(&nests[sid], ap.consumers(sid), &mut self.rng);
        }
        repair_compute_at(&mut child);
        debug_assert!(
            ap.check_schedule(&child).is_ok(),
            "mutation produced illegal schedule: {:?}",
            ap.check_schedule(&child)
        );
        child
    }
}

/// Retarget every `compute_at` that points at an inlined consumer to
/// `Root` — the only pairwise legality constraint a per-stage mutation
/// can violate (per-stage choices are sampled legal by construction).
fn repair_compute_at(sched: &mut PipelineSchedule) {
    let inlined: Vec<bool> = sched
        .stages
        .iter()
        .map(|s| matches!(s.compute, ComputeLoc::Inline))
        .collect();
    for s in &mut sched.stages {
        if let ComputeLoc::At { consumer, .. } = s.compute {
            if inlined[consumer] {
                s.compute = ComputeLoc::Root;
            }
        }
    }
}

impl SearchStrategy for EvolutionStrategy {
    fn name(&self) -> &'static str {
        "evolution"
    }

    fn step(
        &mut self,
        p: &Pipeline,
        nests: &[LoopNest],
        model: &dyn CostModel,
    ) -> Result<Vec<(PipelineSchedule, f64)>> {
        if self.done() {
            return Ok(Vec::new());
        }
        let ap = Arc::clone(
            self.analysis.get_or_insert_with(|| Arc::new(AnalyzedPipeline::build(p, nests))),
        );
        let mut candidates: Vec<PipelineSchedule> = Vec::new();
        if self.population.is_empty() {
            // generation 0: the incumbent default + a random spread
            let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
            candidates.push(PipelineSchedule::default_for(&ranks));
            let spread = (self.cfg.population + self.cfg.offspring).max(2) - 1;
            for _ in 0..spread {
                candidates.push(random_pipeline_schedule(p, nests, &mut self.rng));
            }
        } else {
            for _ in 0..self.cfg.offspring {
                let parent_i = self.rng.gen_range(self.population.len());
                let parent = self.population[parent_i].0.clone();
                candidates.push(self.mutate(&parent, nests, &ap));
            }
            for _ in 0..self.cfg.immigrants {
                candidates.push(random_pipeline_schedule(p, nests, &mut self.rng));
            }
        }
        let scores = model.score(p, nests, &candidates).with_context(|| {
            format!("{} failed scoring generation {}'s candidates", model.name(), self.gen)
        })?;
        let scored: Vec<(PipelineSchedule, f64)> = candidates.into_iter().zip(scores).collect();

        // (μ+λ) selection: survivors + candidates, best-first, distinct
        let mut pool: Vec<(PipelineSchedule, f64)> = self.population.clone();
        pool.extend(scored.iter().cloned());
        pool.sort_by(|a, b| a.1.total_cmp(&b.1));
        let mut seen: HashSet<PipelineSchedule> = HashSet::new();
        self.population = pool
            .into_iter()
            .filter(|(s, _)| seen.insert(s.clone()))
            .take(self.cfg.population.max(1))
            .collect();
        self.gen += 1;
        Ok(scored)
    }

    fn done(&self) -> bool {
        self.gen >= self.cfg.generations
    }

    fn generation(&self) -> usize {
        self.gen
    }

    fn best(&self) -> Option<(&PipelineSchedule, f64)> {
        self.population.first().map(|(s, c)| (s, *c))
    }

    fn save_state(&self) -> Json {
        Json::obj(vec![
            ("rng", rng_state_to_json(self.rng.state())),
            (
                "population",
                Json::Arr(self.population.iter().map(|(s, c)| pair_to_json(s, *c)).collect()),
            ),
            ("generation", Json::Num(self.gen as f64)),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        self.rng =
            Rng::from_state(rng_state_from_json(state.get("rng").context("state missing 'rng'")?)?);
        self.population = state
            .get("population")
            .and_then(|v| v.as_arr())
            .context("state missing 'population'")?
            .iter()
            .map(pair_from_json)
            .collect::<Result<Vec<_>>>()?;
        self.gen = state
            .get("generation")
            .and_then(|v| v.as_usize())
            .context("state missing 'generation'")?;
        self.analysis = None;
        Ok(())
    }
}

/// Construct a boxed strategy of `kind` with the given configs.
pub fn make_strategy(
    kind: StrategyKind,
    beam: &BeamConfig,
    evolution: &EvolutionConfig,
) -> Box<dyn SearchStrategy> {
    match kind {
        StrategyKind::Beam => Box::new(BeamStrategy::new(beam.clone())),
        StrategyKind::Evolution => Box::new(EvolutionStrategy::new(evolution.clone())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::lower_pipeline;
    use crate::schedule::legality::check_pipeline;
    use crate::search::SimCost;
    use crate::sim::{simulate, Machine};
    use crate::util::propcheck;

    fn run_to_done(
        strat: &mut dyn SearchStrategy,
        p: &Pipeline,
        nests: &[LoopNest],
        model: &dyn CostModel,
    ) -> (PipelineSchedule, f64) {
        while !strat.done() {
            strat.step(p, nests, model).unwrap();
        }
        let (s, c) = strat.best().expect("a best schedule");
        (s.clone(), c)
    }

    #[test]
    fn beam_strategy_matches_the_beam_search_wrapper_bitwise() {
        let p = crate::zoo::unet();
        let nests = lower_pipeline(&p);
        let model = SimCost { machine: Machine::default() };
        let cfg = BeamConfig { beam_width: 3, candidates_per_stage: 5, seed: 11 };
        let mut strat = BeamStrategy::new(cfg.clone());
        let (s_strat, c_strat) = run_to_done(&mut strat, &p, &nests, &model);
        let (s_fn, c_fn) = crate::search::beam_search(&p, &nests, &model, &cfg).unwrap();
        assert_eq!(s_strat, s_fn);
        assert_eq!(c_strat.to_bits(), c_fn.to_bits());
        // one expansion per stage + the final re-score
        assert_eq!(strat.generation(), p.num_stages() + 1);
        assert!(strat.step(&p, &nests, &model).unwrap().is_empty(), "done strategy is a no-op");
    }

    #[test]
    fn evolution_improves_over_default_and_is_deterministic() {
        let p = crate::zoo::unet();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let model = SimCost { machine: m.clone() };
        let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
        let default_t = simulate(&p, &nests, &PipelineSchedule::default_for(&ranks), &m);
        let cfg = EvolutionConfig { generations: 6, seed: 42, ..Default::default() };
        let mut a = EvolutionStrategy::new(cfg.clone());
        let (sa, ca) = run_to_done(&mut a, &p, &nests, &model);
        let mut b = EvolutionStrategy::new(cfg);
        let (sb, cb) = run_to_done(&mut b, &p, &nests, &model);
        assert_eq!(sa, sb, "same seed, same best schedule");
        assert_eq!(ca.to_bits(), cb.to_bits());
        // the default seeds generation 0, so the best can never be worse
        assert!(ca <= default_t, "evolution best {ca} regressed past default {default_t}");
        check_pipeline(&p, &nests, &sa).unwrap();
    }

    #[test]
    fn evolution_state_round_trip_resumes_bitwise() {
        let p = crate::zoo::unet();
        let nests = lower_pipeline(&p);
        let model = SimCost { machine: Machine::default() };
        let cfg = EvolutionConfig { generations: 5, seed: 9, ..Default::default() };

        let mut full = EvolutionStrategy::new(cfg.clone());
        let (s_full, c_full) = run_to_done(&mut full, &p, &nests, &model);

        let mut partial = EvolutionStrategy::new(cfg.clone());
        partial.step(&p, &nests, &model).unwrap();
        partial.step(&p, &nests, &model).unwrap();
        // serialize through actual JSON text, as a checkpoint file would
        let text = partial.save_state().to_string();
        let state = Json::parse(&text).unwrap();
        let mut resumed = EvolutionStrategy::new(cfg);
        resumed.restore_state(&state).unwrap();
        assert_eq!(resumed.generation(), 2);
        let (s_res, c_res) = run_to_done(&mut resumed, &p, &nests, &model);
        assert_eq!(s_res, s_full, "resume diverged from the uninterrupted run");
        assert_eq!(c_res.to_bits(), c_full.to_bits());
    }

    #[test]
    fn beam_state_round_trip_resumes_bitwise() {
        let p = crate::zoo::unet();
        let nests = lower_pipeline(&p);
        let model = SimCost { machine: Machine::default() };
        let cfg = BeamConfig { beam_width: 2, candidates_per_stage: 4, seed: 21 };

        let mut full = BeamStrategy::new(cfg.clone());
        let (s_full, c_full) = run_to_done(&mut full, &p, &nests, &model);

        let mut partial = BeamStrategy::new(cfg.clone());
        for _ in 0..3 {
            partial.step(&p, &nests, &model).unwrap();
        }
        let state = Json::parse(&partial.save_state().to_string()).unwrap();
        let mut resumed = BeamStrategy::new(cfg);
        resumed.restore_state(&state).unwrap();
        let (s_res, c_res) = run_to_done(&mut resumed, &p, &nests, &model);
        assert_eq!(s_res, s_full);
        assert_eq!(c_res.to_bits(), c_full.to_bits());
    }

    #[test]
    fn prop_both_strategies_emit_only_legal_schedules() {
        // satellite contract: every schedule produced by beam and the
        // evolutionary strategy passes schedule::legality (random
        // sampling has its own property test in schedule::random)
        let p = crate::zoo::unet();
        let nests = lower_pipeline(&p);
        let model = SimCost { machine: Machine::default() };
        let cases = propcheck::default_cases().min(12);
        propcheck::check_rng("strategy candidates legal", 0x57A7, cases, |rng| {
            let seed = rng.next_u64();
            let mut evo = EvolutionStrategy::new(EvolutionConfig {
                population: 4,
                offspring: 6,
                immigrants: 2,
                generations: 2,
                seed,
            });
            let mut beam = BeamStrategy::new(BeamConfig {
                beam_width: 2,
                candidates_per_stage: 3,
                seed,
            });
            for strat in [&mut evo as &mut dyn SearchStrategy, &mut beam] {
                while !strat.done() {
                    for (sched, _) in strat.step(&p, &nests, &model).map_err(|e| e.to_string())? {
                        check_pipeline(&p, &nests, &sched).map_err(|e| {
                            format!("{} emitted illegal schedule: {e}", strat.name())
                        })?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn strategy_kind_parses() {
        assert_eq!(StrategyKind::parse("beam").unwrap(), StrategyKind::Beam);
        assert_eq!(StrategyKind::parse("evolution").unwrap(), StrategyKind::Evolution);
        assert_eq!(StrategyKind::parse("evo").unwrap(), StrategyKind::Evolution);
        assert!(StrategyKind::parse("anneal").is_err());
    }
}
