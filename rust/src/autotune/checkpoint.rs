//! Per-pipeline search checkpoints: JSON on disk, bitwise-resumable.
//!
//! A checkpoint captures everything a [`crate::autotune::SearchStrategy`]
//! needs to continue exactly where it stopped — best schedule and cost,
//! generation counter, and the strategy's own resumable state including
//! the raw xoshiro RNG words — so an interrupted fleet restarted with
//! `--resume` reaches the *identical* best schedule an uninterrupted run
//! would have (pinned by the round-trip test in `tests/autotune.rs`).
//!
//! Format notes:
//! * RNG words are 64-bit and the JSON layer stores numbers as `f64`
//!   (exact only up to 2^53), so the four state words serialize as hex
//!   strings, never as numbers.
//! * Costs are `f64` and round-trip exactly: the writer emits Rust's
//!   shortest round-trip `Display` form and the parser is `f64::from_str`.
//! * Writes go to a sibling `*.tmp` then rename into place, so a kill
//!   mid-save leaves the previous checkpoint intact instead of a torn
//!   file.

use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Current checkpoint format version; bumped on incompatible change.
pub const CHECKPOINT_VERSION: usize = 1;

// ---------------------------------------------------- schedule <-> JSON

fn compute_to_json(c: &ComputeLoc) -> Json {
    match c {
        ComputeLoc::Root => Json::obj(vec![("loc", Json::Str("root".into()))]),
        ComputeLoc::Inline => Json::obj(vec![("loc", Json::Str("inline".into()))]),
        ComputeLoc::At { consumer, level } => Json::obj(vec![
            ("loc", Json::Str("at".into())),
            ("consumer", Json::Num(*consumer as f64)),
            ("level", Json::Num(*level as f64)),
        ]),
    }
}

fn compute_from_json(j: &Json) -> Result<ComputeLoc> {
    let loc = j.get("loc").and_then(|v| v.as_str()).context("compute location missing 'loc'")?;
    match loc {
        "root" => Ok(ComputeLoc::Root),
        "inline" => Ok(ComputeLoc::Inline),
        "at" => Ok(ComputeLoc::At {
            consumer: j
                .get("consumer")
                .and_then(|v| v.as_usize())
                .context("compute_at missing 'consumer'")?,
            level: j.get("level").and_then(|v| v.as_usize()).context("compute_at missing 'level'")?,
        }),
        other => bail!("unknown compute location {other:?}"),
    }
}

fn usizes_to_json(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn usizes_from_json(j: &Json, what: &str) -> Result<Vec<usize>> {
    j.as_arr()
        .with_context(|| format!("{what} must be an array"))?
        .iter()
        .map(|v| v.as_usize().with_context(|| format!("{what} holds a non-integer")))
        .collect()
}

/// Serialize one schedule to the checkpoint JSON shape.
pub fn schedule_to_json(sched: &PipelineSchedule) -> Json {
    let stages: Vec<Json> = sched
        .stages
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("order", usizes_to_json(&s.order)),
                ("tile", usizes_to_json(&s.tile)),
                ("vector_width", Json::Num(s.vector_width as f64)),
                ("parallel_depth", Json::Num(s.parallel_depth as f64)),
                ("unroll", Json::Num(s.unroll as f64)),
                ("compute", compute_to_json(&s.compute)),
            ])
        })
        .collect();
    Json::obj(vec![("stages", Json::Arr(stages))])
}

/// Parse a schedule back out of [`schedule_to_json`]'s shape.
pub fn schedule_from_json(j: &Json) -> Result<PipelineSchedule> {
    let stages = j.get("stages").and_then(|v| v.as_arr()).context("schedule missing 'stages'")?;
    let stages: Result<Vec<StageSchedule>> = stages
        .iter()
        .map(|sj| {
            Ok(StageSchedule {
                order: usizes_from_json(
                    sj.get("order").context("stage missing 'order'")?,
                    "order",
                )?,
                tile: usizes_from_json(sj.get("tile").context("stage missing 'tile'")?, "tile")?,
                vector_width: sj
                    .get("vector_width")
                    .and_then(|v| v.as_usize())
                    .context("stage missing 'vector_width'")?,
                parallel_depth: sj
                    .get("parallel_depth")
                    .and_then(|v| v.as_usize())
                    .context("stage missing 'parallel_depth'")?,
                unroll: sj
                    .get("unroll")
                    .and_then(|v| v.as_usize())
                    .context("stage missing 'unroll'")?,
                compute: compute_from_json(sj.get("compute").context("stage missing 'compute'")?)?,
            })
        })
        .collect();
    Ok(PipelineSchedule { stages: stages? })
}

// --------------------------------------------------- RNG state <-> JSON

/// The four xoshiro256++ words as hex strings (u64 does not survive the
/// JSON layer's f64 numbers past 2^53).
pub fn rng_state_to_json(s: [u64; 4]) -> Json {
    Json::Arr(s.iter().map(|w| Json::Str(format!("{w:016x}"))).collect())
}

/// Parse a [`rng_state_to_json`] array back into raw state words.
pub fn rng_state_from_json(j: &Json) -> Result<[u64; 4]> {
    let arr = j.as_arr().context("rng state must be an array")?;
    if arr.len() != 4 {
        bail!("rng state must hold 4 words, got {}", arr.len());
    }
    let mut s = [0u64; 4];
    for (i, w) in arr.iter().enumerate() {
        let hex = w.as_str().context("rng word must be a hex string")?;
        s[i] = u64::from_str_radix(hex, 16)
            .map_err(|e| anyhow!("bad rng word {hex:?}: {e}"))?;
    }
    Ok(s)
}

// ------------------------------------------------------- the checkpoint

/// One pipeline's resumable search state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Zoo name of the pipeline being tuned (guards against resuming the
    /// wrong file into the wrong search).
    pub pipeline: String,
    /// Strategy name ([`crate::autotune::SearchStrategy::name`]); resume
    /// refuses a strategy mismatch.
    pub strategy: String,
    /// The per-pipeline derived seed the strategy was constructed with.
    pub seed: u64,
    /// Generations completed when this was saved.
    pub generation: usize,
    /// Whether the search had finished (resume skips straight to report).
    pub done: bool,
    /// Best (schedule, model cost) so far, if any candidate was scored.
    pub best: Option<(PipelineSchedule, f64)>,
    /// Strategy-specific resumable state (beam contents / population /
    /// RNG words), opaque to this module.
    pub state: Json,
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        let best = match &self.best {
            Some((sched, cost)) => Json::obj(vec![
                ("schedule", schedule_to_json(sched)),
                ("cost", Json::Num(*cost)),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("version", Json::Num(CHECKPOINT_VERSION as f64)),
            ("pipeline", Json::Str(self.pipeline.clone())),
            ("strategy", Json::Str(self.strategy.clone())),
            // u64 seeds exceed f64's exact-integer range; keep as string
            ("seed", Json::Str(self.seed.to_string())),
            ("generation", Json::Num(self.generation as f64)),
            ("done", Json::Bool(self.done)),
            ("best", best),
            ("state", self.state.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Checkpoint> {
        let version =
            j.get("version").and_then(|v| v.as_usize()).context("checkpoint missing 'version'")?;
        if version != CHECKPOINT_VERSION {
            bail!("checkpoint version {version} != supported {CHECKPOINT_VERSION}");
        }
        let best = match j.get("best") {
            None | Some(Json::Null) => None,
            Some(b) => {
                let sched =
                    schedule_from_json(b.get("schedule").context("best missing 'schedule'")?)?;
                let cost = b.get("cost").and_then(|v| v.as_f64()).context("best missing 'cost'")?;
                Some((sched, cost))
            }
        };
        Ok(Checkpoint {
            pipeline: j
                .get("pipeline")
                .and_then(|v| v.as_str())
                .context("checkpoint missing 'pipeline'")?
                .to_string(),
            strategy: j
                .get("strategy")
                .and_then(|v| v.as_str())
                .context("checkpoint missing 'strategy'")?
                .to_string(),
            seed: j
                .get("seed")
                .and_then(|v| v.as_str())
                .context("checkpoint missing 'seed'")?
                .parse::<u64>()
                .context("checkpoint seed is not a u64")?,
            generation: j
                .get("generation")
                .and_then(|v| v.as_usize())
                .context("checkpoint missing 'generation'")?,
            done: j.get("done").and_then(|v| v.as_bool()).context("checkpoint missing 'done'")?,
            best,
            state: j.get("state").context("checkpoint missing 'state'")?.clone(),
        })
    }

    /// The checkpoint file for `pipeline` under `dir`.
    pub fn path_for(dir: &Path, pipeline: &str) -> PathBuf {
        dir.join(format!("{pipeline}.ckpt.json"))
    }

    /// Atomically write this checkpoint under `dir` (tmp file + rename,
    /// so an interrupt never leaves a torn checkpoint behind).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let path = Checkpoint::path_for(dir, &self.pipeline);
        let tmp = dir.join(format!("{}.ckpt.json.tmp", self.pipeline));
        std::fs::write(&tmp, self.to_json().to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} into place", path.display()))?;
        Ok(())
    }

    /// Load `pipeline`'s checkpoint from `dir`; `Ok(None)` when absent.
    pub fn load(dir: &Path, pipeline: &str) -> Result<Option<Checkpoint>> {
        let path = Checkpoint::path_for(dir, pipeline);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing checkpoint {}: {e}", path.display()))?;
        let ckpt = Checkpoint::from_json(&j)
            .with_context(|| format!("decoding checkpoint {}", path.display()))?;
        if ckpt.pipeline != pipeline {
            bail!(
                "checkpoint {} names pipeline {:?}, expected {pipeline:?}",
                path.display(),
                ckpt.pipeline
            );
        }
        Ok(Some(ckpt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::lower_pipeline;
    use crate::schedule::random::random_pipeline_schedule;
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    #[test]
    fn prop_schedule_json_round_trips_exactly() {
        let p = crate::zoo::unet();
        let nests = lower_pipeline(&p);
        propcheck::check_rng("schedule json round-trip", 0xC4E7, propcheck::default_cases(), |rng| {
            let s = random_pipeline_schedule(&p, &nests, rng);
            let back = schedule_from_json(&schedule_to_json(&s)).map_err(|e| e.to_string())?;
            if back != s {
                return Err(format!("round trip changed the schedule: {back:?} != {s:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rng_state_json_preserves_full_u64_words() {
        // words above 2^53 are exactly why hex strings are used
        let state = [u64::MAX, 1, 0x9E3779B97F4A7C15, (1u64 << 53) + 1];
        let j = rng_state_to_json(state);
        let text = j.to_string();
        let back = rng_state_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, state);
    }

    #[test]
    fn checkpoint_file_round_trip_and_missing_file() {
        let dir = std::env::temp_dir().join("gcn_perf_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Checkpoint::load(&dir, "unet").unwrap().is_none());

        let p = crate::zoo::unet();
        let nests = lower_pipeline(&p);
        let mut rng = Rng::new(5);
        let sched = random_pipeline_schedule(&p, &nests, &mut rng);
        let ckpt = Checkpoint {
            pipeline: "unet".into(),
            strategy: "evolution".into(),
            seed: u64::MAX - 7,
            generation: 3,
            done: false,
            best: Some((sched.clone(), 1.25e-3)),
            state: Json::obj(vec![("rng", rng_state_to_json(rng.state()))]),
        };
        ckpt.save(&dir).unwrap();
        let back = Checkpoint::load(&dir, "unet").unwrap().expect("saved checkpoint");
        assert_eq!(back.pipeline, "unet");
        assert_eq!(back.strategy, "evolution");
        assert_eq!(back.seed, u64::MAX - 7);
        assert_eq!(back.generation, 3);
        assert!(!back.done);
        let (bs, bc) = back.best.expect("best survives");
        assert_eq!(bs, sched);
        assert_eq!(bc.to_bits(), 1.25e-3f64.to_bits(), "cost must round-trip bitwise");
        let words = rng_state_from_json(back.state.get("rng").unwrap()).unwrap();
        assert_eq!(words, rng.state());

        // wrong-pipeline guard
        let err = Checkpoint::load(&dir, "unet").map(|_| ());
        assert!(err.is_ok());
        std::fs::rename(
            Checkpoint::path_for(&dir, "unet"),
            Checkpoint::path_for(&dir, "alexnet"),
        )
        .unwrap();
        let msg = Checkpoint::load(&dir, "alexnet").unwrap_err().to_string();
        assert!(msg.contains("names pipeline"), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
