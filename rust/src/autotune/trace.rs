//! Search-trace harvesting: turn the candidates a strategy scored into
//! labeled [`GraphSample`]s the training stack can consume.
//!
//! The label is *cost-to-go*, not the raw model score: a candidate seen
//! at generation `g` is labeled with the best score the search reached
//! from generation `g` onward (a suffix-minimum over per-generation
//! bests), clamped by its own score. That is the value-head target of
//! Steiner et al. (value learning for schedule search, PAPERS.md): "how
//! good is the best schedule reachable from here", which is what a
//! lookahead search wants a model to predict — scoring a *prefix* of the
//! search by its eventual outcome instead of its immediate cost.
//!
//! Harvested samples use the `dataset::json` wire format, so
//! `gcn-perf train --data <trace>` and `train::active` ingest
//! autotuner-generated data with no conversion step.

use crate::constants::BENCH_RUNS;
use crate::dataset::builder::featurize_schedule;
use crate::dataset::GraphSample;
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::PipelineSchedule;
use crate::sim::Machine;

/// One scored candidate, held until harvest assigns its cost-to-go label.
#[derive(Debug, Clone)]
struct TraceEntry {
    generation: usize,
    sched: PipelineSchedule,
    score: f64,
}

/// Records (schedule, model score) pairs per generation and harvests
/// them as cost-to-go-labeled [`GraphSample`]s.
///
/// Capped at `cap` entries; later candidates are counted but dropped
/// (search frontiers can be large, and the fleet runs many of them).
#[derive(Debug)]
pub struct TraceRecorder {
    entries: Vec<TraceEntry>,
    cap: usize,
    dropped: usize,
}

impl TraceRecorder {
    pub fn new(cap: usize) -> TraceRecorder {
        TraceRecorder { entries: Vec::new(), cap, dropped: 0 }
    }

    /// Record one generation's scored frontier.
    pub fn record(&mut self, generation: usize, scored: &[(PipelineSchedule, f64)]) {
        for (sched, score) in scored {
            if self.entries.len() >= self.cap {
                self.dropped += 1;
                continue;
            }
            self.entries.push(TraceEntry { generation, sched: sched.clone(), score: *score });
        }
    }

    /// Candidates recorded (excluding dropped ones).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Candidates dropped once the cap was hit.
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Featurize every recorded candidate with its cost-to-go label.
    ///
    /// `pipeline_id` tags all samples (the fleet uses the pipeline's
    /// fleet index); schedule ids are assigned in record order. All
    /// `runs` slots repeat the label — the trainer averages runs into
    /// one target, and a search trace has no per-run noise to model.
    pub fn harvest(
        &self,
        p: &Pipeline,
        nests: &[LoopNest],
        machine: &Machine,
        pipeline_id: u32,
    ) -> Vec<GraphSample> {
        if self.entries.is_empty() {
            return Vec::new();
        }
        // best score achieved at each generation...
        let last_gen = self.entries.iter().map(|e| e.generation).max().unwrap_or(0);
        let mut gen_best = vec![f64::INFINITY; last_gen + 1];
        for e in &self.entries {
            if e.score < gen_best[e.generation] {
                gen_best[e.generation] = e.score;
            }
        }
        // ...then the best achieved from each generation onward
        let mut suffix_best = gen_best;
        for g in (0..last_gen).rev() {
            if suffix_best[g + 1] < suffix_best[g] {
                suffix_best[g] = suffix_best[g + 1];
            }
        }
        self.entries
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let label = e.score.min(suffix_best[e.generation]);
                let mut s =
                    featurize_schedule(p, nests, &e.sched, machine, pipeline_id, i as u32);
                s.runs = [label as f32; BENCH_RUNS];
                s
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::lower_pipeline;
    use crate::schedule::random::random_pipeline_schedule;
    use crate::util::rng::Rng;

    #[test]
    fn labels_are_suffix_minima_of_generation_bests() {
        let p = crate::zoo::alexnet();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let mut rng = Rng::new(7);
        let scheds: Vec<PipelineSchedule> =
            (0..4).map(|_| random_pipeline_schedule(&p, &nests, &mut rng)).collect();

        let mut rec = TraceRecorder::new(100);
        // gen 0 scores 8.0 and 5.0; gen 1 scores 3.0 and 9.0
        rec.record(0, &[(scheds[0].clone(), 8.0), (scheds[1].clone(), 5.0)]);
        rec.record(1, &[(scheds[2].clone(), 3.0), (scheds[3].clone(), 9.0)]);
        let samples = rec.harvest(&p, &nests, &m, 42);
        assert_eq!(samples.len(), 4);
        // gen-0 entries see the eventual best (3.0) as their cost-to-go
        assert_eq!(samples[0].runs[0], 3.0);
        assert_eq!(samples[1].runs[0], 3.0);
        // gen-1: best-from-here is 3.0; own 3.0 and min(9, 3) = 3.0
        assert_eq!(samples[2].runs[0], 3.0);
        assert_eq!(samples[3].runs[0], 3.0);
        for (i, s) in samples.iter().enumerate() {
            s.validate().unwrap();
            assert_eq!(s.pipeline_id, 42);
            assert_eq!(s.schedule_id, i as u32);
            assert!(s.runs.iter().all(|&r| r == s.runs[0]), "uniform runs");
        }
    }

    #[test]
    fn own_score_clamps_the_label_and_cap_drops() {
        let p = crate::zoo::alexnet();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let mut rng = Rng::new(8);
        let s0 = random_pipeline_schedule(&p, &nests, &mut rng);
        let s1 = random_pipeline_schedule(&p, &nests, &mut rng);
        let s2 = random_pipeline_schedule(&p, &nests, &mut rng);

        let mut rec = TraceRecorder::new(2);
        // search got *worse* over time: suffix best from gen 0 is 2.0
        rec.record(0, &[(s0, 2.0), (s1, 4.0)]);
        rec.record(1, &[(s2, 6.0)]); // dropped: over cap
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.dropped(), 1);
        let samples = rec.harvest(&p, &nests, &m, 0);
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].runs[0], 2.0);
        assert_eq!(samples[1].runs[0], 4.0_f32.min(2.0)); // suffix min wins
    }

    #[test]
    fn harvested_traces_round_trip_through_dataset_json() {
        let p = crate::zoo::alexnet();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let mut rng = Rng::new(9);
        let mut rec = TraceRecorder::new(16);
        for g in 0..3 {
            let sched = random_pipeline_schedule(&p, &nests, &mut rng);
            let score = 1.0 + g as f64;
            rec.record(g, &[(sched, score)]);
        }
        let samples = rec.harvest(&p, &nests, &m, 3);
        let text = crate::dataset::json::samples_to_json(&samples);
        let back = crate::dataset::json::samples_from_json(&text).unwrap();
        assert_eq!(back.len(), samples.len());
        for (a, b) in samples.iter().zip(&back) {
            assert_eq!(a.runs, b.runs);
            assert_eq!(a.pipeline_id, b.pipeline_id);
            assert_eq!(a.n_stages, b.n_stages);
        }
        // a trace is trainable data: stats fit without degenerate spread
        let mut ds = crate::dataset::Dataset { samples: back, stats: None };
        ds.fit_stats();
        assert!(ds.stats.is_some());
    }
}
