//! Sparse, variable-size graph batching: CSR adjacency and the
//! block-diagonal [`PackedBatch`].
//!
//! The paper's stage DAGs are tiny and sparse (a Halide pipeline has
//! O(N) producer→consumer edges), so the padded dense
//! `[BATCH, MAX_NODES, MAX_NODES]` layout the AOT artifacts use wastes
//! almost all of its O(B·N²) adjacency on zeros — and caps every pipeline
//! at `MAX_NODES` stages. This module is the native engine's layout
//! instead: every graph keeps exactly its own nodes, all graphs of a
//! batch are concatenated into one packed node matrix, and the
//! row-normalized adjacency A′ = rownorm(A + Aᵀ + I) is stored as one
//! block-diagonal CSR matrix over the packed node ids. There is no
//! padding, no `MAX_NODES` cap and no fixed graph count; aggregation is
//! O(E) instead of O(N²).
//!
//! The dense padded [`crate::model::DenseBatch`] still exists for the
//! PJRT artifacts (fixed shapes are baked into the AOT HLO) and as the
//! reference layout for parity tests; [`DenseBatch::from_packed`] /
//! [`PackedBatch::from_dense`] convert between the two.

use crate::constants::{DEP_DIM, INV_DIM};
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::model::batch::DenseBatch;
use anyhow::{ensure, Result};
use std::ops::Range;
use std::sync::OnceLock;

/// Minimum α weight (Property 2 emphasis floor; see [`PackedBatch::build`]).
pub const ALPHA_FLOOR: f64 = 0.2;

/// A compressed-sparse-row matrix of f32 weights. Column indices are
/// ascending within each row, which fixes the floating-point accumulation
/// order (parity tests rely on it matching a dense in-order sweep).
#[derive(Debug, Clone, Default)]
pub struct Csr {
    /// Row start offsets into `col_idx`/`val`; length `n_rows + 1`.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl Csr {
    pub fn n_rows(&self) -> usize {
        self.row_ptr.len().saturating_sub(1)
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// The columns and values of row `r`.
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
        (&self.col_idx[a..b], &self.val[a..b])
    }

    /// The transpose, with ascending column indices per row (counting
    /// sort over the rows, which are themselves ascending — stable).
    pub fn transpose(&self) -> Csr {
        let n = self.n_rows();
        let mut counts = vec![0u32; n + 1];
        for &c in &self.col_idx {
            counts[c as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts.clone();
        let mut col_idx = vec![0u32; self.nnz()];
        let mut val = vec![0f32; self.nnz()];
        for r in 0..n {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                let slot = counts[c as usize] as usize;
                col_idx[slot] = r as u32;
                val[slot] = v;
                counts[c as usize] += 1;
            }
        }
        Csr { row_ptr, col_idx, val }
    }
}

/// Row-normalized adjacency with self loops for one graph:
/// A′ = rownorm(A + Aᵀ + I), as CSR over the graph's own node ids.
///
/// The paper's eq. uses A+I; we also add Aᵀ so information flows both
/// producer→consumer and consumer→producer (a Halide stage's cost depends
/// on both its producers' and consumers' schedules — see DESIGN.md).
/// Returns an error (instead of panicking) when an edge references a
/// stage outside `0..n_stages`; dataset loaders surface that as a
/// malformed-sample error.
pub fn build_csr(n_stages: usize, edges: &[(u32, u32)]) -> Result<Csr> {
    ensure!(n_stages > 0, "graph must have at least one stage");
    let mut nbrs: Vec<Vec<u32>> = (0..n_stages).map(|i| vec![i as u32]).collect();
    for &(src, dst) in edges {
        let (s, d) = (src as usize, dst as usize);
        ensure!(
            s < n_stages && d < n_stages,
            "edge ({s}, {d}) out of range for a {n_stages}-stage graph"
        );
        if s != d {
            nbrs[s].push(d as u32);
            nbrs[d].push(s as u32);
        }
    }
    let mut row_ptr = Vec::with_capacity(n_stages + 1);
    let mut col_idx = Vec::new();
    let mut val = Vec::new();
    row_ptr.push(0u32);
    for row in &mut nbrs {
        row.sort_unstable();
        row.dedup();
        let w = 1.0 / row.len() as f32;
        col_idx.extend_from_slice(row);
        val.resize(col_idx.len(), w);
        row_ptr.push(col_idx.len() as u32);
    }
    Ok(Csr { row_ptr, col_idx, val })
}

/// A block-diagonal batch of variable-size graphs: the nodes of all
/// graphs concatenated into one packed node matrix, with per-graph
/// offsets, and the adjacency of the whole batch as one CSR matrix over
/// packed node ids (block-diagonal by construction — no cross-graph
/// edges can exist).
#[derive(Debug, Clone)]
pub struct PackedBatch {
    /// Node range of graph `g` is `node_offset[g]..node_offset[g + 1]`;
    /// length `n_graphs + 1`.
    pub node_offset: Vec<u32>,
    /// Standardized schedule-invariant features, `[total_nodes, INV_DIM]`.
    pub inv: Vec<f32>,
    /// Standardized schedule-dependent features, `[total_nodes, DEP_DIM]`.
    pub dep: Vec<f32>,
    /// A′ over packed node ids (forward aggregation).
    pub adj: Csr,
    /// A′ᵀ over packed node ids (backward aggregation) — built lazily on
    /// first [`PackedBatch::adj_t`] call, so inference-only batches (the
    /// hot predict/search path) never pay for the transpose.
    adj_t: OnceLock<Csr>,
    /// log mean runtime per graph, `[n_graphs]`.
    pub log_y: Vec<f32>,
    /// α·β̂ loss weight per graph, `[n_graphs]` (ones for inference).
    pub weight: Vec<f32>,
}

impl PackedBatch {
    pub fn n_graphs(&self) -> usize {
        self.node_offset.len() - 1
    }

    pub fn total_nodes(&self) -> usize {
        *self.node_offset.last().unwrap() as usize
    }

    /// Packed node-id range of graph `g`.
    pub fn graph_nodes(&self, g: usize) -> Range<usize> {
        self.node_offset[g] as usize..self.node_offset[g + 1] as usize
    }

    /// Largest per-graph node count in the batch.
    pub fn max_graph_nodes(&self) -> usize {
        (0..self.n_graphs()).map(|g| self.graph_nodes(g).len()).max().unwrap_or(0)
    }

    /// A′ᵀ for the backward pass, computed on first use and cached (the
    /// training loop reuses a batch across its one train step; inference
    /// never calls this).
    pub fn adj_t(&self) -> &Csr {
        self.adj_t.get_or_init(|| self.adj.transpose())
    }

    /// Contiguous graph ranges whose node totals reach `min_nodes` (the
    /// final range may fall short). Because the adjacency is
    /// block-diagonal, each block's nodes reference only nodes of the
    /// same block, so a worker can run an entire backward pass over its
    /// block without seeing any other block's scratch state.
    ///
    /// The partition depends only on the batch — never on the thread
    /// count — which is what makes the parallel backward's block-order
    /// gradient reduction bitwise-deterministic across thread counts.
    pub fn graph_blocks(&self, min_nodes: usize) -> Vec<Range<usize>> {
        let nb = self.n_graphs();
        let min_nodes = min_nodes.max(1);
        let mut out = Vec::new();
        let mut start = 0usize;
        let mut acc = 0usize;
        for g in 0..nb {
            acc += self.graph_nodes(g).len();
            if acc >= min_nodes {
                out.push(start..g + 1);
                start = g + 1;
                acc = 0;
            }
        }
        if start < nb {
            out.push(start..nb);
        }
        out
    }

    /// Assemble a training batch from any number of samples of any size.
    ///
    /// * features are standardized with `stats`
    /// * `best_runtime[i]` = best mean runtime of sample i's pipeline (α)
    /// * β = 1/std of the runs, normalized to mean 1 within the batch and
    ///   clamped to [0.2, 5] so a near-noiseless outlier cannot dominate
    /// * α is floored at [`ALPHA_FLOOR`]: the paper's α = best/y starves
    ///   very slow schedules of gradient entirely (our random schedule
    ///   space spans >100x within a pipeline, wider than the paper's
    ///   noisy-autoscheduler output); the floor keeps Property 2's
    ///   emphasis while every sample still trains. See DESIGN.md
    ///   §Paper-faithfulness.
    pub fn build(
        samples: &[&GraphSample],
        stats: &FeatureStats,
        best_runtime: &[f64],
    ) -> Result<PackedBatch> {
        ensure!(!samples.is_empty(), "empty batch");
        ensure!(
            samples.len() == best_runtime.len(),
            "{} samples but {} best-runtime entries",
            samples.len(),
            best_runtime.len()
        );

        // β normalization over the batch
        let betas: Vec<f64> = samples
            .iter()
            .map(|s| 1.0 / s.std_runtime().max(1e-9))
            .collect();
        let beta_mean = betas.iter().sum::<f64>() / betas.len() as f64;

        let mut b = PackedBatch::packed_features(samples, stats)?;
        for (gi, s) in samples.iter().enumerate() {
            let mean_y = s.mean_runtime();
            b.log_y[gi] = (mean_y.max(1e-12)).ln() as f32;
            let alpha = (best_runtime[gi] / mean_y).clamp(ALPHA_FLOOR, 1.0);
            let beta_hat = (betas[gi] / beta_mean).clamp(0.2, 5.0);
            b.weight[gi] = (alpha * beta_hat) as f32;
        }
        Ok(b)
    }

    /// Assemble an inference batch: features + adjacency only (loss
    /// weights are ones, targets zero — predictors never read them).
    pub fn for_inference(samples: &[&GraphSample], stats: &FeatureStats) -> Result<PackedBatch> {
        ensure!(!samples.is_empty(), "empty batch");
        PackedBatch::packed_features(samples, stats)
    }

    /// Shared feature/adjacency packing; `log_y` zero, `weight` one.
    fn packed_features(samples: &[&GraphSample], stats: &FeatureStats) -> Result<PackedBatch> {
        let mut node_offset = Vec::with_capacity(samples.len() + 1);
        node_offset.push(0u32);
        let mut total = 0usize;
        for s in samples {
            ensure!(
                s.inv.len() == s.n_stages as usize && s.dep.len() == s.n_stages as usize,
                "sample (pipeline {}, schedule {}) has {} stages but {}/{} feature rows",
                s.pipeline_id,
                s.schedule_id,
                s.n_stages,
                s.inv.len(),
                s.dep.len()
            );
            total += s.n_stages as usize;
            node_offset.push(total as u32);
        }

        let mut inv = vec![0f32; total * INV_DIM];
        let mut dep = vec![0f32; total * DEP_DIM];
        let mut row_ptr = Vec::with_capacity(total + 1);
        row_ptr.push(0u32);
        let mut col_idx = Vec::new();
        let mut val = Vec::new();

        for (gi, s) in samples.iter().enumerate() {
            let base = node_offset[gi] as usize;
            for (si, (iv, dv)) in s.inv.iter().zip(&s.dep).enumerate() {
                let mut f = crate::features::StageFeatures {
                    invariant: *iv,
                    dependent: *dv,
                };
                stats.apply(&mut f);
                let io = (base + si) * INV_DIM;
                inv[io..io + INV_DIM].copy_from_slice(&f.invariant);
                let doff = (base + si) * DEP_DIM;
                dep[doff..doff + DEP_DIM].copy_from_slice(&f.dependent);
            }
            let g = build_csr(s.n_stages as usize, &s.edges)?;
            // splice the graph's CSR block in at the packed offset
            let nnz0 = col_idx.len() as u32;
            col_idx.extend(g.col_idx.iter().map(|&c| c + base as u32));
            val.extend_from_slice(&g.val);
            row_ptr.extend(g.row_ptr[1..].iter().map(|&p| p + nnz0));
        }

        let adj = Csr { row_ptr, col_idx, val };
        let n_graphs = samples.len();
        Ok(PackedBatch {
            node_offset,
            inv,
            dep,
            adj,
            adj_t: OnceLock::new(),
            log_y: vec![0f32; n_graphs],
            weight: vec![1f32; n_graphs],
        })
    }

    /// Convert a dense padded batch (the PJRT/fixture layout) into the
    /// packed layout. Only the real graphs (`sample_mask > 0` rows still
    /// count as graphs — their `weight` is folded with the mask) and the
    /// real nodes of each graph survive; adjacency entries into padding
    /// columns are dropped (their dense contribution is exactly zero, so
    /// outputs are preserved bit-for-bit up to f64 summation of zeros).
    pub fn from_dense(d: &DenseBatch) -> Result<PackedBatch> {
        let np = d.n_pad;
        let mut node_offset = Vec::with_capacity(d.len + 1);
        node_offset.push(0u32);
        let mut sizes = Vec::with_capacity(d.len);
        let mut total = 0usize;
        for g in 0..d.len {
            let mask = &d.mask[g * np..(g + 1) * np];
            let n = mask.iter().take_while(|&&m| m != 0.0).count();
            ensure!(
                mask[n..].iter().all(|&m| m == 0.0),
                "graph {g}: node mask is not a contiguous prefix"
            );
            ensure!(n > 0, "graph {g}: empty node mask");
            sizes.push(n);
            total += n;
            node_offset.push(total as u32);
        }

        let mut inv = vec![0f32; total * INV_DIM];
        let mut dep = vec![0f32; total * DEP_DIM];
        let mut row_ptr = Vec::with_capacity(total + 1);
        row_ptr.push(0u32);
        let mut col_idx = Vec::new();
        let mut val = Vec::new();
        for g in 0..d.len {
            let n = sizes[g];
            let base = node_offset[g] as usize;
            for r in 0..n {
                let src = g * np + r;
                inv[(base + r) * INV_DIM..(base + r + 1) * INV_DIM]
                    .copy_from_slice(&d.inv[src * INV_DIM..(src + 1) * INV_DIM]);
                dep[(base + r) * DEP_DIM..(base + r + 1) * DEP_DIM]
                    .copy_from_slice(&d.dep[src * DEP_DIM..(src + 1) * DEP_DIM]);
                let arow = &d.adj[(g * np + r) * np..(g * np + r) * np + n];
                for (c, &a) in arow.iter().enumerate() {
                    if a != 0.0 {
                        col_idx.push((base + c) as u32);
                        val.push(a);
                    }
                }
                row_ptr.push(col_idx.len() as u32);
            }
        }
        let adj = Csr { row_ptr, col_idx, val };
        let log_y = d.log_y[..d.len].to_vec();
        let weight: Vec<f32> = (0..d.len).map(|g| d.weight[g] * d.sample_mask[g]).collect();
        Ok(PackedBatch {
            node_offset,
            inv,
            dep,
            adj,
            adj_t: OnceLock::new(),
            log_y,
            weight,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testfix::{chain_sample as mk_sample, identity_stats};

    #[test]
    fn csr_rows_sum_to_one_and_are_symmetric_in_structure() {
        let adj = build_csr(3, &[(0, 1), (1, 2)]).unwrap();
        assert_eq!(adj.n_rows(), 3);
        for r in 0..3 {
            let (_, vals) = adj.row(r);
            let sum: f32 = vals.iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "row {r} sums to {sum}");
        }
        // 0↔1 and 1↔2 both directions, self loops everywhere
        let (c0, _) = adj.row(0);
        assert_eq!(c0, &[0, 1]);
        let (c1, _) = adj.row(1);
        assert_eq!(c1, &[0, 1, 2]);
        let (c2, _) = adj.row(2);
        assert_eq!(c2, &[1, 2]);
    }

    #[test]
    fn build_csr_rejects_out_of_range_edges() {
        let err = build_csr(3, &[(0, 7)]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert!(build_csr(0, &[]).is_err());
        // duplicate + self edges are tolerated (dense semantics)
        let adj = build_csr(2, &[(0, 1), (1, 0), (0, 0)]).unwrap();
        let (c0, v0) = adj.row(0);
        assert_eq!(c0, &[0, 1]);
        assert!((v0[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn transpose_roundtrips() {
        let adj = build_csr(4, &[(0, 1), (0, 2), (2, 3)]).unwrap();
        let t = adj.transpose();
        let tt = t.transpose();
        assert_eq!(adj.row_ptr, tt.row_ptr);
        assert_eq!(adj.col_idx, tt.col_idx);
        assert_eq!(adj.val, tt.val);
        // A'[r][c] == A'ᵀ[c][r]
        for r in 0..4 {
            let (cols, vals) = adj.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                let (tc, tv) = t.row(c as usize);
                let pos = tc.iter().position(|&x| x == r as u32).unwrap();
                assert_eq!(tv[pos], v);
            }
        }
    }

    #[test]
    fn packed_layout_and_offsets() {
        let s1 = mk_sample(3, 1e-3);
        let s2 = mk_sample(5, 2e-3);
        let best = vec![1e-3, 1e-3];
        let b = PackedBatch::build(&[&s1, &s2], &identity_stats(), &best).unwrap();
        assert_eq!(b.n_graphs(), 2);
        assert_eq!(b.total_nodes(), 8);
        assert_eq!(b.graph_nodes(0), 0..3);
        assert_eq!(b.graph_nodes(1), 3..8);
        assert_eq!(b.max_graph_nodes(), 5);
        // features at the packed offsets
        assert_eq!(b.inv[0], 0.5);
        assert_eq!(b.dep[0], 1.5);
        assert_eq!(b.inv[3 * INV_DIM], 0.5); // graph 1, stage 0
        // the adjacency is block-diagonal: no column crosses its block
        for g in 0..2 {
            let r = b.graph_nodes(g);
            for node in r.clone() {
                let (cols, _) = b.adj.row(node);
                for &c in cols {
                    assert!(r.contains(&(c as usize)), "edge {node}->{c} leaves block {g}");
                }
            }
        }
        // log targets
        assert!((b.log_y[0] as f64 - (1e-3f64).ln()).abs() < 1e-3);
    }

    #[test]
    fn graph_blocks_tile_graphs_and_respect_node_budget() {
        let samples: Vec<_> = [3u32, 5, 40, 2, 2, 60, 4]
            .iter()
            .map(|&n| mk_sample(n, 1e-3))
            .collect();
        let refs: Vec<_> = samples.iter().collect();
        let b = PackedBatch::for_inference(&refs, &identity_stats()).unwrap();
        let blocks = b.graph_blocks(10);
        // blocks tile 0..n_graphs contiguously in order
        let mut next = 0;
        for r in &blocks {
            assert_eq!(r.start, next);
            assert!(!r.is_empty());
            next = r.end;
        }
        assert_eq!(next, b.n_graphs());
        // every block except the last reaches the node budget, and no
        // block keeps absorbing graphs once it has
        for (i, r) in blocks.iter().enumerate() {
            let nodes: usize = r.clone().map(|g| b.graph_nodes(g).len()).sum();
            if i + 1 < blocks.len() {
                assert!(nodes >= 10, "block {i} holds only {nodes} nodes");
                let without_last: usize =
                    (r.start..r.end - 1).map(|g| b.graph_nodes(g).len()).sum();
                assert!(without_last < 10, "block {i} overshot the budget");
            }
        }
        // degenerate budgets still tile everything
        assert_eq!(b.graph_blocks(1).len(), b.n_graphs());
        assert_eq!(b.graph_blocks(usize::MAX).len(), 1);
        assert_eq!(b.graph_blocks(0).len(), b.n_graphs());
    }

    #[test]
    fn no_node_cap() {
        // far beyond the old MAX_NODES = 48 cap
        let big = mk_sample(200, 1e-3);
        let b = PackedBatch::build(&[&big], &identity_stats(), &[1e-3]).unwrap();
        assert_eq!(b.total_nodes(), 200);
        assert_eq!(b.adj.nnz(), 200 + 2 * 199); // self loops + chain both ways
    }

    #[test]
    fn alpha_weights_best_schedule_highest() {
        let fast = mk_sample(3, 1e-3); // the best schedule
        let slow = mk_sample(3, 8e-3);
        let best = vec![1e-3, 1e-3];
        let b = PackedBatch::build(&[&fast, &slow], &identity_stats(), &best).unwrap();
        assert!(
            b.weight[0] > b.weight[1] * 4.0,
            "α should favor fast schedules: {:?}",
            &b.weight[..2]
        );
    }

    #[test]
    fn beta_clamped() {
        let mut noisy = mk_sample(3, 1e-3);
        noisy.runs[0] = 2e-3; // large spread
        let quiet = mk_sample(3, 1e-3); // zero spread -> huge raw beta
        let best = vec![1e-3, 1e-3];
        let b = PackedBatch::build(&[&noisy, &quiet], &identity_stats(), &best).unwrap();
        assert!(b.weight.iter().all(|w| w.is_finite()));
        assert!(b.weight[1] <= 5.0 * 1.0 + 1e-6);
    }

    #[test]
    fn build_propagates_malformed_edges() {
        let mut bad = mk_sample(3, 1e-3);
        bad.edges.push((0, 40));
        let err = PackedBatch::build(&[&bad], &identity_stats(), &[1e-3])
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn dense_roundtrip_preserves_structure() {
        let s1 = mk_sample(3, 1e-3);
        let s2 = mk_sample(5, 2e-3);
        let best = vec![1e-3, 1e-3];
        let p = PackedBatch::build(&[&s1, &s2], &identity_stats(), &best).unwrap();
        let d = DenseBatch::from_packed(&p, 8, 4).unwrap();
        assert_eq!(d.len, 2);
        assert_eq!(d.n_pad, 8);
        assert_eq!(d.n_graphs, 4);
        let q = PackedBatch::from_dense(&d).unwrap();
        assert_eq!(p.node_offset, q.node_offset);
        assert_eq!(p.inv, q.inv);
        assert_eq!(p.dep, q.dep);
        assert_eq!(p.adj.row_ptr, q.adj.row_ptr);
        assert_eq!(p.adj.col_idx, q.adj.col_idx);
        assert_eq!(p.adj.val, q.adj.val);
        assert_eq!(p.log_y, q.log_y);
        assert_eq!(p.weight, q.weight);
        // a graph bigger than n_pad must be rejected
        assert!(DenseBatch::from_packed(&p, 4, 4).is_err());
        assert!(DenseBatch::from_packed(&p, 8, 1).is_err());
    }
}
