//! Partition-sampled training for over-budget graphs.
//!
//! A TpuGraphs-scale stage graph (10k–100k nodes) cannot ride through a
//! single [`crate::model::PackedBatch`] inside a fixed workspace, so the
//! train/eval loops split it into contiguous node-range partitions and
//! feed each partition through the unmodified packed path:
//!
//! * **Boundaries are backward-block boundaries.** Partition sizes are
//!   multiples of [`PARTITION_BLOCK_NODES`] (the backward pass's fixed
//!   512-node blocking), so a partitioned batch tiles exactly like the
//!   corresponding rows of the full graph would.
//! * **Contiguous ranges, halo radius 0.** Stage ids are topological and
//!   the generators emit overwhelmingly local edges, so cutting at range
//!   boundaries drops only the few edges that span two partitions
//!   ([`Partitioned::cut_edge_fraction`] reports how many). A nonzero
//!   halo would re-attach those edges but double-count the halo nodes in
//!   the model's sum-readout, which is the larger error — so boundary
//!   handling is "drop + account", not "replicate".
//! * **Labels scale by node share.** Partition `p` with `n_p` of `n`
//!   nodes gets runtimes `runs · n_p/n`, so `Σ_p exp(log ŷ_p)` targets
//!   the parent runtime exactly ([`combine_runtimes`] is that sum) and
//!   scaling the per-pipeline best by the same share leaves the loss's
//!   α = best/ȳ term bitwise unchanged.
//!
//! The approximation (pinned by tests here and documented with its error
//! envelope in DESIGN.md): gradients/predictions of a partitioned graph
//! equal the full-graph ones except for messages along cut edges — exact
//! when no edge crosses a boundary, and degrading with
//! [`Partitioned::cut_edge_fraction`].

use crate::constants::PARTITION_BLOCK_NODES;
use crate::dataset::sample::GraphSample;

/// An over-budget sample split into budget-sized sub-samples.
pub struct Partitioned {
    /// Contiguous node-range sub-samples, in node order. Each validates
    /// as a standalone [`GraphSample`] and holds at most the budget the
    /// split was made with.
    pub parts: Vec<GraphSample>,
    /// Node share of each part (`n_p / n`); sums to 1.
    pub shares: Vec<f64>,
    /// Edges dropped because they crossed a partition boundary.
    pub cut_edges: usize,
    /// Edge count of the parent sample.
    pub total_edges: usize,
}

impl Partitioned {
    /// Fraction of parent edges lost at partition boundaries — the knob
    /// that bounds the approximation error (0.0 = exact).
    pub fn cut_edge_fraction(&self) -> f64 {
        if self.total_edges == 0 {
            0.0
        } else {
            self.cut_edges as f64 / self.total_edges as f64
        }
    }
}

/// Largest partition size that is a whole number of backward blocks and
/// fits `node_budget`.
fn part_nodes(node_budget: usize) -> usize {
    let budget = node_budget.max(PARTITION_BLOCK_NODES);
    (budget / PARTITION_BLOCK_NODES) * PARTITION_BLOCK_NODES
}

/// Split `s` into contiguous node-range partitions of at most
/// `node_budget` nodes (block-aligned). A sample already within budget
/// comes back unchanged as a single part with share 1.
pub fn partition_sample(s: &GraphSample, node_budget: usize) -> Partitioned {
    let n = s.n_stages as usize;
    let total_edges = s.edges.len();
    if n <= node_budget.max(PARTITION_BLOCK_NODES) {
        return Partitioned {
            parts: vec![s.clone()],
            shares: vec![1.0],
            cut_edges: 0,
            total_edges,
        };
    }
    let step = part_nodes(node_budget);
    let mut parts = Vec::with_capacity(n.div_ceil(step));
    let mut shares = Vec::with_capacity(n.div_ceil(step));
    let mut cut_edges = 0usize;
    let mut start = 0usize;
    while start < n {
        let end = (start + step).min(n);
        let len = end - start;
        let share = len as f64 / n as f64;
        let edges: Vec<(u32, u32)> = s
            .edges
            .iter()
            .filter(|&&(src, dst)| {
                let keep = (src as usize) >= start
                    && (src as usize) < end
                    && (dst as usize) >= start
                    && (dst as usize) < end;
                if !keep && (src as usize) < end && (dst as usize) >= start {
                    // spans this boundary; counted once, at the part
                    // that contains its source
                    cut_edges += usize::from((src as usize) >= start);
                }
                keep
            })
            .map(|&(src, dst)| (src - start as u32, dst - start as u32))
            .collect();
        let mut runs = s.runs;
        for r in &mut runs {
            *r = (*r as f64 * share) as f32;
        }
        parts.push(GraphSample {
            pipeline_id: s.pipeline_id,
            schedule_id: s.schedule_id,
            n_stages: len as u32,
            edges,
            inv: s.inv[start..end].to_vec(),
            dep: s.dep[start..end].to_vec(),
            runs,
        });
        shares.push(share);
        start = end;
    }
    Partitioned { parts, shares, cut_edges, total_edges }
}

/// Combine per-partition runtime predictions into the parent-graph
/// prediction. Labels are node-share-scaled, so the parts' runtimes sum
/// to the parent's: ŷ = Σ_p ŷ_p.
pub fn combine_runtimes(part_predictions: &[f64]) -> f64 {
    part_predictions.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testfix::chain_sample;

    #[test]
    fn within_budget_is_identity() {
        let s = chain_sample(10, 1e-3);
        let p = partition_sample(&s, 512);
        assert_eq!(p.parts.len(), 1);
        assert_eq!(p.shares, vec![1.0]);
        assert_eq!(p.cut_edges, 0);
        let only = &p.parts[0];
        assert_eq!(only.n_stages, s.n_stages);
        assert_eq!(only.edges, s.edges);
        assert_eq!(only.runs, s.runs);
    }

    #[test]
    fn chain_partitions_are_aligned_valid_and_account_for_cuts() {
        let s = chain_sample(2000, 1e-3);
        let p = partition_sample(&s, 512);
        // 512 + 512 + 512 + 464
        assert_eq!(p.parts.len(), 4);
        let total: u32 = p.parts.iter().map(|q| q.n_stages).sum();
        assert_eq!(total, 2000);
        for q in &p.parts[..3] {
            assert_eq!(q.n_stages as usize % PARTITION_BLOCK_NODES, 0);
        }
        for q in &p.parts {
            assert!(q.n_stages as usize <= 512);
            q.validate().unwrap();
        }
        // a chain crosses each of the 3 boundaries exactly once
        assert_eq!(p.cut_edges, 3);
        assert_eq!(p.total_edges, 1999);
        assert!((p.cut_edge_fraction() - 3.0 / 1999.0).abs() < 1e-15);
        assert!((p.shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn shares_preserve_alpha_and_label_mass() {
        let s = chain_sample(1500, 2e-3);
        let p = partition_sample(&s, 512);
        let parent_mean = s.mean_runtime();
        let best = parent_mean * 0.5; // any per-pipeline best
        let mut recombined = 0.0;
        for (q, &share) in p.parts.iter().zip(&p.shares) {
            let m = q.mean_runtime();
            // label mass scales with the node share...
            assert!((m - parent_mean * share).abs() / (parent_mean * share) < 1e-5);
            // ...so a share-scaled best keeps α = best/ȳ unchanged
            let alpha_part = (best * share) / m;
            let alpha_full = best / parent_mean;
            assert!((alpha_part - alpha_full).abs() < 1e-5);
            recombined += m;
        }
        assert!((combine_runtimes(&[recombined]) - parent_mean).abs() / parent_mean < 1e-5);
    }

    #[test]
    fn budget_rounds_down_to_block_multiples() {
        let s = chain_sample(3000, 1e-3);
        // an unaligned budget must floor to whole backward blocks
        let p = partition_sample(&s, 700);
        for q in &p.parts {
            assert!(q.n_stages as usize <= 512);
        }
        let total: u32 = p.parts.iter().map(|q| q.n_stages).sum();
        assert_eq!(total, 3000);
    }
}
