//! Model-side data preparation.
//!
//! [`graph`] holds the native engine's layout: CSR adjacency and the
//! block-diagonal variable-size [`PackedBatch`] (no node caps, no
//! padding). [`batch`] keeps the dense padded [`DenseBatch`] that the
//! fixed-shape PJRT artifacts require, plus the converters between the
//! two layouts. [`partition`] splits over-budget graphs into
//! block-aligned node-range sub-samples so TpuGraphs-scale graphs train
//! through the packed path inside a fixed node budget.

pub mod batch;
pub mod graph;
pub mod partition;

pub use batch::DenseBatch;
pub use graph::{build_csr, Csr, PackedBatch, ALPHA_FLOOR};
pub use partition::{combine_runtimes, partition_sample, Partitioned};
