//! Model-side data preparation.
//!
//! [`graph`] holds the native engine's layout: CSR adjacency and the
//! block-diagonal variable-size [`PackedBatch`] (no node caps, no
//! padding). [`batch`] keeps the dense padded [`DenseBatch`] that the
//! fixed-shape PJRT artifacts require, plus the converters between the
//! two layouts.

pub mod batch;
pub mod graph;

pub use batch::DenseBatch;
pub use graph::{build_csr, Csr, PackedBatch, ALPHA_FLOOR};
