//! Model-side data preparation: padded graph batches and the normalized
//! adjacency transform — the rust half of the contract with the AOT'd JAX
//! model (shapes fixed by `artifacts/manifest.json`).

pub mod batch;

pub use batch::{build_adjacency, Batch};
