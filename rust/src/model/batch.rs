//! The dense padded batch layout — kept for the PJRT path and as the
//! parity-test reference.
//!
//! The AOT artifacts take fixed shapes [B, N, ·] (B = `BATCH`,
//! N = `MAX_NODES`), so the PJRT backend converts the native engine's
//! [`crate::model::PackedBatch`] into a [`DenseBatch`] right before
//! upload ([`DenseBatch::from_packed`] with those exact dims). The dense
//! reference engine ([`crate::runtime::DenseRefBackend`]) uses the same
//! layout with free dims to reproduce the pre-sparse execution semantics
//! for parity tests and the dense-vs-sparse benchmarks. Nothing else in
//! the stack builds dense batches anymore.

use crate::constants::{DEP_DIM, INV_DIM};
use crate::model::graph::PackedBatch;
use anyhow::{ensure, Result};

/// One fixed-shape dense batch, flat row-major. With `n_graphs = BATCH`
/// and `n_pad = MAX_NODES` this is byte-for-byte the PJRT upload layout.
#[derive(Debug, Clone)]
pub struct DenseBatch {
    /// Padded graph rows (≥ `len`).
    pub n_graphs: usize,
    /// Padded node count per graph.
    pub n_pad: usize,
    pub inv: Vec<f32>,         // [n_graphs, n_pad, INV_DIM]
    pub dep: Vec<f32>,         // [n_graphs, n_pad, DEP_DIM]
    pub adj: Vec<f32>,         // [n_graphs, n_pad, n_pad]
    pub mask: Vec<f32>,        // [n_graphs, n_pad]
    pub log_y: Vec<f32>,       // [n_graphs]
    pub weight: Vec<f32>,      // [n_graphs]  α·β̂ loss weights
    pub sample_mask: Vec<f32>, // [n_graphs]  0 for padding rows
    /// Number of real graphs (≤ `n_graphs`).
    pub len: usize,
}

impl DenseBatch {
    /// An all-zero batch of the given padded dims.
    pub fn zeros(n_graphs: usize, n_pad: usize, len: usize) -> DenseBatch {
        DenseBatch {
            n_graphs,
            n_pad,
            inv: vec![0.0; n_graphs * n_pad * INV_DIM],
            dep: vec![0.0; n_graphs * n_pad * DEP_DIM],
            adj: vec![0.0; n_graphs * n_pad * n_pad],
            mask: vec![0.0; n_graphs * n_pad],
            log_y: vec![0.0; n_graphs],
            weight: vec![0.0; n_graphs],
            sample_mask: vec![0.0; n_graphs],
            len,
        }
    }

    /// Pad a packed batch out to fixed dense shapes. Errors when a graph
    /// exceeds `n_pad` nodes or the batch exceeds `n_graphs` graphs —
    /// which is exactly the old `MAX_NODES`/`BATCH` cap, now confined to
    /// the PJRT artifacts that actually require it.
    pub fn from_packed(p: &PackedBatch, n_pad: usize, n_graphs: usize) -> Result<DenseBatch> {
        ensure!(
            p.n_graphs() <= n_graphs,
            "packed batch has {} graphs, dense layout holds {n_graphs}",
            p.n_graphs()
        );
        let mut d = DenseBatch::zeros(n_graphs, n_pad, p.n_graphs());
        for g in 0..p.n_graphs() {
            let nodes = p.graph_nodes(g);
            let base = nodes.start;
            let n = nodes.len();
            ensure!(
                n <= n_pad,
                "graph {g} has {n} nodes, dense layout pads to {n_pad}"
            );
            for r in 0..n {
                let dst = g * n_pad + r;
                let src = base + r;
                d.inv[dst * INV_DIM..(dst + 1) * INV_DIM]
                    .copy_from_slice(&p.inv[src * INV_DIM..(src + 1) * INV_DIM]);
                d.dep[dst * DEP_DIM..(dst + 1) * DEP_DIM]
                    .copy_from_slice(&p.dep[src * DEP_DIM..(src + 1) * DEP_DIM]);
                d.mask[dst] = 1.0;
                let arow = &mut d.adj[(g * n_pad + r) * n_pad..(g * n_pad + r + 1) * n_pad];
                let (cols, vals) = p.adj.row(src);
                for (&c, &v) in cols.iter().zip(vals) {
                    let local = c as usize;
                    ensure!(
                        nodes.contains(&local),
                        "adjacency entry {src}->{local} crosses graph {g}'s block"
                    );
                    arow[local - base] = v;
                }
            }
            // padding node rows: bare self loop, so the conv is the
            // identity there (the node mask gates them out anyway)
            for r in n..n_pad {
                d.adj[(g * n_pad + r) * n_pad + r] = 1.0;
            }
            d.log_y[g] = p.log_y[g];
            d.weight[g] = p.weight[g];
            d.sample_mask[g] = 1.0;
        }
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_has_consistent_shapes() {
        let d = DenseBatch::zeros(4, 7, 2);
        assert_eq!(d.inv.len(), 4 * 7 * INV_DIM);
        assert_eq!(d.adj.len(), 4 * 7 * 7);
        assert_eq!(d.mask.len(), 4 * 7);
        assert_eq!(d.len, 2);
    }
}
