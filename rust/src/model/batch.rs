//! Padded, normalized graph batches.
//!
//! The AOT artifacts take fixed shapes [B, N, ·] (B = BATCH, N = MAX_NODES).
//! `Batch` owns the flat row-major buffers in exactly the layout PJRT
//! expects, so `runtime` can upload without copies.

use crate::constants::{BATCH, DEP_DIM, INV_DIM, MAX_NODES};
#[cfg(test)]
use crate::constants::BENCH_RUNS;
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;

/// Row-normalized adjacency with self loops: A' = rownorm(A + Aᵀ + I).
///
/// The paper's eq. uses A+I; we also add Aᵀ so information flows both
/// producer→consumer and consumer→producer (a Halide stage's cost depends
/// on both its producers' and consumers' schedules — see DESIGN.md). Rows
/// of padding nodes get a bare self loop so the conv is the identity there.
pub fn build_adjacency(n_stages: usize, edges: &[(u16, u16)], n_pad: usize) -> Vec<f32> {
    let mut a = vec![0f32; n_pad * n_pad];
    for i in 0..n_pad {
        a[i * n_pad + i] = 1.0;
    }
    for &(src, dst) in edges {
        let (s, d) = (src as usize, dst as usize);
        assert!(s < n_stages && d < n_stages, "edge out of range");
        a[s * n_pad + d] = 1.0;
        a[d * n_pad + s] = 1.0;
    }
    for r in 0..n_pad {
        let row = &mut a[r * n_pad..(r + 1) * n_pad];
        let sum: f32 = row.iter().sum();
        if sum > 0.0 {
            row.iter_mut().for_each(|v| *v /= sum);
        }
    }
    a
}

/// Minimum α weight (Property 2 emphasis floor; see `Batch::build`).
pub const ALPHA_FLOOR: f64 = 0.2;

/// One fixed-shape batch, flat row-major, ready for PJRT upload.
#[derive(Debug, Clone)]
pub struct Batch {
    pub inv: Vec<f32>,         // [B, N, INV_DIM]
    pub dep: Vec<f32>,         // [B, N, DEP_DIM]
    pub adj: Vec<f32>,         // [B, N, N]
    pub mask: Vec<f32>,        // [B, N]
    pub log_y: Vec<f32>,       // [B]
    pub weight: Vec<f32>,      // [B]  α·β̂ loss weights
    pub sample_mask: Vec<f32>, // [B]  0 for padding rows
    /// Number of real samples (≤ BATCH).
    pub len: usize,
}

impl Batch {
    /// Assemble a batch from ≤ BATCH samples.
    ///
    /// * features are standardized with `stats`
    /// * `best_runtime[i]` = best mean runtime of sample i's pipeline (α)
    /// * β = 1/std of the runs, normalized to mean 1 within the batch and
    ///   clamped to [0.2, 5] so a near-noiseless outlier cannot dominate
    pub fn build(
        samples: &[&GraphSample],
        stats: &FeatureStats,
        best_runtime: &[f64],
    ) -> Batch {
        assert!(!samples.is_empty() && samples.len() <= BATCH);
        assert_eq!(samples.len(), best_runtime.len());
        let n = MAX_NODES;
        let mut b = Batch {
            inv: vec![0.0; BATCH * n * INV_DIM],
            dep: vec![0.0; BATCH * n * DEP_DIM],
            adj: vec![0.0; BATCH * n * n],
            mask: vec![0.0; BATCH * n],
            log_y: vec![0.0; BATCH],
            weight: vec![0.0; BATCH],
            sample_mask: vec![0.0; BATCH],
            len: samples.len(),
        };

        // β normalization over the real samples
        let betas: Vec<f64> = samples
            .iter()
            .map(|s| 1.0 / s.std_runtime().max(1e-9))
            .collect();
        let beta_mean = betas.iter().sum::<f64>() / betas.len() as f64;

        for (bi, s) in samples.iter().enumerate() {
            let ns = s.n_stages as usize;
            assert!(ns <= n, "sample has {ns} stages > MAX_NODES {n}");
            for (si, (iv, dv)) in s.inv.iter().zip(&s.dep).enumerate() {
                let mut f = crate::features::StageFeatures {
                    invariant: *iv,
                    dependent: *dv,
                };
                stats.apply(&mut f);
                let io = (bi * n + si) * INV_DIM;
                b.inv[io..io + INV_DIM].copy_from_slice(&f.invariant);
                let doff = (bi * n + si) * DEP_DIM;
                b.dep[doff..doff + DEP_DIM].copy_from_slice(&f.dependent);
                b.mask[bi * n + si] = 1.0;
            }
            let adj = build_adjacency(ns, &s.edges, n);
            b.adj[bi * n * n..(bi + 1) * n * n].copy_from_slice(&adj);

            let mean_y = s.mean_runtime();
            b.log_y[bi] = (mean_y.max(1e-12)).ln() as f32;
            // α floor: the paper's α = best/y starves very slow schedules of
            // gradient entirely (our random schedule space spans >100x within
            // a pipeline, wider than the paper's noisy-autoscheduler output);
            // a 0.2 floor keeps Property 2's emphasis while every sample
            // still trains. See DESIGN.md §Paper-faithfulness.
            let alpha = (best_runtime[bi] / mean_y).clamp(ALPHA_FLOOR, 1.0);
            let beta_hat = (betas[bi] / beta_mean).clamp(0.2, 5.0);
            b.weight[bi] = (alpha * beta_hat) as f32;
            b.sample_mask[bi] = 1.0;
        }
        b
    }

    /// Mean measured runtimes (seconds) of the real samples.
    pub fn targets(&self) -> Vec<f64> {
        (0..self.len).map(|i| (self.log_y[i] as f64).exp()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::sample::GraphSample;

    fn mk_sample(n_stages: u16, runtime: f32) -> GraphSample {
        let ns = n_stages as usize;
        GraphSample {
            pipeline_id: 1,
            schedule_id: 0,
            n_stages,
            edges: (0..ns.saturating_sub(1))
                .map(|i| (i as u16, (i + 1) as u16))
                .collect(),
            inv: vec![[0.5; INV_DIM]; ns],
            dep: vec![[1.5; DEP_DIM]; ns],
            runs: [runtime; BENCH_RUNS],
        }
    }

    fn identity_stats() -> FeatureStats {
        FeatureStats {
            inv_mean: vec![0.0; INV_DIM],
            inv_std: vec![1.0; INV_DIM],
            dep_mean: vec![0.0; DEP_DIM],
            dep_std: vec![1.0; DEP_DIM],
        }
    }

    #[test]
    fn adjacency_rows_sum_to_one() {
        let adj = build_adjacency(3, &[(0, 1), (1, 2)], 5);
        for r in 0..5 {
            let sum: f32 = adj[r * 5..(r + 1) * 5].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "row {r} sums to {sum}");
        }
        // padding rows are pure self loops
        assert_eq!(adj[3 * 5 + 3], 1.0);
        assert_eq!(adj[4 * 5 + 4], 1.0);
        // symmetric off-diagonal structure
        assert!(adj[1] > 0.0 && adj[5] > 0.0); // 0->1 and 1->0
    }

    #[test]
    fn batch_layout_and_masks() {
        let s1 = mk_sample(3, 1e-3);
        let s2 = mk_sample(5, 2e-3);
        let best = vec![1e-3, 1e-3];
        let b = Batch::build(&[&s1, &s2], &identity_stats(), &best);
        assert_eq!(b.len, 2);
        // masks
        let n = MAX_NODES;
        assert_eq!(b.mask[0..3], [1.0, 1.0, 1.0]);
        assert_eq!(b.mask[3], 0.0);
        assert_eq!(b.mask[n..n + 5], [1.0; 5]);
        assert_eq!(b.sample_mask[..3], [1.0, 1.0, 0.0]);
        // features placed at the right offsets
        assert_eq!(b.inv[0], 0.5);
        assert_eq!(b.dep[0], 1.5);
        assert_eq!(b.inv[(n + 4) * INV_DIM], 0.5); // sample 2, stage 4
        // log targets
        assert!((b.log_y[0] as f64 - (1e-3f64).ln()).abs() < 1e-3);
    }

    #[test]
    fn alpha_weights_best_schedule_highest() {
        let fast = mk_sample(3, 1e-3); // the best schedule
        let slow = mk_sample(3, 8e-3);
        let best = vec![1e-3, 1e-3];
        let b = Batch::build(&[&fast, &slow], &identity_stats(), &best);
        assert!(
            b.weight[0] > b.weight[1] * 4.0,
            "α should favor fast schedules: {:?}",
            &b.weight[..2]
        );
    }

    #[test]
    fn beta_clamped() {
        let mut noisy = mk_sample(3, 1e-3);
        noisy.runs[0] = 2e-3; // large spread
        let quiet = mk_sample(3, 1e-3); // zero spread -> huge raw beta
        let best = vec![1e-3, 1e-3];
        let b = Batch::build(&[&noisy, &quiet], &identity_stats(), &best);
        assert!(b.weight.iter().all(|w| w.is_finite()));
        assert!(b.weight[1] <= 5.0 * 1.0 + 1e-6);
    }
}
