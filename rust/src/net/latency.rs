//! Request-latency recording for the serving layer.
//!
//! A fixed-size reservoir (algorithm R, driven by the crate's own
//! deterministic [`Rng`]) keeps percentiles exact while the sample count
//! stays under the cap and an unbiased sample beyond it, so a week-long
//! daemon reports honest p99 without unbounded memory. Snapshots also
//! bin the sampled values into power-of-two buckets — the latency
//! histogram `BENCH_6.json` records.

use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Quantiles;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Reservoir size: exact percentiles up to 64 Ki recorded latencies.
pub const RESERVOIR_CAP: usize = 1 << 16;

struct RecorderState {
    reservoir: Vec<u64>,
    seen: u64,
    sum_ns: u128,
    max_ns: u64,
    rng: Rng,
}

/// Thread-safe latency reservoir; `record` is called from every
/// connection's writer thread, `snapshot` from `STATS` handlers.
pub struct LatencyRecorder {
    state: Mutex<RecorderState>,
}

fn lock(state: &Mutex<RecorderState>) -> MutexGuard<'_, RecorderState> {
    state.lock().unwrap_or_else(|e| e.into_inner())
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder {
            state: Mutex::new(RecorderState {
                reservoir: Vec::new(),
                seen: 0,
                sum_ns: 0,
                max_ns: 0,
                rng: Rng::new(0x1A7E1),
            }),
        }
    }

    /// Record one request latency in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        let mut s = lock(&self.state);
        s.seen += 1;
        s.sum_ns += ns as u128;
        s.max_ns = s.max_ns.max(ns);
        if s.reservoir.len() < RESERVOIR_CAP {
            s.reservoir.push(ns);
        } else {
            let seen = s.seen as usize;
            let j = s.rng.gen_range(seen);
            if j < RESERVOIR_CAP {
                s.reservoir[j] = ns;
            }
        }
    }

    /// Record one request latency from a [`Duration`].
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Point-in-time summary of everything recorded so far.
    pub fn snapshot(&self) -> LatencySummary {
        let s = lock(&self.state);
        if s.reservoir.is_empty() {
            return LatencySummary {
                count: 0,
                mean_ns: 0.0,
                p50_ns: 0.0,
                p90_ns: 0.0,
                p99_ns: 0.0,
                max_ns: 0,
                buckets: Vec::new(),
            };
        }
        let xs: Vec<f64> = s.reservoir.iter().map(|&v| v as f64).collect();
        let q = Quantiles::new(&xs);
        let mut counts = [0u64; 64];
        for &v in &s.reservoir {
            counts[v.max(1).ilog2() as usize] += 1;
        }
        let buckets = counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| LatencyBucket {
                lo_ns: 1u64 << i,
                hi_ns: if i + 1 < 64 { (1u64 << (i + 1)) - 1 } else { u64::MAX },
                count: c,
            })
            .collect();
        LatencySummary {
            count: s.seen,
            mean_ns: (s.sum_ns as f64) / (s.seen as f64),
            p50_ns: q.quantile(50.0),
            p90_ns: q.quantile(90.0),
            p99_ns: q.quantile(99.0),
            max_ns: s.max_ns,
            buckets,
        }
    }
}

/// One power-of-two histogram bucket: latencies in `[lo_ns, hi_ns]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyBucket {
    pub lo_ns: u64,
    pub hi_ns: u64,
    pub count: u64,
}

/// Summary statistics over the recorded (or reservoir-sampled) latencies.
#[derive(Debug, Clone)]
pub struct LatencySummary {
    /// Total latencies recorded (not capped by the reservoir).
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub p99_ns: f64,
    pub max_ns: u64,
    pub buckets: Vec<LatencyBucket>,
}

impl LatencySummary {
    /// The JSON shape shared by `STATS` responses and `BENCH_6.json`.
    pub fn to_json(&self) -> Json {
        let hist: Vec<Json> = self
            .buckets
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("lo_ns", Json::Num(b.lo_ns as f64)),
                    ("hi_ns", Json::Num(b.hi_ns as f64)),
                    ("count", Json::Num(b.count as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p90_ns", Json::Num(self.p90_ns)),
            ("p99_ns", Json::Num(self.p99_ns)),
            ("max_ns", Json::Num(self.max_ns as f64)),
            ("histogram", Json::Arr(hist)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_zeroed() {
        let rec = LatencyRecorder::new();
        let s = rec.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.max_ns, 0);
        assert!(s.buckets.is_empty());
    }

    #[test]
    fn exact_percentiles_under_cap() {
        let rec = LatencyRecorder::new();
        for v in 1..=100u64 {
            rec.record_ns(v * 1000);
        }
        let s = rec.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.max_ns, 100_000);
        assert!((s.mean_ns - 50_500.0).abs() < 1e-9);
        assert!((s.p50_ns - 50_500.0).abs() < 1e-9);
        // linear interpolation on ranks: p99 of 1k..=100k lands at 99.01k
        assert!((s.p99_ns - 99_010.0).abs() < 1e-6, "p99 {}", s.p99_ns);
    }

    #[test]
    fn histogram_buckets_partition_the_samples() {
        let rec = LatencyRecorder::new();
        for v in [3u64, 5, 9, 17, 1000, 1001] {
            rec.record_ns(v);
        }
        let s = rec.snapshot();
        let total: u64 = s.buckets.iter().map(|b| b.count).sum();
        assert_eq!(total, 6);
        for b in &s.buckets {
            assert!(b.lo_ns <= b.hi_ns);
            assert!(b.lo_ns.is_power_of_two());
        }
        // 1000 and 1001 share the [512, 1023] bucket
        assert!(s.buckets.iter().any(|b| b.lo_ns == 512 && b.count == 2));
    }

    #[test]
    fn reservoir_stays_bounded_past_the_cap() {
        let rec = LatencyRecorder::new();
        for v in 0..(RESERVOIR_CAP as u64 + 500) {
            rec.record_ns(v + 1);
        }
        let s = rec.snapshot();
        assert_eq!(s.count, RESERVOIR_CAP as u64 + 500);
        assert_eq!(s.max_ns, RESERVOIR_CAP as u64 + 500);
        // sampled percentiles stay in range even after replacement kicks in
        assert!(s.p50_ns >= 1.0 && s.p50_ns <= s.max_ns as f64);
        assert!(s.p99_ns >= s.p50_ns);
    }

    #[test]
    fn summary_json_has_the_bench6_fields() {
        let rec = LatencyRecorder::new();
        rec.record(Duration::from_micros(120));
        let j = rec.snapshot().to_json();
        let text = j.to_string();
        for key in ["p50_ns", "p99_ns", "histogram", "mean_ns"] {
            assert!(text.contains(key), "missing {key} in {text}");
        }
        Json::parse(&text).unwrap();
    }
}
