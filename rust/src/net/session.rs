//! One serving session: the line-delimited JSON protocol over any byte
//! stream.
//!
//! Both front-ends run this exact loop — `gcn-perf serve` in stdin mode
//! passes stdin/stdout, the TCP server passes each accepted socket — so
//! protocol behavior (pipelining, backpressure, `STATS`, error replies)
//! cannot drift between the two. Per session:
//!
//! * a reader loop frames lines ([`FrameReader`]), parses each request
//!   and submits it to the shared [`PredictService`] immediately
//!   (*pipelining*: up to `max_inflight` requests from this peer ride
//!   the service queue at once, so concurrent lines coalesce into fused
//!   batches);
//! * a writer thread drains completions in FIFO order, preserving the
//!   one-response-per-request-line, in-request-order contract;
//! * backpressure composes: the FIFO channel is bounded by
//!   `max_inflight` and `PredictService::submit` blocks at `queue_cap`,
//!   so a flooding peer stalls its own reader (and, over TCP, its own
//!   socket) instead of growing server memory.
//!
//! The `STATS` keyword answers with a point-in-time counter snapshot
//! (service counters, connection counters, latency percentiles) through
//! the same ordered response channel.

use crate::dataset::json::samples_from_json;
use crate::dataset::sample::GraphSample;
use crate::net::framing::{is_timeout, write_frame, FrameError, FrameReader};
use crate::net::latency::LatencyRecorder;
use crate::predictor::{PredictHandle, PredictRequest, PredictService};
use crate::util::json::Json;
use anyhow::Result;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Monotonic front-end counters, shared by every session on one server
/// (or the single stdin session) and reported by `STATS`.
#[derive(Debug, Default)]
pub struct ServerCounters {
    /// Connections ever accepted (0 in stdin mode).
    pub connections_total: AtomicUsize,
    /// Connections currently being served.
    pub connections_active: AtomicUsize,
    /// Connections turned away by admission control.
    pub connections_rejected: AtomicUsize,
    /// Non-empty request lines read (predictions + `STATS`).
    pub request_lines: AtomicUsize,
    /// Response lines written.
    pub responses: AtomicUsize,
    /// Requests answered with an `{"error": ...}` line.
    pub protocol_errors: AtomicUsize,
}

/// Everything a session needs from its server: the service plus the
/// shared observability state. Cheap to clone (all `Arc`s).
#[derive(Clone)]
pub struct ServeShared {
    pub service: Arc<PredictService>,
    pub latency: Arc<LatencyRecorder>,
    pub counters: Arc<ServerCounters>,
}

impl ServeShared {
    /// Wrap a service with fresh counters and latency state.
    pub fn new(service: Arc<PredictService>) -> ServeShared {
        ServeShared {
            service,
            latency: Arc::new(LatencyRecorder::new()),
            counters: Arc::new(ServerCounters::default()),
        }
    }
}

/// Per-session knobs (the server derives them from its config; stdin
/// mode from CLI flags).
#[derive(Debug, Clone)]
pub struct SessionOpts {
    /// Cap on one request line; longer peers get an error and a close.
    pub max_frame_bytes: usize,
    /// Pipelining window: requests from this peer in flight at once.
    pub max_inflight: usize,
}

impl Default for SessionOpts {
    fn default() -> Self {
        SessionOpts {
            max_frame_bytes: crate::net::framing::DEFAULT_MAX_FRAME_BYTES,
            max_inflight: 32,
        }
    }
}

/// Why the session's reader stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloseReason {
    /// Clean end-of-stream (peer finished, or the server drained it).
    Eof,
    /// The peer held the connection open past the read timeout.
    ReadTimeout,
    /// The peer exceeded `max_frame_bytes` on one line.
    Oversized,
    /// The write side failed (peer stopped reading / closed), so there
    /// is nobody left to answer.
    WriterClosed,
}

/// What one session did, for logs and tests.
#[derive(Debug, Clone, Copy)]
pub struct SessionSummary {
    /// Prediction requests accepted by the service.
    pub requests: usize,
    /// Response lines successfully written (predictions, stats, errors).
    pub responses: usize,
    pub reason: CloseReason,
}

/// What the writer emits for one request line: an immediate answer
/// (stats snapshot, parse/submit error) or a pending service completion.
enum Outcome {
    Ready(Json),
    Pending { ids: Vec<(u32, u32)>, handle: PredictHandle, submitted: Instant },
}

/// `(pipeline_id, schedule_id)` pairs — all a prediction report needs
/// from the request, captured before the samples move into the service.
pub fn sample_ids(samples: &[GraphSample]) -> Vec<(u32, u32)> {
    samples.iter().map(|s| (s.pipeline_id, s.schedule_id)).collect()
}

/// Build the `{"model": ..., "predictions": [...]}` response object for
/// a set of served samples (shared by `predict`, stdin serve and TCP).
pub fn prediction_report(model: &str, ids: &[(u32, u32)], preds: &[f64]) -> Json {
    let rows: Vec<Json> = ids
        .iter()
        .zip(preds)
        .map(|(&(pid, sid), &p)| {
            Json::obj(vec![
                ("pipeline_id", Json::Num(pid as f64)),
                ("schedule_id", Json::Num(sid as f64)),
                ("predicted_runtime_s", Json::Num(p)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("model", Json::Str(model.to_string())),
        ("predictions", Json::Arr(rows)),
    ])
}

/// The `{"error": ...}` response line.
pub fn error_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.to_string()))])
}

/// The `STATS` response: one `{"stats": {...}}` object joining service
/// counters, front-end counters and the latency summary. Identical in
/// stdin and TCP mode by construction — both call this.
pub fn stats_json(shared: &ServeShared) -> Json {
    let c = &shared.counters;
    let n = |v: usize| Json::Num(v as f64);
    // The service-counter fields come verbatim from the one canonical
    // snapshot shape (`ServiceStats::to_json`); this function only adds
    // the front-end fields around them.
    let mut obj = match shared.service.stats().to_json() {
        Json::Obj(m) => m,
        _ => unreachable!("ServiceStats::to_json returns an object"),
    };
    let front = [
        ("model", Json::Str(shared.service.model_name())),
        ("queue_cap", n(shared.service.queue_cap())),
        ("connections_total", n(c.connections_total.load(Ordering::Relaxed))),
        ("connections_active", n(c.connections_active.load(Ordering::Relaxed))),
        ("connections_rejected", n(c.connections_rejected.load(Ordering::Relaxed))),
        ("request_lines", n(c.request_lines.load(Ordering::Relaxed))),
        ("responses", n(c.responses.load(Ordering::Relaxed))),
        ("protocol_errors", n(c.protocol_errors.load(Ordering::Relaxed))),
        ("latency", shared.latency.snapshot().to_json()),
    ];
    for (k, v) in front {
        obj.insert(k.to_string(), v);
    }
    Json::obj(vec![("stats", Json::Obj(obj))])
}

/// Run one session to completion: read frames from `reader`, write one
/// response line per request to `writer`, in request order. Returns when
/// the peer is done (EOF), misbehaves (oversize, timeout) or stops
/// reading responses — never because of a bad request, which is answered
/// inline and served past.
pub fn serve_session<R: Read, W: Write + Send>(
    reader: R,
    writer: W,
    shared: &ServeShared,
    opts: &SessionOpts,
) -> Result<SessionSummary> {
    let mut frames = FrameReader::new(reader, opts.max_frame_bytes);
    let (tx, rx) = mpsc::sync_channel::<Outcome>(opts.max_inflight.max(1));

    std::thread::scope(|scope| {
        let writer_handle = scope.spawn(move || -> usize {
            let mut w = writer;
            let mut written = 0usize;
            for item in rx {
                let json = match item {
                    Outcome::Ready(j) => j,
                    Outcome::Pending { ids, handle, submitted } => match handle.wait() {
                        Ok(resp) => {
                            shared.latency.record(submitted.elapsed());
                            prediction_report(&resp.model, &ids, &resp.predictions)
                        }
                        Err(e) => {
                            shared.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                            error_json(&format!("{e:#}"))
                        }
                    },
                };
                if write_frame(&mut w, &json.to_string()).is_err() {
                    // peer stopped reading; drop the rest (their handles
                    // still resolve inside the service, keeping counters
                    // and the memo cache consistent)
                    break;
                }
                written += 1;
                shared.counters.responses.fetch_add(1, Ordering::Relaxed);
            }
            written
        });

        let mut requests = 0usize;
        let reason = loop {
            match frames.next_frame() {
                Ok(None) => break CloseReason::Eof,
                Ok(Some(line)) => {
                    let line = line.trim();
                    if line.is_empty() {
                        continue;
                    }
                    shared.counters.request_lines.fetch_add(1, Ordering::Relaxed);
                    let outcome = if line == "STATS" {
                        Outcome::Ready(stats_json(shared))
                    } else {
                        match samples_from_json(line) {
                            Ok(samples) => {
                                let ids = sample_ids(&samples);
                                // blocks at queue_cap: stdin stops being
                                // read / the socket stops being drained,
                                // which is the backpressure
                                match shared.service.submit(PredictRequest::new(samples)) {
                                    Ok(handle) => {
                                        requests += 1;
                                        Outcome::Pending { ids, handle, submitted: Instant::now() }
                                    }
                                    Err(e) => {
                                        shared
                                            .counters
                                            .protocol_errors
                                            .fetch_add(1, Ordering::Relaxed);
                                        Outcome::Ready(error_json(&format!("{e:#}")))
                                    }
                                }
                            }
                            Err(e) => {
                                shared.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                                Outcome::Ready(error_json(&format!("{e:#}")))
                            }
                        }
                    };
                    if tx.send(outcome).is_err() {
                        break CloseReason::WriterClosed;
                    }
                }
                Err(FrameError::Oversized { limit, .. }) => {
                    shared.counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Outcome::Ready(error_json(&format!(
                        "request line exceeds {limit} bytes"
                    ))));
                    break CloseReason::Oversized;
                }
                Err(FrameError::Io(e)) if is_timeout(&e) => break CloseReason::ReadTimeout,
                // connection reset etc. — the peer is gone; treat as EOF
                Err(FrameError::Io(_)) => break CloseReason::Eof,
            }
        };
        drop(tx); // writer drains everything in flight, then exits
        let responses = writer_handle.join().unwrap_or(0);
        Ok(SessionSummary { requests, responses, reason })
    })
}
