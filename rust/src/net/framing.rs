//! Wire framing for the serving protocol: newline-delimited frames.
//!
//! One frame is one line — a request is a JSON sample array, a response
//! is a JSON object, and `STATS` is a bare keyword. `Json::to_string`
//! never emits a raw newline (control characters are escaped), so any
//! payload the server produces is a valid single frame by construction;
//! the property tests in this module pin that invariant.
//!
//! [`FrameReader`] does its own buffering on top of any [`Read`] (a
//! `TcpStream`, stdin, an in-memory slice), so frames split across
//! arbitrary read boundaries reassemble correctly, and a byte cap turns
//! unbounded lines — a hostile client streaming garbage without ever
//! sending `\n` — into a clean [`FrameError::Oversized`] instead of
//! unbounded memory growth.

use std::io::Read;

/// Default cap on a single frame (8 MiB — a ~2000-stage sample array is
/// well under 1 MiB).
pub const DEFAULT_MAX_FRAME_BYTES: usize = 8 << 20;

/// Why a frame could not be produced.
#[derive(Debug)]
pub enum FrameError {
    /// The peer buffered more than `limit` bytes without a newline.
    Oversized { limit: usize, have: usize },
    /// The underlying reader failed (includes read timeouts; see
    /// [`is_timeout`]).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { limit, have } => {
                write!(f, "frame exceeds {limit} bytes ({have} buffered without a newline)")
            }
            FrameError::Io(e) => write!(f, "read frame: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// True when an I/O error is a socket read timeout (`SO_RCVTIMEO`
/// surfaces as `WouldBlock` on unix, `TimedOut` on windows).
pub fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Incremental line framer over any byte stream.
pub struct FrameReader<R> {
    inner: R,
    buf: Vec<u8>,
    /// Bytes before this offset are known newline-free (scan resume point).
    scan_from: usize,
    max_frame: usize,
    eof: bool,
}

impl<R: Read> FrameReader<R> {
    pub fn new(inner: R, max_frame: usize) -> FrameReader<R> {
        FrameReader { inner, buf: Vec::new(), scan_from: 0, max_frame: max_frame.max(1), eof: false }
    }

    /// Next complete frame, without its line terminator (`\r\n` and `\n`
    /// both accepted). `Ok(None)` is clean end-of-stream. A final
    /// unterminated line is yielded as a frame — a client that dies after
    /// half a request still gets that half parsed (and answered with a
    /// parse error) rather than silently dropped.
    pub fn next_frame(&mut self) -> Result<Option<String>, FrameError> {
        loop {
            if let Some(rel) = self.buf[self.scan_from..].iter().position(|&b| b == b'\n') {
                let pos = self.scan_from + rel;
                let rest = self.buf.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.buf, rest);
                line.pop();
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                self.scan_from = 0;
                return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
            }
            self.scan_from = self.buf.len();
            if self.buf.len() > self.max_frame {
                return Err(FrameError::Oversized { limit: self.max_frame, have: self.buf.len() });
            }
            if self.eof {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                let mut line = std::mem::take(&mut self.buf);
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                self.scan_from = 0;
                return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
            }
            let mut chunk = [0u8; 8192];
            match self.inner.read(&mut chunk) {
                Ok(0) => self.eof = true,
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
    }
}

/// Write one frame: the line plus `\n`, flushed so a pipelining peer sees
/// it immediately.
pub fn write_frame<W: std::io::Write>(w: &mut W, line: &str) -> std::io::Result<()> {
    debug_assert!(!line.contains('\n'), "frames are newline-delimited");
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    /// A reader that hands out its bytes in caller-chosen chunk sizes, to
    /// exercise frames split across arbitrary read boundaries.
    struct ChunkedReader {
        data: Vec<u8>,
        cuts: Vec<usize>,
        pos: usize,
        cut_idx: usize,
    }

    impl ChunkedReader {
        fn new(data: Vec<u8>, cuts: Vec<usize>) -> ChunkedReader {
            ChunkedReader { data, cuts, pos: 0, cut_idx: 0 }
        }
    }

    impl Read for ChunkedReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            let want = if self.cut_idx < self.cuts.len() {
                let w = self.cuts[self.cut_idx].max(1);
                self.cut_idx += 1;
                w
            } else {
                self.data.len() - self.pos
            };
            let n = want.min(out.len()).min(self.data.len() - self.pos);
            out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn read_all(reader: ChunkedReader, max: usize) -> Result<Vec<String>, FrameError> {
        let mut fr = FrameReader::new(reader, max);
        let mut out = Vec::new();
        while let Some(frame) = fr.next_frame()? {
            out.push(frame);
        }
        Ok(out)
    }

    #[test]
    fn basic_lines_and_crlf() {
        let data = b"abc\ndef\r\n\nxyz".to_vec();
        let frames = read_all(ChunkedReader::new(data, vec![]), 1024).unwrap();
        assert_eq!(frames, vec!["abc", "def", "", "xyz"]);
    }

    #[test]
    fn oversized_line_is_detected_before_newline() {
        // 100 bytes buffered, cap 64, no newline anywhere: the reader must
        // fail while buffering, not wait forever for a terminator.
        let data = vec![b'x'; 100];
        match read_all(ChunkedReader::new(data, vec![7, 9, 3]), 64) {
            Err(FrameError::Oversized { limit: 64, .. }) => {}
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn empty_stream_is_clean_eof() {
        let frames = read_all(ChunkedReader::new(Vec::new(), vec![]), 64).unwrap();
        assert!(frames.is_empty());
    }

    fn random_frame(r: &mut Rng) -> String {
        let len = r.gen_range(80);
        (0..len)
            .map(|_| {
                // printable ASCII plus some multi-byte UTF-8, never '\n'
                match r.gen_range(10) {
                    0 => 'λ',
                    1 => '→',
                    2 => '\t',
                    _ => (b' ' + r.gen_range(95) as u8) as char,
                }
            })
            .collect()
    }

    #[test]
    fn prop_frames_roundtrip_across_arbitrary_read_boundaries() {
        propcheck::check_rng(
            "framing-roundtrip",
            0xF8A31,
            propcheck::default_cases(),
            |r| {
                let frames: Vec<String> = (0..r.gen_range_incl(1, 12))
                    .map(|_| random_frame(r))
                    .collect();
                let mut wire = Vec::new();
                for f in &frames {
                    write_frame(&mut wire, f).map_err(|e| e.to_string())?;
                }
                let cuts: Vec<usize> =
                    (0..r.gen_range(20)).map(|_| r.gen_range_incl(1, 9)).collect();
                let got = read_all(ChunkedReader::new(wire, cuts), 1 << 16)
                    .map_err(|e| e.to_string())?;
                if got == frames {
                    Ok(())
                } else {
                    Err(format!("mismatch: sent {frames:?}, got {got:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_json_payloads_never_contain_raw_newlines() {
        // The protocol is sound only because every JSON payload the server
        // or client emits is newline-free; Json escapes control characters,
        // and this pins it for strings embedding '\n', '\r' and friends.
        use crate::util::json::Json;
        propcheck::check(
            "json-newline-free",
            0x11E,
            propcheck::default_cases(),
            |r| {
                let noisy: String = (0..r.gen_range(40))
                    .map(|_| match r.gen_range(6) {
                        0 => '\n',
                        1 => '\r',
                        2 => '"',
                        3 => '\\',
                        _ => (b'a' + r.gen_range(26) as u8) as char,
                    })
                    .collect();
                Json::obj(vec![
                    ("error", Json::Str(noisy)),
                    ("value", Json::Num(r.f64() * 1e-3)),
                ])
            },
            |j| {
                let text = j.to_string();
                if text.contains('\n') || text.contains('\r') {
                    return Err(format!("raw newline in serialized JSON: {text:?}"));
                }
                // and the escaped form still round-trips
                Json::parse(&text).map(|_| ()).map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn trailing_unterminated_line_is_yielded() {
        let data = b"complete\nhalf-writ".to_vec();
        let frames = read_all(ChunkedReader::new(data, vec![4, 4, 4]), 1024).unwrap();
        assert_eq!(frames, vec!["complete", "half-writ"]);
    }
}
