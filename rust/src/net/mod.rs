//! Network serving: the multi-client TCP front-end for the
//! [`crate::predictor::PredictService`], plus the load-test harness that
//! proves it out.
//!
//! The wire protocol is the same line-delimited JSON `gcn-perf serve`
//! has always spoken on stdin — one request (a JSON sample array, or
//! the `STATS` keyword) per line, one JSON response per line, in
//! request order — now shared verbatim between both front-ends through
//! [`session::serve_session`]. Layers:
//!
//! * [`framing`] — newline-delimited frames over any byte stream, with
//!   a byte cap and split-read reassembly;
//! * [`session`] — one client's protocol loop: pipelined submission
//!   into the service, FIFO response writer, `STATS`;
//! * [`server`] — thread-per-connection TCP listener with admission
//!   control, per-connection fairness windows and graceful drain;
//! * [`signal`] — SIGTERM/SIGINT → shutdown-flag bridge for the daemon;
//! * [`latency`] — reservoir latency recorder behind `STATS` p50/p99
//!   and the `BENCH_6.json` histogram;
//! * [`loadgen`] — the concurrent client fleet (`gcn-perf loadgen`)
//!   with bitwise verification against direct predictions.
//!
//! See DESIGN.md §"Network serving" for the protocol grammar,
//! connection lifecycle and drain semantics.

pub mod framing;
pub mod latency;
pub mod loadgen;
pub mod server;
pub mod session;
pub mod signal;

pub use framing::{is_timeout, write_frame, FrameError, FrameReader, DEFAULT_MAX_FRAME_BYTES};
pub use latency::{LatencyRecorder, LatencySummary};
pub use loadgen::{fetch_stats, run_loadgen, LoadgenConfig, LoadgenReport};
pub use server::{ServerReport, TcpServer, TcpServerConfig};
pub use session::{
    error_json, prediction_report, sample_ids, serve_session, stats_json, CloseReason,
    ServeShared, ServerCounters, SessionOpts, SessionSummary,
};
