//! Concurrent load generator for the TCP serving front-end — the
//! `gcn-perf loadgen` subcommand and the workhorse of
//! [`crate::eval::net_bench`].
//!
//! Simulates many concurrent clients, each pipelining requests over one
//! connection under a sliding window (`pipeline_depth` in flight) at an
//! optional per-client arrival rate. Every response is checked
//! structurally, and — when the caller supplies direct
//! `Predictor::predict` outputs for the sample pool — **bitwise**: the
//! serving path (JSON framing included; `Json` float formatting is
//! round-trip exact) must reproduce direct predictions to the last bit,
//! whatever batches the coalescer fused. Request composition is a pure
//! function of `(client, request index, pool)`, so a run is exactly
//! reproducible and the expected values are known up front.
//!
//! Clients tolerate a server that drains mid-load (shutdown tests):
//! send errors and early EOF end the run gracefully with partial
//! counts, and every response that did arrive is still verified.

use crate::dataset::json::samples_to_json;
use crate::dataset::sample::GraphSample;
use crate::net::framing::{write_frame, FrameReader, DEFAULT_MAX_FRAME_BYTES};
use crate::net::latency::{LatencyRecorder, LatencySummary};
use crate::util::json::Json;
use anyhow::{anyhow, ensure, Context, Result};
use std::net::{Shutdown, TcpStream};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Workload shape for one loadgen run.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests each client sends over its one connection.
    pub requests_per_client: usize,
    /// Samples per request line.
    pub samples_per_request: usize,
    /// Per-client arrival rate in requests/s; 0 = send as fast as the
    /// window allows.
    pub rate_per_client: f64,
    /// Sliding window: requests in flight per connection.
    pub pipeline_depth: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            clients: 32,
            requests_per_client: 32,
            samples_per_request: 4,
            rate_per_client: 0.0,
            pipeline_depth: 8,
        }
    }
}

/// Aggregated results of one run.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub clients: usize,
    pub requests_sent: usize,
    pub responses_ok: usize,
    pub responses_err: usize,
    /// Responses checked bitwise against direct predictions (0 when no
    /// expected values were supplied).
    pub bitwise_verified: usize,
    /// Individual sample predictions received.
    pub samples_scored: usize,
    pub wall_ns: f64,
    pub requests_per_s: f64,
    pub samples_per_s: f64,
    pub latency: LatencySummary,
}

impl LoadgenReport {
    /// Error unless aggregate throughput met `min_rps`. Enforced by the
    /// serial CI smoke (`loadgen --fast --min-rps ...`), not by
    /// `cargo test`, so the test suite stays deterministic on noisy
    /// shared runners.
    pub fn require_throughput(&self, min_rps: f64) -> Result<()> {
        ensure!(
            self.requests_per_s >= min_rps,
            "loadgen throughput {:.1} req/s is under the floor of {min_rps:.1} req/s",
            self.requests_per_s
        );
        Ok(())
    }
}

/// The pool indices request `(c, i)` scores: deterministic, striding the
/// pool so every client mixes all graph sizes (tiny generator pipelines
/// and resnet50 alike, when the pool holds both).
pub fn request_indices(c: usize, i: usize, spr: usize, pool_len: usize) -> Vec<usize> {
    (0..spr).map(|j| (c * 131 + i * 17 + j) % pool_len).collect()
}

/// Pull the per-sample predictions out of one response object.
fn parse_predictions(j: &Json) -> Result<Vec<f64>> {
    let rows = j
        .get("predictions")
        .and_then(|p| p.as_arr())
        .context("response lacks a 'predictions' array")?;
    rows.iter()
        .map(|r| {
            r.get("predicted_runtime_s")
                .and_then(|v| v.as_f64())
                .context("prediction row lacks 'predicted_runtime_s'")
        })
        .collect()
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct WinState {
    inflight: usize,
    /// Set by the reader when the connection is done — unblocks a sender
    /// waiting on the window after an early server close.
    dead: bool,
}

struct Window {
    m: Mutex<WinState>,
    cv: Condvar,
}

#[derive(Debug, Default, Clone, Copy)]
struct ClientOut {
    sent: usize,
    ok: usize,
    err: usize,
    verified: usize,
    samples: usize,
}

/// Many concurrent connects can outrun the accept loop's backlog; a
/// short retry keeps client start-up from being a flake source.
fn connect_retry(addr: &str) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for _ in 0..20 {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
    Err(anyhow!("connect {addr}: {}", last.expect("retry loop recorded an error")))
}

fn client_run(
    addr: &str,
    pool: &[GraphSample],
    expected: Option<&[f64]>,
    cfg: &LoadgenConfig,
    c: usize,
    latency: &LatencyRecorder,
) -> Result<ClientOut> {
    let n = cfg.requests_per_client;
    let spr = cfg.samples_per_request;
    let depth = cfg.pipeline_depth.max(1);
    let stream = connect_retry(addr)?;
    let _ = stream.set_nodelay(true);
    let reader = stream.try_clone().context("clone client socket")?;

    // expected predictions per request, resolved through the same index
    // function the sender uses
    let expected_rows: Option<Vec<Vec<f64>>> = expected.map(|ex| {
        (0..n)
            .map(|i| request_indices(c, i, spr, pool.len()).iter().map(|&k| ex[k]).collect())
            .collect()
    });

    let window = Window { m: Mutex::new(WinState { inflight: 0, dead: false }), cv: Condvar::new() };
    let send_ts: Mutex<Vec<Option<Instant>>> = Mutex::new(vec![None; n]);

    std::thread::scope(|scope| {
        let window = &window;
        let send_ts = &send_ts;
        let sender = scope.spawn(move || -> usize {
            let mut w = stream;
            let gap = (cfg.rate_per_client > 0.0)
                .then(|| Duration::from_secs_f64(1.0 / cfg.rate_per_client));
            let t0 = Instant::now();
            let mut sent = 0usize;
            for i in 0..n {
                {
                    let mut st = lock(&window.m);
                    while st.inflight >= depth && !st.dead {
                        st = window.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                    }
                    if st.dead {
                        break;
                    }
                    st.inflight += 1;
                }
                if let Some(g) = gap {
                    // arrival-rate shaping (not synchronization): keep the
                    // i-th send at t0 + i/rate
                    let target = t0 + g.mul_f64(i as f64);
                    let now = Instant::now();
                    if target > now {
                        std::thread::sleep(target - now);
                    }
                }
                let samples: Vec<GraphSample> = request_indices(c, i, spr, pool.len())
                    .iter()
                    .map(|&k| pool[k].clone())
                    .collect();
                let line = samples_to_json(&samples);
                lock(send_ts)[i] = Some(Instant::now());
                if write_frame(&mut w, &line).is_err() {
                    break; // server drained mid-load; reader will see EOF
                }
                sent += 1;
            }
            // half-close: tells the server this client is done, so its
            // session answers what it accepted and closes cleanly
            let _ = w.shutdown(Shutdown::Write);
            sent
        });

        let result = (|| -> Result<ClientOut> {
            let mut frames = FrameReader::new(reader, DEFAULT_MAX_FRAME_BYTES);
            let mut out = ClientOut::default();
            let mut next = 0usize;
            loop {
                match frames.next_frame() {
                    Ok(Some(line)) => {
                        {
                            let mut st = lock(&window.m);
                            st.inflight = st.inflight.saturating_sub(1);
                            window.cv.notify_all();
                        }
                        if let Some(t) = lock(send_ts).get(next).copied().flatten() {
                            latency.record(t.elapsed());
                        }
                        let j = Json::parse(&line)
                            .map_err(|e| anyhow!("client {c}: unparseable response: {e}"))?;
                        if j.get("error").is_some() {
                            out.err += 1;
                        } else {
                            let preds = parse_predictions(&j)
                                .with_context(|| format!("client {c} response {next}"))?;
                            out.samples += preds.len();
                            if let Some(rows) = &expected_rows {
                                let want = &rows[next];
                                ensure!(
                                    preds.len() == want.len(),
                                    "client {c} response {next}: {} predictions, expected {}",
                                    preds.len(),
                                    want.len()
                                );
                                ensure!(
                                    preds.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits()),
                                    "client {c} response {next}: predictions diverge bitwise \
                                     from direct Predictor::predict"
                                );
                                out.verified += 1;
                            }
                            out.ok += 1;
                        }
                        next += 1;
                        if next == n {
                            break;
                        }
                    }
                    Ok(None) => break, // server closed early (drain) — keep partial counts
                    Err(_) => break,   // reset mid-load — ditto
                }
            }
            Ok(out)
        })();

        // always unblock the sender before propagating any reader error,
        // or the scope would deadlock joining it
        {
            let mut st = lock(&window.m);
            st.dead = true;
            window.cv.notify_all();
        }
        let sent = sender.join().unwrap_or(0);
        result.map(|mut out| {
            out.sent = sent;
            out
        })
    })
}

/// Run the full fleet against `addr` and aggregate. `expected[k]` (when
/// given) is `Predictor::predict`'s direct output for `pool[k]`; every
/// response is then verified bitwise and any divergence fails the run.
pub fn run_loadgen(
    addr: &str,
    pool: &[GraphSample],
    expected: Option<&[f64]>,
    cfg: &LoadgenConfig,
) -> Result<LoadgenReport> {
    ensure!(!pool.is_empty(), "loadgen needs a non-empty sample pool");
    ensure!(
        cfg.clients >= 1 && cfg.requests_per_client >= 1 && cfg.samples_per_request >= 1,
        "loadgen config must have at least one client, request and sample"
    );
    if let Some(ex) = expected {
        ensure!(
            ex.len() == pool.len(),
            "expected predictions ({}) must match the pool ({})",
            ex.len(),
            pool.len()
        );
    }
    let latency = LatencyRecorder::new();
    let t0 = Instant::now();
    let outs: Vec<Result<ClientOut>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|c| {
                let latency = &latency;
                scope.spawn(move || client_run(addr, pool, expected, cfg, c, latency))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("loadgen client panicked")).and_then(|r| r))
            .collect()
    });
    let wall_ns = t0.elapsed().as_nanos() as f64;

    let mut report = LoadgenReport {
        clients: cfg.clients,
        requests_sent: 0,
        responses_ok: 0,
        responses_err: 0,
        bitwise_verified: 0,
        samples_scored: 0,
        wall_ns,
        requests_per_s: 0.0,
        samples_per_s: 0.0,
        latency: latency.snapshot(),
    };
    for o in outs {
        let o = o?;
        report.requests_sent += o.sent;
        report.responses_ok += o.ok;
        report.responses_err += o.err;
        report.bitwise_verified += o.verified;
        report.samples_scored += o.samples;
    }
    let wall_s = (wall_ns / 1e9).max(1e-9);
    report.requests_per_s = (report.responses_ok + report.responses_err) as f64 / wall_s;
    report.samples_per_s = report.samples_scored as f64 / wall_s;
    Ok(report)
}

/// One-shot `STATS` query over a fresh connection.
pub fn fetch_stats(addr: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    write_frame(&mut stream, "STATS").context("send STATS")?;
    let reader = stream.try_clone().context("clone stats socket")?;
    let mut frames = FrameReader::new(reader, DEFAULT_MAX_FRAME_BYTES);
    let line = frames
        .next_frame()
        .map_err(|e| anyhow!("read STATS response: {e}"))?
        .context("server closed before answering STATS")?;
    let _ = stream.shutdown(Shutdown::Both);
    Json::parse(&line).map_err(|e| anyhow!("parse STATS response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_indices_are_deterministic_and_in_range() {
        let a = request_indices(3, 7, 4, 36);
        let b = request_indices(3, 7, 4, 36);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.iter().all(|&k| k < 36));
        assert_ne!(a, request_indices(4, 7, 4, 36));
    }

    #[test]
    fn parse_predictions_reads_the_report_shape() {
        let j = Json::parse(
            r#"{"model":"gcn","predictions":[
                {"pipeline_id":0,"schedule_id":1,"predicted_runtime_s":0.125},
                {"pipeline_id":0,"schedule_id":2,"predicted_runtime_s":3.5e-4}]}"#,
        )
        .unwrap();
        let p = parse_predictions(&j).unwrap();
        assert_eq!(p, vec![0.125, 3.5e-4]);
        assert!(parse_predictions(&Json::parse(r#"{"error":"x"}"#).unwrap()).is_err());
    }
}
