//! SIGTERM/SIGINT → shutdown-flag bridge for the serving daemon.
//!
//! The TCP server polls an `Arc<AtomicBool>`; this module flips it from
//! a signal handler so `kill -TERM` (or ctrl-c) triggers the same
//! graceful drain the tests drive by storing the flag directly. The
//! handler body is async-signal-safe: one atomic store through a
//! pre-initialized `OnceLock`, no allocation, no locks. std exposes no
//! signal API and this crate vendors no libc, so the one `signal(2)`
//! entry point is declared here directly; on non-unix targets install
//! is a no-op and the flag is only ever set programmatically.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

static TERM_FLAG: OnceLock<Arc<AtomicBool>> = OnceLock::new();

#[cfg(unix)]
mod ffi {
    use std::os::raw::c_int;

    pub const SIGINT: c_int = 2;
    pub const SIGTERM: c_int = 15;

    extern "C" {
        /// POSIX `signal(2)`. The returned previous handler is unused.
        pub fn signal(signum: c_int, handler: extern "C" fn(c_int)) -> usize;
    }
}

#[cfg(unix)]
extern "C" fn on_term(_sig: std::os::raw::c_int) {
    if let Some(flag) = TERM_FLAG.get() {
        flag.store(true, Ordering::SeqCst);
    }
}

/// Install the SIGTERM/SIGINT handler (idempotently) and return the
/// process-wide shutdown flag it sets. Call once from the daemon's serve
/// path; library users and tests pass their own flag to the server and
/// never touch process signal state.
pub fn install_term_flag() -> Arc<AtomicBool> {
    let flag = Arc::clone(TERM_FLAG.get_or_init(|| Arc::new(AtomicBool::new(false))));
    #[cfg(unix)]
    unsafe {
        ffi::signal(ffi::SIGTERM, on_term);
        ffi::signal(ffi::SIGINT, on_term);
    }
    flag
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    extern "C" {
        fn raise(sig: std::os::raw::c_int) -> std::os::raw::c_int;
    }

    #[test]
    fn sigterm_sets_the_flag() {
        let flag = install_term_flag();
        assert!(Arc::ptr_eq(&flag, &install_term_flag()), "install is idempotent");
        assert!(!flag.load(Ordering::SeqCst));
        // POSIX runs the handler on this thread before raise() returns
        unsafe {
            raise(ffi::SIGTERM);
        }
        assert!(flag.load(Ordering::SeqCst), "handler stored the flag");
        flag.store(false, Ordering::SeqCst); // leave global state clean
    }
}
