//! Multi-client TCP front-end for the [`PredictService`] — the
//! `gcn-perf serve --listen ADDR` daemon.
//!
//! Thread-per-connection over the service's bounded queue: the accept
//! loop hands each socket to a [`serve_session`] running the same
//! line-protocol loop stdin mode uses. Scheduling is fair by
//! construction — every connection gets at most
//! `max_inflight_per_conn` requests into the *shared FIFO* service
//! queue, so one flooding client saturates its own window and then
//! waits behind everyone else's submissions instead of monopolizing the
//! workers. Admission control caps concurrent connections; excess
//! clients get one `{"error": ...}` line and a close.
//!
//! **Graceful drain.** Shutdown is an `Arc<AtomicBool>` (set by
//! SIGTERM/SIGINT via [`crate::net::signal`], by [`TcpServer::shutdown_now`],
//! or directly in tests). The accept loop polls it (the listener is
//! non-blocking), and on shutdown: stop accepting, half-close every
//! live connection's *read* side — each session sees EOF, answers
//! everything already submitted, and exits — then join the connection
//! threads. Every accepted request still gets exactly one response;
//! only unread bytes are dropped.

use crate::net::framing::write_frame;
use crate::net::session::{error_json, serve_session, ServeShared, SessionOpts};
use crate::predictor::PredictService;
use crate::util::threadpool::spawn_named;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

/// Knobs for the TCP front-end.
#[derive(Debug, Clone)]
pub struct TcpServerConfig {
    /// Concurrent-connection cap (admission control).
    pub max_conns: usize,
    /// Per-line byte cap, enforced by the framer.
    pub max_frame_bytes: usize,
    /// Pipelining window per connection (fairness bound).
    pub max_inflight_per_conn: usize,
    /// Read timeout per connection; `None` waits forever. Production
    /// daemons set this to evict slow-loris peers that hold sockets
    /// open without completing a line.
    pub read_timeout: Option<Duration>,
}

impl Default for TcpServerConfig {
    fn default() -> Self {
        TcpServerConfig {
            max_conns: 256,
            max_frame_bytes: crate::net::framing::DEFAULT_MAX_FRAME_BYTES,
            max_inflight_per_conn: 32,
            read_timeout: None,
        }
    }
}

/// Lifetime totals, reported by [`TcpServer::join`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerReport {
    pub connections: usize,
    pub rejected: usize,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A running TCP front-end. Bind with [`TcpServer::bind`], stop by
/// setting the shutdown flag (or [`TcpServer::shutdown_now`]), then
/// [`TcpServer::join`] for the drained report.
pub struct TcpServer {
    addr: SocketAddr,
    shared: ServeShared,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting. `shutdown` is the caller's drain trigger.
    pub fn bind(
        addr: &str,
        shared: ServeShared,
        cfg: TcpServerConfig,
        shutdown: Arc<AtomicBool>,
    ) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        let accept_thread = {
            let shared = shared.clone();
            let shutdown = Arc::clone(&shutdown);
            spawn_named("net-accept".to_string(), move || {
                accept_loop(&listener, &shared, &cfg, &shutdown);
            })
        };
        Ok(TcpServer { addr: local, shared, shutdown, accept_thread: Some(accept_thread) })
    }

    /// The bound address — the real port when bound to `:0`.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The underlying service (for direct submissions in tests).
    pub fn service(&self) -> &Arc<PredictService> {
        &self.shared.service
    }

    /// Trigger the graceful drain without waiting for it.
    pub fn shutdown_now(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Wait until the shutdown flag stops the accept loop and every
    /// connection has drained; returns the lifetime totals.
    pub fn join(mut self) -> Result<ServerReport> {
        if let Some(h) = self.accept_thread.take() {
            h.join().map_err(|_| anyhow::anyhow!("accept loop panicked"))?;
        }
        let c = &self.shared.counters;
        Ok(ServerReport {
            connections: c.connections_total.load(Ordering::Relaxed),
            rejected: c.connections_rejected.load(Ordering::Relaxed),
        })
    }
}

impl Drop for TcpServer {
    /// A dropped (un-`join`ed) server still drains instead of leaking
    /// its accept loop.
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: &ServeShared,
    cfg: &TcpServerConfig,
    shutdown: &Arc<AtomicBool>,
) {
    // Non-blocking so the loop can poll the shutdown flag; accepted
    // sockets are switched back to blocking below (accept(2) does not
    // propagate O_NONBLOCK to them on Linux, but that is not portable).
    let _ = listener.set_nonblocking(true);
    let registry: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
    let mut conn_threads: Vec<JoinHandle<()>> = Vec::new();
    let mut next_id = 0u64;

    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                if shared.counters.connections_active.load(Ordering::Relaxed) >= cfg.max_conns {
                    shared.counters.connections_rejected.fetch_add(1, Ordering::Relaxed);
                    let mut s = stream;
                    let msg = format!("server at capacity ({} connections)", cfg.max_conns);
                    let _ = write_frame(&mut s, &error_json(&msg).to_string());
                    continue; // dropping `s` closes it
                }
                shared.counters.connections_total.fetch_add(1, Ordering::Relaxed);
                shared.counters.connections_active.fetch_add(1, Ordering::Relaxed);
                let id = next_id;
                next_id += 1;
                // a second handle to the socket, so the drain below can
                // half-close connections the session thread owns
                if let Ok(clone) = stream.try_clone() {
                    lock(&registry).insert(id, clone);
                }
                let shared = shared.clone();
                let cfg = cfg.clone();
                let registry = Arc::clone(&registry);
                conn_threads.push(spawn_named(format!("net-conn-{id}"), move || {
                    handle_conn(stream, &shared, &cfg);
                    lock(&registry).remove(&id);
                    shared.counters.connections_active.fetch_sub(1, Ordering::Relaxed);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // poll tick: reap finished sessions, then wait a beat
                conn_threads.retain(|h| !h.is_finished());
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // transient accept failure (ECONNABORTED, fd pressure):
            // back off instead of spinning or dying
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }

    // Graceful drain: half-close every live connection's read side so
    // its session sees EOF and finishes what was already submitted. A
    // bounded write timeout keeps a peer that stopped *reading* from
    // stalling the drain forever.
    for stream in lock(&registry).values() {
        let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
        let _ = stream.shutdown(Shutdown::Read);
    }
    for h in conn_threads {
        let _ = h.join();
    }
}

fn handle_conn(stream: TcpStream, shared: &ServeShared, cfg: &TcpServerConfig) {
    let _ = stream.set_nodelay(true);
    if let Some(t) = cfg.read_timeout {
        let _ = stream.set_read_timeout(Some(t));
    }
    let Ok(reader) = stream.try_clone() else { return };
    let opts = SessionOpts {
        max_frame_bytes: cfg.max_frame_bytes,
        max_inflight: cfg.max_inflight_per_conn,
    };
    // session outcomes (EOF, timeout, oversize) are per-connection by
    // design — nothing here can poison the shared service
    let _ = serve_session(reader, stream, shared, &opts);
}
