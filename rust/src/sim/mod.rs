//! Simulated benchmarking machine.
//!
//! The paper benchmarks every schedule on 18-core Intel Xeon D-2191 CPUs;
//! this module is the stand-in (DESIGN.md §Substitutions): an analytical
//! machine model that "executes" a scheduled pipeline and returns a run time
//! with realistic measurement noise. The model captures the effects the
//! paper's feature set is built around — cache-fit vs tiling, SIMD
//! vectorization, multicore scaling with bandwidth saturation, inlining
//! recompute, compute_at producer/consumer locality, allocation and
//! page-fault overheads — so the learning problem has the same structure as
//! the paper's, including the *inter-stage* interactions the GCN is designed
//! to exploit.

pub mod analysis;
pub mod cost;

pub use analysis::{analyze_pipeline, Level, StageAnalysis};
pub use cost::{cost_pipeline, cost_stage};

use crate::constants::BENCH_RUNS;
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::PipelineSchedule;
use crate::util::rng::Rng;

/// Machine configuration (defaults: Xeon D-2191-like).
#[derive(Debug, Clone)]
pub struct Machine {
    pub cores: usize,
    pub freq_hz: f64,
    /// f32 SIMD lanes (AVX2-class).
    pub simd_lanes: usize,
    /// Peak vector flops/cycle/core (lanes × 2 FMA ports × 2 flops).
    pub vec_flops_per_cycle: f64,
    /// Peak scalar flops/cycle/core.
    pub scalar_flops_per_cycle: f64,
    pub l1_bytes: f64,
    pub l2_bytes: f64,
    /// Shared last-level cache.
    pub llc_bytes: f64,
    /// Shared DRAM bandwidth, bytes/s.
    pub dram_bw: f64,
    /// Shared LLC bandwidth, bytes/s.
    pub llc_bw: f64,
    /// Per-core L2 bandwidth, bytes/s.
    pub l2_bw: f64,
    /// Per-core L1 bandwidth, bytes/s.
    pub l1_bw: f64,
    /// Thread-pool task dispatch overhead, seconds/task.
    pub task_overhead_s: f64,
    /// Per-stage fixed overhead (function call, bounds queries), seconds.
    pub stage_overhead_s: f64,
    /// Cost of first-touching one 4 KiB page (page fault + zeroing), seconds.
    pub page_fault_s: f64,
    /// Heap allocation overhead, seconds per allocation.
    pub malloc_s: f64,
    /// Log-space σ of per-run measurement noise.
    pub noise_sigma: f64,
}

impl Machine {
    /// The paper's testbed: Xeon D-2191, 18 cores @ 1.6 GHz (= default).
    pub fn xeon_d2191() -> Machine {
        Machine::default()
    }

    /// A 4-core desktop part (higher clock, smaller core count, larger
    /// per-core caches) — used by the §VI-A cross-machine transfer study.
    pub fn desktop_4core() -> Machine {
        Machine {
            cores: 4,
            freq_hz: 3.6e9,
            l2_bytes: 2048.0 * 1024.0,
            llc_bytes: 12.0 * 1024.0 * 1024.0,
            dram_bw: 40e9,
            llc_bw: 120e9,
            ..Machine::default()
        }
    }

    /// A many-core server (lower clock, big LLC, more bandwidth).
    pub fn server_64core() -> Machine {
        Machine {
            cores: 64,
            freq_hz: 1.2e9,
            llc_bytes: 96.0 * 1024.0 * 1024.0,
            dram_bw: 180e9,
            llc_bw: 500e9,
            ..Machine::default()
        }
    }

    /// Preset by name (CLI).
    pub fn by_name(name: &str) -> Option<Machine> {
        match name {
            "xeon" | "xeon_d2191" | "default" => Some(Machine::xeon_d2191()),
            "desktop" | "desktop_4core" => Some(Machine::desktop_4core()),
            "server" | "server_64core" => Some(Machine::server_64core()),
            _ => None,
        }
    }
}

impl Default for Machine {
    fn default() -> Self {
        Machine {
            cores: 18,
            freq_hz: 1.6e9,
            simd_lanes: 8,
            vec_flops_per_cycle: 32.0,
            scalar_flops_per_cycle: 4.0,
            l1_bytes: 32.0 * 1024.0,
            l2_bytes: 1024.0 * 1024.0,
            llc_bytes: 24.0 * 1024.0 * 1024.0,
            dram_bw: 60e9,
            llc_bw: 200e9,
            l2_bw: 80e9,
            l1_bw: 150e9,
            task_overhead_s: 0.5e-6,
            stage_overhead_s: 2.0e-6,
            page_fault_s: 0.25e-6,
            malloc_s: 0.1e-6,
            noise_sigma: 0.03,
        }
    }
}

/// Noise-free run time (seconds) of a scheduled pipeline.
pub fn simulate(
    p: &Pipeline,
    nests: &[LoopNest],
    sched: &PipelineSchedule,
    machine: &Machine,
) -> f64 {
    let analyses = analyze_pipeline(p, nests, sched, machine);
    cost_pipeline(&analyses, machine)
}

/// "Benchmark" a schedule: `BENCH_RUNS` noisy measurements, as the paper
/// does (each schedule run 10×; the loss uses mean and std of the runs).
pub fn bench_schedule(
    p: &Pipeline,
    nests: &[LoopNest],
    sched: &PipelineSchedule,
    machine: &Machine,
    rng: &mut Rng,
) -> Vec<f64> {
    let t = simulate(p, nests, sched, machine);
    (0..BENCH_RUNS)
        .map(|_| {
            let mut noise = rng.lognormal(machine.noise_sigma);
            // occasional scheduling-jitter outlier (never faster than clean)
            if rng.chance(0.02) {
                noise *= rng.uniform(1.1, 1.4);
            }
            t * noise
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;
    use crate::schedule::random::random_pipeline_schedule;
    use crate::util::propcheck;
    use crate::util::stats;

    fn conv_relu(hw: usize, cout: usize) -> (Pipeline, Vec<LoopNest>) {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, hw, hw]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = cout;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let nests = lower_pipeline(&p);
        (p, nests)
    }

    fn default_sched(p: &Pipeline) -> PipelineSchedule {
        PipelineSchedule::default_for(&p.stages.iter().map(|s| s.shape.len()).collect::<Vec<_>>())
    }

    #[test]
    fn runtime_positive_and_finite() {
        let (p, nests) = conv_relu(32, 32);
        let t = simulate(&p, &nests, &default_sched(&p), &Machine::default());
        assert!(t.is_finite() && t > 0.0, "t = {t}");
    }

    #[test]
    fn bigger_workload_takes_longer() {
        let m = Machine::default();
        let (p1, n1) = conv_relu(16, 16);
        let (p2, n2) = conv_relu(64, 64);
        let t1 = simulate(&p1, &n1, &default_sched(&p1), &m);
        let t2 = simulate(&p2, &n2, &default_sched(&p2), &m);
        assert!(t2 > 4.0 * t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn vectorization_helps_compute_bound_stage() {
        let m = Machine::default();
        let (p, nests) = conv_relu(64, 64);
        let mut s = default_sched(&p);
        let base = simulate(&p, &nests, &s, &m);
        s.stages[0].vector_width = 8;
        let vec = simulate(&p, &nests, &s, &m);
        assert!(vec < base * 0.6, "base={base} vec={vec}");
    }

    #[test]
    fn parallelism_helps_large_stage() {
        let m = Machine::default();
        let (p, nests) = conv_relu(64, 64);
        let mut s = default_sched(&p);
        let base = simulate(&p, &nests, &s, &m);
        s.stages[0].order = vec![1, 2, 3, 0];
        s.stages[0].parallel_depth = 2; // parallelize cout×h
        let par = simulate(&p, &nests, &s, &m);
        assert!(par < base * 0.4, "base={base} par={par}");
    }

    #[test]
    fn inlining_pointwise_helps() {
        // relu materialized vs inlined... relu is output here, so build a
        // 3-stage chain where the middle relu can inline.
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 64, 64]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 32;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        let r = p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        p.add_stage("abs", Op::new(OpKind::Abs), vec![r]).unwrap();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let mut s = default_sched(&p);
        let base = simulate(&p, &nests, &s, &m);
        s.stages[1].compute = crate::schedule::primitives::ComputeLoc::Inline;
        let inl = simulate(&p, &nests, &s, &m);
        assert!(inl < base, "base={base} inlined={inl}");
    }

    #[test]
    fn noise_has_expected_spread() {
        let (p, nests) = conv_relu(32, 16);
        let m = Machine::default();
        let mut rng = Rng::new(5);
        let runs = bench_schedule(&p, &nests, &default_sched(&p), &m, &mut rng);
        assert_eq!(runs.len(), BENCH_RUNS);
        let mean = stats::mean(&runs);
        let cv = stats::std_dev(&runs) / mean;
        assert!(cv < 0.25, "cv={cv}");
        assert!(runs.iter().all(|&r| r > 0.0));
    }

    #[test]
    fn prop_random_schedules_cost_finite_and_ordered_vs_zero() {
        propcheck::check_rng("sim finite", 0xC0FFEE, 32, |rng| {
            let hw = 8 << rng.gen_range(3);
            let (p, nests) = conv_relu(hw, 8 << rng.gen_range(3));
            let m = Machine::default();
            for _ in 0..4 {
                let s = random_pipeline_schedule(&p, &nests, rng);
                let t = simulate(&p, &nests, &s, &m);
                if !(t.is_finite() && t > 0.0) {
                    return Err(format!("bad time {t} for {s:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn machine_presets_differ_meaningfully() {
        let (p, nests) = conv_relu(64, 64);
        let sched = default_sched(&p);
        let xeon = simulate(&p, &nests, &sched, &Machine::xeon_d2191());
        let desktop = simulate(&p, &nests, &sched, &Machine::desktop_4core());
        // scalar single-thread schedule: desktop's higher clock wins
        assert!(desktop < xeon, "desktop {desktop} !< xeon {xeon}");
        // parallel schedule: the 18-core xeon catches up or wins
        let mut par = default_sched(&p);
        par.stages[0].order = vec![1, 2, 3, 0];
        par.stages[0].parallel_depth = 2;
        par.stages[0].vector_width = 8;
        let xeon_p = simulate(&p, &nests, &par, &Machine::xeon_d2191());
        let desk_p = simulate(&p, &nests, &par, &Machine::desktop_4core());
        let xeon_speedup = xeon / xeon_p;
        let desk_speedup = desktop / desk_p;
        assert!(
            xeon_speedup > desk_speedup,
            "xeon parallel speedup {xeon_speedup} !> desktop {desk_speedup}"
        );
        assert!(Machine::by_name("server").is_some());
        assert!(Machine::by_name("nope").is_none());
    }

    #[test]
    fn schedules_materially_change_runtime() {
        let (p, nests) = conv_relu(64, 32);
        let m = Machine::default();
        let mut rng = Rng::new(42);
        let times: Vec<f64> = (0..64)
            .map(|_| {
                let s = random_pipeline_schedule(&p, &nests, &mut rng);
                simulate(&p, &nests, &s, &m)
            })
            .collect();
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 3.0, "schedule space too flat: {min}..{max}");
    }
}
