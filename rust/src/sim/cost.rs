//! Cost model: [`StageAnalysis`] → seconds on a [`Machine`].
//!
//! Per stage we compute a compute-side time and a memory-side time and take
//! the roofline max, then add parallelization, allocation and page-fault
//! overheads. Pipeline time is the sum over materialized stages (Halide
//! executes the DAG stage by stage under compute_root granularity).

use crate::sim::analysis::{Level, StageAnalysis};
use crate::sim::Machine;

/// Cycles per element for long-latency ops, scalar vs vectorized.
const DIV_CYCLES_SCALAR: f64 = 8.0;
const DIV_CYCLES_VEC: f64 = 2.0;
const TRANS_CYCLES_SCALAR: f64 = 16.0;
const TRANS_CYCLES_VEC: f64 = 4.0;
/// Loop-control overhead per (post-unroll, post-vectorize) inner iteration.
const LOOP_CYCLES: f64 = 2.0;

/// Compute-side seconds for one stage on ONE core.
fn compute_seconds(a: &StageAnalysis, m: &Machine) -> f64 {
    let w = &a.work;
    let vec = a.vector_width > 1;
    let lanes = a.vector_width as f64;

    // FMA-pairable flops
    let flops = (w.fmul + w.fadd) * a.points;
    let flop_cycles = if vec {
        flops / m.vec_flops_per_cycle
    } else {
        flops / m.scalar_flops_per_cycle
    };
    // divides and transcendentals
    let div_cycles = w.fdiv * a.points
        * (if vec { DIV_CYCLES_VEC } else { DIV_CYCLES_SCALAR });
    let trans_cycles = w.transcendental * a.points
        * (if vec { TRANS_CYCLES_VEC } else { TRANS_CYCLES_SCALAR });
    // integer / bool / compare issue on the scalar ports; vectorization
    // amortizes indexing across lanes
    let misc = (w.int_ops + w.bool_ops + w.cmp_ops) * a.points
        / (2.0 * if vec { lanes } else { 1.0 });
    // loop control
    let loop_cycles = a.inner_iters * LOOP_CYCLES;

    (flop_cycles + div_cycles + trans_cycles + misc + loop_cycles) / m.freq_hz
}

fn level_bw(level: Level, m: &Machine, cores_used: f64) -> f64 {
    match level {
        // per-core bandwidths scale with cores; shared ones don't
        Level::L1 => m.l1_bw * cores_used,
        Level::L2 => m.l2_bw * cores_used,
        Level::Llc => m.llc_bw,
        Level::Dram => m.dram_bw,
    }
}

/// Memory-side seconds for one stage, given `cores_used` active cores.
fn memory_seconds(a: &StageAnalysis, m: &Machine, cores_used: f64) -> f64 {
    let mut t = 0.0;
    for tr in &a.traffic {
        t += tr.cold_bytes / level_bw(tr.cold_level, m, cores_used);
        t += tr.reuse_bytes / level_bw(tr.reuse_level, m, cores_used);
    }
    t + a.out_bytes / level_bw(a.out_level, m, cores_used)
}

/// Seconds for one stage under its schedule.
pub fn cost_stage(a: &StageAnalysis, m: &Machine) -> f64 {
    if a.inlined {
        return 0.0; // carried by consumers
    }
    let tasks = a.parallel_tasks.max(1);
    let cores_used = (tasks.min(m.cores)) as f64;
    // load imbalance: last wave of tasks may underfill the cores
    let waves = (tasks as f64 / cores_used).ceil();
    let efficiency = tasks as f64 / (waves * cores_used);

    let comp = compute_seconds(a, m) / (cores_used * efficiency);
    let mem = memory_seconds(a, m, cores_used);
    let roofline = comp.max(mem);

    let task_overhead = if tasks > 1 { tasks as f64 * m.task_overhead_s } else { 0.0 };
    let alloc_overhead = if a.alloc_bytes > 0.0 { m.malloc_s } else { 0.0 };
    let fault_overhead = a.page_faults * m.page_fault_s;

    roofline + task_overhead + alloc_overhead + fault_overhead + m.stage_overhead_s
}

/// Total pipeline seconds.
pub fn cost_pipeline(analyses: &[StageAnalysis], m: &Machine) -> f64 {
    analyses.iter().map(|a| cost_stage(a, m)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::WorkProfile;

    fn dummy_analysis(points: f64) -> StageAnalysis {
        StageAnalysis {
            stage_id: 0,
            inlined: false,
            points,
            recompute: 1.0,
            work: WorkProfile { fmul: 1.0, fadd: 1.0, ..Default::default() },
            vector_width: 1,
            parallel_tasks: 1,
            inner_iters: points,
            unroll: 1,
            traffic: vec![],
            out_bytes: points * 4.0,
            out_level: Level::Dram,
            alloc_bytes: points * 4.0,
            page_faults: points * 4.0 / 4096.0,
            footprint: points * 4.0,
            tile_ws: points * 4.0,
        }
    }

    #[test]
    fn inlined_stage_costs_nothing() {
        let mut a = dummy_analysis(1e6);
        a.inlined = true;
        assert_eq!(cost_stage(&a, &Machine::default()), 0.0);
    }

    #[test]
    fn vectorization_reduces_compute_time() {
        let m = Machine::default();
        let mut a = dummy_analysis(1e8);
        a.out_bytes = 0.0;
        a.page_faults = 0.0;
        let scalar = cost_stage(&a, &m);
        a.vector_width = 8;
        a.inner_iters = 1e8 / 8.0;
        let vec = cost_stage(&a, &m);
        assert!(vec < scalar / 3.0, "scalar={scalar} vec={vec}");
    }

    #[test]
    fn parallel_efficiency_with_imbalance() {
        let m = Machine::default();
        let mut a = dummy_analysis(1e8);
        a.page_faults = 0.0;
        a.out_bytes = 0.0;
        a.parallel_tasks = 18;
        let even = cost_stage(&a, &m);
        a.parallel_tasks = 19; // 2 waves, half-empty second wave
        let uneven = cost_stage(&a, &m);
        assert!(uneven > even, "imbalance should hurt: even={even} uneven={uneven}");
    }

    #[test]
    fn dram_slower_than_l2() {
        let m = Machine::default();
        let mut a = dummy_analysis(1e4);
        a.work = WorkProfile::default();
        a.inner_iters = 0.0;
        a.page_faults = 0.0;
        a.alloc_bytes = 0.0;
        a.out_bytes = 0.0;
        a.traffic = vec![crate::sim::analysis::Traffic {
            cold_bytes: 1e8,
            cold_level: Level::Dram,
            reuse_bytes: 0.0,
            reuse_level: Level::L1,
            line_utilization: 1.0,
        }];
        let dram = cost_stage(&a, &m);
        a.traffic[0].cold_level = Level::L2;
        let l2 = cost_stage(&a, &m);
        assert!(dram > l2, "dram={dram} l2={l2}");
    }

    #[test]
    fn page_faults_add_cost() {
        let m = Machine::default();
        let mut a = dummy_analysis(1e6);
        let with = cost_stage(&a, &m);
        a.page_faults = 0.0;
        let without = cost_stage(&a, &m);
        assert!(with > without);
    }
}
