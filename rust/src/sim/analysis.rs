//! Schedule analysis: turn (pipeline, loop nests, schedule) into per-stage
//! derived quantities. Shared by the cost model ([`super::cost`]) and the
//! featurizer ([`crate::features`]) so that features measure the same
//! effects the machine model charges for — exactly the situation the
//! paper's hand-engineered features are in with respect to real hardware.

use crate::ir::pipeline::{Pipeline, SourceRef};
use crate::lower::{AccessPattern, LoopNest, WorkProfile};
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule};
use crate::sim::Machine;

/// Memory hierarchy level serving a traffic stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    L1,
    L2,
    Llc,
    Dram,
}

/// One operand's traffic, split into compulsory (cold) and reuse traffic.
#[derive(Debug, Clone)]
pub struct Traffic {
    /// Bytes that must come from `cold_level` once (compulsory misses,
    /// inflated by poor cache-line utilization).
    pub cold_bytes: f64,
    pub cold_level: Level,
    /// Bytes re-read beyond the first touch, served by `reuse_level`.
    pub reuse_bytes: f64,
    pub reuse_level: Level,
    /// Fraction of each cache line actually used (1.0 = perfect).
    pub line_utilization: f64,
}

/// Everything the cost model / featurizer needs to know about one stage
/// under a given schedule.
#[derive(Debug, Clone)]
pub struct StageAnalysis {
    pub stage_id: usize,
    /// True when the stage is inlined — its cost is carried by consumers.
    pub inlined: bool,
    /// Output points computed, including recompute inflation (≥ nest points).
    pub points: f64,
    /// Recompute factor ≥ 1 (inlining multiplicity / compute_at halo).
    pub recompute: f64,
    /// Work per output point including work absorbed from inlined producers.
    pub work: WorkProfile,
    /// Effective SIMD width for this stage's inner loop.
    pub vector_width: usize,
    /// Number of parallel tasks the schedule exposes.
    pub parallel_tasks: usize,
    /// Innermost-loop iteration count (drives loop overhead), post
    /// vectorization/unroll.
    pub inner_iters: f64,
    pub unroll: usize,
    /// Traffic per operand buffer (graph + weights, incl. inlined producers').
    pub traffic: Vec<Traffic>,
    /// Bytes written to the stage's own output.
    pub out_bytes: f64,
    /// Level absorbing the output writes.
    pub out_level: Level,
    /// Heap bytes allocated for the output buffer (0 when inlined or tiled
    /// into a small pool).
    pub alloc_bytes: f64,
    /// Estimated page faults from first-touch of freshly allocated memory.
    pub page_faults: f64,
    /// Total unique bytes this stage touches (all operands + output).
    pub footprint: f64,
    /// Working-set bytes of one tile (≤ footprint; = footprint when untiled).
    pub tile_ws: f64,
}

fn smallest_fitting_level(bytes: f64, m: &Machine) -> Level {
    if bytes <= m.l1_bytes {
        Level::L1
    } else if bytes <= m.l2_bytes {
        Level::L2
    } else if bytes <= m.llc_bytes {
        Level::Llc
    } else {
        Level::Dram
    }
}

/// Cache-line utilization of an access pattern (f32 elements, 64 B lines).
fn line_util(pattern: AccessPattern) -> f64 {
    match pattern {
        AccessPattern::Contiguous | AccessPattern::Broadcast | AccessPattern::Stencil => 1.0,
        AccessPattern::Strided(s) => (16.0 / s as f64).min(1.0).max(1.0 / 16.0),
        AccessPattern::Transposed => 1.0 / 16.0,
    }
}

/// Analyze the whole pipeline under `sched`.
pub fn analyze_pipeline(
    p: &Pipeline,
    nests: &[LoopNest],
    sched: &PipelineSchedule,
    m: &Machine,
) -> Vec<StageAnalysis> {
    let n = p.num_stages();
    let consumers = p.consumers();

    // --- pass 1: effective (transitively inlined) per-point work and the
    // operand accesses each stage performs once inlining is resolved.
    // eff_work[i] / eff_accesses[i] describe computing ONE point of stage i.
    let mut eff_work: Vec<WorkProfile> = vec![WorkProfile::default(); n];
    let mut eff_accesses: Vec<Vec<(Option<SourceRef>, f64, f64, AccessPattern)>> = vec![vec![]; n];
    for i in 0..n {
        let nest = &nests[i];
        let mut w = nest.work;
        let mut accs: Vec<(Option<SourceRef>, f64, f64, AccessPattern)> = Vec::new();
        for a in &nest.accesses {
            match a.source {
                Some(SourceRef::Stage(pid))
                    if matches!(sched.stages[pid].compute, ComputeLoc::Inline) =>
                {
                    // Absorb the inlined producer: its per-point work and its
                    // own operand reads happen per consumer point. (Stages are
                    // topologically ordered, so eff_* of pid is final.)
                    let ratio = a.bytes_per_point / 4.0; // uses per point
                    w = w.add(&eff_work[pid].scale(ratio));
                    for (src, fpb, bpp, pat) in &eff_accesses[pid] {
                        accs.push((*src, *fpb, bpp * ratio, *pat));
                    }
                }
                _ => accs.push((a.source, a.footprint_bytes, a.bytes_per_point, a.pattern)),
            }
        }
        eff_work[i] = w;
        eff_accesses[i] = accs;
    }

    // --- pass 2: per-stage analysis
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let nest = &nests[i];
        let s = &sched.stages[i];
        let base_points = nest.points();

        if matches!(s.compute, ComputeLoc::Inline) {
            out.push(StageAnalysis {
                stage_id: i,
                inlined: true,
                points: 0.0,
                recompute: consumers[i].len().max(1) as f64,
                work: eff_work[i],
                vector_width: 1,
                parallel_tasks: 1,
                inner_iters: 0.0,
                unroll: 1,
                traffic: vec![],
                out_bytes: 0.0,
                out_level: Level::L1,
                alloc_bytes: 0.0,
                page_faults: 0.0,
                footprint: 0.0,
                tile_ws: 0.0,
            });
            continue;
        }

        // recompute from compute_at halo (stencil consumers recompute
        // producer rows shared between tiles; finer levels → more halo)
        let recompute = match s.compute {
            ComputeLoc::At { consumer, level } => {
                let stencil_consumer = nests[consumer]
                    .accesses
                    .iter()
                    .any(|a| a.source == Some(SourceRef::Stage(i)) && a.pattern == AccessPattern::Stencil);
                if stencil_consumer {
                    1.0 + 0.12 * level as f64 * level as f64
                } else {
                    1.0
                }
            }
            _ => 1.0,
        };
        let points = base_points * recompute;

        // tile working set: fraction of the iteration space one tile covers
        let tile_frac: f64 = (0..nest.spatial.len())
            .map(|d| {
                let f = s.tile[d].max(1);
                if f > 1 && f < nest.spatial[d] {
                    f as f64 / nest.spatial[d] as f64
                } else {
                    1.0
                }
            })
            .product();
        // compute_at also confines the producer to the consumer's tile
        let at_frac = match s.compute {
            ComputeLoc::At { level, .. } => (0.5f64).powi(2 * level as i32),
            _ => 1.0,
        };
        let eff_tile_frac = (tile_frac * at_frac).min(1.0);

        // traffic per operand
        let red = nest.red_extent();
        let mut traffic = Vec::new();
        let mut footprint = nest.out_bytes;
        for (src, fp_bytes, bpp, pattern) in &eff_accesses[i] {
            footprint += fp_bytes;
            let total_read = bpp * points;
            let util = line_util(*pattern);

            // where do compulsory misses come from?
            let cold_level = match src {
                Some(SourceRef::Stage(pid)) => match sched.stages[*pid].compute {
                    // producer left its tile in cache for us
                    ComputeLoc::At { .. } => Level::L2,
                    _ => {
                        // materialized buffer: DRAM if it spilled the LLC
                        smallest_fitting_level(*fp_bytes, m).max(Level::Llc)
                    }
                },
                _ => smallest_fitting_level(*fp_bytes, m).max(Level::Llc),
            };
            // poor line utilization fetches whole lines for few useful
            // bytes: inflate by 1/util, bounded by the line-inflated total
            let cold_bytes = (fp_bytes / util).min((total_read / util).max(*fp_bytes));

            // reuse traffic: reads beyond first touch, served where the
            // reuse working set fits. Tiling shrinks the working set.
            let reuse_bytes = (total_read - fp_bytes).max(0.0);
            let reuse_ws = match pattern {
                AccessPattern::Broadcast => *fp_bytes,
                AccessPattern::Stencil => {
                    // a few rows of the input stay hot between window steps
                    (fp_bytes * 0.1).max(4.0 * red)
                }
                _ if red > 1.0 => fp_bytes * eff_tile_frac,
                _ => 64.0,
            };
            let reuse_level = smallest_fitting_level(reuse_ws, m);
            traffic.push(Traffic {
                cold_bytes,
                cold_level,
                reuse_bytes,
                reuse_level,
                line_utilization: util,
            });
        }

        // output writes + allocation
        let out_bytes = nest.out_bytes * recompute;
        let (out_level, alloc_bytes, page_faults) = match s.compute {
            ComputeLoc::At { .. } => {
                // tile-sized scratch buffer, reused across tiles
                let tile_bytes = nest.out_bytes * eff_tile_frac;
                (smallest_fitting_level(tile_bytes, m), tile_bytes, tile_bytes / 4096.0)
            }
            _ => {
                let lvl = smallest_fitting_level(nest.out_bytes, m);
                (lvl, nest.out_bytes, nest.out_bytes / 4096.0)
            }
        };

        // vector width effective only if the (possibly tiled) inner extent
        // covers it; legality already checks, so take it as-is
        let vw = s.vector_width.max(1);
        let inner_iters = points * red / (vw as f64 * s.unroll as f64);

        out.push(StageAnalysis {
            stage_id: i,
            inlined: false,
            points,
            recompute,
            work: eff_work[i],
            vector_width: vw,
            parallel_tasks: s.parallel_tasks(&nest.spatial),
            inner_iters,
            unroll: s.unroll,
            traffic,
            out_bytes,
            out_level,
            alloc_bytes,
            page_faults,
            footprint,
            tile_ws: footprint * eff_tile_frac,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;
    use crate::schedule::primitives::PipelineSchedule;

    fn chain() -> (Pipeline, Vec<LoopNest>) {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 32;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        let r = p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        p.add_stage("exp", Op::new(OpKind::Exp), vec![r]).unwrap();
        let nests = lower_pipeline(&p);
        (p, nests)
    }

    fn default_sched(p: &Pipeline) -> PipelineSchedule {
        PipelineSchedule::default_for(&p.stages.iter().map(|s| s.shape.len()).collect::<Vec<_>>())
    }

    #[test]
    fn default_analysis_sane() {
        let (p, nests) = chain();
        let m = Machine::default();
        let a = analyze_pipeline(&p, &nests, &default_sched(&p), &m);
        assert_eq!(a.len(), 3);
        for st in &a {
            assert!(!st.inlined);
            assert!(st.points > 0.0);
            assert_eq!(st.recompute, 1.0);
            assert!(st.footprint > 0.0);
        }
        // conv reads input + weights
        assert_eq!(a[0].traffic.len(), 2);
    }

    #[test]
    fn inlined_relu_work_moves_to_consumer() {
        let (p, nests) = chain();
        let m = Machine::default();
        let mut s = default_sched(&p);
        s.stages[1].compute = ComputeLoc::Inline;
        let a = analyze_pipeline(&p, &nests, &s, &m);
        assert!(a[1].inlined);
        assert_eq!(a[1].out_bytes, 0.0);
        // exp stage now carries relu's cmp work
        assert!(a[2].work.cmp_ops >= 1.0, "absorbed work: {:?}", a[2].work);
        // and reads conv's buffer directly
        assert!(a[2]
            .traffic
            .iter()
            .any(|t| t.cold_bytes > 0.0));
    }

    #[test]
    fn compute_at_moves_cold_traffic_to_cache() {
        let (p, nests) = chain();
        let m = Machine::default();
        let mut s = default_sched(&p);
        s.stages[1].compute = ComputeLoc::At { consumer: 2, level: 2 };
        let a = analyze_pipeline(&p, &nests, &s, &m);
        // relu's output is a tile-sized scratch buffer now
        assert!(a[1].alloc_bytes < nests[1].out_bytes);
        // exp's read of relu comes from L2, not DRAM
        let t = &a[2].traffic[0];
        assert_eq!(t.cold_level, Level::L2);
    }

    #[test]
    fn tiling_shrinks_reuse_working_set() {
        // gemm with large K: untiled reuse is DRAM-resident, tiled fits L2
        let mut p = Pipeline::new("g");
        let x = p.add_input(vec![512, 4096]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 512;
        p.add_stage("fc", Op::with_attrs(OpKind::Gemm, attrs), vec![x]).unwrap();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let mut s = default_sched(&p);
        let base = analyze_pipeline(&p, &nests, &s, &m);
        s.stages[0].tile = vec![32, 32];
        let tiled = analyze_pipeline(&p, &nests, &s, &m);
        let base_lvl = base[0].traffic[0].reuse_level;
        let tiled_lvl = tiled[0].traffic[0].reuse_level;
        assert!(tiled_lvl < base_lvl, "tiled {tiled_lvl:?} !< base {base_lvl:?}");
    }

    #[test]
    fn transposed_access_inflates_cold_traffic() {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![2048, 2048]);
        let mut attrs = OpAttrs::default();
        attrs.perm = vec![1, 0];
        p.add_stage("tr", Op::with_attrs(OpKind::Transpose, attrs), vec![x]).unwrap();
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let a = analyze_pipeline(&p, &nests, &default_sched(&p), &m);
        let t = &a[0].traffic[0];
        assert!(t.line_utilization < 0.1);
        assert!(t.cold_bytes > nests[0].accesses[0].footprint_bytes * 10.0);
    }
}
