//! TVM-style gradient-boosted regression trees (Chen et al. 2018 use
//! XGBoost). From-scratch implementation: histogram feature binning,
//! second-order (Newton) leaf weights with L2 regularization, shrinkage,
//! depth-limited greedy splits.
//!
//! Program featurization follows TVM's flattened "context features": each
//! sample becomes a fixed-size vector of [sum, max, mean] aggregates of its
//! per-stage features, and the model regresses log-runtime with squared
//! error (predictions are exponentiated back to seconds).

use crate::constants::{DEP_DIM, INV_DIM};
use crate::dataset::sample::{Dataset, GraphSample};
use anyhow::{bail, Result};

pub const GBT_FEATS: usize = 3 * (INV_DIM + DEP_DIM) + 2;

/// Aggregate a sample into TVM-style flattened context features.
pub fn gbt_features(s: &GraphSample) -> Vec<f32> {
    let ns = s.n_stages as usize;
    let mut out = vec![0f32; GBT_FEATS];
    let (sum_off, max_off, mean_off) = (0, INV_DIM + DEP_DIM, 2 * (INV_DIM + DEP_DIM));
    let width = INV_DIM + DEP_DIM;
    for (iv, dv) in s.inv.iter().zip(&s.dep) {
        for (d, &v) in iv.iter().chain(dv.iter()).enumerate() {
            out[sum_off + d] += v;
            if v > out[max_off + d] {
                out[max_off + d] = v;
            }
        }
    }
    for d in 0..width {
        out[mean_off + d] = out[sum_off + d] / ns as f32;
    }
    out[3 * width] = ns as f32;
    out[3 * width + 1] = s.edges.len() as f32;
    out
}

#[derive(Debug, Clone)]
enum Node {
    Leaf(f32),
    Split { feat: usize, threshold: f32, left: usize, right: usize },
}

#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f32]) -> f32 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf(v) => return *v,
                Node::Split { feat, threshold, left, right } => {
                    i = if x[*feat] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct GbtConfig {
    pub n_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f32,
    pub min_child_weight: f32,
    pub lambda: f32,
    pub n_bins: usize,
    pub min_gain: f32,
}

impl Default for GbtConfig {
    fn default() -> Self {
        GbtConfig {
            n_trees: 120,
            max_depth: 6,
            learning_rate: 0.15,
            min_child_weight: 4.0,
            lambda: 1.0,
            n_bins: 32,
            min_gain: 1e-6,
        }
    }
}

pub struct Gbt {
    pub cfg: GbtConfig,
    base: f32,
    trees: Vec<Tree>,
    /// Per-feature bin edges computed on the training set.
    bins: Vec<Vec<f32>>,
}

struct BuildCtx<'a> {
    x: &'a [Vec<f32>],
    grad: &'a [f32], // g_i (squared error: pred - target)
    hess: f32,       // h_i = 1 for squared error
    cfg: &'a GbtConfig,
    bins: &'a [Vec<f32>],
}

impl Gbt {
    /// Fit on (features, log-runtime targets).
    pub fn fit_xy(x: &[Vec<f32>], y: &[f32], cfg: GbtConfig) -> Gbt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let nf = x[0].len();
        // bin edges by per-feature quantiles
        let mut bins = Vec::with_capacity(nf);
        for f in 0..nf {
            let mut vals: Vec<f32> = x.iter().map(|r| r[f]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            let mut edges = Vec::new();
            if vals.len() > 1 {
                for b in 1..cfg.n_bins.min(vals.len()) {
                    let q = b * (vals.len() - 1) / cfg.n_bins.min(vals.len());
                    let e = vals[q];
                    if edges.last() != Some(&e) {
                        edges.push(e);
                    }
                }
            }
            bins.push(edges);
        }

        let base = y.iter().sum::<f32>() / y.len() as f32;
        let mut pred = vec![base; y.len()];
        let mut trees = Vec::with_capacity(cfg.n_trees);
        for _ in 0..cfg.n_trees {
            let grad: Vec<f32> = pred.iter().zip(y).map(|(p, t)| p - t).collect();
            let ctx = BuildCtx { x, grad: &grad, hess: 1.0, cfg: &cfg, bins: &bins };
            let mut nodes = Vec::new();
            let idx: Vec<u32> = (0..x.len() as u32).collect();
            build_node(&ctx, &idx, 0, &mut nodes);
            let tree = Tree { nodes };
            for (i, row) in x.iter().enumerate() {
                pred[i] += cfg.learning_rate * tree.predict(row);
            }
            trees.push(tree);
        }
        Gbt { cfg, base, trees, bins }
    }

    /// Fit on a dataset (targets = log mean runtimes).
    pub fn fit(ds: &Dataset, cfg: GbtConfig) -> Gbt {
        let x: Vec<Vec<f32>> = ds.samples.iter().map(gbt_features).collect();
        let y: Vec<f32> = ds
            .samples
            .iter()
            .map(|s| (s.mean_runtime().max(1e-12)).ln() as f32)
            .collect();
        Gbt::fit_xy(&x, &y, cfg)
    }

    /// Predicted log-runtime for a feature row.
    pub fn predict_log(&self, x: &[f32]) -> f32 {
        self.base
            + self.cfg.learning_rate
                * self.trees.iter().map(|t| t.predict(x)).sum::<f32>()
    }

    pub fn predict_sample(&self, s: &GraphSample) -> f64 {
        (self.predict_log(&gbt_features(s)) as f64).exp()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    pub fn bin_count(&self) -> usize {
        self.bins.iter().map(|b| b.len()).sum()
    }

    pub fn base(&self) -> f32 {
        self.base
    }

    /// Flatten each tree to `[tag, feat, threshold/value, left, right]`
    /// rows (tag 0 = leaf with its value in slot 2; tag 1 = split) — for
    /// bundle serialization by `predictor::GbtPredictor`.
    pub fn export_trees(&self) -> Vec<Vec<[f32; 5]>> {
        self.trees
            .iter()
            .map(|t| {
                t.nodes
                    .iter()
                    .map(|n| match n {
                        Node::Leaf(v) => [0.0, 0.0, *v, 0.0, 0.0],
                        Node::Split { feat, threshold, left, right } => {
                            [1.0, *feat as f32, *threshold, *left as f32, *right as f32]
                        }
                    })
                    .collect()
            })
            .collect()
    }

    /// Rebuild from flattened trees (inverse of [`Self::export_trees`]).
    /// Bins are fit-time state only and come back empty. Child indices are
    /// validated so a corrupt bundle fails here, not by panicking in
    /// `predict`.
    pub fn from_export(cfg: GbtConfig, base: f32, trees: Vec<Vec<[f32; 5]>>) -> Result<Gbt> {
        let mut parsed = Vec::with_capacity(trees.len());
        for (ti, rows) in trees.iter().enumerate() {
            let mut nodes = Vec::with_capacity(rows.len());
            for (ni, row) in rows.iter().enumerate() {
                let node = match row[0] {
                    t if t == 0.0 => Node::Leaf(row[2]),
                    t if t == 1.0 => {
                        let (left, right) = (row[3] as usize, row[4] as usize);
                        if left >= rows.len() || right >= rows.len() {
                            bail!(
                                "gbt tree {ti} node {ni}: child index out of range \
                                 ({left}/{right} of {})",
                                rows.len()
                            );
                        }
                        // children always follow their parent (build_node
                        // pushes the placeholder first), so forward-only
                        // links also rule out cycles in `Tree::predict`
                        if left <= ni || right <= ni {
                            bail!(
                                "gbt tree {ti} node {ni}: child index must be forward \
                                 (got {left}/{right})"
                            );
                        }
                        let feat = row[1] as usize;
                        if feat >= GBT_FEATS {
                            bail!(
                                "gbt tree {ti} node {ni}: feature index {feat} out of \
                                 range (this build has {GBT_FEATS} features)"
                            );
                        }
                        Node::Split { feat, threshold: row[2], left, right }
                    }
                    other => bail!("gbt tree {ti} node {ni}: unknown node tag {other}"),
                };
                nodes.push(node);
            }
            if nodes.is_empty() {
                bail!("gbt tree {ti} is empty");
            }
            parsed.push(Tree { nodes });
        }
        Ok(Gbt { cfg, base, trees: parsed, bins: Vec::new() })
    }
}

/// Recursively grow one node; returns its index in `nodes`.
fn build_node(ctx: &BuildCtx, idx: &[u32], depth: usize, nodes: &mut Vec<Node>) -> usize {
    let g_sum: f32 = idx.iter().map(|&i| ctx.grad[i as usize]).sum();
    let h_sum: f32 = idx.len() as f32 * ctx.hess;
    let leaf_value = -g_sum / (h_sum + ctx.cfg.lambda);

    if depth >= ctx.cfg.max_depth || idx.len() < 2 {
        nodes.push(Node::Leaf(leaf_value));
        return nodes.len() - 1;
    }

    // find best split over (feature, bin edge)
    let parent_score = g_sum * g_sum / (h_sum + ctx.cfg.lambda);
    let mut best: Option<(usize, f32, f32)> = None; // (feat, threshold, gain)
    let nf = ctx.x[0].len();
    for f in 0..nf {
        let edges = &ctx.bins[f];
        if edges.is_empty() {
            continue;
        }
        // histogram of gradients per bin
        let nb = edges.len() + 1;
        let mut hg = vec![0f32; nb];
        let mut hh = vec![0f32; nb];
        for &i in idx {
            let v = ctx.x[i as usize][f];
            let b = edges.partition_point(|&e| e < v);
            hg[b] += ctx.grad[i as usize];
            hh[b] += ctx.hess;
        }
        let mut gl = 0f32;
        let mut hl = 0f32;
        for b in 0..nb - 1 {
            gl += hg[b];
            hl += hh[b];
            let gr = g_sum - gl;
            let hr = h_sum - hl;
            if hl < ctx.cfg.min_child_weight || hr < ctx.cfg.min_child_weight {
                continue;
            }
            let gain = gl * gl / (hl + ctx.cfg.lambda) + gr * gr / (hr + ctx.cfg.lambda)
                - parent_score;
            if gain > ctx.cfg.min_gain && best.map(|(_, _, g)| gain > g).unwrap_or(true) {
                best = Some((f, edges[b], gain));
            }
        }
    }

    match best {
        None => {
            nodes.push(Node::Leaf(leaf_value));
            nodes.len() - 1
        }
        Some((feat, threshold, _)) => {
            let (li, ri): (Vec<u32>, Vec<u32>) =
                idx.iter().partition(|&&i| ctx.x[i as usize][feat] <= threshold);
            let me = nodes.len();
            nodes.push(Node::Leaf(0.0)); // placeholder
            let left = build_node(ctx, &li, depth + 1, nodes);
            let right = build_node(ctx, &ri, depth + 1, nodes);
            nodes[me] = Node::Split { feat, threshold, left, right };
            me
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fits_simple_function() {
        // y = 2*x0 + step(x1)
        let mut rng = Rng::new(1);
        let x: Vec<Vec<f32>> = (0..400)
            .map(|_| vec![rng.f32(), rng.f32(), rng.f32()])
            .collect();
        let y: Vec<f32> = x
            .iter()
            .map(|r| 2.0 * r[0] + if r[1] > 0.5 { 1.0 } else { 0.0 })
            .collect();
        let gbt = Gbt::fit_xy(&x, &y, GbtConfig { n_trees: 60, ..Default::default() });
        let mse: f32 = x
            .iter()
            .zip(&y)
            .map(|(r, &t)| (gbt.predict_log(r) - t).powi(2))
            .sum::<f32>()
            / y.len() as f32;
        assert!(mse < 0.02, "mse {mse}");
    }

    #[test]
    fn constant_target_learned_exactly() {
        let x: Vec<Vec<f32>> = (0..50).map(|i| vec![i as f32]).collect();
        let y = vec![3.5f32; 50];
        let gbt = Gbt::fit_xy(&x, &y, GbtConfig::default());
        assert!((gbt.predict_log(&[7.0]) - 3.5).abs() < 1e-4);
    }

    #[test]
    fn respects_max_depth_and_tree_count() {
        let mut rng = Rng::new(2);
        let x: Vec<Vec<f32>> = (0..100).map(|_| vec![rng.f32(), rng.f32()]).collect();
        let y: Vec<f32> = x.iter().map(|r| r[0] * r[1]).collect();
        let cfg = GbtConfig { n_trees: 10, max_depth: 3, ..Default::default() };
        let gbt = Gbt::fit_xy(&x, &y, cfg);
        assert_eq!(gbt.n_trees(), 10);
    }

    #[test]
    fn gbt_features_shape_and_aggregates() {
        use crate::constants::BENCH_RUNS;
        let s = GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: 2,
            edges: vec![(0, 1)],
            inv: vec![[1.0; INV_DIM], [3.0; INV_DIM]],
            dep: vec![[2.0; DEP_DIM], [4.0; DEP_DIM]],
            runs: [1.0; BENCH_RUNS],
        };
        let f = gbt_features(&s);
        assert_eq!(f.len(), GBT_FEATS);
        assert_eq!(f[0], 4.0); // sum of inv dim 0
        assert_eq!(f[INV_DIM + DEP_DIM], 3.0); // max of inv dim 0
        assert_eq!(f[2 * (INV_DIM + DEP_DIM)], 2.0); // mean of inv dim 0
        assert_eq!(f[GBT_FEATS - 2], 2.0); // n_stages
        assert_eq!(f[GBT_FEATS - 1], 1.0); // n_edges
    }

    #[test]
    fn improves_over_mean_predictor_on_dataset() {
        use crate::dataset::builder::{build_dataset, DataGenConfig};
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 10,
            schedules_per_pipeline: 8,
            seed: 31,
            ..Default::default()
        });
        let gbt = Gbt::fit(&ds, GbtConfig { n_trees: 40, ..Default::default() });
        let truth: Vec<f64> = ds.samples.iter().map(|s| s.mean_runtime()).collect();
        let preds: Vec<f64> = ds.samples.iter().map(|s| gbt.predict_sample(s)).collect();
        let log_t: Vec<f64> = truth.iter().map(|t| t.ln()).collect();
        let log_p: Vec<f64> = preds.iter().map(|p| p.ln()).collect();
        let r2 = crate::util::stats::r2_score(&log_t, &log_p);
        assert!(r2 > 0.5, "train R² {r2}");
    }
}
