//! The comparison models from the paper's evaluation (§IV):
//!
//! * [`halide_ffn`] — the Halide auto-scheduler model of Adams et al. 2019
//!   (Fig 3): per-stage embedding MLPs whose head emits coefficients over 27
//!   hand-crafted terms; stage runtimes sum to the pipeline prediction.
//!   Implemented with [`nn`], a tiny dependency-free dense-layer library
//!   with manual backprop.
//! * [`gbt`] — the TVM auto-scheduler model (Chen et al. 2018): XGBoost-style
//!   gradient-boosted regression trees over flattened per-program features,
//!   written from scratch (histogram splits, second-order gain, shrinkage).
//! * [`rnn`] — a bi-GRU extension standing in for the Halide value-learning
//!   LSTM family (sequence order without DAG structure).
//!
//! These modules hold the models and their training loops only; the
//! crate-wide prediction interface is [`crate::predictor::Predictor`],
//! with adapters (`FfnPredictor`, `GbtPredictor`, `GruPredictor`) in
//! [`crate::predictor`].

pub mod nn;
pub mod halide_ffn;
pub mod gbt;
pub mod rnn;
