//! The two comparison models from the paper's evaluation (§IV):
//!
//! * [`halide_ffn`] — the Halide auto-scheduler model of Adams et al. 2019
//!   (Fig 3): per-stage embedding MLPs whose head emits coefficients over 27
//!   hand-crafted terms; stage runtimes sum to the pipeline prediction.
//!   Implemented with [`nn`], a tiny dependency-free dense-layer library
//!   with manual backprop.
//! * [`gbt`] — the TVM auto-scheduler model (Chen et al. 2018): XGBoost-style
//!   gradient-boosted regression trees over flattened per-program features,
//!   written from scratch (histogram splits, second-order gain, shrinkage).

pub mod nn;
pub mod halide_ffn;
pub mod gbt;
pub mod rnn;

use crate::dataset::sample::Dataset;

/// Common interface for baseline models in the eval harness.
pub trait PerfModel {
    /// Predicted mean runtimes (seconds), one per sample.
    fn predict(&self, ds: &Dataset) -> Vec<f64>;
    fn name(&self) -> &'static str;
}
