//! Recurrent baseline — the Halide *value-learning* model family ([6],
//! §V: "replaces the feed-forward network with a bi-directional LSTM and
//! demonstrates significant improvement in prediction accuracy").
//!
//! We implement a bidirectional gated recurrent unit (GRU, the LSTM's
//! lighter sibling) over the stages in topological order: per-stage
//! embeddings feed forward and backward GRUs; the concatenated final
//! hidden states pass through a linear head to the log-runtime. Manual
//! backprop (BPTT) with gradient clipping and Adagrad, like the other
//! in-tree baselines.
//!
//! This is an *extension* beyond the paper's Fig 8 (which compares GCN vs
//! FFN vs GBT); the eval harness can include it to show where a sequence
//! model lands between the FFN and the GCN — sequence models see order but
//! not DAG structure.

use crate::baselines::nn::Linear;
use crate::constants::{DEP_DIM, INV_DIM};
use crate::dataset::sample::{Dataset, GraphSample};
use crate::features::normalize::FeatureStats;
use crate::features::StageFeatures;
use crate::util::rng::Rng;

const IN_DIM: usize = INV_DIM + DEP_DIM;

/// One GRU direction. Gates: z (update), r (reset), n (candidate).
struct GruCell {
    // weights [IN, 3H] and [H, 3H], bias [3H]; gate order: z | r | n
    wx: Vec<f32>,
    wh: Vec<f32>,
    b: Vec<f32>,
    h: usize,
    // adagrad accumulators
    gwx2: Vec<f32>,
    gwh2: Vec<f32>,
    gb2: Vec<f32>,
    // accumulated grads
    gwx: Vec<f32>,
    gwh: Vec<f32>,
    gb: Vec<f32>,
}

/// Per-step cache for BPTT.
struct StepCache {
    x: Vec<f32>,
    h_prev: Vec<f32>,
    z: Vec<f32>,
    r: Vec<f32>,
    n: Vec<f32>,
    h: Vec<f32>,
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl GruCell {
    fn new(in_dim: usize, h: usize, rng: &mut Rng) -> GruCell {
        let sx = (1.0 / in_dim as f64).sqrt();
        let sh = (1.0 / h as f64).sqrt();
        GruCell {
            wx: (0..in_dim * 3 * h).map(|_| (rng.normal() * sx) as f32).collect(),
            wh: (0..h * 3 * h).map(|_| (rng.normal() * sh) as f32).collect(),
            b: vec![0.0; 3 * h],
            h,
            gwx2: vec![0.0; in_dim * 3 * h],
            gwh2: vec![0.0; h * 3 * h],
            gb2: vec![0.0; 3 * h],
            gwx: vec![0.0; in_dim * 3 * h],
            gwh: vec![0.0; h * 3 * h],
            gb: vec![0.0; 3 * h],
        }
    }

    /// One step: h' = (1−z)⊙n + z⊙h.
    fn step(&self, x: &[f32], h_prev: &[f32]) -> StepCache {
        let h = self.h;
        let mut pre = self.b.clone(); // [3H]
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.wx[i * 3 * h..(i + 1) * 3 * h];
            for (j, &w) in row.iter().enumerate() {
                pre[j] += xi * w;
            }
        }
        // z and r gates get the full recurrent term; n gets r⊙h later
        let mut rec = vec![0f32; 3 * h];
        for (i, &hi) in h_prev.iter().enumerate() {
            if hi == 0.0 {
                continue;
            }
            let row = &self.wh[i * 3 * h..(i + 1) * 3 * h];
            for (j, &w) in row.iter().enumerate() {
                rec[j] += hi * w;
            }
        }
        let mut z = vec![0f32; h];
        let mut r = vec![0f32; h];
        let mut n = vec![0f32; h];
        let mut h_new = vec![0f32; h];
        for j in 0..h {
            z[j] = sigmoid(pre[j] + rec[j]);
            r[j] = sigmoid(pre[h + j] + rec[h + j]);
            n[j] = (pre[2 * h + j] + r[j] * rec[2 * h + j]).tanh();
            h_new[j] = (1.0 - z[j]) * n[j] + z[j] * h_prev[j];
        }
        StepCache { x: x.to_vec(), h_prev: h_prev.to_vec(), z, r, n, h: h_new }
    }

    /// BPTT through one step: given dL/dh', accumulate grads, return
    /// (dL/dx, dL/dh_prev).
    fn backward(&mut self, c: &StepCache, dh: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let h = self.h;
        // recompute rec term for the n-gate path
        let mut rec_n = vec![0f32; h];
        for (i, &hi) in c.h_prev.iter().enumerate() {
            if hi == 0.0 {
                continue;
            }
            let row = &self.wh[i * 3 * h..(i + 1) * 3 * h];
            for (j, v) in rec_n.iter_mut().enumerate() {
                *v += hi * row[2 * h + j];
            }
        }
        // gate pre-activation grads
        let mut dpre = vec![0f32; 3 * h]; // z | r | n pre-activations
        let mut dh_prev = vec![0f32; h];
        for j in 0..h {
            let dz = dh[j] * (c.h_prev[j] - c.n[j]);
            let dn = dh[j] * (1.0 - c.z[j]);
            dh_prev[j] += dh[j] * c.z[j];
            let dn_pre = dn * (1.0 - c.n[j] * c.n[j]);
            let dr = dn_pre * rec_n[j];
            dpre[2 * h + j] = dn_pre;
            dpre[j] = dz * c.z[j] * (1.0 - c.z[j]);
            dpre[h + j] = dr * c.r[j] * (1.0 - c.r[j]);
        }
        // param grads + input grads
        let mut dx = vec![0f32; c.x.len()];
        for (i, &xi) in c.x.iter().enumerate() {
            let grow = &mut self.gwx[i * 3 * h..(i + 1) * 3 * h];
            let wrow = &self.wx[i * 3 * h..(i + 1) * 3 * h];
            let mut acc = 0f32;
            for j in 0..3 * h {
                grow[j] += dpre[j] * xi;
                acc += dpre[j] * wrow[j];
            }
            dx[i] = acc;
        }
        // recurrent weights: z,r gates see h_prev directly; n sees r⊙(wh·h)
        for (i, &hi) in c.h_prev.iter().enumerate() {
            let grow = &mut self.gwh[i * 3 * h..(i + 1) * 3 * h];
            let wrow = &self.wh[i * 3 * h..(i + 1) * 3 * h];
            let mut acc = 0f32;
            for j in 0..h {
                // z gate
                grow[j] += dpre[j] * hi;
                acc += dpre[j] * wrow[j];
                // r gate
                grow[h + j] += dpre[h + j] * hi;
                acc += dpre[h + j] * wrow[h + j];
                // n gate through r⊙rec
                grow[2 * h + j] += dpre[2 * h + j] * c.r[j] * hi;
                acc += dpre[2 * h + j] * c.r[j] * wrow[2 * h + j];
            }
            dh_prev[i] += acc;
        }
        for j in 0..3 * h {
            self.gb[j] += dpre[j];
        }
        (dx, dh_prev)
    }

    fn step_params(&mut self, lr: f32, clip: f32) {
        let apply = |w: &mut [f32], g: &mut [f32], g2: &mut [f32]| {
            for i in 0..w.len() {
                let gi = g[i].clamp(-clip, clip);
                g2[i] += gi * gi;
                w[i] -= lr * gi / (g2[i].sqrt() + 1e-10);
                g[i] = 0.0;
            }
        };
        apply(&mut self.wx, &mut self.gwx, &mut self.gwx2);
        apply(&mut self.wh, &mut self.gwh, &mut self.gwh2);
        apply(&mut self.b, &mut self.gb, &mut self.gb2);
    }
}

pub struct BiGru {
    fwd: GruCell,
    bwd: GruCell,
    head: Linear,
    stats: FeatureStats,
    hidden: usize,
}

#[derive(Debug, Clone)]
pub struct RnnTrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub clip: f32,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for RnnTrainConfig {
    fn default() -> Self {
        RnnTrainConfig { epochs: 20, lr: 0.02, clip: 1.0, seed: 31, verbose: false }
    }
}

impl BiGru {
    pub fn new(stats: FeatureStats, hidden: usize, seed: u64) -> BiGru {
        let mut rng = Rng::new(seed);
        BiGru {
            fwd: GruCell::new(IN_DIM, hidden, &mut rng),
            bwd: GruCell::new(IN_DIM, hidden, &mut rng),
            head: Linear::new(2 * hidden, 1, false, &mut rng),
            stats,
            hidden,
        }
    }

    fn stage_inputs(&self, s: &GraphSample) -> Vec<Vec<f32>> {
        s.inv
            .iter()
            .zip(&s.dep)
            .map(|(iv, dv)| {
                let mut f = StageFeatures { invariant: *iv, dependent: *dv };
                self.stats.apply(&mut f);
                let mut x = Vec::with_capacity(IN_DIM);
                x.extend_from_slice(&f.invariant);
                x.extend_from_slice(&f.dependent);
                x
            })
            .collect()
    }

    /// Forward; returns (log ŷ, caches) — caches reused by backward.
    fn forward_sample(&mut self, s: &GraphSample) -> (f32, Vec<StepCache>, Vec<StepCache>) {
        let xs = self.stage_inputs(s);
        let h = self.hidden;
        let mut hf = vec![0f32; h];
        let mut fcaches = Vec::with_capacity(xs.len());
        for x in &xs {
            let c = self.fwd.step(x, &hf);
            hf = c.h.clone();
            fcaches.push(c);
        }
        let mut hb = vec![0f32; h];
        let mut bcaches = Vec::with_capacity(xs.len());
        for x in xs.iter().rev() {
            let c = self.bwd.step(x, &hb);
            hb = c.h.clone();
            bcaches.push(c);
        }
        let mut cat = Vec::with_capacity(2 * h);
        cat.extend_from_slice(&hf);
        cat.extend_from_slice(&hb);
        let z = self.head.forward(&cat, 1)[0];
        (z, fcaches, bcaches)
    }

    fn backward_sample(&mut self, dz: f32, fcaches: &[StepCache], bcaches: &[StepCache]) {
        let h = self.hidden;
        let dcat = self.head.backward(&[dz]);
        let mut dhf = dcat[..h].to_vec();
        for c in fcaches.iter().rev() {
            let (_dx, dh_prev) = self.fwd.backward(c, &dhf);
            dhf = dh_prev;
        }
        let mut dhb = dcat[h..].to_vec();
        for c in bcaches.iter().rev() {
            let (_dx, dh_prev) = self.bwd.backward(c, &dhb);
            dhb = dh_prev;
        }
    }

    /// Train on log-runtime with squared error (the value-learning setup).
    pub fn fit(&mut self, ds: &Dataset, cfg: &RnnTrainConfig) {
        let mut rng = Rng::new(cfg.seed);
        // output-bias init at the mean log target (same trick as the GCN)
        let mean_log: f32 = ds
            .samples
            .iter()
            .map(|s| s.mean_runtime().max(1e-12).ln() as f32)
            .sum::<f32>()
            / ds.len().max(1) as f32;
        self.head.b[0] = mean_log;
        for epoch in 0..cfg.epochs {
            let mut order: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut order);
            let mut loss = 0f64;
            for &i in &order {
                let s = &ds.samples[i];
                let target = s.mean_runtime().max(1e-12).ln() as f32;
                let (z, fc, bc) = self.forward_sample(s);
                let err = z - target;
                loss += (err * err) as f64;
                self.backward_sample(2.0 * err, &fc, &bc);
                self.fwd.step_params(cfg.lr, cfg.clip);
                self.bwd.step_params(cfg.lr, cfg.clip);
                self.head.step(cfg.lr, 1e-4);
            }
            if cfg.verbose {
                eprintln!("gru epoch {epoch:>3} mse {:.4}", loss / ds.len() as f64);
            }
        }
    }

    pub fn predict_sample(&mut self, s: &GraphSample) -> f64 {
        let (z, _, _) = self.forward_sample(s);
        (z as f64).exp()
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    pub fn stats(&self) -> &FeatureStats {
        &self.stats
    }

    /// Clone out all learned weights — for bundle serialization by
    /// `predictor::GruPredictor`.
    pub fn export_weights(&self) -> BiGruWeights {
        BiGruWeights {
            fwd_wx: self.fwd.wx.clone(),
            fwd_wh: self.fwd.wh.clone(),
            fwd_b: self.fwd.b.clone(),
            bwd_wx: self.bwd.wx.clone(),
            bwd_wh: self.bwd.wh.clone(),
            bwd_b: self.bwd.b.clone(),
            head_w: self.head.w.clone(),
            head_b: self.head.b.clone(),
        }
    }

    /// Rebuild from exported weights (fresh optimizer state and caches).
    /// Callers are expected to have validated the vector lengths against
    /// `hidden` and `INV_DIM + DEP_DIM`.
    pub fn from_weights(stats: FeatureStats, hidden: usize, w: BiGruWeights) -> BiGru {
        let mut me = BiGru::new(stats, hidden, 0);
        me.fwd.wx = w.fwd_wx;
        me.fwd.wh = w.fwd_wh;
        me.fwd.b = w.fwd_b;
        me.bwd.wx = w.bwd_wx;
        me.bwd.wh = w.bwd_wh;
        me.bwd.b = w.bwd_b;
        me.head.w = w.head_w;
        me.head.b = w.head_b;
        me
    }
}

/// Flat learned-weight set of a [`BiGru`] (gate order z | r | n, row-major
/// `[in, 3H]` / `[H, 3H]` matrices — the in-memory layout, unchanged).
#[derive(Debug, Clone)]
pub struct BiGruWeights {
    pub fwd_wx: Vec<f32>,
    pub fwd_wh: Vec<f32>,
    pub fwd_b: Vec<f32>,
    pub bwd_wx: Vec<f32>,
    pub bwd_wh: Vec<f32>,
    pub bwd_b: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constants::BENCH_RUNS;

    fn toy_sample(vals: &[f32], rt: f32) -> GraphSample {
        let ns = vals.len();
        GraphSample {
            pipeline_id: 0,
            schedule_id: 0,
            n_stages: ns as u32,
            edges: (0..ns - 1).map(|i| (i as u32, i as u32 + 1)).collect(),
            inv: vals.iter().map(|&v| [v; INV_DIM]).collect(),
            dep: vals.iter().map(|&v| [v * 0.5; DEP_DIM]).collect(),
            runs: [rt; BENCH_RUNS],
        }
    }

    fn identity_stats() -> FeatureStats {
        FeatureStats {
            inv_mean: vec![0.0; INV_DIM],
            inv_std: vec![1.0; INV_DIM],
            dep_mean: vec![0.0; DEP_DIM],
            dep_std: vec![1.0; DEP_DIM],
        }
    }

    #[test]
    fn gru_gradient_check_numeric() {
        let mut rng = Rng::new(4);
        let mut cell = GruCell::new(3, 2, &mut rng);
        let x = [0.4f32, -0.3, 0.8];
        let h0 = [0.1f32, -0.2];
        // loss = sum(h'); analytic
        let c = cell.step(&x, &h0);
        cell.backward(&c, &[1.0, 1.0]);
        let analytic_wx = cell.gwx.clone();
        let analytic_wh = cell.gwh.clone();
        let eps = 1e-3f32;
        for idx in [0usize, 5, 11] {
            let orig = cell.wx[idx];
            cell.wx[idx] = orig + eps;
            let lp: f32 = cell.step(&x, &h0).h.iter().sum();
            cell.wx[idx] = orig - eps;
            let lm: f32 = cell.step(&x, &h0).h.iter().sum();
            cell.wx[idx] = orig;
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - analytic_wx[idx]).abs() < 5e-3,
                "wx[{idx}]: numeric {numeric} vs analytic {}",
                analytic_wx[idx]
            );
        }
        for idx in [0usize, 3, 7] {
            let orig = cell.wh[idx];
            cell.wh[idx] = orig + eps;
            let lp: f32 = cell.step(&x, &h0).h.iter().sum();
            cell.wh[idx] = orig - eps;
            let lm: f32 = cell.step(&x, &h0).h.iter().sum();
            cell.wh[idx] = orig;
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - analytic_wh[idx]).abs() < 5e-3,
                "wh[{idx}]: numeric {numeric} vs analytic {}",
                analytic_wh[idx]
            );
        }
    }

    #[test]
    fn learns_to_separate_two_sequences() {
        let fast = toy_sample(&[0.1, 0.2, 0.1], 1e-4);
        let slow = toy_sample(&[0.9, 0.8, 0.9, 0.7], 1e-1);
        let ds = Dataset {
            samples: vec![fast.clone(), slow.clone(), fast, slow],
            stats: None,
        };
        let mut gru = BiGru::new(identity_stats(), 16, 7);
        gru.fit(&ds, &RnnTrainConfig { epochs: 60, ..Default::default() });
        let p_fast = gru.predict_sample(&ds.samples[0]);
        let p_slow = gru.predict_sample(&ds.samples[1]);
        assert!(
            p_fast < p_slow,
            "fast {p_fast} should predict below slow {p_slow}"
        );
    }

    #[test]
    fn variable_length_sequences_ok() {
        let mut gru = BiGru::new(identity_stats(), 8, 9);
        for len in [1usize, 2, 7, 20] {
            let s = toy_sample(&vec![0.3; len.max(2)], 1e-3);
            let p = gru.predict_sample(&s);
            assert!(p.is_finite() && p > 0.0, "len {len}: {p}");
        }
    }
}
