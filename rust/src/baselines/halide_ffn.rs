//! The Halide auto-scheduler performance model (Adams et al. 2019, paper
//! Fig 3), retrained on our dataset exactly as the paper does for its
//! comparison.
//!
//! Per stage: algorithm features → 32-d embedding, schedule features → 48-d
//! embedding; the stacked embedding passes through a hidden FC layer and a
//! head that emits coefficients for 27 hand-crafted terms derived from the
//! schedule features. The stage runtime is the coefficient·term dot product
//! and the pipeline runtime is the sum over stages.

use crate::baselines::nn::Linear;
use crate::constants::{DEP_DIM, FFN_TERMS, INV_DIM};
use crate::dataset::sample::{Dataset, GraphSample};
use crate::features::normalize::FeatureStats;
use crate::features::StageFeatures;
use crate::util::rng::Rng;

/// Indices into the raw dependent-feature vector whose `expm1` is used as a
/// hand-crafted term (they are `ln(1+x)`-squashed quantities: ideal
/// vector/scalar ns, DRAM-bound ns, loop/dispatch/fault overheads, op and
/// traffic totals, … — the same families Adams et al. hand-pick).
const TERM_IDX: [usize; FFN_TERMS] = [
    68, 69, 70, 71, 67, 77, 78, 55, // runtime estimates (ns-scale)
    18, 19, 20, 21, // vector/scalar op counts
    40, 41, 43, 79, // traffic totals
    49, 27, 34, 36, // points, iters, footprints
    52, 54, 22, 33, // alloc, faults, tasks, recompute flops
    51, 11, 58, // flops/pt, reduction, arithmetic intensity
];

/// Layer widths of the Adams et al. architecture — shared with the bundle
/// loader in `predictor` so saved models and this definition cannot drift.
pub const FFN_EMB_INV: usize = 32;
pub const FFN_EMB_DEP: usize = 48;
pub const FFN_CAT: usize = FFN_EMB_INV + FFN_EMB_DEP;
pub const FFN_HIDDEN: usize = 64;

/// Hand-crafted terms for one stage (seconds-ish scale).
pub fn stage_terms(dep_raw: &[f32; DEP_DIM]) -> [f32; FFN_TERMS] {
    let mut t = [0f32; FFN_TERMS];
    for (k, &idx) in TERM_IDX.iter().enumerate() {
        // undo ln(1+x); scale so coefficients are O(1)
        t[k] = (dep_raw[idx] as f64).exp_m1() as f32 * 1e-9;
    }
    t
}

pub struct HalideFfn {
    emb_inv: Linear,
    emb_dep: Linear,
    hidden: Linear,
    head: Linear,
    stats: FeatureStats,
}

#[derive(Debug, Clone)]
pub struct FfnTrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub batch: usize,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for FfnTrainConfig {
    fn default() -> Self {
        FfnTrainConfig {
            epochs: 30,
            lr: 0.01,
            weight_decay: 1e-4,
            batch: 32,
            seed: 17,
            verbose: false,
        }
    }
}

impl HalideFfn {
    pub fn new(stats: FeatureStats, seed: u64) -> HalideFfn {
        let mut rng = Rng::new(seed);
        HalideFfn {
            emb_inv: Linear::new(INV_DIM, FFN_EMB_INV, true, &mut rng),
            emb_dep: Linear::new(DEP_DIM, FFN_EMB_DEP, true, &mut rng),
            hidden: Linear::new(FFN_CAT, FFN_HIDDEN, true, &mut rng),
            head: Linear::new(FFN_HIDDEN, FFN_TERMS, false, &mut rng),
            stats,
        }
    }

    /// Forward for one sample: returns (ŷ seconds, per-stage terms) with
    /// layer activations cached for backward.
    fn forward_sample(&mut self, s: &GraphSample) -> (f64, Vec<[f32; FFN_TERMS]>) {
        let ns = s.n_stages as usize;
        let mut inv_in = Vec::with_capacity(ns * INV_DIM);
        let mut dep_in = Vec::with_capacity(ns * DEP_DIM);
        let mut terms = Vec::with_capacity(ns);
        for (iv, dv) in s.inv.iter().zip(&s.dep) {
            let mut f = StageFeatures { invariant: *iv, dependent: *dv };
            self.stats.apply(&mut f);
            inv_in.extend_from_slice(&f.invariant);
            dep_in.extend_from_slice(&f.dependent);
            terms.push(stage_terms(dv));
        }
        let ei = self.emb_inv.forward(&inv_in, ns);
        let ed = self.emb_dep.forward(&dep_in, ns);
        // stack embeddings per stage
        let mut cat = vec![0f32; ns * 80];
        for r in 0..ns {
            cat[r * 80..r * 80 + 32].copy_from_slice(&ei[r * 32..(r + 1) * 32]);
            cat[r * 80 + 32..(r + 1) * 80].copy_from_slice(&ed[r * 48..(r + 1) * 48]);
        }
        let h = self.hidden.forward(&cat, ns);
        let coeffs = self.head.forward(&h, ns);
        let mut y = 0f64;
        for r in 0..ns {
            let c = &coeffs[r * FFN_TERMS..(r + 1) * FFN_TERMS];
            for k in 0..FFN_TERMS {
                y += (c[k] * terms[r][k]) as f64;
            }
        }
        (y, terms)
    }

    /// Backward from dL/dŷ through the cached forward pass.
    fn backward_sample(&mut self, dy: f64, terms: &[[f32; FFN_TERMS]]) {
        let ns = terms.len();
        let mut dcoef = vec![0f32; ns * FFN_TERMS];
        for r in 0..ns {
            for k in 0..FFN_TERMS {
                dcoef[r * FFN_TERMS + k] = dy as f32 * terms[r][k];
            }
        }
        let dh = self.head.backward(&dcoef);
        let dcat = self.hidden.backward(&dh);
        let mut dei = vec![0f32; ns * 32];
        let mut ded = vec![0f32; ns * 48];
        for r in 0..ns {
            dei[r * 32..(r + 1) * 32].copy_from_slice(&dcat[r * 80..r * 80 + 32]);
            ded[r * 48..(r + 1) * 48].copy_from_slice(&dcat[r * 80 + 32..(r + 1) * 80]);
        }
        self.emb_inv.backward(&dei);
        self.emb_dep.backward(&ded);
    }

    fn step(&mut self, lr: f32, wd: f32) {
        self.emb_inv.step(lr, wd);
        self.emb_dep.step(lr, wd);
        self.hidden.step(lr, wd);
        self.head.step(lr, wd);
    }

    /// Train with the same ξ·α·β̂ loss the GCN uses.
    pub fn fit(&mut self, ds: &Dataset, cfg: &FfnTrainConfig) {
        let best = ds.best_per_pipeline();
        let mut rng = Rng::new(cfg.seed);
        let betas: Vec<f64> = ds
            .samples
            .iter()
            .map(|s| 1.0 / s.std_runtime().max(1e-9))
            .collect();
        let beta_mean = betas.iter().sum::<f64>() / betas.len().max(1) as f64;

        for epoch in 0..cfg.epochs {
            let mut order: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut order);
            let mut epoch_loss = 0f64;
            for (bi, chunk) in order.chunks(cfg.batch).enumerate() {
                for &i in chunk {
                    let s = &ds.samples[i];
                    let y_true = s.mean_runtime();
                    let (y_pred, terms) = self.forward_sample(s);
                    let alpha = (best[&s.pipeline_id] / y_true).clamp(0.0, 1.0);
                    let beta = (betas[i] / beta_mean).clamp(0.2, 5.0);
                    let w = alpha * beta;
                    let ratio = y_pred / y_true - 1.0;
                    epoch_loss += w * ratio.abs();
                    // d|r|/dŷ = sign(r)/ȳ ; clip for stability
                    let dy = (w * ratio.signum() / y_true).clamp(-1e7, 1e7);
                    self.backward_sample(dy, &terms);
                }
                self.step(cfg.lr, cfg.weight_decay);
                let _ = bi;
            }
            if cfg.verbose {
                eprintln!(
                    "ffn epoch {epoch:>3} loss {:.4}",
                    epoch_loss / ds.len() as f64
                );
            }
        }
    }

    pub fn predict_sample(&mut self, s: &GraphSample) -> f64 {
        self.forward_sample(s).0.max(1e-9)
    }

    /// The four layers in forward order (inv/dep embeddings, hidden, head)
    /// — for bundle serialization by `predictor::FfnPredictor`.
    pub fn linears(&self) -> [&Linear; 4] {
        [&self.emb_inv, &self.emb_dep, &self.hidden, &self.head]
    }

    /// Rebuild from deserialized layers (same order as [`Self::linears`]).
    pub fn from_linears(stats: FeatureStats, linears: [Linear; 4]) -> HalideFfn {
        let [emb_inv, emb_dep, hidden, head] = linears;
        HalideFfn { emb_inv, emb_dep, hidden, head, stats }
    }

    pub fn stats(&self) -> &FeatureStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    fn tiny_ds() -> Dataset {
        build_dataset(&DataGenConfig {
            n_pipelines: 8,
            schedules_per_pipeline: 8,
            seed: 19,
            ..Default::default()
        })
    }

    #[test]
    fn terms_are_finite_and_nonnegative() {
        let ds = tiny_ds();
        for s in &ds.samples {
            for dv in &s.dep {
                let t = stage_terms(dv);
                assert!(t.iter().all(|v| v.is_finite() && *v >= 0.0));
            }
        }
    }

    #[test]
    fn training_reduces_loss() {
        let ds = tiny_ds();
        let stats = ds.stats.clone().unwrap();
        let mut ffn = HalideFfn::new(stats, 23);
        let mape_before = eval_mape(&mut ffn, &ds);
        ffn.fit(&ds, &FfnTrainConfig { epochs: 20, ..Default::default() });
        let mape_after = eval_mape(&mut ffn, &ds);
        assert!(
            mape_after < mape_before,
            "before {mape_before:.1}% after {mape_after:.1}%"
        );
    }

    fn eval_mape(ffn: &mut HalideFfn, ds: &Dataset) -> f64 {
        let preds: Vec<f64> = ds.samples.iter().map(|s| ffn.predict_sample(s)).collect();
        let truth: Vec<f64> = ds.samples.iter().map(|s| s.mean_runtime()).collect();
        crate::util::stats::mape(&truth, &preds)
    }

    #[test]
    fn predictions_positive() {
        let ds = tiny_ds();
        let stats = ds.stats.clone().unwrap();
        let mut ffn = HalideFfn::new(stats, 29);
        ffn.fit(&ds, &FfnTrainConfig { epochs: 3, ..Default::default() });
        for s in &ds.samples {
            assert!(ffn.predict_sample(s) > 0.0);
        }
    }
}
