//! Minimal dense-layer neural network with manual backprop and Adagrad —
//! just enough to implement the Halide FFN baseline without external crates.

use crate::util::rng::Rng;

/// Fully connected layer y = relu?(xW + b) with stored activations for
/// backprop. Row-major W: [in, out].
pub struct Linear {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub n_in: usize,
    pub n_out: usize,
    pub relu: bool,
    // adagrad state
    gw2: Vec<f32>,
    gb2: Vec<f32>,
    // cached forward pass (batch)
    last_x: Vec<f32>,
    last_y: Vec<f32>,
    last_batch: usize,
    // accumulated grads
    gw: Vec<f32>,
    gb: Vec<f32>,
}

impl Linear {
    pub fn new(n_in: usize, n_out: usize, relu: bool, rng: &mut Rng) -> Linear {
        assert!(n_out <= 512, "Linear supports n_out <= 512");
        let std = (2.0 / n_in as f64).sqrt();
        Linear {
            w: (0..n_in * n_out).map(|_| (rng.normal() * std) as f32).collect(),
            b: vec![0.0; n_out],
            n_in,
            n_out,
            relu,
            gw2: vec![0.0; n_in * n_out],
            gb2: vec![0.0; n_out],
            last_x: vec![],
            last_y: vec![],
            last_batch: 0,
            gw: vec![0.0; n_in * n_out],
            gb: vec![0.0; n_out],
        }
    }

    /// Forward for a batch of rows; caches inputs/outputs for backward.
    pub fn forward(&mut self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.n_in);
        let mut y = vec![0f32; batch * self.n_out];
        for r in 0..batch {
            let xr = &x[r * self.n_in..(r + 1) * self.n_in];
            let yr = &mut y[r * self.n_out..(r + 1) * self.n_out];
            yr.copy_from_slice(&self.b);
            for (i, &xi) in xr.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let wrow = &self.w[i * self.n_out..(i + 1) * self.n_out];
                for (j, &wij) in wrow.iter().enumerate() {
                    yr[j] += xi * wij;
                }
            }
            if self.relu {
                for v in yr.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
        }
        self.last_x = x.to_vec();
        self.last_y = y.clone();
        self.last_batch = batch;
        y
    }

    /// Backward: takes dL/dy, accumulates param grads, returns dL/dx.
    pub fn backward(&mut self, dy: &[f32]) -> Vec<f32> {
        let batch = self.last_batch;
        assert_eq!(dy.len(), batch * self.n_out);
        let mut dx = vec![0f32; batch * self.n_in];
        for r in 0..batch {
            let xr = &self.last_x[r * self.n_in..(r + 1) * self.n_in];
            let yr = &self.last_y[r * self.n_out..(r + 1) * self.n_out];
            let dyr = &dy[r * self.n_out..(r + 1) * self.n_out];
            // relu mask
            let mut g = [0f32; 512];
            let g = &mut g[..self.n_out];
            for j in 0..self.n_out {
                g[j] = if self.relu && yr[j] <= 0.0 { 0.0 } else { dyr[j] };
                self.gb[j] += g[j];
            }
            let dxr = &mut dx[r * self.n_in..(r + 1) * self.n_in];
            for i in 0..self.n_in {
                let wrow = &self.w[i * self.n_out..(i + 1) * self.n_out];
                let gwrow = &mut self.gw[i * self.n_out..(i + 1) * self.n_out];
                let xi = xr[i];
                let mut acc = 0f32;
                for j in 0..self.n_out {
                    acc += g[j] * wrow[j];
                    gwrow[j] += g[j] * xi;
                }
                dxr[i] = acc;
            }
        }
        dx
    }

    /// Adagrad update with the accumulated grads, then clears them.
    pub fn step(&mut self, lr: f32, weight_decay: f32) {
        for i in 0..self.w.len() {
            let g = self.gw[i] + weight_decay * self.w[i];
            self.gw2[i] += g * g;
            self.w[i] -= lr * g / (self.gw2[i].sqrt() + 1e-10);
            self.gw[i] = 0.0;
        }
        for j in 0..self.b.len() {
            let g = self.gb[j];
            self.gb2[j] += g * g;
            self.b[j] -= lr * g / (self.gb2[j].sqrt() + 1e-10);
            self.gb[j] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_matches_manual() {
        let mut rng = Rng::new(1);
        let mut l = Linear::new(2, 2, false, &mut rng);
        l.w = vec![1.0, 2.0, 3.0, 4.0]; // rows: in0 -> [1,2], in1 -> [3,4]
        l.b = vec![0.5, -0.5];
        let y = l.forward(&[1.0, 1.0], 1);
        assert_eq!(y, vec![1.0 + 3.0 + 0.5, 2.0 + 4.0 - 0.5]);
    }

    #[test]
    fn gradient_check_numeric() {
        let mut rng = Rng::new(2);
        let mut l = Linear::new(3, 2, true, &mut rng);
        let x = [0.3f32, -0.2, 0.9, 0.1, 0.5, -0.7];
        // loss = sum(y); analytic grad via backward with dy = 1
        let _ = l.forward(&x, 2);
        let _dx = l.backward(&[1.0; 4]);
        let analytic = l.gw.clone();
        // numeric
        let eps = 1e-3f32;
        for idx in [0usize, 2, 5] {
            let orig = l.w[idx];
            l.w[idx] = orig + eps;
            let lp: f32 = l.forward(&x, 2).iter().sum();
            l.w[idx] = orig - eps;
            let lm: f32 = l.forward(&x, 2).iter().sum();
            l.w[idx] = orig;
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - analytic[idx]).abs() < 1e-2,
                "idx {idx}: numeric {numeric} vs analytic {}",
                analytic[idx]
            );
        }
    }

    #[test]
    fn learns_linear_map() {
        let mut rng = Rng::new(3);
        let mut l = Linear::new(1, 1, false, &mut rng);
        // fit y = 3x (Adagrad's 1/√t step decay needs a generous budget)
        for _ in 0..4000 {
            let x = rng.f32() * 2.0 - 1.0;
            let y = l.forward(&[x], 1)[0];
            let target = 3.0 * x;
            let dy = 2.0 * (y - target);
            l.backward(&[dy]);
            l.step(0.3, 0.0);
        }
        let pred = l.forward(&[0.5], 1)[0];
        assert!((pred - 1.5).abs() < 0.15, "pred {pred}");
    }
}
