//! Random ONNX-style model generation — Algorithm 1 of the paper.
//!
//! Models are built stage-layer by stage-layer; each node samples a type
//! (unary / binary / ternary) and an operation from per-type categorical
//! distributions, then wires itself to compatible tensors from the previous
//! layer. Candidate models pass the paper's filters: ≤ 1 output (mostly),
//! depth ≥ 5 and presence of favored operators (conv / relu / …).

pub mod generator;

pub use generator::{generate_model, GenConfig};
