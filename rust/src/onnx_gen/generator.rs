//! BUILD_RANDOM_ONNX_MODEL / BUILD_NEW_STAGE / BUILD_RANDOM_NODE
//! (Algorithm 1, §III-A).

use crate::ir::op::{Op, OpAttrs, OpKind};
use crate::ir::pipeline::{Pipeline, SourceRef};
use crate::util::rng::Rng;

/// Generator configuration; defaults follow §III-A.
#[derive(Debug, Clone)]
pub struct GenConfig {
    pub min_inputs: usize,
    pub max_inputs: usize,
    /// Stage *layers* (Algorithm 1 `num_stages`).
    pub min_layers: usize,
    pub max_layers: usize,
    /// Nodes per layer (Algorithm 1 `width`).
    pub min_width: usize,
    pub max_width: usize,
    /// Paper: `depth_thresh = 5`.
    pub depth_thresh: usize,
    /// Paper: discard *most* graphs with more than `output_thresh` outputs.
    pub output_thresh: usize,
    /// Probability of keeping a model that violates the output filter.
    pub multi_output_keep_prob: f64,
    /// Probability of keeping a model with no favored ops.
    pub unfavored_keep_prob: f64,
    /// Reject stages whose output exceeds this many elements.
    pub max_stage_elems: usize,
    /// Hard cap on total stages. A generation knob, not a model limit:
    /// the sparse packed-batch engine handles any graph size (raise
    /// `max_layers`/`max_width` along with this to actually generate
    /// deeper models — see the `deep_configs_generate_past_the_old_cap`
    /// test). The default stays at `constants::MAX_NODES` only so that
    /// default-generated datasets remain consumable by the fixed-shape
    /// pjrt artifacts; the native engine does not care.
    pub max_total_stages: usize,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            min_inputs: 1,
            max_inputs: 3,
            min_layers: 4,
            max_layers: 12,
            min_width: 1,
            max_width: 4,
            depth_thresh: 5,
            output_thresh: 1,
            multi_output_keep_prob: 0.05,
            unfavored_keep_prob: 0.1,
            max_stage_elems: 16 << 20, // 64 MiB f32
            max_total_stages: crate::constants::MAX_NODES,
        }
    }
}

/// Unary op distribution (Algorithm 1 line 35: pad, pool, softmax, …).
const UNARY_OPS: &[(OpKind, f64)] = &[
    (OpKind::Relu, 8.0),
    (OpKind::Sigmoid, 2.0),
    (OpKind::Tanh, 1.5),
    (OpKind::LeakyRelu, 1.5),
    (OpKind::Elu, 0.7),
    (OpKind::Gelu, 1.0),
    (OpKind::HardSwish, 0.7),
    (OpKind::Softplus, 0.5),
    (OpKind::Erf, 0.3),
    (OpKind::Exp, 0.7),
    (OpKind::Log, 0.5),
    (OpKind::Sqrt, 0.5),
    (OpKind::Reciprocal, 0.3),
    (OpKind::Abs, 0.5),
    (OpKind::Neg, 0.4),
    (OpKind::Clip, 0.8),
    (OpKind::Floor, 0.2),
    (OpKind::Ceil, 0.2),
    (OpKind::Round, 0.2),
    (OpKind::Sign, 0.2),
    (OpKind::Not, 0.1),
    (OpKind::MaxPool, 3.0),
    (OpKind::AveragePool, 2.0),
    (OpKind::GlobalAveragePool, 1.0),
    (OpKind::ReduceMean, 0.7),
    (OpKind::ReduceSum, 0.7),
    (OpKind::ReduceMax, 0.5),
    (OpKind::Softmax, 1.5),
    (OpKind::LogSoftmax, 0.4),
    (OpKind::Pad, 0.8),
    (OpKind::Slice, 0.6),
    (OpKind::Transpose, 0.6),
    (OpKind::Flatten, 0.8),
    (OpKind::Upsample, 0.7),
    (OpKind::Identity, 0.3),
    // weight-bearing "unary" graph ops (weights are implicit params)
    (OpKind::Conv2d, 10.0),
    (OpKind::DepthwiseConv2d, 2.5),
    (OpKind::Gemm, 4.0),
    (OpKind::BatchNorm, 4.0),
    (OpKind::LayerNorm, 1.0),
    (OpKind::InstanceNorm, 0.5),
];

/// Binary op distribution (Algorithm 1 line 38).
const BINARY_OPS: &[(OpKind, f64)] = &[
    (OpKind::Add, 8.0),
    (OpKind::Sub, 1.5),
    (OpKind::Mul, 3.0),
    (OpKind::Div, 0.8),
    (OpKind::Pow, 0.3),
    (OpKind::Min, 0.6),
    (OpKind::Max, 0.6),
    (OpKind::PRelu, 0.8),
    (OpKind::And, 0.2),
    (OpKind::Or, 0.2),
    (OpKind::Xor, 0.1),
    (OpKind::Greater, 0.3),
    (OpKind::Less, 0.3),
    (OpKind::Equal, 0.2),
    (OpKind::Concat, 2.0),
    (OpKind::MatMul, 1.5),
];

fn sample_from(table: &[(OpKind, f64)], rng: &mut Rng) -> OpKind {
    let weights: Vec<f64> = table.iter().map(|(_, w)| *w).collect();
    table[rng.categorical(&weights)].0
}

fn sample_attrs(kind: OpKind, in_shape: &[usize], rng: &mut Rng) -> OpAttrs {
    let mut a = OpAttrs::default();
    match kind {
        OpKind::Conv2d | OpKind::DepthwiseConv2d => {
            let k = *rng.choice(&[1usize, 3, 3, 3, 5, 7]);
            a.kernel = (k, k);
            a.pad = if rng.chance(0.8) { k / 2 } else { 0 };
            a.stride = if rng.chance(0.25) { 2 } else { 1 };
            a.out_channels = *rng.choice(&[8usize, 16, 24, 32, 48, 64, 96, 128]);
        }
        OpKind::MaxPool | OpKind::AveragePool => {
            let k = *rng.choice(&[2usize, 2, 3]);
            a.kernel = (k, k);
            a.stride = if rng.chance(0.8) { k } else { 1 };
            a.pad = 0;
        }
        OpKind::Gemm => {
            a.out_channels = *rng.choice(&[16usize, 32, 64, 128, 256, 512, 1024]);
        }
        OpKind::ReduceMean | OpKind::ReduceSum | OpKind::ReduceMax => {
            a.axis = rng.gen_range(in_shape.len().max(1));
            a.keepdims = rng.chance(0.6);
        }
        OpKind::Softmax | OpKind::LogSoftmax => {
            a.axis = in_shape.len().saturating_sub(1);
        }
        OpKind::Concat => {
            a.axis = if in_shape.len() >= 2 { 1 } else { 0 };
        }
        OpKind::Pad => {
            a.pad = rng.gen_range_incl(1, 3);
        }
        OpKind::Slice => {
            a.axis = rng.gen_range(in_shape.len().max(1));
            a.slice_frac = (1, 2);
        }
        OpKind::Transpose => {
            let mut perm: Vec<usize> = (0..in_shape.len()).collect();
            rng.shuffle(&mut perm);
            a.perm = perm;
        }
        OpKind::Flatten => {
            a.axis = 1;
        }
        OpKind::Upsample => {
            a.scale = 2;
        }
        OpKind::Reshape => {
            // collapse to 2D preserving numel
            let n: usize = in_shape.iter().product();
            let d = *rng.choice(&[2usize, 4, 8]);
            if n % d == 0 {
                a.target_shape = vec![d, n / d];
            } else {
                a.target_shape = vec![n];
            }
        }
        _ => {}
    }
    a
}

/// BUILD_RANDOM_NODE: sample a node and wire it to compatible tensors from
/// `avail`. Returns the added stage's SourceRef, or `None` after `tries`
/// failed attempts.
fn build_random_node(
    p: &mut Pipeline,
    avail: &[SourceRef],
    cfg: &GenConfig,
    rng: &mut Rng,
    node_idx: usize,
) -> Option<SourceRef> {
    for _try in 0..12 {
        let is_binary = rng.chance(0.3);
        let kind = if is_binary {
            sample_from(BINARY_OPS, rng)
        } else {
            sample_from(UNARY_OPS, rng)
        };
        let arity = kind.graph_arity();
        if arity > avail.len() {
            continue;
        }
        // pick operands (first uniformly; rest searched for compatibility)
        let first = *rng.choice(avail);
        let first_shape = p.shape_of(first).to_vec();
        let attrs = sample_attrs(kind, &first_shape, rng);
        let op = Op::with_attrs(kind, attrs);

        let mut operands = vec![first];
        let mut shapes: Vec<Vec<usize>> = vec![first_shape];
        let mut ok = true;
        for _ in 1..arity {
            // search available tensors for one that type-checks
            let mut cand_order = rng.sample_indices(avail.len(), avail.len());
            let mut found = None;
            for ci in cand_order.drain(..) {
                let cand = avail[ci];
                let mut test_shapes: Vec<&[usize]> = shapes.iter().map(|s| s.as_slice()).collect();
                let cand_shape = p.shape_of(cand).to_vec();
                test_shapes.push(&cand_shape);
                // pad remaining operand slots with the candidate to test
                while test_shapes.len() < arity {
                    test_shapes.push(&cand_shape);
                }
                if op.infer_shape(&test_shapes).is_some() {
                    found = Some((cand, cand_shape));
                    break;
                }
            }
            match found {
                Some((cand, cs)) => {
                    operands.push(cand);
                    shapes.push(cs);
                }
                None => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        // fill ternary (Where) remaining slot by reusing an operand
        while operands.len() < arity {
            operands.push(operands[operands.len() - 1]);
        }
        let shape_refs: Vec<&[usize]> = operands.iter().map(|&s| p.shape_of(s)).collect();
        if let Some(out) = op.infer_shape(&shape_refs) {
            if out.iter().product::<usize>() > cfg.max_stage_elems || out.iter().any(|&d| d == 0) {
                continue;
            }
            let name = format!("n{}_{}", node_idx, kind.name().to_lowercase());
            return p.add_stage(&name, op, operands).ok();
        }
    }
    None
}

/// BUILD_RANDOM_ONNX_MODEL: one attempt. Returns `None` when a filter
/// rejects the model (callers loop; see [`generate_model`]).
fn build_random_model(cfg: &GenConfig, rng: &mut Rng, name: &str) -> Option<Pipeline> {
    let mut p = Pipeline::new(name);

    // line 3-4: inputs
    let num_inputs = rng.gen_range_incl(cfg.min_inputs, cfg.max_inputs);
    let mut input_stage: Vec<SourceRef> = Vec::new();
    for _ in 0..num_inputs {
        let shape = match rng.gen_range(3) {
            0 => {
                // rank-4 NCHW feature map
                let c = *rng.choice(&[3usize, 8, 16, 24, 32]);
                let hw = *rng.choice(&[14usize, 28, 32, 56, 64, 112, 128, 224]);
                vec![1, c, hw, hw]
            }
            1 => {
                // rank-2 matrix
                let r = *rng.choice(&[16usize, 32, 64, 128, 256]);
                let c = *rng.choice(&[64usize, 128, 256, 512, 1024]);
                vec![r, c]
            }
            _ => {
                // rank-3 sequence
                let b = *rng.choice(&[1usize, 4, 8]);
                let t = *rng.choice(&[32usize, 64, 128, 256]);
                let d = *rng.choice(&[64usize, 128, 256]);
                vec![b, t, d]
            }
        };
        input_stage.push(p.add_input(shape));
    }

    // line 5-9: stages layer by layer
    let num_layers = rng.gen_range_incl(cfg.min_layers, cfg.max_layers);
    for _layer in 0..num_layers {
        if p.num_stages() >= cfg.max_total_stages {
            break;
        }
        let width = rng
            .gen_range_incl(cfg.min_width, cfg.max_width)
            .min(cfg.max_total_stages - p.num_stages());
        let mut new_stage: Vec<SourceRef> = Vec::new();
        let mut used: Vec<SourceRef> = Vec::new();
        for w in 0..width {
            let node_idx = p.num_stages() + w;
            if let Some(node) = build_random_node(&mut p, &input_stage, cfg, rng, node_idx) {
                // remember which tensors got consumed
                if let SourceRef::Stage(id) = node {
                    used.extend(p.stages[id].inputs.iter().copied());
                }
                new_stage.push(node);
            }
        }
        if new_stage.is_empty() {
            return None; // dead end
        }
        // line 27: carry over unused tensors so later layers can still read
        // them (skip connections)
        for &t in &input_stage {
            if !used.contains(&t) && rng.chance(0.5) {
                new_stage.push(t);
            }
        }
        input_stage = new_stage;
    }

    // --- filters (lines 10-20)
    if p.num_stages() < 2 || p.num_stages() > cfg.max_total_stages {
        return None;
    }
    let outputs = p.outputs();
    if outputs.len() > cfg.output_thresh && !rng.chance(cfg.multi_output_keep_prob) {
        return None;
    }
    if p.depth() < cfg.depth_thresh {
        return None;
    }
    let has_favored = p.stages.iter().any(|s| s.op.kind.is_favored());
    if !has_favored && !rng.chance(cfg.unfavored_keep_prob) {
        return None;
    }
    debug_assert!(p.validate().is_ok(), "{:?}", p.validate());
    Some(p)
}

/// Generate one valid random model (retrying internally until the filters
/// pass — the paper's generator likewise loops until a model is accepted).
pub fn generate_model(cfg: &GenConfig, rng: &mut Rng, id: usize) -> Pipeline {
    for attempt in 0.. {
        let name = format!("rand_{id}");
        if let Some(p) = build_random_model(cfg, rng, &name) {
            return p;
        }
        assert!(attempt < 10_000, "generator failed to produce a valid model");
    }
    unreachable!()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn generates_valid_filtered_models() {
        let cfg = GenConfig::default();
        let mut rng = Rng::new(1);
        for i in 0..20 {
            let p = generate_model(&cfg, &mut rng, i);
            p.validate().unwrap();
            assert!(p.depth() >= cfg.depth_thresh, "depth {}", p.depth());
            assert!(p.num_stages() <= cfg.max_total_stages);
            assert!(p.num_stages() >= 2);
        }
    }

    #[test]
    fn prop_generated_models_structurally_sound() {
        propcheck::check_rng("onnx_gen sound", 0xDEAD, 24, |rng| {
            let cfg = GenConfig::default();
            let p = generate_model(&cfg, rng, 0);
            p.validate().map_err(|e| e)?;
            // every stage's buffers bounded
            for s in &p.stages {
                let elems: usize = s.shape.iter().product();
                if elems > cfg.max_stage_elems {
                    return Err(format!("stage {} too big: {elems}", s.id));
                }
                if elems == 0 {
                    return Err(format!("stage {} empty shape {:?}", s.id, s.shape));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deep_configs_generate_past_the_old_cap() {
        // max_total_stages is a knob, not a 48-stage model limit: a deep
        // config must be able to produce graphs the old dense layout
        // could not represent
        let cfg = GenConfig {
            min_layers: 24,
            max_layers: 32,
            min_width: 2,
            max_width: 4,
            max_total_stages: 128,
            ..GenConfig::default()
        };
        let mut rng = Rng::new(13);
        let deepest = (0..8)
            .map(|i| generate_model(&cfg, &mut rng, i).num_stages())
            .max()
            .unwrap();
        assert!(
            deepest > crate::constants::MAX_NODES,
            "deep config topped out at {deepest} stages"
        );
    }

    #[test]
    fn determinism_per_seed() {
        let cfg = GenConfig::default();
        let a = generate_model(&cfg, &mut Rng::new(99), 0);
        let b = generate_model(&cfg, &mut Rng::new(99), 0);
        assert_eq!(a.num_stages(), b.num_stages());
        for (x, y) in a.stages.iter().zip(&b.stages) {
            assert_eq!(x.op.kind, y.op.kind);
            assert_eq!(x.shape, y.shape);
        }
    }

    #[test]
    fn models_are_diverse() {
        let cfg = GenConfig::default();
        let mut rng = Rng::new(7);
        let mut sizes = std::collections::HashSet::new();
        let mut kinds = std::collections::HashSet::new();
        for i in 0..30 {
            let p = generate_model(&cfg, &mut rng, i);
            sizes.insert(p.num_stages());
            for s in &p.stages {
                kinds.insert(s.op.kind);
            }
        }
        assert!(sizes.len() >= 5, "stage-count diversity {sizes:?}");
        assert!(kinds.len() >= 15, "op diversity: {} kinds", kinds.len());
    }

    #[test]
    fn favored_ops_mostly_present() {
        let cfg = GenConfig::default();
        let mut rng = Rng::new(3);
        let favored = (0..30)
            .filter(|i| {
                generate_model(&cfg, &mut rng, *i)
                    .stages
                    .iter()
                    .any(|s| s.op.kind.is_favored())
            })
            .count();
        assert!(favored >= 25, "{favored}/30 favored");
    }
}
