//! # gcn-perf
//!
//! Reproduction of *"Using Graph Neural Networks to model the performance of
//! Deep Neural Networks"* (Singh, Hegarty, Leather, Steiner, 2021).
//!
//! The crate contains the full stack described in DESIGN.md:
//!
//! * a Halide-like compiler substrate: pipeline IR ([`ir`]), random ONNX-style
//!   model generator ([`onnx_gen`]), op → loop-nest lowering ([`lower`]) and
//!   scheduling primitives ([`schedule`]);
//! * a multi-pass static analyzer ([`analysis`]): a diagnostics engine with
//!   stable codes, pipeline/schedule/data verification passes, and the
//!   precomputed [`analysis::AnalyzedPipeline`] legality fast path used by
//!   the autotuner and the `gcn-perf analyze` subcommand;
//! * a simulated 18-core Xeon benchmarking machine ([`sim`]) standing in for
//!   the paper's hardware testbed;
//! * the §II-C featurization ([`features`]) and dataset pipeline ([`dataset`]);
//! * the GCN execution backends behind the [`runtime::Backend`] trait —
//!   the default pure-Rust sparse engine (CSR adjacency, block-diagonal
//!   packed batches, no graph-size caps), the dense padded reference,
//!   and, behind the `pjrt` cargo feature, the PJRT path for the
//!   AOT-compiled JAX/Pallas artifacts ([`runtime`]) — plus the training
//!   driver ([`train`]) and graph batching ([`model`]);
//! * the crate's one prediction API ([`predictor`]): the object-safe,
//!   thread-safe [`predictor::Predictor`] trait, the
//!   [`predictor::GcnPredictor`] session with single-file model bundles,
//!   adapters for every baseline, a name registry, the concurrent
//!   coalescing [`predictor::PredictService`] serving layer (bounded
//!   queue, shared memo cache, `gcn-perf serve` daemon) and the
//!   [`predictor::PredictorCost`] search bridge riding it;
//! * the network serving front-end ([`net`]): the newline-framed wire
//!   protocol, a multi-client TCP server with admission control and
//!   graceful drain (`gcn-perf serve --listen`), and the concurrent
//!   load generator (`gcn-perf loadgen`) that verifies served
//!   predictions bitwise against direct calls;
//! * the comparison models from the paper's evaluation ([`baselines`]): the
//!   Halide feed-forward model and a TVM-style gradient-boosted-tree model;
//! * the evaluation harnesses for Fig 8 and Fig 9 plus the
//!   dense-vs-sparse perf bench ([`eval`]), the real-world networks
//!   ([`zoo`]) and the beam-search auto-scheduler ([`search`]);
//! * the fleet autotuner ([`autotune`]): resumable search strategies
//!   (beam + seeded evolutionary) tuning many pipelines concurrently
//!   through one shared service, with per-pipeline checkpoints and
//!   search-trace harvesting into the dataset format (`gcn-perf
//!   autotune`);
//! * dependency-free infrastructure ([`util`]): PRNG, thread pool, JSON,
//!   stats, CLI parsing, bench + property-test harnesses.

// Stylistic clippy lints this numeric, dependency-free codebase opts out
// of wholesale: index-heavy kernel loops and wide explicit signatures are
// the local idiom, and `Json::to_string` predates the lint.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::inherent_to_string,
    clippy::comparison_chain,
    clippy::manual_range_contains
)]

pub mod util;
pub mod ir;

// Count every heap allocation (one relaxed atomic add over `System`) so
// the workspace tests can pin the inference fast path's steady-state
// allocation budget. Installed only in this crate's own test harness —
// the `gcn-perf` binary installs the same allocator in `main.rs` for
// `bench --engine` — so library embedders keep their own choice of
// global allocator. See `util::alloc_count`.
#[cfg(test)]
#[global_allocator]
static GLOBAL_ALLOC: util::alloc_count::CountingAlloc = util::alloc_count::CountingAlloc;

pub mod onnx_gen;
pub mod lower;
pub mod schedule;
pub mod analysis;
pub mod sim;
pub mod features;
pub mod dataset;
pub mod model;
pub mod runtime;
pub mod predictor;
pub mod net;
pub mod train;
pub mod baselines;
pub mod eval;
pub mod zoo;
pub mod search;
pub mod autotune;
pub mod constants;

// Shared test fixtures (JAX-pinned parity tensors, synthetic samples) —
// test builds only, used by the model and runtime test suites alike.
#[cfg(test)]
pub(crate) mod testfix;
