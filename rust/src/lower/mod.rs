//! Lowering: each pipeline stage becomes a [`LoopNest`] — the spatial loops
//! over its output domain, an optional reduction domain, a per-point work
//! profile and the buffer access patterns. This is the representation the
//! scheduler transforms, the simulator costs, and the featurizer reads.

pub mod loopnest;

pub use loopnest::{lower_pipeline, lower_stage, Access, AccessPattern, LoopNest, WorkProfile};
