//! Stage → loop-nest lowering.
//!
//! A stage computing an output of shape `[d0, .., dk]` lowers to a perfect
//! loop nest over those dims (outermost..innermost, innermost = last dim =
//! contiguous in memory), an optional reduction domain (Halide `RDom`), a
//! per-output-point [`WorkProfile`] and one [`Access`] per operand buffer
//! (graph operands *and* implicit weight buffers).

use crate::ir::op::{OpCategory, OpKind};
use crate::ir::pipeline::{Pipeline, SourceRef, Stage};
use crate::ir::tensor::numel;

/// Arithmetic performed per output point (after reduction-loop expansion:
/// counts are totals per output element, i.e. already multiplied by the
/// reduction extent where applicable).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WorkProfile {
    /// f32 multiplies (fused into FMAs by the machine model when paired).
    pub fmul: f64,
    /// f32 adds/subs.
    pub fadd: f64,
    /// f32 divides (long-latency).
    pub fdiv: f64,
    /// Transcendentals (exp/log/tanh/erf/...), ~20 cycles each scalar.
    pub transcendental: f64,
    /// Integer ops (indexing arithmetic).
    pub int_ops: f64,
    /// Boolean/logical ops.
    pub bool_ops: f64,
    /// Comparisons / select.
    pub cmp_ops: f64,
}

impl WorkProfile {
    pub fn total_flops(&self) -> f64 {
        self.fmul + self.fadd + self.fdiv + self.transcendental
    }
    pub fn scale(&self, k: f64) -> WorkProfile {
        WorkProfile {
            fmul: self.fmul * k,
            fadd: self.fadd * k,
            fdiv: self.fdiv * k,
            transcendental: self.transcendental * k,
            int_ops: self.int_ops * k,
            bool_ops: self.bool_ops * k,
            cmp_ops: self.cmp_ops * k,
        }
    }
    pub fn add(&self, o: &WorkProfile) -> WorkProfile {
        WorkProfile {
            fmul: self.fmul + o.fmul,
            fadd: self.fadd + o.fadd,
            fdiv: self.fdiv + o.fdiv,
            transcendental: self.transcendental + o.transcendental,
            int_ops: self.int_ops + o.int_ops,
            bool_ops: self.bool_ops + o.bool_ops,
            cmp_ops: self.cmp_ops + o.cmp_ops,
        }
    }
}

/// How a buffer is traversed relative to the stage's loop nest (§II-C.1:
/// "access patterns like striding behavior, transposed access, broadcasts").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessPattern {
    /// Innermost loop walks unit stride.
    Contiguous,
    /// Innermost loop walks a fixed non-unit stride (elements).
    Strided(usize),
    /// Dimension order inverted vs storage (worst locality).
    Transposed,
    /// Operand dim of size 1 broadcast across a loop (high reuse).
    Broadcast,
    /// Stencil window (conv/pool): overlapping reads with halo reuse.
    Stencil,
}

/// One operand buffer read by the stage.
#[derive(Debug, Clone)]
pub struct Access {
    /// Graph source, or `None` for an implicit weight/parameter buffer.
    pub source: Option<SourceRef>,
    /// Total unique bytes in the accessed region.
    pub footprint_bytes: f64,
    /// Bytes *read* per output point (counting reduction-loop re-reads).
    pub bytes_per_point: f64,
    pub pattern: AccessPattern,
}

/// The lowered form of one stage.
#[derive(Debug, Clone)]
pub struct LoopNest {
    pub stage_id: usize,
    /// Spatial loop extents, outermost first (= output shape).
    pub spatial: Vec<usize>,
    /// Reduction loop extents (innermost of the nest).
    pub reduction: Vec<usize>,
    /// Work per output point (totals incl. reduction).
    pub work: WorkProfile,
    pub accesses: Vec<Access>,
    /// Bytes written to the stage's output buffer.
    pub out_bytes: f64,
    /// True when the op is a pure element-wise map (inlinable in Halide
    /// without introducing a reduction into the consumer).
    pub pointwise: bool,
}

impl LoopNest {
    /// Number of output points.
    pub fn points(&self) -> f64 {
        self.spatial.iter().product::<usize>() as f64
    }
    /// Reduction trip count (1 when no reduction).
    pub fn red_extent(&self) -> f64 {
        self.reduction.iter().product::<usize>().max(1) as f64
    }
    /// Total floating-point operations for the whole stage.
    pub fn total_flops(&self) -> f64 {
        self.points() * self.work.total_flops()
    }
    /// Total bytes read across operands.
    pub fn total_read_bytes(&self) -> f64 {
        self.accesses.iter().map(|a| a.bytes_per_point).sum::<f64>() * self.points()
    }
}

fn unit_work(kind: OpKind) -> WorkProfile {
    use OpKind::*;
    let mut w = WorkProfile::default();
    match kind {
        Relu => w.cmp_ops = 1.0,
        LeakyRelu | PRelu => {
            w.cmp_ops = 1.0;
            w.fmul = 1.0;
        }
        Elu | Softplus => {
            w.transcendental = 1.0;
            w.fadd = 1.0;
        }
        Sigmoid | Tanh => {
            w.transcendental = 1.0;
            w.fdiv = 1.0;
        }
        Gelu | Erf => {
            w.transcendental = 1.0;
            w.fmul = 2.0;
            w.fadd = 1.0;
        }
        HardSwish => {
            w.cmp_ops = 2.0;
            w.fmul = 2.0;
        }
        Exp | Log | Sqrt => w.transcendental = 1.0,
        Reciprocal => w.fdiv = 1.0,
        Abs | Neg | Sign => w.cmp_ops = 1.0,
        Floor | Ceil | Round => w.int_ops = 1.0,
        Clip => w.cmp_ops = 2.0,
        Add | Sub => w.fadd = 1.0,
        Mul => w.fmul = 1.0,
        Div => w.fdiv = 1.0,
        Pow => w.transcendental = 2.0,
        Min | Max => w.cmp_ops = 1.0,
        And | Or | Xor | Not => w.bool_ops = 1.0,
        Greater | Less | Equal => w.cmp_ops = 1.0,
        Where => {
            w.cmp_ops = 1.0;
            w.bool_ops = 1.0;
        }
        // reduction-style work is attached per reduction element by the
        // lowering functions below; this is the per-element cost inside.
        Conv2d | DepthwiseConv2d | Gemm | MatMul => {
            w.fmul = 1.0;
            w.fadd = 1.0; // one FMA per reduction element
        }
        BatchNorm | InstanceNorm | LayerNorm => {
            w.fmul = 2.0;
            w.fadd = 2.0;
        }
        MaxPool | ReduceMax => w.cmp_ops = 1.0,
        AveragePool | GlobalAveragePool | ReduceMean | ReduceSum => w.fadd = 1.0,
        Softmax | LogSoftmax => {
            w.transcendental = 1.0;
            w.fadd = 1.0;
            w.fdiv = 1.0;
        }
        Pad | Concat | Slice | Transpose | Reshape | Flatten | Upsample | Identity => {
            w.int_ops = 1.0 // pure data movement: index math only
        }
    }
    // every op pays index arithmetic: ~1 int op per loop dim is added later
    w
}

/// Detect the access pattern of a graph operand relative to the stage loops.
fn operand_pattern(kind: OpKind, out_shape: &[usize], in_shape: &[usize]) -> AccessPattern {
    use OpKind::*;
    match kind {
        Conv2d | DepthwiseConv2d | MaxPool | AveragePool => AccessPattern::Stencil,
        Transpose => AccessPattern::Transposed,
        Upsample => AccessPattern::Broadcast,
        _ => {
            // broadcast if operand rank-extended or has 1-dims vs output
            if in_shape.len() < out_shape.len()
                || in_shape
                    .iter()
                    .rev()
                    .zip(out_shape.iter().rev())
                    .any(|(i, o)| *i == 1 && *o > 1)
            {
                AccessPattern::Broadcast
            } else if kind == Slice {
                AccessPattern::Strided(2)
            } else {
                AccessPattern::Contiguous
            }
        }
    }
}

/// Lower a single stage of `p`.
pub fn lower_stage(p: &Pipeline, stage: &Stage) -> LoopNest {
    use OpKind::*;
    let kind = stage.op.kind;
    let a = &stage.op.attrs;
    let out_shape = &stage.shape;
    let out_points = numel(out_shape) as f64;
    let base = unit_work(kind);

    // reduction extents + per-point work + weight accesses by op family
    let (reduction, work, weight_accesses): (Vec<usize>, WorkProfile, Vec<Access>) = match kind {
        Conv2d => {
            let in_shape = p.shape_of(stage.inputs[0]);
            let cin = in_shape[1];
            let (kh, kw) = a.kernel;
            let red = cin / a.groups.max(1) * kh * kw;
            let wbytes = (a.out_channels * cin / a.groups.max(1) * kh * kw * 4) as f64;
            (
                vec![cin / a.groups.max(1), kh, kw],
                base.scale(red as f64),
                vec![Access {
                    source: None,
                    footprint_bytes: wbytes,
                    bytes_per_point: (red * 4) as f64,
                    pattern: AccessPattern::Contiguous,
                }],
            )
        }
        DepthwiseConv2d => {
            let (kh, kw) = a.kernel;
            let red = kh * kw;
            let cin = p.shape_of(stage.inputs[0])[1];
            (
                vec![kh, kw],
                base.scale(red as f64),
                vec![Access {
                    source: None,
                    footprint_bytes: (cin * kh * kw * 4) as f64,
                    bytes_per_point: (red * 4) as f64,
                    pattern: AccessPattern::Contiguous,
                }],
            )
        }
        Gemm => {
            let k = *p.shape_of(stage.inputs[0]).last().unwrap();
            (
                vec![k],
                base.scale(k as f64),
                vec![Access {
                    source: None,
                    footprint_bytes: (k * a.out_channels * 4) as f64,
                    bytes_per_point: (k * 4) as f64,
                    // weight walked along K for fixed output col: strided
                    pattern: AccessPattern::Strided(a.out_channels),
                }],
            )
        }
        MatMul => {
            let k = *p.shape_of(stage.inputs[0]).last().unwrap();
            (vec![k], base.scale(k as f64), vec![])
        }
        BatchNorm | InstanceNorm | LayerNorm => {
            let c = if out_shape.len() >= 2 { out_shape[1] } else { out_shape[0] };
            (
                vec![],
                base,
                vec![Access {
                    source: None,
                    footprint_bytes: (4 * c * 4) as f64, // scale/shift/mean/var
                    bytes_per_point: 16.0,
                    pattern: AccessPattern::Broadcast,
                }],
            )
        }
        MaxPool | AveragePool => {
            let (kh, kw) = a.kernel;
            (vec![kh, kw], base.scale((kh * kw) as f64), vec![])
        }
        GlobalAveragePool => {
            let in_shape = p.shape_of(stage.inputs[0]);
            let red = in_shape[2] * in_shape[3];
            (vec![in_shape[2], in_shape[3]], base.scale(red as f64), vec![])
        }
        ReduceMean | ReduceSum | ReduceMax => {
            let in_shape = p.shape_of(stage.inputs[0]);
            let red = in_shape[a.axis.min(in_shape.len() - 1)];
            (vec![red], base.scale(red as f64), vec![])
        }
        Softmax | LogSoftmax => {
            let in_shape = p.shape_of(stage.inputs[0]);
            let red = in_shape[a.axis.min(in_shape.len() - 1)];
            // softmax makes 3 passes over the axis: max, exp-sum, normalize
            (vec![red], base.scale(3.0), vec![])
        }
        _ => (vec![], base, vec![]),
    };

    // graph operand accesses
    let red_extent: f64 = reduction.iter().product::<usize>().max(1) as f64;
    let mut accesses = Vec::new();
    for &src in &stage.inputs {
        let in_shape = p.shape_of(src);
        let fp = (numel(in_shape) * 4) as f64;
        let pattern = operand_pattern(kind, out_shape, in_shape);
        // bytes read from this operand per output point
        let bpp = match kind {
            Conv2d | DepthwiseConv2d | MaxPool | AveragePool | GlobalAveragePool => {
                4.0 * red_extent
            }
            Gemm | MatMul => {
                if matches!(src, SourceRef::Stage(_) | SourceRef::Input(_))
                    && std::ptr::eq(in_shape, p.shape_of(stage.inputs[0]))
                {
                    4.0 * red_extent // LHS row walked per output point
                } else {
                    4.0 * red_extent // RHS column walked per output point
                }
            }
            ReduceMean | ReduceSum | ReduceMax => 4.0 * red_extent,
            Softmax | LogSoftmax => 12.0, // 3 passes
            Upsample => 4.0 / (a.scale * a.scale) as f64,
            _ => {
                // elementwise/broadcast: one read per point, but broadcasts
                // re-read a smaller buffer (counted once; reuse handled by
                // the cache model via the small footprint)
                4.0
            }
        };
        accesses.push(Access {
            source: Some(src),
            footprint_bytes: fp,
            bytes_per_point: bpp,
            pattern,
        });
    }
    accesses.extend(weight_accesses);

    // index arithmetic: one int op per loop level per point
    let mut work = work;
    work.int_ops += (out_shape.len() + reduction.len()) as f64;

    let pointwise = matches!(
        kind.category(),
        OpCategory::UnaryElementwise | OpCategory::BinaryElementwise | OpCategory::Logical
    ) || matches!(kind, Identity | Pad | Slice | Upsample | Concat);

    LoopNest {
        stage_id: stage.id,
        spatial: out_shape.clone(),
        reduction,
        work,
        accesses,
        out_bytes: out_points * 4.0,
        pointwise,
    }
}

/// Lower every stage of a pipeline.
pub fn lower_pipeline(p: &Pipeline) -> Vec<LoopNest> {
    p.stages.iter().map(|s| lower_stage(p, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::ir::pipeline::Pipeline;

    fn conv_pipeline() -> Pipeline {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let mut attrs = OpAttrs::default();
        attrs.kernel = (3, 3);
        attrs.pad = 1;
        attrs.out_channels = 32;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        p
    }

    #[test]
    fn conv_flops_match_formula() {
        let p = conv_pipeline();
        let nests = lower_pipeline(&p);
        let conv = &nests[0];
        // 2 * N*Cout*H*W * Cin*Kh*Kw flops
        let expect = 2.0 * (32 * 32 * 32) as f64 * (16 * 9) as f64;
        assert!((conv.total_flops() - expect).abs() / expect < 1e-9);
        assert_eq!(conv.reduction, vec![16, 3, 3]);
        assert!(!conv.pointwise);
    }

    #[test]
    fn relu_is_pointwise_with_no_flops() {
        let p = conv_pipeline();
        let nests = lower_pipeline(&p);
        let relu = &nests[1];
        assert!(relu.pointwise);
        assert_eq!(relu.reduction.len(), 0);
        assert_eq!(relu.total_flops(), 0.0); // cmp only
        assert!(relu.work.cmp_ops > 0.0);
    }

    #[test]
    fn conv_has_stencil_access_and_weight_buffer() {
        let p = conv_pipeline();
        let conv = &lower_pipeline(&p)[0];
        assert_eq!(conv.accesses.len(), 2); // input + weights
        assert_eq!(conv.accesses[0].pattern, AccessPattern::Stencil);
        assert!(conv.accesses[1].source.is_none());
        // weight footprint = 32*16*3*3*4 bytes
        assert_eq!(conv.accesses[1].footprint_bytes, (32 * 16 * 9 * 4) as f64);
    }

    #[test]
    fn gemm_reduction_is_k() {
        let mut p = Pipeline::new("g");
        let x = p.add_input(vec![64, 512]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 10;
        p.add_stage("fc", Op::with_attrs(OpKind::Gemm, attrs), vec![x]).unwrap();
        let nest = &lower_pipeline(&p)[0];
        assert_eq!(nest.reduction, vec![512]);
        let expect = 2.0 * (64 * 10) as f64 * 512.0;
        assert!((nest.total_flops() - expect).abs() < 1.0);
    }

    #[test]
    fn broadcast_detected() {
        let mut p = Pipeline::new("b");
        let x = p.add_input(vec![8, 128]);
        let b = p.add_input(vec![128]);
        p.add_stage("add", Op::new(OpKind::Add), vec![x, b]).unwrap();
        let nest = &lower_pipeline(&p)[0];
        assert_eq!(nest.accesses[0].pattern, AccessPattern::Contiguous);
        assert_eq!(nest.accesses[1].pattern, AccessPattern::Broadcast);
    }

    #[test]
    fn transpose_pattern() {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![64, 128]);
        let mut attrs = OpAttrs::default();
        attrs.perm = vec![1, 0];
        p.add_stage("tr", Op::with_attrs(OpKind::Transpose, attrs), vec![x]).unwrap();
        let nest = &lower_pipeline(&p)[0];
        assert_eq!(nest.accesses[0].pattern, AccessPattern::Transposed);
    }

    #[test]
    fn out_bytes_match_shape() {
        let p = conv_pipeline();
        let nests = lower_pipeline(&p);
        assert_eq!(nests[0].out_bytes, (32 * 32 * 32 * 4) as f64);
    }

    #[test]
    fn softmax_three_passes() {
        let mut p = Pipeline::new("s");
        let x = p.add_input(vec![32, 1000]);
        let mut attrs = OpAttrs::default();
        attrs.axis = 1;
        p.add_stage("sm", Op::with_attrs(OpKind::Softmax, attrs), vec![x]).unwrap();
        let nest = &lower_pipeline(&p)[0];
        assert_eq!(nest.accesses[0].bytes_per_point, 12.0);
        assert_eq!(nest.reduction, vec![1000]);
    }
}
