//! `artifacts/manifest.json` — the contract between `aot.py` and the rust
//! runtime: model dimensions and the flat parameter calling convention.

use crate::constants;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub inv_dim: usize,
    pub dep_dim: usize,
    pub node_dim: usize,
    pub n_conv: usize,
    pub max_nodes: usize,
    pub batch: usize,
    pub learning_rate: f64,
    pub weight_decay: f64,
    pub params: Vec<ParamSpec>,
    /// Conv-depth ablation variants present in the artifacts (may be empty).
    pub ablation_layers: Vec<usize>,
}

fn parse_params(j: &Json) -> Result<Vec<ParamSpec>> {
    let arr = j.as_arr().context("params not an array")?;
    arr.iter()
        .map(|e| {
            Ok(ParamSpec {
                name: e
                    .get("name")
                    .and_then(|n| n.as_str())
                    .context("param name")?
                    .to_string(),
                shape: e
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .context("param shape")?
                    .iter()
                    .map(|d| d.as_usize().context("shape dim"))
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

/// Ordered parameter specs for an `n_conv`-layer model — the flat calling
/// convention shared with `model.param_specs` in `python/compile/model.py`.
pub fn param_specs(n_conv: usize) -> Vec<ParamSpec> {
    let mut specs = vec![
        ParamSpec { name: "w_inv".into(), shape: vec![constants::INV_DIM, constants::EMB_INV] },
        ParamSpec { name: "b_inv".into(), shape: vec![constants::EMB_INV] },
        ParamSpec { name: "w_dep".into(), shape: vec![constants::DEP_DIM, constants::EMB_DEP] },
        ParamSpec { name: "b_dep".into(), shape: vec![constants::EMB_DEP] },
    ];
    for k in 0..n_conv {
        specs.push(ParamSpec {
            name: format!("conv{k}_w"),
            shape: vec![constants::HIDDEN, constants::HIDDEN],
        });
        specs.push(ParamSpec { name: format!("conv{k}_b"), shape: vec![constants::HIDDEN] });
        specs.push(ParamSpec { name: format!("conv{k}_scale"), shape: vec![constants::HIDDEN] });
        specs.push(ParamSpec { name: format!("conv{k}_shift"), shape: vec![constants::HIDDEN] });
    }
    specs.push(ParamSpec {
        name: "w_out".into(),
        shape: vec![constants::NODE_DIM * (n_conv + 1), 1],
    });
    specs.push(ParamSpec { name: "b_out".into(), shape: vec![1] });
    specs
}

impl Manifest {
    /// In-memory manifest for the native backend — no artifact files
    /// required; dimensions come straight from [`crate::constants`].
    pub fn native(n_conv: usize) -> Manifest {
        Manifest {
            inv_dim: constants::INV_DIM,
            dep_dim: constants::DEP_DIM,
            node_dim: constants::NODE_DIM,
            n_conv,
            max_nodes: constants::MAX_NODES,
            batch: constants::BATCH,
            learning_rate: constants::LEARNING_RATE,
            weight_decay: constants::WEIGHT_DECAY,
            params: param_specs(n_conv),
            ablation_layers: vec![],
        }
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            j.get(k).and_then(|v| v.as_usize()).with_context(|| format!("manifest key {k}"))
        };
        let m = Manifest {
            inv_dim: get("inv_dim")?,
            dep_dim: get("dep_dim")?,
            node_dim: get("node_dim")?,
            n_conv: get("n_conv")?,
            max_nodes: get("max_nodes")?,
            batch: get("batch")?,
            learning_rate: j
                .get("learning_rate")
                .and_then(|v| v.as_f64())
                .context("learning_rate")?,
            weight_decay: j
                .get("weight_decay")
                .and_then(|v| v.as_f64())
                .context("weight_decay")?,
            params: parse_params(j.get("params").context("params")?)?,
            ablation_layers: j
                .get("ablation_layers")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
        };
        m.check_against_constants()?;
        Ok(m)
    }

    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    /// Fail fast when python and rust dims drift.
    fn check_against_constants(&self) -> Result<()> {
        if self.inv_dim != constants::INV_DIM
            || self.dep_dim != constants::DEP_DIM
            || self.max_nodes != constants::MAX_NODES
            || self.batch != constants::BATCH
        {
            bail!(
                "manifest dims {:?} disagree with rust constants ({}, {}, {}, {}) — \
                 rebuild artifacts",
                (self.inv_dim, self.dep_dim, self.max_nodes, self.batch),
                constants::INV_DIM,
                constants::DEP_DIM,
                constants::MAX_NODES,
                constants::BATCH
            );
        }
        Ok(())
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> String {
        format!(
            r#"{{"inv_dim": {}, "dep_dim": {}, "node_dim": 80, "hidden": 80,
                "n_conv": 2, "readout": 240, "max_nodes": {}, "batch": {},
                "learning_rate": 0.0075, "weight_decay": 0.0001,
                "params": [
                  {{"name": "w_inv", "shape": [{}, 32]}},
                  {{"name": "b_out", "shape": [1]}}
                ]}}"#,
            constants::INV_DIM,
            constants::DEP_DIM,
            constants::MAX_NODES,
            constants::BATCH,
            constants::INV_DIM,
        )
    }

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::parse(&sample_manifest()).unwrap();
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].name, "w_inv");
        assert_eq!(m.params[0].numel(), constants::INV_DIM * 32);
        assert!((m.learning_rate - 0.0075).abs() < 1e-12);
    }

    #[test]
    fn rejects_dim_drift() {
        let bad = sample_manifest().replace(
            &format!("\"batch\": {}", constants::BATCH),
            "\"batch\": 7",
        );
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn native_manifest_matches_python_spec() {
        let m = Manifest::native(2);
        assert_eq!(m.params.len(), 14);
        assert_eq!(m.params[0].name, "w_inv");
        assert_eq!(m.params[4].name, "conv0_w");
        assert_eq!(m.params[12].name, "w_out");
        assert_eq!(m.params[12].shape, vec![crate::constants::READOUT, 1]);
        assert_eq!(m.params[13].name, "b_out");
        assert_eq!(Manifest::native(0).params.len(), 6);
        assert_eq!(Manifest::native(4).params.len(), 22);
        m.check_against_constants().unwrap();
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.n_conv, constants::N_CONV);
            assert!(m.total_param_elems() > 10_000);
        }
    }
}
