//! Dense padded reference engine — the pre-sparse execution semantics,
//! kept as a [`Backend`] for parity tests and the dense-vs-sparse
//! benchmarks.
//!
//! This is the O(B·N²·D) padded implementation the sparse
//! [`crate::runtime::NativeBackend`] replaced: every graph padded to a
//! common node count, a full dense `[n_pad, n_pad]` adjacency sweep per
//! node (skipping masked rows), masked sum-pool readout. It consumes the
//! same [`PackedBatch`] as every other backend and converts internally
//! via [`DenseBatch::from_packed`], padding to
//! `max(MAX_NODES, largest graph)` — exactly the workload shape the old
//! engine paid for — so `BENCH_3.json` can report the dense-vs-sparse
//! gap on identical inputs, and the property tests can pin the sparse
//! engine against it on arbitrary variable-size graphs.
//!
//! The JAX-pinned parity fixtures (dense layout, `REF_Z`/`REF_GRADS`)
//! also live here, running straight through the dense forward/backward —
//! they anchor this reference to `python/compile/kernels/ref.py`, and the
//! sparse engine's own parity tests anchor it to this reference through
//! `PackedBatch::from_dense`.

use crate::constants::{
    DEP_DIM, EMB_DEP, EMB_INV, INV_DIM, MAX_NODES, NODE_DIM, N_CONV,
};
use crate::model::{DenseBatch, PackedBatch};
use crate::runtime::backend::Backend;
use crate::runtime::manifest::Manifest;
use crate::runtime::native::{
    apply_adagrad, check_params_against, xi_and_grad, LN_EPS,
};
use crate::runtime::params::Params;
use anyhow::Result;

/// The dense reference engine. Same manifest and parameter convention as
/// the native backend; only the batch layout and loop structure differ.
pub struct DenseRefBackend {
    manifest: Manifest,
}

impl Default for DenseRefBackend {
    fn default() -> Self {
        DenseRefBackend::new()
    }
}

impl DenseRefBackend {
    pub fn new() -> DenseRefBackend {
        DenseRefBackend::with_layers(N_CONV)
    }

    pub fn with_layers(n_conv: usize) -> DenseRefBackend {
        DenseRefBackend { manifest: Manifest::native(n_conv) }
    }

    fn n_conv(&self) -> usize {
        self.manifest.n_conv
    }

    fn readout(&self) -> usize {
        NODE_DIM * (self.n_conv() + 1)
    }

    fn p_w_out(&self) -> usize {
        4 + 4 * self.n_conv()
    }

    /// Pad a packed batch to this engine's dense workload shape: at least
    /// the old `MAX_NODES` width, wider only when a graph demands it.
    /// Public so benchmarks can convert once, outside their timed loops —
    /// the pre-sparse engine consumed ready-built dense batches, so a
    /// fair dense-vs-sparse comparison must not time the converter.
    pub fn to_dense(&self, batch: &PackedBatch) -> Result<DenseBatch> {
        let n_pad = batch.max_graph_nodes().max(MAX_NODES);
        DenseBatch::from_packed(batch, n_pad, batch.n_graphs())
    }

    /// Forward on a ready-built dense batch (no conversion) — the timed
    /// kernel of the dense side of `gcn-perf bench`.
    pub fn infer_dense(&self, params: &Params, batch: &DenseBatch) -> Result<Vec<f32>> {
        check_params_against(&self.manifest, params)?;
        let fwd = self.forward(params, batch);
        Ok(fwd.z[..batch.len].to_vec())
    }

    /// Train step on a ready-built dense batch (no conversion).
    pub fn train_step_dense(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &DenseBatch,
        lr: f32,
    ) -> Result<f32> {
        check_params_against(&self.manifest, params)?;
        check_params_against(&self.manifest, accum)?;
        let fwd = self.forward(params, batch);
        let (loss, dz) = dense_loss_and_dz(&fwd.z, batch);
        let grads = self.backward(params, batch, &fwd, &dz);
        apply_adagrad(params, accum, &grads, lr as f64, self.manifest.weight_decay);
        Ok(loss as f32)
    }

    /// Full dense forward pass, keeping every intermediate backprop needs.
    fn forward(&self, params: &Params, batch: &DenseBatch) -> DenseForward {
        let kk = self.n_conv();
        let readout = self.readout();
        let nb = batch.n_graphs;
        let np = batch.n_pad;
        let n_elems = nb * np * NODE_DIM;

        // ---- Fig 5 embedding, masked: padded nodes stay exactly zero.
        let (w_inv, b_inv) = (&params.values[0], &params.values[1]);
        let (w_dep, b_dep) = (&params.values[2], &params.values[3]);
        let mut e0 = vec![0f32; n_elems];
        for node in 0..nb * np {
            if batch.mask[node] == 0.0 {
                continue;
            }
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            let out = &mut e0[node * NODE_DIM..(node + 1) * NODE_DIM];
            for j in 0..EMB_INV {
                let mut acc = b_inv[j] as f64;
                for (i, &x) in inv.iter().enumerate() {
                    acc += x as f64 * w_inv[i * EMB_INV + j] as f64;
                }
                out[j] = acc.max(0.0) as f32;
            }
            for j in 0..EMB_DEP {
                let mut acc = b_dep[j] as f64;
                for (i, &x) in dep.iter().enumerate() {
                    acc += x as f64 * w_dep[i * EMB_DEP + j] as f64;
                }
                out[EMB_INV + j] = acc.max(0.0) as f32;
            }
        }

        let mut e_list = Vec::with_capacity(kk + 1);
        e_list.push(e0);
        let mut h_list = Vec::with_capacity(kk);
        let mut xhat_list = Vec::with_capacity(kk);
        let mut rstd_list = Vec::with_capacity(kk);

        // ---- graph convolutions
        for k in 0..kk {
            let w = &params.values[4 + 4 * k];
            let bvec = &params.values[5 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let shift = &params.values[7 + 4 * k];
            let e_prev = &e_list[k];

            // t = E · W per node (zero rows for padded nodes)
            let mut t = vec![0f32; n_elems];
            for node in 0..nb * np {
                if batch.mask[node] == 0.0 {
                    continue;
                }
                let e_row = &e_prev[node * NODE_DIM..(node + 1) * NODE_DIM];
                let mut acc = [0f64; NODE_DIM];
                for (i, &x) in e_row.iter().enumerate() {
                    if x == 0.0 {
                        continue;
                    }
                    let xf = x as f64;
                    let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        acc[j] += xf * wrow[j] as f64;
                    }
                }
                let t_row = &mut t[node * NODE_DIM..(node + 1) * NODE_DIM];
                for j in 0..NODE_DIM {
                    t_row[j] = acc[j] as f32;
                }
            }

            // c = A' · t + b (full dense row sweep), channel norm, ReLU
            let mut h = vec![0f32; n_elems];
            let mut xhat = vec![0f32; n_elems];
            let mut rstd = vec![0f32; nb * np];
            let mut e_next = vec![0f32; n_elems];
            for b in 0..nb {
                for n in 0..np {
                    let node = b * np + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let arow = &batch.adj[node * np..(node + 1) * np];
                    let mut c = [0f64; NODE_DIM];
                    for (r, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let af = a as f64;
                        let t_row = &t[(b * np + r) * NODE_DIM..(b * np + r + 1) * NODE_DIM];
                        for j in 0..NODE_DIM {
                            c[j] += af * t_row[j] as f64;
                        }
                    }
                    for j in 0..NODE_DIM {
                        c[j] += bvec[j] as f64;
                    }
                    let mean = c.iter().sum::<f64>() / NODE_DIM as f64;
                    let var =
                        c.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / NODE_DIM as f64;
                    let rs = 1.0 / (var + LN_EPS).sqrt();
                    rstd[node] = rs as f32;
                    let o = node * NODE_DIM;
                    for j in 0..NODE_DIM {
                        let xh = (c[j] - mean) * rs;
                        xhat[o + j] = xh as f32;
                        let hv = xh * scale[j] as f64 + shift[j] as f64;
                        h[o + j] = hv as f32;
                        e_next[o + j] = hv.max(0.0) as f32;
                    }
                }
            }
            h_list.push(h);
            xhat_list.push(xhat);
            rstd_list.push(rstd);
            e_list.push(e_next);
        }

        // ---- masked sum-pool readout per conv level + linear head
        let w_out = &params.values[self.p_w_out()];
        let b_out = &params.values[self.p_w_out() + 1];
        let mut feat = vec![0f32; nb * readout];
        let mut z = vec![0f32; nb];
        for b in 0..nb {
            for (k, e) in e_list.iter().enumerate() {
                let f_off = b * readout + k * NODE_DIM;
                for n in 0..np {
                    let node = b * np + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let row = &e[node * NODE_DIM..(node + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        feat[f_off + j] += row[j];
                    }
                }
            }
            let mut acc = b_out[0] as f64;
            for r in 0..readout {
                acc += feat[b * readout + r] as f64 * w_out[r] as f64;
            }
            z[b] = acc as f32;
        }

        DenseForward { e: e_list, h: h_list, xhat: xhat_list, rstd: rstd_list, feat, z }
    }

    /// Analytic gradients on the dense layout (weight decay applied in
    /// the Adagrad step).
    fn backward(
        &self,
        params: &Params,
        batch: &DenseBatch,
        fwd: &DenseForward,
        dz: &[f64],
    ) -> Vec<Vec<f64>> {
        let kk = self.n_conv();
        let readout = self.readout();
        let iw = self.p_w_out();
        let w_out = &params.values[iw];
        let nb = batch.n_graphs;
        let np = batch.n_pad;
        let mut grads: Vec<Vec<f64>> =
            params.values.iter().map(|v| vec![0f64; v.len()]).collect();

        // ---- head: z = feat · w_out + b_out
        for b in 0..nb {
            if dz[b] == 0.0 {
                continue;
            }
            grads[iw + 1][0] += dz[b];
            for r in 0..readout {
                grads[iw][r] += fwd.feat[b * readout + r] as f64 * dz[b];
            }
        }

        // dL/de for the deepest activations
        let mut de = vec![0f64; nb * np * NODE_DIM];
        for b in 0..nb {
            if dz[b] == 0.0 {
                continue;
            }
            for n in 0..np {
                let node = b * np + n;
                if batch.mask[node] == 0.0 {
                    continue;
                }
                let o = node * NODE_DIM;
                for j in 0..NODE_DIM {
                    de[o + j] = dz[b] * w_out[kk * NODE_DIM + j] as f64;
                }
            }
        }

        // ---- conv layers, deepest first
        for k in (0..kk).rev() {
            let w = &params.values[4 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let h = &fwd.h[k];
            let xh = &fwd.xhat[k];
            let rstd = &fwd.rstd[k];
            let e_prev = &fwd.e[k];

            // ReLU + channel-norm backward: de -> dc (per node)
            let mut dc = vec![0f64; nb * np * NODE_DIM];
            for node in 0..nb * np {
                if batch.mask[node] == 0.0 {
                    continue;
                }
                let o = node * NODE_DIM;
                let mut dxh = [0f64; NODE_DIM];
                let mut sum1 = 0f64;
                let mut sum2 = 0f64;
                for j in 0..NODE_DIM {
                    let dh = if h[o + j] > 0.0 { de[o + j] } else { 0.0 };
                    grads[6 + 4 * k][j] += dh * xh[o + j] as f64;
                    grads[7 + 4 * k][j] += dh;
                    let dx = dh * scale[j] as f64;
                    dxh[j] = dx;
                    sum1 += dx;
                    sum2 += dx * xh[o + j] as f64;
                }
                let rs = rstd[node] as f64;
                for j in 0..NODE_DIM {
                    let v =
                        rs * (dxh[j] - (sum1 + xh[o + j] as f64 * sum2) / NODE_DIM as f64);
                    dc[o + j] = v;
                    grads[5 + 4 * k][j] += v;
                }
            }

            // dt = A'ᵀ · dc per sample, then de_prev = dt · Wᵀ and
            // dW += e_prevᵀ · dt
            let mut de_new = vec![0f64; nb * np * NODE_DIM];
            let mut dt = vec![0f64; np * NODE_DIM];
            for b in 0..nb {
                dt.iter_mut().for_each(|v| *v = 0.0);
                for r in 0..np {
                    let rnode = b * np + r;
                    if batch.mask[rnode] == 0.0 {
                        continue;
                    }
                    let o = rnode * NODE_DIM;
                    let arow = &batch.adj[rnode * np..(rnode + 1) * np];
                    for (c_ix, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let af = a as f64;
                        let trow = &mut dt[c_ix * NODE_DIM..(c_ix + 1) * NODE_DIM];
                        for j in 0..NODE_DIM {
                            trow[j] += af * dc[o + j];
                        }
                    }
                }
                for n in 0..np {
                    let node = b * np + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let dtrow = &dt[n * NODE_DIM..(n + 1) * NODE_DIM];
                    let erow = &e_prev[node * NODE_DIM..(node + 1) * NODE_DIM];
                    let o = node * NODE_DIM;
                    for i in 0..NODE_DIM {
                        let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                        let mut acc = 0f64;
                        for j in 0..NODE_DIM {
                            acc += dtrow[j] * wrow[j] as f64;
                        }
                        de_new[o + i] = acc;
                        let ev = erow[i] as f64;
                        if ev != 0.0 {
                            let gw = &mut grads[4 + 4 * k][i * NODE_DIM..(i + 1) * NODE_DIM];
                            for j in 0..NODE_DIM {
                                gw[j] += ev * dtrow[j];
                            }
                        }
                    }
                }
            }

            // pooled-readout gradient for level k
            for b in 0..nb {
                if dz[b] == 0.0 {
                    continue;
                }
                for n in 0..np {
                    let node = b * np + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let o = node * NODE_DIM;
                    for j in 0..NODE_DIM {
                        de_new[o + j] += dz[b] * w_out[k * NODE_DIM + j] as f64;
                    }
                }
            }
            de = de_new;
        }

        // ---- embedding backward
        let e0 = &fwd.e[0];
        for node in 0..nb * np {
            if batch.mask[node] == 0.0 {
                continue;
            }
            let o = node * NODE_DIM;
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            for j in 0..EMB_INV {
                if e0[o + j] <= 0.0 {
                    continue;
                }
                let g = de[o + j];
                if g == 0.0 {
                    continue;
                }
                grads[1][j] += g;
                for (i, &x) in inv.iter().enumerate() {
                    grads[0][i * EMB_INV + j] += x as f64 * g;
                }
            }
            for j in 0..EMB_DEP {
                if e0[o + EMB_INV + j] <= 0.0 {
                    continue;
                }
                let g = de[o + EMB_INV + j];
                if g == 0.0 {
                    continue;
                }
                grads[3][j] += g;
                for (i, &x) in dep.iter().enumerate() {
                    grads[2][i * EMB_DEP + j] += x as f64 * g;
                }
            }
        }

        grads
    }
}

/// Forward intermediates of the dense layout.
struct DenseForward {
    e: Vec<Vec<f32>>,
    h: Vec<Vec<f32>>,
    xhat: Vec<Vec<f32>>,
    rstd: Vec<Vec<f32>>,
    feat: Vec<f32>,
    z: Vec<f32>,
}

/// §III-C loss on the dense layout: `weight·sample_mask`-weighted mean ξ.
fn dense_loss_and_dz(z: &[f32], batch: &DenseBatch) -> (f64, Vec<f64>) {
    let nb = batch.n_graphs;
    let mut wsum = 0f64;
    for b in 0..nb {
        wsum += (batch.weight[b] * batch.sample_mask[b]) as f64;
    }
    let denom = wsum.max(1e-6);
    let mut loss = 0f64;
    let mut dz = vec![0f64; nb];
    for b in 0..nb {
        let w = (batch.weight[b] * batch.sample_mask[b]) as f64;
        if w == 0.0 {
            continue;
        }
        let d = z[b] as f64 - batch.log_y[b] as f64;
        let (xi, gr) = xi_and_grad(d);
        loss += w * xi;
        dz[b] = w * gr / denom;
    }
    (loss / denom, dz)
}

impl Backend for DenseRefBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "dense-ref"
    }

    fn infer(&self, params: &Params, batch: &PackedBatch) -> Result<Vec<f32>> {
        let dense = self.to_dense(batch)?;
        self.infer_dense(params, &dense)
    }

    fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &PackedBatch,
        lr: f32,
    ) -> Result<f32> {
        let dense = self.to_dense(batch)?;
        self.train_step_dense(params, accum, &dense, lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constants::BATCH;
    use crate::testfix::{
        grad_fixture_batch, parity_batch, parity_params, REF_GRADS, REF_LOSS, REF_Z,
    };

    #[test]
    fn forward_matches_jax_reference() {
        let be = DenseRefBackend::new();
        let batch = parity_batch();
        let params = parity_params(be.manifest());
        let fwd = be.forward(&params, &batch);
        assert_eq!(fwd.z.len(), BATCH);
        for (i, (&got, &want)) in fwd.z.iter().zip(REF_Z.iter()).enumerate() {
            assert!(
                (got - want).abs() <= 1e-5,
                "z[{i}] = {got}, reference {want} (|diff| = {})",
                (got - want).abs()
            );
        }
    }

    #[test]
    fn backward_matches_jax_grads() {
        let be = DenseRefBackend::new();
        let batch = grad_fixture_batch();
        let params = parity_params(be.manifest());
        let fwd = be.forward(&params, &batch);
        let (loss, dz) = dense_loss_and_dz(&fwd.z, &batch);
        assert!(
            (loss - REF_LOSS).abs() < 5e-3,
            "loss {loss} vs jax reference {REF_LOSS}"
        );
        let grads = be.backward(&params, &batch, &fwd, &dz);
        for &(t, i, want) in REF_GRADS.iter() {
            let got = grads[t][i];
            let tol = 1e-3 + 2e-3 * want.abs();
            assert!(
                (got - want).abs() <= tol,
                "grad[{t}][{i}] = {got}, jax reference {want} (tol {tol})"
            );
        }
    }

    #[test]
    fn padding_poison_is_invisible_through_the_dense_path() {
        // the dense layout's masking contract: poisoning padded feature
        // rows must not change predictions (regression guard on from_packed
        // + the masked dense sweep)
        use crate::constants::{DEP_DIM, INV_DIM};
        use crate::testfix::{identity_stats, synth_sample};
        let be = DenseRefBackend::new();
        let samples: Vec<_> =
            (0..5).map(|i| synth_sample(0, i, 1e-3 * (1.0 + i as f32))).collect();
        let refs: Vec<_> = samples.iter().collect();
        let packed = PackedBatch::for_inference(&refs, &identity_stats()).unwrap();
        let params = be.init_params(3);
        let clean = be.to_dense(&packed).unwrap();
        let z_clean = be.forward(&params, &clean).z;
        let mut poisoned = clean.clone();
        let np = poisoned.n_pad;
        for node in 0..poisoned.n_graphs * np {
            if poisoned.mask[node] == 0.0 {
                for v in &mut poisoned.inv[node * INV_DIM..(node + 1) * INV_DIM] {
                    *v = 1234.5;
                }
                for v in &mut poisoned.dep[node * DEP_DIM..(node + 1) * DEP_DIM] {
                    *v = -77.7;
                }
            }
        }
        let z_poisoned = be.forward(&params, &poisoned).z;
        assert_eq!(z_clean, z_poisoned, "padding rows leaked into predictions");
    }
}
