//! The PJRT GCN runtime (`pjrt` cargo feature): executes the AOT-compiled
//! infer + train HLO artifacts.
//!
//! Artifact signatures (see `aot.py`), with `B = BATCH`, `N = MAX_NODES`:
//!
//! * infer: `(*params, inv[B,N,INV_DIM], dep[B,N,DEP_DIM], adj[B,N,N],
//!   mask[B,N]) -> (z[B],)` — all tensors `f32`, `z` is log-runtime;
//! * train: `(*params, *accum, inv, dep, adj, mask, log_y[B], weight[B],
//!   sample_mask[B], lr) -> (*params', *accum', loss)`.
//!
//! The artifacts bake those fixed shapes in, so this is the one backend
//! that still needs the dense padded layout: every call converts the
//! sparse [`PackedBatch`] via [`DenseBatch::from_packed`] right before
//! upload, and fails cleanly when a batch exceeds the artifact's
//! `BATCH`/`MAX_NODES` envelope (the native engine has no such caps).
//!
//! This module only typechecks against the in-tree `xla` API stub by
//! default; the [`crate::runtime::load_backend`] loader falls back to the
//! native backend when PJRT is unavailable at runtime.

use crate::constants::{BATCH, DEP_DIM, INV_DIM, MAX_NODES};
use crate::model::{DenseBatch, PackedBatch};
use crate::runtime::backend::Backend;
use crate::runtime::manifest::Manifest;
use crate::runtime::params::Params;
use anyhow::{Context, Result};
use std::path::Path;

pub struct GcnRuntime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    infer_exe: xla::PjRtLoadedExecutable,
    train_exe: Option<xla::PjRtLoadedExecutable>,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("artifact path not utf-8")?,
    )
    .with_context(|| format!("parse HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

impl GcnRuntime {
    /// Load the default artifacts (`gcn_infer.hlo.txt` / `gcn_train.hlo.txt`).
    pub fn load(artifacts_dir: &Path, with_train: bool) -> Result<GcnRuntime> {
        Self::load_variant(artifacts_dir, "", with_train)
    }

    /// Load an ablation variant (`suffix` e.g. "_l0", "_l1", "_l4").
    pub fn load_variant(
        artifacts_dir: &Path,
        suffix: &str,
        with_train: bool,
    ) -> Result<GcnRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        let infer_exe = compile(&client, &artifacts_dir.join(format!("gcn_infer{suffix}.hlo.txt")))?;
        let train_exe = if with_train {
            Some(compile(&client, &artifacts_dir.join(format!("gcn_train{suffix}.hlo.txt")))?)
        } else {
            None
        };
        Ok(GcnRuntime { client, manifest, infer_exe, train_exe })
    }

    fn buffers_for_params(&self, params: &Params) -> Result<Vec<xla::PjRtBuffer>> {
        params
            .values
            .iter()
            .zip(&params.shapes)
            .map(|(v, s)| Ok(self.client.buffer_from_host_buffer(v, s, None)?))
            .collect()
    }

    fn batch_buffers(&self, batch: &DenseBatch) -> Result<Vec<xla::PjRtBuffer>> {
        let n = MAX_NODES;
        let c = &self.client;
        Ok(vec![
            c.buffer_from_host_buffer(&batch.inv, &[BATCH, n, INV_DIM], None)?,
            c.buffer_from_host_buffer(&batch.dep, &[BATCH, n, DEP_DIM], None)?,
            c.buffer_from_host_buffer(&batch.adj, &[BATCH, n, n], None)?,
            c.buffer_from_host_buffer(&batch.mask, &[BATCH, n], None)?,
        ])
    }

    /// Pad a packed batch to the artifact's fixed dense shapes.
    fn to_dense(batch: &PackedBatch) -> Result<DenseBatch> {
        DenseBatch::from_packed(batch, MAX_NODES, BATCH).context(
            "the PJRT artifacts take fixed [BATCH, MAX_NODES] shapes; \
             use the native backend for larger graphs or batches",
        )
    }
}

/// `init_params`, `train_step` and `predict_runtimes` come from the trait
/// defaults; `predict_runtimes` stays sequential because the PJRT client
/// is driven from one thread.
impl Backend for GcnRuntime {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Predicted log-runtimes for the graphs of the batch.
    fn infer(&self, params: &Params, batch: &PackedBatch) -> Result<Vec<f32>> {
        let dense = Self::to_dense(batch)?;
        let mut args = self.buffers_for_params(params)?;
        args.extend(self.batch_buffers(&dense)?);
        let result = self.infer_exe.execute_b::<xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let z = result.to_tuple1()?;
        let v = z.to_vec::<f32>()?;
        Ok(v[..dense.len].to_vec())
    }

    /// One Adagrad step with an explicit learning rate (runtime input to
    /// the artifact — no re-AOT needed to tune/schedule it).
    fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &PackedBatch,
        lr: f32,
    ) -> Result<f32> {
        let train_exe = self
            .train_exe
            .as_ref()
            .context("runtime loaded without the train executable")?;
        let dense = Self::to_dense(batch)?;
        let mut args = self.buffers_for_params(params)?;
        args.extend(self.buffers_for_params(accum)?);
        args.extend(self.batch_buffers(&dense)?);
        let c = &self.client;
        args.push(c.buffer_from_host_buffer(&dense.log_y, &[BATCH], None)?);
        args.push(c.buffer_from_host_buffer(&dense.weight, &[BATCH], None)?);
        args.push(c.buffer_from_host_buffer(&dense.sample_mask, &[BATCH], None)?);
        args.push(c.buffer_from_host_buffer(&[lr], &[], None)?);

        let result = train_exe.execute_b::<xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let np = params.values.len();
        anyhow::ensure!(parts.len() == 2 * np + 1, "train tuple arity {}", parts.len());
        for (i, part) in parts.iter().take(np).enumerate() {
            params.values[i] = part.to_vec::<f32>()?;
        }
        for (i, part) in parts.iter().skip(np).take(np).enumerate() {
            accum.values[i] = part.to_vec::<f32>()?;
        }
        let loss = parts[2 * np].to_vec::<f32>()?[0];
        Ok(loss)
    }
}
