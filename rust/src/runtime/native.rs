//! Pure-Rust GCN execution engine — the default [`Backend`], running on
//! the sparse block-diagonal [`PackedBatch`] layout.
//!
//! Implements the paper's model (Fig 7) with the exact artifact semantics
//! of `python/compile/aot.py` / `python/compile/model.py`:
//!
//! * forward: Fig 5 dual feature embedding → `n_conv` graph convolutions
//!   (Kipf–Welling aggregate-update `A' · (E · W) + b`, per-node channel
//!   normalization, ReLU) → segment-sum readout per conv level →
//!   linear head predicting log-runtime `z` (one value per graph);
//! * train: the §III-C weighted relative-error loss
//!   `ξ = |exp(z − log ȳ) − 1|` (linearized beyond `|d| = 3`), analytic
//!   backprop through the whole network, and an Adagrad step with weight
//!   decay — semantically identical to `model.train_step`.
//!
//! Unlike the padded dense layout (kept behind the `pjrt` feature and in
//! [`crate::runtime::DenseRefBackend`]), the packed layout holds exactly
//! the real nodes of every graph: the dense projections (embedding and
//! per-conv `E · W`) run as blocked GEMMs over the packed node matrix and
//! the aggregation `A' · t` is an O(E) gather over the CSR rows — no
//! `MAX_NODES` cap, no O(N²) adjacency sweeps over padding. Row blocks
//! fan out over [`crate::util::threadpool`] when a batch is large enough
//! to pay for it.
//!
//! Tensor math accumulates in `f64` and stores `f32` at the same op
//! boundaries as the JAX model; because CSR rows keep ascending column
//! order, every per-element accumulation visits the same nonzero terms in
//! the same order as the dense in-order sweep, so outputs match the
//! dependency-free reference (`python/compile/kernels/ref.py`) to ≤1e-5.
//! The parity tests below pin that against JAX-generated reference
//! numbers via `PackedBatch::from_dense` over the dense fixtures.
//!
//! [`Backend::predict_runtimes`] is overridden to fan batch chunks out
//! over the thread pool, which is what lets beam search and the eval
//! harnesses amortize model queries across cores.

use crate::constants::{
    ADAGRAD_EPS, BATCH, DEP_DIM, EMB_DEP, EMB_INV, INV_DIM, NODE_DIM, N_CONV,
};
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::model::PackedBatch;
use crate::runtime::backend::{predict_chunk, Backend};
use crate::runtime::manifest::Manifest;
use crate::runtime::params::Params;
use crate::util::threadpool::{chunk_ranges, parallel_map};
use anyhow::{ensure, Result};
use std::ops::Range;

// The conv math below indexes weight tensors of manifest shape
// [HIDDEN, HIDDEN] with NODE_DIM strides; that is only sound while the
// conv width equals the node embedding width (true in the paper's model).
const _: () = assert!(
    crate::constants::HIDDEN == NODE_DIM,
    "native backend assumes HIDDEN == NODE_DIM (conv width == embedding width)"
);

/// Channel-normalization epsilon (`graph_batch_norm` in `model.py`).
pub(crate) const LN_EPS: f64 = 1e-5;
/// Loss linearization point: ξ switches to a linear tail beyond |d| = 3.
pub(crate) const LOSS_CLIP: f64 = 3.0;

/// Minimum packed rows per parallel block. Below roughly one chunk of
/// small graphs the scoped fan-out costs more than it saves — and the
/// chunked [`Backend::predict_runtimes`] path is already parallel at the
/// batch level, so in-batch blocking only needs to win on big graphs.
const PAR_MIN_ROWS: usize = 512;

/// Fill a row-major `[n_rows, width]` f32 matrix, parallel over
/// contiguous row blocks on the shared thread pool when the batch is
/// large. Deterministic: each row depends only on its own index.
fn par_rows<F>(n_rows: usize, width: usize, f: F) -> Vec<f32>
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(n_rows, PAR_MIN_ROWS);
    if ranges.len() <= 1 {
        let mut out = vec![0f32; n_rows * width];
        for (r, row) in out.chunks_mut(width.max(1)).enumerate() {
            f(r, row);
        }
        return out;
    }
    let parts = parallel_map(&ranges, |range| {
        let mut block = vec![0f32; range.len() * width];
        for (i, row) in block.chunks_mut(width.max(1)).enumerate() {
            f(range.start + i, row);
        }
        block
    });
    let mut out = Vec::with_capacity(n_rows * width);
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

/// The native engine. Stateless apart from its manifest; cheap to build
/// and `Sync`, so inference parallelizes freely.
pub struct NativeBackend {
    manifest: Manifest,
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// The paper's configuration: two graph-convolution layers.
    pub fn new() -> NativeBackend {
        NativeBackend::with_layers(N_CONV)
    }

    /// A conv-depth ablation variant (§III-C sweep: 0/1/2/4 layers).
    pub fn with_layers(n_conv: usize) -> NativeBackend {
        NativeBackend { manifest: Manifest::native(n_conv) }
    }

    fn n_conv(&self) -> usize {
        self.manifest.n_conv
    }

    fn readout(&self) -> usize {
        NODE_DIM * (self.n_conv() + 1)
    }

    /// Index of `w_out` in the flat parameter list (`b_out` follows it).
    fn p_w_out(&self) -> usize {
        4 + 4 * self.n_conv()
    }

    fn check_params(&self, params: &Params) -> Result<()> {
        check_params_against(&self.manifest, params)
    }

    /// Full forward pass, keeping every intermediate backprop needs.
    fn forward(&self, params: &Params, batch: &PackedBatch) -> Forward {
        let kk = self.n_conv();
        let readout = self.readout();
        let nn = batch.total_nodes();
        let nb = batch.n_graphs();

        // ---- Fig 5 embedding: e0 = relu(inv·Wi + bi) ++ relu(dep·Wd + bd)
        // — a blocked GEMM over the packed node matrix (every row is real;
        // the packed layout has no padding nodes to skip).
        let (w_inv, b_inv) = (&params.values[0], &params.values[1]);
        let (w_dep, b_dep) = (&params.values[2], &params.values[3]);
        let e0 = par_rows(nn, NODE_DIM, |node, out| {
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            for j in 0..EMB_INV {
                let mut acc = b_inv[j] as f64;
                for (i, &x) in inv.iter().enumerate() {
                    acc += x as f64 * w_inv[i * EMB_INV + j] as f64;
                }
                out[j] = acc.max(0.0) as f32;
            }
            for j in 0..EMB_DEP {
                let mut acc = b_dep[j] as f64;
                for (i, &x) in dep.iter().enumerate() {
                    acc += x as f64 * w_dep[i * EMB_DEP + j] as f64;
                }
                out[EMB_INV + j] = acc.max(0.0) as f32;
            }
        });

        let mut e_list = Vec::with_capacity(kk + 1);
        e_list.push(e0);
        let mut h_list = Vec::with_capacity(kk);
        let mut xhat_list = Vec::with_capacity(kk);
        let mut rstd_list = Vec::with_capacity(kk);

        // ---- graph convolutions
        for k in 0..kk {
            let w = &params.values[4 + 4 * k];
            let bvec = &params.values[5 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let shift = &params.values[7 + 4 * k];
            let e_prev = &e_list[k];

            // t = E · W per node — blocked GEMM, exploiting ReLU sparsity
            let t = par_rows(nn, NODE_DIM, |node, t_row| {
                let e_row = &e_prev[node * NODE_DIM..(node + 1) * NODE_DIM];
                let mut acc = [0f64; NODE_DIM];
                for (i, &x) in e_row.iter().enumerate() {
                    if x == 0.0 {
                        continue;
                    }
                    let xf = x as f64;
                    let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        acc[j] += xf * wrow[j] as f64;
                    }
                }
                for j in 0..NODE_DIM {
                    t_row[j] = acc[j] as f32;
                }
            });

            // c = A' · t + b (O(E) gather over the CSR row), then per-node
            // channel norm and ReLU — fused, parallel over row blocks
            let conv = par_conv(batch, &t, bvec, scale, shift);
            h_list.push(conv.h);
            xhat_list.push(conv.xhat);
            rstd_list.push(conv.rstd);
            e_list.push(conv.e_next);
        }

        // ---- segment-sum readout per conv level + linear head
        let w_out = &params.values[self.p_w_out()];
        let b_out = &params.values[self.p_w_out() + 1];
        let mut feat = vec![0f32; nb * readout];
        let mut z = vec![0f32; nb];
        for g in 0..nb {
            for (k, e) in e_list.iter().enumerate() {
                let f_off = g * readout + k * NODE_DIM;
                for node in batch.graph_nodes(g) {
                    let row = &e[node * NODE_DIM..(node + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        feat[f_off + j] += row[j];
                    }
                }
            }
            let mut acc = b_out[0] as f64;
            for r in 0..readout {
                acc += feat[g * readout + r] as f64 * w_out[r] as f64;
            }
            z[g] = acc as f32;
        }

        Forward { e: e_list, h: h_list, xhat: xhat_list, rstd: rstd_list, feat, z }
    }

    /// Analytic gradients of the §III-C loss w.r.t. every parameter
    /// (weight decay is applied later, in the Adagrad step — matching
    /// `model.train_step`). Sequential over packed nodes in graph order,
    /// which keeps the accumulation order of the pre-sparse engine.
    fn backward(
        &self,
        params: &Params,
        batch: &PackedBatch,
        fwd: &Forward,
        dz: &[f64],
    ) -> Vec<Vec<f64>> {
        let kk = self.n_conv();
        let readout = self.readout();
        let iw = self.p_w_out();
        let w_out = &params.values[iw];
        let nn = batch.total_nodes();
        let nb = batch.n_graphs();
        let mut grads: Vec<Vec<f64>> =
            params.values.iter().map(|v| vec![0f64; v.len()]).collect();

        // ---- head: z = feat · w_out + b_out
        for g in 0..nb {
            if dz[g] == 0.0 {
                continue;
            }
            grads[iw + 1][0] += dz[g];
            for r in 0..readout {
                grads[iw][r] += fwd.feat[g * readout + r] as f64 * dz[g];
            }
        }

        // dL/de for the deepest activations: the level-kk segment-sum
        // readout broadcasts dz · w_out[kk·F + j] to every node of the
        // graph.
        let mut de = vec![0f64; nn * NODE_DIM];
        for g in 0..nb {
            if dz[g] == 0.0 {
                continue;
            }
            for node in batch.graph_nodes(g) {
                let o = node * NODE_DIM;
                for j in 0..NODE_DIM {
                    de[o + j] = dz[g] * w_out[kk * NODE_DIM + j] as f64;
                }
            }
        }

        // ---- conv layers, deepest first
        for k in (0..kk).rev() {
            let w = &params.values[4 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let h = &fwd.h[k];
            let xh = &fwd.xhat[k];
            let rstd = &fwd.rstd[k];
            let e_prev = &fwd.e[k];

            // ReLU + channel-norm backward: de -> dc (per node)
            let mut dc = vec![0f64; nn * NODE_DIM];
            for node in 0..nn {
                let o = node * NODE_DIM;
                let mut dxh = [0f64; NODE_DIM];
                let mut sum1 = 0f64;
                let mut sum2 = 0f64;
                for j in 0..NODE_DIM {
                    let dh = if h[o + j] > 0.0 { de[o + j] } else { 0.0 };
                    grads[6 + 4 * k][j] += dh * xh[o + j] as f64;
                    grads[7 + 4 * k][j] += dh;
                    let dx = dh * scale[j] as f64;
                    dxh[j] = dx;
                    sum1 += dx;
                    sum2 += dx * xh[o + j] as f64;
                }
                let rs = rstd[node] as f64;
                for j in 0..NODE_DIM {
                    let v =
                        rs * (dxh[j] - (sum1 + xh[o + j] as f64 * sum2) / NODE_DIM as f64);
                    dc[o + j] = v;
                    grads[5 + 4 * k][j] += v;
                }
            }

            // dt = A'ᵀ · dc — O(E) gather over the transpose CSR (built
            // lazily on the batch's first train step; ascending source
            // rows keep the dense accumulation order)
            let adj_t = batch.adj_t();
            let mut dt = vec![0f64; nn * NODE_DIM];
            for node in 0..nn {
                let (rows, vals) = adj_t.row(node);
                let o = node * NODE_DIM;
                for (&r, &a) in rows.iter().zip(vals) {
                    let af = a as f64;
                    let src = &dc[r as usize * NODE_DIM..(r as usize + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        dt[o + j] += af * src[j];
                    }
                }
            }

            // de_prev = dt · Wᵀ and dW += e_prevᵀ · dt
            let mut de_new = vec![0f64; nn * NODE_DIM];
            for node in 0..nn {
                let o = node * NODE_DIM;
                let dtrow = &dt[o..o + NODE_DIM];
                let erow = &e_prev[o..o + NODE_DIM];
                for i in 0..NODE_DIM {
                    let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                    let mut acc = 0f64;
                    for j in 0..NODE_DIM {
                        acc += dtrow[j] * wrow[j] as f64;
                    }
                    de_new[o + i] = acc;
                    let ev = erow[i] as f64;
                    if ev != 0.0 {
                        let gw = &mut grads[4 + 4 * k][i * NODE_DIM..(i + 1) * NODE_DIM];
                        for j in 0..NODE_DIM {
                            gw[j] += ev * dtrow[j];
                        }
                    }
                }
            }

            // segment-sum readout gradient for level k
            for g in 0..nb {
                if dz[g] == 0.0 {
                    continue;
                }
                for node in batch.graph_nodes(g) {
                    let o = node * NODE_DIM;
                    for j in 0..NODE_DIM {
                        de_new[o + j] += dz[g] * w_out[k * NODE_DIM + j] as f64;
                    }
                }
            }
            de = de_new;
        }

        // ---- embedding backward
        let e0 = &fwd.e[0];
        for node in 0..nn {
            let o = node * NODE_DIM;
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            for j in 0..EMB_INV {
                if e0[o + j] <= 0.0 {
                    continue;
                }
                let g = de[o + j];
                if g == 0.0 {
                    continue;
                }
                grads[1][j] += g;
                for (i, &x) in inv.iter().enumerate() {
                    grads[0][i * EMB_INV + j] += x as f64 * g;
                }
            }
            for j in 0..EMB_DEP {
                if e0[o + EMB_INV + j] <= 0.0 {
                    continue;
                }
                let g = de[o + EMB_INV + j];
                if g == 0.0 {
                    continue;
                }
                grads[3][j] += g;
                for (i, &x) in dep.iter().enumerate() {
                    grads[2][i * EMB_DEP + j] += x as f64 * g;
                }
            }
        }

        grads
    }
}

/// Validate a flat parameter list against a manifest (shared with the
/// dense reference engine).
pub(crate) fn check_params_against(manifest: &Manifest, params: &Params) -> Result<()> {
    ensure!(
        params.values.len() == manifest.params.len(),
        "backend expects {} param tensors, got {}",
        manifest.params.len(),
        params.values.len()
    );
    for (v, spec) in params.values.iter().zip(&manifest.params) {
        ensure!(
            v.len() == spec.numel(),
            "param '{}' has {} elements, manifest expects {}",
            spec.name,
            v.len(),
            spec.numel()
        );
    }
    Ok(())
}

/// One conv layer's fused aggregate+norm+ReLU output rows.
struct ConvRows {
    h: Vec<f32>,
    xhat: Vec<f32>,
    e_next: Vec<f32>,
    rstd: Vec<f32>,
}

fn conv_block(
    batch: &PackedBatch,
    t: &[f32],
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
    range: Range<usize>,
) -> ConvRows {
    let n = range.len();
    let mut out = ConvRows {
        h: vec![0f32; n * NODE_DIM],
        xhat: vec![0f32; n * NODE_DIM],
        e_next: vec![0f32; n * NODE_DIM],
        rstd: vec![0f32; n],
    };
    for (i, node) in range.enumerate() {
        let (cols, vals) = batch.adj.row(node);
        let mut c = [0f64; NODE_DIM];
        for (&cix, &a) in cols.iter().zip(vals) {
            let af = a as f64;
            let t_row = &t[cix as usize * NODE_DIM..(cix as usize + 1) * NODE_DIM];
            for j in 0..NODE_DIM {
                c[j] += af * t_row[j] as f64;
            }
        }
        for j in 0..NODE_DIM {
            c[j] += bvec[j] as f64;
        }
        let mean = c.iter().sum::<f64>() / NODE_DIM as f64;
        let var = c.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / NODE_DIM as f64;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        out.rstd[i] = rs as f32;
        let o = i * NODE_DIM;
        for j in 0..NODE_DIM {
            let xh = (c[j] - mean) * rs;
            out.xhat[o + j] = xh as f32;
            let hv = xh * scale[j] as f64 + shift[j] as f64;
            out.h[o + j] = hv as f32;
            out.e_next[o + j] = hv.max(0.0) as f32;
        }
    }
    out
}

fn par_conv(
    batch: &PackedBatch,
    t: &[f32],
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
) -> ConvRows {
    let nn = batch.total_nodes();
    let ranges = chunk_ranges(nn, PAR_MIN_ROWS);
    if ranges.len() <= 1 {
        return conv_block(batch, t, bvec, scale, shift, 0..nn);
    }
    let parts = parallel_map(&ranges, |r| conv_block(batch, t, bvec, scale, shift, r.clone()));
    let mut out = ConvRows {
        h: Vec::with_capacity(nn * NODE_DIM),
        xhat: Vec::with_capacity(nn * NODE_DIM),
        e_next: Vec::with_capacity(nn * NODE_DIM),
        rstd: Vec::with_capacity(nn),
    };
    for p in parts {
        out.h.extend_from_slice(&p.h);
        out.xhat.extend_from_slice(&p.xhat);
        out.e_next.extend_from_slice(&p.e_next);
        out.rstd.extend_from_slice(&p.rstd);
    }
    out
}

/// Forward intermediates kept for the backward pass.
struct Forward {
    /// Node activations per level: `e[k]` for k = 0..=n_conv, each flat
    /// `[total_nodes, NODE_DIM]`.
    e: Vec<Vec<f32>>,
    /// Post-norm pre-ReLU activations per conv layer.
    h: Vec<Vec<f32>>,
    /// Normalized (pre scale/shift) activations per conv layer.
    xhat: Vec<Vec<f32>>,
    /// Reciprocal std per node per conv layer, flat `[total_nodes]`.
    rstd: Vec<Vec<f32>>,
    /// Segment-summed readout features, flat `[n_graphs, READOUT]`.
    feat: Vec<f32>,
    /// Predicted log-runtime per graph.
    z: Vec<f32>,
}

/// The §III-C ξ loss term and its derivative at `d = z − log ȳ`:
/// `ξ = |expm1(clamp(d, ±3))| + |d − clamp(d, ±3)|·e³`.
pub(crate) fn xi_and_grad(d: f64) -> (f64, f64) {
    let e3 = LOSS_CLIP.exp();
    let dclamped = d.clamp(-LOSS_CLIP, LOSS_CLIP);
    let xi = dclamped.exp_m1().abs() + (d - dclamped).abs() * e3;
    let g = if d > LOSS_CLIP {
        e3
    } else if d < -LOSS_CLIP {
        -e3
    } else if d > 0.0 {
        d.exp()
    } else if d < 0.0 {
        -d.exp()
    } else {
        0.0
    };
    (xi, g)
}

/// §III-C loss and its gradient w.r.t. z: the `weight`-weighted mean of ξ
/// over the batch's graphs.
fn loss_and_dz(z: &[f32], batch: &PackedBatch) -> (f64, Vec<f64>) {
    let nb = batch.n_graphs();
    let mut wsum = 0f64;
    for g in 0..nb {
        wsum += batch.weight[g] as f64;
    }
    let denom = wsum.max(1e-6);
    let mut loss = 0f64;
    let mut dz = vec![0f64; nb];
    for g in 0..nb {
        let w = batch.weight[g] as f64;
        if w == 0.0 {
            continue;
        }
        let d = z[g] as f64 - batch.log_y[g] as f64;
        let (xi, gr) = xi_and_grad(d);
        loss += w * xi;
        dz[g] = w * gr / denom;
    }
    (loss / denom, dz)
}

/// Adagrad with weight decay: `g += wd·p; a += g²; p −= lr·g/(√a + ε)`.
pub(crate) fn apply_adagrad(
    params: &mut Params,
    accum: &mut Params,
    grads: &[Vec<f64>],
    lr: f64,
    wd: f64,
) {
    for (t, g) in grads.iter().enumerate() {
        let pv = &mut params.values[t];
        let av = &mut accum.values[t];
        for i in 0..g.len() {
            let gi = g[i] + wd * pv[i] as f64;
            let a = av[i] as f64 + gi * gi;
            av[i] = a as f32;
            pv[i] = (pv[i] as f64 - lr * gi / (a.sqrt() + ADAGRAD_EPS)) as f32;
        }
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn infer(&self, params: &Params, batch: &PackedBatch) -> Result<Vec<f32>> {
        self.check_params(params)?;
        let fwd = self.forward(params, batch);
        Ok(fwd.z)
    }

    fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &PackedBatch,
        lr: f32,
    ) -> Result<f32> {
        self.check_params(params)?;
        self.check_params(accum)?;
        let fwd = self.forward(params, batch);
        let (loss, dz) = loss_and_dz(&fwd.z, batch);
        let grads = self.backward(params, batch, &fwd, &dz);
        apply_adagrad(params, accum, &grads, lr as f64, self.manifest.weight_decay);
        Ok(loss as f32)
    }

    /// Parallel over batch chunks: each worker packs its own batch and
    /// runs the forward pass independently (the backend is stateless).
    /// Every chunk goes through the same [`predict_chunk`] helper as the
    /// sequential trait default.
    fn predict_runtimes(
        &self,
        params: &Params,
        samples: &[&GraphSample],
        stats: &FeatureStats,
    ) -> Result<Vec<f64>> {
        self.check_params(params)?;
        let chunks: Vec<&[&GraphSample]> = samples.chunks(BATCH).collect();
        let outs = crate::util::threadpool::parallel_map(&chunks, |chunk| {
            predict_chunk(self, params, chunk, stats)
        });
        let mut out = Vec::with_capacity(samples.len());
        for r in outs {
            out.extend(r?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::dense_ref::DenseRefBackend;
    use crate::testfix::{
        grad_fixture_batch, identity_stats, parity_batch, parity_params, synth_packed_batch,
        synth_sample, REF_GRADS, REF_LOSS, REF_Z,
    };
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    #[test]
    fn forward_matches_jax_reference_through_packed_conversion() {
        let be = NativeBackend::new();
        let dense = parity_batch();
        let batch = PackedBatch::from_dense(&dense).unwrap();
        let params = parity_params(be.manifest());
        let z = be.infer(&params, &batch).unwrap();
        assert_eq!(z.len(), BATCH);
        for (i, (&got, &want)) in z.iter().zip(REF_Z.iter()).enumerate() {
            assert!(
                (got - want).abs() <= 1e-5,
                "z[{i}] = {got}, reference {want} (|diff| = {})",
                (got - want).abs()
            );
        }
    }

    #[test]
    fn backward_matches_jax_grads_through_packed_conversion() {
        let be = NativeBackend::new();
        let batch = PackedBatch::from_dense(&grad_fixture_batch()).unwrap();
        let params = parity_params(be.manifest());
        let fwd = be.forward(&params, &batch);
        let (loss, dz) = loss_and_dz(&fwd.z, &batch);
        assert!(
            (loss - REF_LOSS).abs() < 5e-3,
            "loss {loss} vs jax reference {REF_LOSS}"
        );
        let grads = be.backward(&params, &batch, &fwd, &dz);
        for &(t, i, want) in REF_GRADS.iter() {
            let got = grads[t][i];
            let tol = 1e-3 + 2e-3 * want.abs();
            assert!(
                (got - want).abs() <= tol,
                "grad[{t}][{i}] = {got}, jax reference {want} (tol {tol})"
            );
        }
    }

    /// A random sample with arbitrary node count (beyond the old 48-node
    /// cap), arbitrary edges and dense-ish random features.
    fn random_sample(rng: &mut Rng, max_nodes: usize, pid: u32) -> GraphSample {
        let n = 1 + rng.gen_range(max_nodes);
        let mut edges = Vec::new();
        for _ in 0..rng.gen_range(3 * n + 1) {
            edges.push((rng.gen_range(n) as u16, rng.gen_range(n) as u16));
        }
        let mut inv = vec![[0f32; INV_DIM]; n];
        let mut dep = vec![[0f32; DEP_DIM]; n];
        for s in 0..n {
            for v in inv[s].iter_mut() {
                *v = rng.uniform(-2.0, 2.0) as f32;
            }
            for v in dep[s].iter_mut() {
                *v = rng.uniform(-2.0, 2.0) as f32;
            }
        }
        let mut runs = [0f32; crate::constants::BENCH_RUNS];
        let base = rng.uniform(1e-4, 1e-2);
        for r in runs.iter_mut() {
            *r = (base * rng.uniform(0.9, 1.1)) as f32;
        }
        GraphSample {
            pipeline_id: pid,
            schedule_id: 0,
            n_stages: n as u16,
            edges,
            inv,
            dep,
            runs,
        }
    }

    /// Property parity: for random variable-size graphs (including well
    /// past the old 48-stage cap), the sparse forward and backward match
    /// the dense reference engine within 1e-5.
    #[test]
    fn prop_sparse_matches_dense_reference() {
        let sparse = NativeBackend::new();
        let dense = DenseRefBackend::new();
        propcheck::check_rng("sparse vs dense-ref parity", 0x5EED, 10, |rng| {
            let n_graphs = 1 + rng.gen_range(5);
            let samples: Vec<GraphSample> = (0..n_graphs)
                .map(|g| random_sample(rng, 80, g as u32))
                .collect();
            let refs: Vec<&GraphSample> = samples.iter().collect();
            let min_rt = refs
                .iter()
                .map(|s| s.mean_runtime())
                .fold(f64::INFINITY, f64::min);
            let best = vec![min_rt; refs.len()];
            let batch = PackedBatch::build(&refs, &identity_stats(), &best)
                .map_err(|e| e.to_string())?;

            let params = sparse.init_params(rng.next_u64());
            let zs = sparse.infer(&params, &batch).map_err(|e| e.to_string())?;
            let zd = dense.infer(&params, &batch).map_err(|e| e.to_string())?;
            if zs.len() != zd.len() {
                return Err(format!("length mismatch {} vs {}", zs.len(), zd.len()));
            }
            for (i, (a, b)) in zs.iter().zip(&zd).enumerate() {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("forward diverges at graph {i}: {a} vs {b}"));
                }
            }

            let mut ps = params.clone();
            let mut as_ = ps.zeros_like();
            let mut pd = params.clone();
            let mut ad = pd.zeros_like();
            let ls = sparse
                .train_step_lr(&mut ps, &mut as_, &batch, 0.01)
                .map_err(|e| e.to_string())?;
            let ld = dense
                .train_step_lr(&mut pd, &mut ad, &batch, 0.01)
                .map_err(|e| e.to_string())?;
            if (ls - ld).abs() > 1e-5 * ld.abs().max(1.0) {
                return Err(format!("loss diverges: sparse {ls} vs dense {ld}"));
            }
            for (t, (vs, vd)) in ps.values.iter().zip(&pd.values).enumerate() {
                for (i, (a, b)) in vs.iter().zip(vd).enumerate() {
                    if (a - b).abs() > 1e-5 {
                        return Err(format!(
                            "post-step param[{t}][{i}] diverges: {a} vs {b}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn adagrad_training_reduces_loss_over_50_steps() {
        let be = NativeBackend::new();
        let batch = synth_packed_batch();
        // deterministic patterned init (the JAX simulation of this exact
        // fixture converges 6.06 -> 0.33 in 50 steps at lr 0.01)
        let mut params = parity_params(be.manifest());
        // output-bias init at the batch mean log-runtime (as train() does)
        let nb = batch.n_graphs();
        let mean_log_y: f32 = batch.log_y.iter().sum::<f32>() / nb as f32;
        params.values.last_mut().unwrap()[0] = mean_log_y;
        let mut accum = params.zeros_like();
        let mut losses = Vec::with_capacity(50);
        for _ in 0..50 {
            let l = be.train_step_lr(&mut params, &mut accum, &batch, 0.01).unwrap();
            assert!(l.is_finite(), "loss must stay finite");
            losses.push(l);
        }
        assert!(
            losses[49] < losses[0],
            "50 Adagrad steps must reduce the loss: {} -> {}",
            losses[0],
            losses[49]
        );
        // and decisively so on a memorizable single batch
        assert!(
            losses[49] < losses[0] * 0.5,
            "expected >2x loss reduction: {} -> {}",
            losses[0],
            losses[49]
        );
    }

    #[test]
    fn infer_is_deterministic_across_repeats() {
        let be = NativeBackend::new();
        let samples: Vec<GraphSample> =
            (0..5).map(|i| synth_sample(0, i, 1e-3 * (1.0 + i as f32))).collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let batch = PackedBatch::for_inference(&refs, &identity_stats()).unwrap();
        let params = be.init_params(3);
        let z1 = be.infer(&params, &batch).unwrap();
        let z2 = be.infer(&params, &batch).unwrap();
        assert_eq!(z1.len(), 5);
        assert_eq!(z1, z2);
        assert!(z1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn graphs_beyond_the_old_cap_run() {
        // 200 stages — impossible to even represent in the padded layout
        let be = NativeBackend::new();
        let big = GraphSample {
            pipeline_id: 7,
            schedule_id: 0,
            n_stages: 200,
            edges: (0..199).map(|i| (i as u16, (i + 1) as u16)).collect(),
            inv: vec![[0.1; INV_DIM]; 200],
            dep: vec![[0.2; DEP_DIM]; 200],
            runs: [1e-3; crate::constants::BENCH_RUNS],
        };
        let refs = vec![&big];
        let batch = PackedBatch::for_inference(&refs, &identity_stats()).unwrap();
        assert_eq!(batch.total_nodes(), 200);
        let params = be.init_params(2);
        let z = be.infer(&params, &batch).unwrap();
        assert_eq!(z.len(), 1);
        assert!(z[0].is_finite());
    }

    #[test]
    fn predict_runtimes_parallel_matches_sequential() {
        let be = NativeBackend::new();
        let samples: Vec<GraphSample> = (0..70)
            .map(|i| synth_sample((i / 10) as u32, (i % 10) as u32, 1e-3 * (1.0 + i as f32)))
            .collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let stats = identity_stats();
        let params = be.init_params(11);
        let parallel = be.predict_runtimes(&params, &refs, &stats).unwrap();
        assert_eq!(parallel.len(), 70);

        // sequential reference: one packed batch per chunk
        let mut sequential = Vec::new();
        for chunk in refs.chunks(BATCH) {
            let batch = PackedBatch::for_inference(chunk, &stats).unwrap();
            let z = be.infer(&params, &batch).unwrap();
            sequential.extend(z.iter().map(|&v| (v as f64).exp()));
        }
        assert_eq!(parallel, sequential);
        assert!(parallel.iter().all(|p| p.is_finite() && *p > 0.0));
    }

    #[test]
    fn ablation_depths_run_natively() {
        for layers in [0usize, 1, 4] {
            let be = NativeBackend::with_layers(layers);
            assert_eq!(be.manifest().params.len(), 6 + 4 * layers);
            let batch = synth_packed_batch();
            let params = be.init_params(5);
            let z = be.infer(&params, &batch).unwrap();
            assert_eq!(z.len(), batch.n_graphs());
            assert!(z.iter().all(|v| v.is_finite()));
            let mut p = params.clone();
            let mut a = p.zeros_like();
            let l = be.train_step_lr(&mut p, &mut a, &batch, 0.01).unwrap();
            assert!(l.is_finite());
        }
    }

    #[test]
    fn check_params_rejects_wrong_layout() {
        let be = NativeBackend::new();
        let wrong = be.init_params(1);
        let be0 = NativeBackend::with_layers(0);
        let batch = synth_packed_batch();
        assert!(be0.infer(&wrong, &batch).is_err());
    }
}
